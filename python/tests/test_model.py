# pytest: L2 model — TT layer vs dense reconstruction, MLP shapes, grads.
import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


KEY = jax.random.PRNGKey(0)


def test_core_shapes_match_t3f_layout():
    cs = model.core_shapes((5, 5, 3, 2, 2), (2, 2, 2, 7, 14),
                           (1, 10, 10, 10, 10, 1))
    # paper Sec. 2: G^0..G^4 shapes (r_{t-1}, n_t, m_t, r_t)
    assert cs == [(1, 2, 5, 10), (10, 2, 5, 10), (10, 2, 3, 10),
                  (10, 7, 2, 10), (10, 14, 2, 1)]


def test_init_variance_roughly_glorot():
    cores = model.init_tt_cores(KEY, (20, 15), (28, 28), (1, 8, 1))
    w = ref.tt_reconstruct(cores)
    target = 2.0 / (300 + 784)
    var = float(jnp.var(w))
    assert 0.1 * target < var < 10 * target


def test_tt_linear_apply_impls_agree():
    cores = model.init_tt_cores(KEY, (20, 15), (28, 28), (1, 8, 1))
    bias = jnp.linspace(-1, 1, 300, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 784))
    a = model.tt_linear_apply(cores, bias, x, impl="pallas")
    b = model.tt_linear_apply(cores, bias, x, impl="jnp")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_tt_linear_equals_dense_on_reconstruction():
    cores = model.init_tt_cores(KEY, (10, 10), (20, 15), (1, 8, 1))
    w = ref.tt_reconstruct(cores)
    bias = jnp.zeros((100,))
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 300))
    tt = model.tt_linear_apply(cores, bias, x, impl="pallas")
    dn = model.dense_apply(w, bias, x)
    np.testing.assert_allclose(np.asarray(tt), np.asarray(dn),
                               rtol=1e-4, atol=1e-4)


def test_mlp_variants_shapes():
    xt = jax.random.normal(jax.random.PRNGKey(3), (7, 784))
    tt = model.mlp_tt_apply(model.init_mlp_tt(KEY), xt)
    dn = model.mlp_dense_apply(model.init_mlp_dense(KEY), xt)
    assert tt.shape == dn.shape == (7, 10)


def test_flatten_unflatten_roundtrip():
    params = model.init_mlp_tt(KEY)
    flat = model.flatten_tt_mlp_params(params)
    back = model.unflatten_tt_mlp_params(flat)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 784))
    a = model.mlp_tt_apply(params, x, impl="jnp")
    b = model.mlp_tt_apply(back, x, impl="jnp")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_flat_entry_points_return_tuples():
    params = model.init_mlp_tt(KEY)
    flat = model.flatten_tt_mlp_params(params)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 784))
    (out,) = model.mlp_tt_forward_flat(x, *flat)
    assert out.shape == (2, 10)


def test_grad_descends_loss():
    params = model.init_mlp_tt(KEY)
    x = jax.random.normal(jax.random.PRNGKey(6), (32, 784))
    labels = jnp.arange(32) % 10
    loss0 = model.mlp_tt_loss(params, x, labels)
    grads = model.mlp_tt_grad(params, x, labels)
    stepped = jax.tree_util.tree_map(lambda p, g: p - 0.5 * g, params, grads)
    loss1 = model.mlp_tt_loss(stepped, x, labels)
    assert float(loss1) < float(loss0)


def test_tt_compression_counts():
    # the LeNet300 l1 factorization must actually compress (paper Eq. 4)
    spec = model.LENET300_TT_SPEC["l1"]
    p = ref.tt_params(spec["m_shape"], spec["n_shape"], spec["ranks"])
    dense_params = 300 * 784 + 300
    assert p < dense_params / 25  # > 25x parameter compression (8140 params)
    f = ref.tt_flops(spec["m_shape"], spec["n_shape"], spec["ranks"])
    dense_flops = 2 * 300 * 784 + 300
    assert f < dense_flops  # initial-layer constraint satisfied


@settings(max_examples=10, deadline=None)
@given(batch=st.integers(1, 9), seed=st.integers(0, 1000))
def test_mlp_tt_batch_invariance(batch, seed):
    # per-sample results must not depend on which batch they ride in
    params = model.init_mlp_tt(KEY)
    x = jax.random.normal(jax.random.PRNGKey(seed), (batch, 784))
    full = model.mlp_tt_apply(params, x, impl="jnp")
    one = model.mlp_tt_apply(params, x[:1], impl="jnp")
    np.testing.assert_allclose(np.asarray(full[:1]), np.asarray(one),
                               rtol=1e-4, atol=1e-5)
