# pytest: the L1 structural performance estimators behave sanely.
import math

from compile.kernels import tt_einsum as tk
from compile import perf_report


def test_vmem_formula_counts_all_tiles():
    # (G tile + In tile + Out tile) * 4 bytes
    v = tk.vmem_bytes_per_cell(r=8, n=4, m=64, k=8, tm=16, tb=32)
    expected = 4 * (8 * 4 * 16 * 8 + 32 * 4 * 8 + 16 * 32 * 8)
    assert v == expected


def test_mxu_utilization_bounds_and_monotonicity():
    # always in (0, 1]; bigger tiles can't hurt utilization
    small = tk.mxu_utilization_estimate(8, 4, 64, 8, tm=8, tb=8)
    big = tk.mxu_utilization_estimate(8, 4, 64, 8, tm=64, tb=128)
    assert 0.0 < small <= 1.0
    assert 0.0 < big <= 1.0
    assert big >= small


def test_full_mxu_tiles_are_perfect():
    # every dot dimension a multiple of 128 -> utilization exactly 1
    u = tk.mxu_utilization_estimate(r=8, n=16, m=1024, k=8, tm=128, tb=128)
    # contraction n*k = 128, a = tb = 128, b = tm*r = 1024
    assert math.isclose(u, 1.0)


def test_block_choice_report_structure():
    rows = tk.block_choice_report(8, 4, 64, 8, 3582)
    assert len(rows) >= 3
    for x in rows:
        assert x["tm"] <= 64 and x["tb"] <= 3582
        assert x["vmem_bytes"] > 0
        assert x["grid"] >= 1


def test_pick_block_prefers_fitting_shapes():
    best, rows = perf_report.pick_block(8, 4, 64, 8, 3582)
    assert best in rows
    assert best["vmem_bytes"] <= perf_report.VMEM_BUDGET
    # best has max utilization among fitting candidates
    fitting = [x for x in rows if x["vmem_bytes"] <= perf_report.VMEM_BUDGET]
    assert best["mxu_util"] == max(x["mxu_util"] for x in fitting)
