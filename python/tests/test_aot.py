# pytest: AOT lowering — HLO text artifacts well-formed and manifest correct.
import json
import os

import pytest
import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x * 2.0 + 1.0,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_tt_fc_entry_lowers_with_pallas_kernel():
    cs = model.core_shapes((20, 15), (28, 28), (1, 8, 1))
    args = [jax.ShapeDtypeStruct((2, 784), jnp.float32)]
    args += [jax.ShapeDtypeStruct(s, jnp.float32) for s in cs]
    args += [jax.ShapeDtypeStruct((300,), jnp.float32)]
    text = aot.lower_entry(model.tt_fc_forward_flat, args)
    assert "HloModule" in text
    # interpret=True means no Mosaic custom-calls may appear
    assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


def test_build_artifacts_manifest(tmp_path):
    manifest = aot.build_artifacts(str(tmp_path))
    names = {a["name"] for a in manifest["artifacts"]}
    for required in ("mlp_tt_b1", "mlp_tt_b16", "mlp_dense_b16",
                     "dense_fc_784x300_b16", "tt_fc_784x300_d2_r8_b16",
                     "tt_einsum_middle_cb5"):
        assert required in names
    for a in manifest["artifacts"]:
        path = tmp_path / a["file"]
        assert path.exists()
        head = path.read_text()[:200]
        assert "HloModule" in head
        assert all("shape" in s and "dtype" in s for s in a["args"])
    # manifest must round-trip through json
    loaded = json.loads((tmp_path / "manifest.json").read_text())
    assert loaded["return_tuple"] is True


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="make artifacts has not run")
def test_checked_in_artifacts_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    manifest = json.load(open(os.path.join(root, "manifest.json")))
    for a in manifest["artifacts"]:
        assert os.path.exists(os.path.join(root, a["file"])), a["file"]
