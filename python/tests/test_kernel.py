# pytest: Pallas kernel vs pure-jnp oracle — the CORE correctness signal.
#
# hypothesis sweeps the kernel's shape/dtype space (including the degenerate
# first/final-einsum rank extents and non-dividing tile sizes) and asserts
# allclose against ref.py.
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import tt_einsum as tk


def rand(shape, dtype=np.float32, seed=0):
    rng = np.random.default_rng(seed + sum(shape))
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def assert_kernel_matches_ref(r, n, m, k, b, tm=None, tb=None,
                              dtype=np.float32, rtol=1e-5, atol=1e-5):
    g = rand((r, n, m, k), dtype, seed=1)
    x = rand((b, n, k), dtype, seed=2)
    got = tk.tt_einsum_pallas(g, x, tm=tm, tb=tb)
    want = ref.einsum_ref(g, x)
    assert got.shape == (m, b, r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# Deterministic cases: the paper's Table 3 configurations (rank 8).
# ---------------------------------------------------------------------------

# (mt, bt, nt) for first einsum (k=1), middle (r=k=8), final (r=1), Table 3.
CB_FIRST = [(512, 32, 128), (64, 64, 64), (128, 1024, 4), (256, 64, 784),
            (32, 64, 392), (512, 896, 28), (100, 12, 64), (16, 4, 150)]
CB_MIDDLE = [(48, 224, 2), (64, 3582, 4), (96, 128, 14), (64, 64, 32),
             (256, 128, 4), (32, 9, 7), (4, 16383, 28), (64, 1020, 28)]
CB_FINAL = [(32, 126, 256), (64, 64, 128), (32, 126, 4), (256, 16, 7),
            (8, 510, 896), (32, 250, 4), (124, 9, 16), (48, 21, 4)]


@pytest.mark.parametrize("mt,bt,nt", CB_FIRST[:4])
def test_first_einsum_table3(mt, bt, nt):
    # first: right rank k = r_d = 1, left rank r = 8
    assert_kernel_matches_ref(r=8, n=nt, m=mt, k=1, b=bt, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mt,bt,nt", CB_MIDDLE[:4])
def test_middle_einsum_table3(mt, bt, nt):
    assert_kernel_matches_ref(r=8, n=nt, m=mt, k=8, b=bt, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mt,bt,nt", CB_FINAL[:4])
def test_final_einsum_table3(mt, bt, nt):
    assert_kernel_matches_ref(r=1, n=nt, m=mt, k=8, b=bt, rtol=1e-4, atol=1e-4)


def test_variant_wrappers_enforce_rank_extents():
    g_mid = rand((8, 4, 6, 8))
    x = rand((5, 4, 8))
    with pytest.raises(ValueError):
        tk.first_einsum_pallas(g_mid, x)
    with pytest.raises(ValueError):
        tk.final_einsum_pallas(g_mid, x)
    out = tk.middle_einsum_pallas(g_mid, x)
    assert out.shape == (6, 5, 8)


def test_incompatible_input_slab_raises():
    g = rand((8, 4, 6, 8))
    x_bad = rand((5, 3, 8))
    with pytest.raises(ValueError):
        tk.tt_einsum_pallas(g, x_bad)


def test_oracles_agree_with_each_other():
    g = rand((8, 7, 32, 8))
    x = rand((9, 7, 8))
    a = ref.einsum_ref(g, x)
    b = ref.einsum_loop_ref(g, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes, rank extents, tile sizes, dtypes.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(
    r=st.sampled_from([1, 2, 8, 16]),
    k=st.sampled_from([1, 2, 8, 16]),
    n=st.integers(1, 12),
    m=st.integers(1, 40),
    b=st.integers(1, 40),
)
def test_kernel_shape_sweep(r, k, n, m, b):
    assert_kernel_matches_ref(r, n, m, k, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(3, 50),
    b=st.integers(3, 50),
    tm=st.integers(1, 17),
    tb=st.integers(1, 17),
)
def test_kernel_nondividing_tiles(m, b, tm, tb):
    # tile sizes that do not divide (m, b) exercise the pad-and-slice path
    assert_kernel_matches_ref(r=8, n=5, m=m, k=8, b=b, tm=tm, tb=tb,
                              rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(2, 24), b=st.integers(2, 24))
def test_kernel_bfloat16(m, b):
    g = rand((8, 4, m, 8)).astype(jnp.bfloat16)
    x = rand((b, 4, 8)).astype(jnp.bfloat16)
    got = tk.tt_einsum_pallas(g, x)
    want = ref.einsum_ref(g.astype(jnp.float32), x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want),
        rtol=5e-2, atol=5e-2)


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(1, 4),
    data=st.data(),
)
def test_tt_forward_pallas_matches_ref_chain(d, data):
    ms = [data.draw(st.integers(2, 5)) for _ in range(d)]
    ns = [data.draw(st.integers(2, 5)) for _ in range(d)]
    ranks = [1] + [data.draw(st.sampled_from([1, 2, 4])) for _ in range(d - 1)] + [1]
    batch = data.draw(st.integers(1, 6))
    rng = np.random.default_rng(42)
    cores = [jnp.asarray(rng.standard_normal(
        (ranks[t], ns[t], ms[t], ranks[t + 1])).astype(np.float32) * 0.5)
        for t in range(d)]
    n_total = int(np.prod(ns))
    x = jnp.asarray(rng.standard_normal((batch, n_total)).astype(np.float32))
    bias = jnp.asarray(rng.standard_normal(int(np.prod(ms))).astype(np.float32))
    got = tk.tt_forward_pallas(x, cores, bias)
    want = ref.tt_forward_ref(x, cores, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_tt_forward_equals_dense_matmul():
    # The whole point of TTD: the chain computes x @ W.T for the
    # reconstructed W (paper Eq. 2/3 with row-major multi-indices).
    rng = np.random.default_rng(7)
    shapes = [(1, 2, 5, 4), (4, 2, 5, 4), (4, 2, 3, 4), (4, 7, 2, 4),
              (4, 14, 2, 1)]
    cores = [jnp.asarray(rng.standard_normal(s).astype(np.float32) * 0.3)
             for s in shapes]
    w = ref.tt_reconstruct(cores)
    assert w.shape == (300, 784)
    x = jnp.asarray(rng.standard_normal((3, 784)).astype(np.float32))
    got = tk.tt_forward_pallas(x, cores)
    want = x @ w.T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# Cost-equation oracles (paper Eq. 4 / Eq. 11) — cross-language fixtures.
# The Rust ttd::cost module asserts the same values; keep in sync.
# ---------------------------------------------------------------------------

def test_params_eq4_running_example():
    # paper Sec. 2 example at R = 10:
    # cores (1,2,5,10),(10,2,5,10),(10,2,3,10),(10,7,2,10),(10,14,2,1)
    p = ref.tt_params([5, 5, 3, 2, 2], [2, 2, 2, 7, 14],
                      [1, 10, 10, 10, 10, 1])
    expected = 300 + (1 * 2 * 5 * 10 + 10 * 2 * 5 * 10 + 10 * 2 * 3 * 10
                      + 10 * 7 * 2 * 10 + 10 * 14 * 2 * 1)
    assert p == expected == 300 + 100 + 1000 + 600 + 1400 + 280


def test_flops_eq11_is_sum_of_eq13_terms():
    ms, ns, rk = [5, 3, 2], [2, 7, 14], [1, 4, 4, 1]
    total = ref.tt_flops(ms, ns, rk)
    # Eq. 13: FLOPs^(t) = 2 * r_t * r_{t-1} * m_t..m_d * n_1..n_t
    e1 = 2 * 4 * 1 * (5 * 3 * 2) * 2
    e2 = 2 * 4 * 4 * (3 * 2) * (2 * 7)
    e3 = 2 * 1 * 4 * 2 * (2 * 7 * 14)
    assert total == (5 * 3 * 2) + e1 + e2 + e3


def test_flops_match_actual_multiply_count():
    # count scalar multiplies the chain performs and compare with Eq. 11
    ms, ns, rk = [4, 3], [2, 5], [1, 2, 1]
    d = 2
    n_total = int(np.prod(ns))
    mults = 0
    cur_size = n_total  # batch 1
    for t in range(d - 1, -1, -1):
        r_prev, n_t, m_t, r_t = rk[t], ns[t], ms[t], rk[t + 1]
        bt = cur_size // (n_t * r_t)
        # each output element needs n_t*r_t mults and n_t*r_t adds
        mults += 2 * m_t * bt * r_prev * n_t * r_t
        cur_size = m_t * bt * r_prev
    assert mults + int(np.prod(ms)) == ref.tt_flops(ms, ns, rk)
