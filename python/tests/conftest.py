# Allow running pytest from the repo root OR from python/: put python/ on
# sys.path so `import compile` resolves.
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
