#!/usr/bin/env python3
"""Independent `.ttrv` decoder: a Python mirror of the Rust reader
(rust/src/artifact/reader.rs) for debugging bundles and cross-checking the
golden artifact. Validates the container (magic, version, TOC CRC, section
CRCs), decodes the OPS grammar, re-runs the engine-side consistency checks
(`TtFcEngine::from_parts`), and — for batch-1, Canonical-layout bundles —
replays a forward pass with numpy.

Usage: check_ttrv.py <bundle.ttrv> [x_csv]
"""

import json
import struct
import sys
import zlib

import numpy as np

HEADER_LEN, TOC_ENTRY_LEN, MAX_SECTIONS = 16, 24, 64
# v2 added the optional TUNE section (id 4); v3 appended the tuning
# kernel name as a trailing field of the TUNE grammar; v4 added the
# optional QUANT section (id 5: int8-quantized TT cores)
MIN_VERSION, VERSION = 1, 4


class Cur:
    def __init__(self, buf):
        self.buf, self.pos = buf, 0

    def take(self, n):
        assert self.pos + n <= len(self.buf), f"truncated at {self.pos}"
        out = self.buf[self.pos : self.pos + n]
        self.pos += n
        return out

    def u8(self):
        return self.take(1)[0]

    def u32(self):
        return struct.unpack("<I", self.take(4))[0]

    def u64(self):
        return struct.unpack("<Q", self.take(8))[0]

    def f64(self):
        return struct.unpack("<d", self.take(8))[0]

    def f32s(self, n):
        return np.frombuffer(self.take(4 * n), dtype="<f4").copy()


def parse_container(b):
    assert len(b) >= HEADER_LEN, "too short"
    assert b[0:4] == b"TTRV", "bad magic"
    version, count, toc_crc = struct.unpack("<III", b[4:16])
    assert MIN_VERSION <= version <= VERSION, f"version {version}"
    assert 1 <= count <= MAX_SECTIONS, f"count {count}"
    toc_end = HEADER_LEN + count * TOC_ENTRY_LEN
    assert toc_end <= len(b), "truncated TOC"
    toc = b[HEADER_LEN:toc_end]
    assert zlib.crc32(toc) == toc_crc, "TOC crc"
    sections = {}
    ranges = []
    for i in range(count):
        sid, crc, off, ln = struct.unpack(
            "<IIQQ", toc[i * TOC_ENTRY_LEN : (i + 1) * TOC_ENTRY_LEN]
        )
        assert toc_end <= off and off + ln <= len(b), f"section {sid} bounds"
        assert sid not in sections, f"dup section {sid}"
        payload = b[off : off + ln]
        assert zlib.crc32(payload) == crc, f"section {sid} crc"
        sections[sid] = payload
        ranges.append((off, off + ln))
    cursor = toc_end
    for off, end in sorted(ranges):
        assert off == cursor, f"unchecksummed gap/overlap at {cursor}"
        cursor = end
    assert cursor == len(b), "trailing bytes after the last section"
    return sections


def decode_layout(c):
    d = c.u32()
    assert 1 <= d <= 64
    m = [c.u64() for _ in range(d)]
    n = [c.u64() for _ in range(d)]
    r = [c.u64() for _ in range(d + 1)]
    assert r[0] == 1 and r[d] == 1 and all(v >= 1 for v in m + n + r)
    return m, n, r


def decode_bias(c, m_total):
    flag = c.u8()
    if flag == 0:
        return None
    assert flag == 1
    ln = c.u64()
    assert ln == m_total
    return c.f32s(ln)


def decode_plan(c):
    kind = c.u8()
    assert kind in (0, 1, 2)
    m, b, n, r, k = (c.u64() for _ in range(5))
    pack_g, vloop = c.u8(), c.u8()
    assert pack_g in (0, 1) and vloop in (0, 1, 2)
    vl = c.u64()
    rb = [c.u64() for _ in range(4)]
    order, has_btl = c.u8(), c.u8()
    assert order in (0, 1) and has_btl in (0, 1)
    btl = c.u64()
    threads, ls = c.u32(), c.u64()
    return dict(kind=kind, m=m, b=b, n=n, r=r, k=k, pack_g=pack_g, vloop=vloop,
                vl=vl, rb=rb, order=order, btl=btl if has_btl else None,
                threads=threads, ls=ls)


def decode_packed(c):
    glayout = c.u8()
    assert glayout in (0, 1, 2)
    r, n, m, k, r_pad = (c.u64() for _ in range(5))
    if glayout in (0, 2):
        assert r_pad == r
        expected = r * n * m * k
    else:
        assert r_pad >= r and r_pad % 8 == 0
        expected = m * r_pad * n * k
    ln = c.u64()
    assert ln == expected
    return dict(glayout=glayout, dims=(r, n, m, k), r_pad=r_pad, data=c.f32s(ln))


def einsum_chain(m_shape, n_shape, ranks, batch):
    """Mirror of ttd::cost::einsum_chain."""
    d = len(m_shape)
    cur = batch * int(np.prod(n_shape))
    steps = []
    for t in reversed(range(d)):
        r_prev, n_t, m_t, r_t = ranks[t], n_shape[t], m_shape[t], ranks[t + 1]
        b_t = cur // (n_t * r_t)
        kind = 0 if (t == d - 1 and d > 1) else (2 if t == 0 else 1)
        steps.append(dict(kind=kind, m=m_t, b=b_t, n=n_t, r=r_prev, k=r_t))
        cur = m_t * b_t * r_prev
    return steps


def decode_ops(payload):
    c = Cur(payload)
    ops = []
    for _ in range(c.u32()):
        tag = c.u8()
        if tag == 0:
            m, n, r = decode_layout(c)
            decode_layout(c)  # selected layout
            c.u64(), c.u64(), c.u64(), c.f64(), c.f64()  # rank/params/flops/time/speedup
            m_total = int(np.prod(m))
            bias = decode_bias(c, m_total)
            steps = c.u32()
            assert steps == len(m)
            plans, packed = [], []
            for _ in range(steps):
                plans.append(decode_plan(c))
                packed.append(decode_packed(c))
            # from_parts validation: plan dims == batch-1 chain dims
            for plan, chain in zip(plans, einsum_chain(m, n, r, 1)):
                for key in ("kind", "m", "b", "n", "r", "k"):
                    assert plan[key] == chain[key], (key, plan, chain)
            for pg, chain in zip(packed, einsum_chain(m, n, r, 1)):
                assert pg["dims"] == (chain["r"], chain["n"], chain["m"], chain["k"])
            ops.append(("tt", (m, n, r), plans, packed, bias))
        elif tag == 1:
            mm, nn = c.u64(), c.u64()
            w = c.f32s(mm * nn).reshape(mm, nn)
            bias = decode_bias(c, mm)
            ops.append(("dense", w, bias))
        elif tag == 2:
            ops.append(("relu",))
        else:
            raise AssertionError(f"op tag {tag}")
    assert c.pos == len(payload), "trailing bytes"
    return ops


def decode_tune(payload, ops, version):
    """Mirror of reader.rs decode_tune: optional measured plans per TT op.

    Validates op targeting, strictly-increasing indices, plan count vs
    layout d, per-step dims vs the batch-1 chain, and that tuned plans
    keep the analytic plan's vectorized loop / packing choice. From
    format v3 the entries are followed by the tuning-host kernel name
    (length-prefixed UTF-8; empty = unknown).
    """
    c = Cur(payload)
    count = c.u32()
    assert count <= len(ops), f"TUNE entry count {count}"
    prev = -1
    tuned = {}
    for _ in range(count):
        idx = c.u32()
        assert idx > prev, f"TUNE op index {idx} not strictly increasing"
        prev = idx
        assert idx < len(ops) and ops[idx][0] == "tt", f"TUNE target {idx}"
        _, (m, n, r), plans, _packed, _bias = ops[idx]
        steps = c.u32()
        assert steps == len(m), f"TUNE entry for op {idx}: {steps} plans"
        entry = []
        for step, chain in zip(range(steps), einsum_chain(m, n, r, 1)):
            plan = decode_plan(c)
            for key in ("kind", "m", "b", "n", "r", "k"):
                assert plan[key] == chain[key], (key, plan, chain)
            assert plan["vloop"] == plans[step]["vloop"], "tuned plan changes layout"
            assert plan["pack_g"] == plans[step]["pack_g"], "tuned plan changes layout"
            entry.append(plan)
        tuned[idx] = entry
    kernel = None
    if version >= 3:
        ln = c.u32()
        assert ln <= 64, f"TUNE kernel name length {ln}"
        name = c.take(ln).decode("utf-8")
        kernel = name or None
    assert c.pos == len(payload), "trailing bytes in TUNE"
    return tuned, kernel


def decode_quant(payload, ops):
    """Mirror of reader.rs decode_quant (format v4): optional int8 cores
    per TT op, each cross-validated against the f32 packed core it
    shadows — same layout tag, dims and padding, one finite positive
    scale per `m` slice, and an int8 payload of exactly the packed
    core's element count (symmetric quantization: -128 never appears).
    """
    c = Cur(payload)
    count = c.u32()
    assert count <= len(ops), f"QUANT entry count {count}"
    prev = -1
    quant = {}
    for _ in range(count):
        idx = c.u32()
        assert idx > prev, f"QUANT op index {idx} not strictly increasing"
        prev = idx
        assert idx < len(ops) and ops[idx][0] == "tt", f"QUANT target {idx}"
        packed = ops[idx][3]
        steps = c.u32()
        assert steps == len(packed), f"QUANT entry for op {idx}: {steps} cores"
        cores = []
        for pg in packed:
            glayout = c.u8()
            assert glayout in (0, 1, 2), f"QUANT layout tag {glayout}"
            assert glayout == pg["glayout"], "QUANT layout disagrees with OPS"
            dims = tuple(c.u64() for _ in range(4))
            r_pad = c.u64()
            assert dims == pg["dims"] and r_pad == pg["r_pad"], \
                "QUANT dims disagree with OPS"
            sc = c.u64()
            assert sc == dims[2], f"QUANT scale count {sc} != m {dims[2]}"
            scales = c.f32s(sc)
            assert np.all(np.isfinite(scales)) and np.all(scales > 0), \
                "QUANT scales must be finite and positive"
            ln = c.u64()
            assert ln == len(pg["data"]), "QUANT payload length"
            data = np.frombuffer(c.take(ln), dtype=np.int8).copy()
            assert data.min(initial=0) >= -127, "symmetric int8 never emits -128"
            # a zeroed f32 pad lane must quantize to a zeroed int8 lane
            assert np.all(data[pg["data"] == 0.0] == 0), "QUANT pad lanes"
            cores.append(dict(glayout=glayout, dims=dims, r_pad=r_pad,
                              scales=scales, data=data))
        quant[idx] = cores
    assert c.pos == len(payload), "trailing bytes in QUANT"
    return quant


def forward(ops, x, meta):
    cur = np.asarray(x, dtype=np.float32)
    for op in ops:
        if op[0] == "relu":
            cur = np.maximum(cur, 0)
        elif op[0] == "dense":
            _, w, bias = op
            cur = cur @ w.T + (0 if bias is None else bias)
        else:
            _, (m_shape, n_shape, ranks), plans, packed, bias = op
            batch = cur.shape[0]
            flat = cur.ravel()
            for plan, pg in zip(plans, einsum_chain(m_shape, n_shape, ranks, batch)):
                assert plan["vloop"] == 2 and plan["pack_g"] == 0, (
                    "python replay only mirrors the Canonical/naive configuration"
                )
            d = len(m_shape)
            for step, chain in enumerate(einsum_chain(m_shape, n_shape, ranks, batch)):
                pg = packed[step]
                r, n, m, k = pg["dims"]
                g = pg["data"].reshape(r, n, m, k)
                xs = flat.reshape(chain["b"], n, k)
                flat = np.einsum("rnmk,bnk->mbr", g, xs).ravel()
            m_total = int(np.prod(m_shape))
            cur = flat.reshape(m_total, batch).T + (0 if bias is None else bias)
    assert cur.shape[1] == meta["out_dim"]
    return cur


def check_auto_meta(meta):
    """Mirror of reader.rs decode_meta's auto-rank record: `auto_budget`
    and `auto_layers` are additive keys written by `compress --rank auto`
    — either both absent (fixed-rank bundle) or both present, with one
    entry per FC shape: null (dense / no sweep pick) or the sweep's
    {rank, rel_error}.
    """
    budget = meta.get("auto_budget")
    layers = meta.get("auto_layers")
    if budget is None and layers is None:
        return None
    assert budget is not None and layers is not None, \
        "auto_budget and auto_layers must be present together"
    assert isinstance(budget, (int, float)) and not isinstance(budget, bool) \
        and np.isfinite(budget) and budget > 0, f"auto_budget {budget!r}"
    assert isinstance(layers, list) and len(layers) == len(meta["shapes"]), \
        f"auto_layers has {len(layers)} entries for {len(meta['shapes'])} FC layers"
    for i, entry in enumerate(layers):
        if entry is None:
            continue
        rank, rel = entry.get("rank"), entry.get("rel_error")
        assert isinstance(rank, int) and 1 <= rank <= 0xFFFFFFFF, \
            f"auto_layers[{i}].rank {rank!r}"
        assert isinstance(rel, (int, float)) and not isinstance(rel, bool) \
            and np.isfinite(rel) and rel >= 0, f"auto_layers[{i}].rel_error {rel!r}"
    return budget, layers


def main():
    path = sys.argv[1]
    blob = open(path, "rb").read()
    sections = parse_container(blob)
    meta = json.loads(sections[1])
    assert meta["format"] == "ttrv-bundle"
    auto = check_auto_meta(meta)
    ops = decode_ops(sections[2])
    json.loads(sections[3])
    # id 4 only means TUNE from format v2; in a v1 file it is an unknown
    # (third-party) section and is skipped, like the Rust reader does
    version = struct.unpack("<I", blob[4:8])[0]
    if version >= 2 and 4 in sections:
        tuned, kernel = decode_tune(sections[4], ops, version)
    else:
        tuned, kernel = {}, None
    # id 5 only means QUANT from format v4; older files skip it likewise
    if version >= 4 and 5 in sections:
        quant = decode_quant(sections[5], ops)
    else:
        quant = {}
    print(f"{path}: ok — model {meta['model']}, {len(ops)} ops, "
          f"{len(blob)} bytes, machine {meta['machine']}, "
          f"{len(tuned)} TT layer(s) with measured TUNE plans"
          + (f" (tuned on kernel {kernel})" if kernel else "")
          + f", {len(quant)} int8 QUANT layer(s)"
          + (f", auto-rank budget {auto[0]} "
             f"({sum(1 for e in auto[1] if e)} swept layer(s))" if auto else ""))
    if len(sys.argv) > 2:
        x = np.array([float(v) for v in open(sys.argv[2]).read().split(",")])
        y = forward(ops, x.reshape(1, -1), meta)
        print("forward:", y[0].tolist())


if __name__ == "__main__":
    main()
