#!/usr/bin/env python3
"""Schema sanity check for the `ttrv bench` trajectory files
(BENCH_kernels.json / BENCH_serve.json), run by CI after the bench step so
a malformed report fails the build instead of silently polluting the perf
trajectory.

Checks per file: top-level shape, schema name/version, non-empty results,
required keys per result row, and that every reachable number is finite
(the Rust writer encodes non-finite as null; a null in a *required numeric
field that must be positive* is an error here).

Usage: check_bench_json.py BENCH_kernels.json BENCH_serve.json ...
Exit status: 0 = all files valid, 1 = any violation (printed to stderr).
"""

import json
import math
import sys

SCHEMA_VERSION = 1

MEASUREMENT_KEYS = ("seconds", "min_seconds", "mad", "iters", "gflops")

KERNEL_ROW_KEYS = (
    "id", "kind", "m", "b", "n", "r", "k", "flops",
    "ours", "iree_like", "pluto_like", "speedup_vs_iree", "speedup_vs_pluto",
)

SERVE_ROW_KEYS = (
    "workers", "max_batch", "requests", "elapsed_s", "req_per_s",
    "p50_us", "p99_us", "mean_batch",
)


class Violation(Exception):
    pass


def need(cond, msg):
    if not cond:
        raise Violation(msg)


def is_finite_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def check_measurement(m, path):
    need(isinstance(m, dict), f"{path}: not an object")
    for key in MEASUREMENT_KEYS:
        need(key in m, f"{path}: missing '{key}'")
        need(is_finite_number(m[key]), f"{path}.{key}: not a finite number: {m[key]!r}")
    need(m["iters"] >= 1, f"{path}.iters: must be >= 1")
    need(m["seconds"] >= 0, f"{path}.seconds: negative")


def check_kernels(doc):
    need(doc.get("schema") == "ttrv-bench-kernels", "schema != ttrv-bench-kernels")
    for row in doc["results"]:
        rid = row.get("id", "<missing id>")
        for key in KERNEL_ROW_KEYS:
            need(key in row, f"results[{rid}]: missing '{key}'")
        need(row["kind"] in ("first", "middle", "final"), f"results[{rid}]: bad kind")
        for key in ("m", "b", "n", "r", "k", "flops"):
            need(is_finite_number(row[key]) and row[key] >= 1, f"results[{rid}].{key}: bad dim")
        for impl in ("ours", "iree_like", "pluto_like"):
            check_measurement(row[impl], f"results[{rid}].{impl}")
        for key in ("speedup_vs_iree", "speedup_vs_pluto"):
            v = row[key]
            # null = flagged-degenerate measurement; a number must be finite > 0
            need(v is None or (is_finite_number(v) and v > 0), f"results[{rid}].{key}: {v!r}")


def check_serve(doc):
    need(doc.get("schema") == "ttrv-bench-serve", "schema != ttrv-bench-serve")
    need(isinstance(doc.get("model"), str) and doc["model"], "missing model name")
    for i, row in enumerate(doc["results"]):
        for key in SERVE_ROW_KEYS:
            need(key in row, f"results[{i}]: missing '{key}'")
            need(is_finite_number(row[key]), f"results[{i}].{key}: not finite: {row[key]!r}")
        need(row["workers"] >= 1 and row["max_batch"] >= 1, f"results[{i}]: bad config")
        need(row["requests"] >= 1, f"results[{i}]: no requests")
        need(row["req_per_s"] > 0, f"results[{i}]: non-positive throughput")
        need(row["p99_us"] >= row["p50_us"], f"results[{i}]: p99 < p50")


def check_file(path):
    with open(path) as fh:
        doc = json.load(fh)
    need(isinstance(doc, dict), "top level is not an object")
    need(doc.get("schema_version") == SCHEMA_VERSION,
         f"schema_version {doc.get('schema_version')!r} != {SCHEMA_VERSION}")
    need(isinstance(doc.get("quick"), bool), "missing/bad 'quick' flag")
    need(isinstance(doc.get("results"), list) and doc["results"], "empty results")
    need(is_finite_number(doc.get("host_threads")) and doc["host_threads"] >= 1,
         "bad host_threads")
    schema = doc.get("schema")
    if schema == "ttrv-bench-kernels":
        check_kernels(doc)
    elif schema == "ttrv-bench-serve":
        check_serve(doc)
    else:
        raise Violation(f"unknown schema {schema!r}")
    return len(doc["results"])


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    failed = False
    for path in argv[1:]:
        try:
            n = check_file(path)
            print(f"{path}: ok ({n} result rows)")
        except (Violation, OSError, json.JSONDecodeError, KeyError) as e:
            print(f"{path}: INVALID: {e}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
