#!/usr/bin/env python3
"""Schema sanity check for ttrv's machine-readable JSON artifacts:

* `BENCH_kernels.json`   (schema `ttrv-bench-kernels`, v3: per-row `kernel`
                          naming the dispatched microkernel plus a
                          `per_kernel` sweep of every candidate — the int8
                          family included — measured side by side)
* `BENCH_serve.json`     (schema `ttrv-bench-serve`,   v2: per-model rows,
                          a `models` axis, and an embedded serve snapshot)
* serve snapshot dumps   (schema `ttrv-serve-snapshot`, v2: the document
                          `ttrv serve-demo --snapshot-json` writes and
                          `Server::snapshot()` returns, with a top-level
                          `kernel` key)
* DSE reports            (schema `ttrv-dse-report`, v1: the document
                          `ttrv dse --json` prints — stage counts,
                          Pareto frontier, selection, and when the rank
                          sweep ran, `rank_sweep` rows carrying
                          `rel_error`/`quant_error` plus the
                          accuracy-budget pick's `selected_rank`)
* lint reports           (schema `ttrv-lint-report`, v1: the document
                          `ttrv lint --json` prints — one row per
                          plan x core pair with the static verifier's
                          per-invariant violations; `clean` must agree
                          with the violation count)

Run by CI after the bench/serve steps so a malformed report fails the
build instead of silently polluting the perf trajectory. Files are
dispatched by their `schema` field, so any mix of the three kinds can be
passed in one invocation.

Checks per file: top-level shape, schema name/version, non-empty results,
required keys per result row, and that every reachable number is finite
(the Rust writer encodes non-finite as null; a null in a *required numeric
field that must be positive* is an error here).

Usage: check_bench_json.py BENCH_kernels.json BENCH_serve.json snap.json ...
Exit status: 0 = all files valid, 1 = any violation (printed to stderr).
"""

import json
import math
import sys

EXPECTED_VERSIONS = {
    "ttrv-bench-kernels": 3,
    "ttrv-bench-serve": 2,
    "ttrv-serve-snapshot": 2,
    "ttrv-dse-report": 1,
    "ttrv-lint-report": 1,
}

# Kernel names the Rust dispatch layer can emit (dispatch.rs); the set is
# closed per release, so an unknown name is a schema violation.
KNOWN_KERNELS = ("portable", "avx2-fma", "neon",
                 "int8-portable", "int8-avx2", "int8-neon")
INT8_KERNELS = ("int8-portable", "int8-avx2", "int8-neon")

MEASUREMENT_KEYS = ("seconds", "min_seconds", "mad", "iters", "gflops")

KERNEL_ROW_KEYS = (
    "id", "kind", "m", "b", "n", "r", "k", "flops", "kernel",
    "ours", "iree_like", "pluto_like", "speedup_vs_iree", "speedup_vs_pluto",
    "per_kernel",
)

PER_KERNEL_KEYS = ("kernel", "int8", "measurement", "speedup_vs_ours")

DSE_COUNT_KEYS = ("all", "aligned", "vectorized", "initial", "scalability", "timed")

DSE_SOLUTION_KEYS = (
    "m_shape", "n_shape", "rank", "d", "params", "flops",
    "modeled_time_s", "speedup_vs_dense",
)

SERVE_ROW_KEYS = (
    "workers", "max_batch", "models", "requests", "elapsed_s", "req_per_s",
    "p50_us", "p99_us", "mean_batch",
)

HISTOGRAM_KEYS = ("count", "mean", "p50", "p99", "max", "buckets")

METRICS_KEYS = (
    "requests", "batches", "rejected", "slo_missed", "mean_batch",
    "latency_us", "queue_wait_us", "exec_us", "batch_size",
)

REGISTRY_KEYS = ("models", "resident", "loads", "evictions", "cache_bytes",
                 "resident_bytes")

SNAPSHOT_MODEL_KEYS = ("model", "resident", "pinned", "engine_bytes",
                       "req_per_s", "metrics")


class Violation(Exception):
    pass


def need(cond, msg):
    if not cond:
        raise Violation(msg)


def is_finite_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def check_measurement(m, path):
    need(isinstance(m, dict), f"{path}: not an object")
    for key in MEASUREMENT_KEYS:
        need(key in m, f"{path}: missing '{key}'")
        need(is_finite_number(m[key]), f"{path}.{key}: not a finite number: {m[key]!r}")
    need(m["iters"] >= 1, f"{path}.iters: must be >= 1")
    need(m["seconds"] >= 0, f"{path}.seconds: negative")


def check_kernels(doc):
    for row in doc["results"]:
        rid = row.get("id", "<missing id>")
        for key in KERNEL_ROW_KEYS:
            need(key in row, f"results[{rid}]: missing '{key}'")
        need(row["kind"] in ("first", "middle", "final"), f"results[{rid}]: bad kind")
        need(row["kernel"] in KNOWN_KERNELS, f"results[{rid}].kernel: {row['kernel']!r}")
        for key in ("m", "b", "n", "r", "k", "flops"):
            need(is_finite_number(row[key]) and row[key] >= 1, f"results[{rid}].{key}: bad dim")
        for impl in ("ours", "iree_like", "pluto_like"):
            check_measurement(row[impl], f"results[{rid}].{impl}")
        for key in ("speedup_vs_iree", "speedup_vs_pluto"):
            v = row[key]
            # null = flagged-degenerate measurement; a number must be finite > 0
            need(v is None or (is_finite_number(v) and v > 0), f"results[{rid}].{key}: {v!r}")
        # v3: the per-candidate comparison sweep — every supported kernel
        # (f32 over the packed core, int8 over its quantized shadow)
        cells = row["per_kernel"]
        need(isinstance(cells, list) and cells, f"results[{rid}].per_kernel: empty")
        seen = set()
        for j, cell in enumerate(cells):
            cpath = f"results[{rid}].per_kernel[{j}]"
            need(isinstance(cell, dict), f"{cpath}: not an object")
            for key in PER_KERNEL_KEYS:
                need(key in cell, f"{cpath}: missing '{key}'")
            need(cell["kernel"] in KNOWN_KERNELS, f"{cpath}.kernel: {cell['kernel']!r}")
            need(cell["kernel"] not in seen, f"{cpath}.kernel: duplicate")
            seen.add(cell["kernel"])
            need(isinstance(cell["int8"], bool), f"{cpath}.int8: not a bool")
            need(cell["int8"] == (cell["kernel"] in INT8_KERNELS),
                 f"{cpath}: int8 flag disagrees with kernel name")
            check_measurement(cell["measurement"], f"{cpath}.measurement")
            v = cell["speedup_vs_ours"]
            need(v is None or (is_finite_number(v) and v > 0),
                 f"{cpath}.speedup_vs_ours: {v!r}")
        # the roster always contains both reference kernels
        need("portable" in seen, f"results[{rid}].per_kernel: portable missing")
        need("int8-portable" in seen, f"results[{rid}].per_kernel: int8-portable missing")


def check_histogram(h, path):
    need(isinstance(h, dict), f"{path}: not an object")
    for key in HISTOGRAM_KEYS:
        need(key in h, f"{path}: missing '{key}'")
    for key in ("count", "mean", "p50", "p99", "max"):
        need(is_finite_number(h[key]) and h[key] >= 0, f"{path}.{key}: {h[key]!r}")
    need(h["p99"] >= h["p50"], f"{path}: p99 < p50")
    need(isinstance(h["buckets"], list), f"{path}.buckets: not a list")
    for i, pair in enumerate(h["buckets"]):
        need(isinstance(pair, list) and len(pair) == 2,
             f"{path}.buckets[{i}]: not an [upper_bound, count] pair")
        need(all(is_finite_number(v) and v >= 0 for v in pair),
             f"{path}.buckets[{i}]: bad numbers {pair!r}")
    total = sum(pair[1] for pair in h["buckets"])
    need(total == h["count"], f"{path}: bucket counts sum to {total}, count is {h['count']}")


def check_metrics(m, path):
    need(isinstance(m, dict), f"{path}: not an object")
    for key in METRICS_KEYS:
        need(key in m, f"{path}: missing '{key}'")
    for key in ("requests", "batches", "rejected", "slo_missed", "mean_batch"):
        need(is_finite_number(m[key]) and m[key] >= 0, f"{path}.{key}: {m[key]!r}")
    for key in ("latency_us", "queue_wait_us", "exec_us", "batch_size"):
        check_histogram(m[key], f"{path}.{key}")


def check_snapshot(doc, path="snapshot"):
    for key in ("uptime_s", "workers", "shards", "queue_depth", "req_per_s"):
        need(is_finite_number(doc.get(key)) and doc[key] >= 0, f"{path}.{key}: bad value")
    need(doc["workers"] >= 1 and doc["shards"] >= 1, f"{path}: empty pool")
    need(doc.get("steal") in ("ring", "off"), f"{path}.steal: {doc.get('steal')!r}")
    need(doc.get("kernel") in KNOWN_KERNELS, f"{path}.kernel: {doc.get('kernel')!r}")
    check_metrics(doc.get("process"), f"{path}.process")
    reg = doc.get("registry")
    need(isinstance(reg, dict), f"{path}.registry: not an object")
    for key in REGISTRY_KEYS:
        need(is_finite_number(reg.get(key)) and reg[key] >= 0, f"{path}.registry.{key}: bad value")
    models = doc.get("models")
    need(isinstance(models, list) and models, f"{path}.models: empty")
    need(reg["models"] == len(models), f"{path}: registry.models != len(models)")
    for i, row in enumerate(models):
        mpath = f"{path}.models[{i}]"
        for key in SNAPSHOT_MODEL_KEYS:
            need(key in row, f"{mpath}: missing '{key}'")
        need(isinstance(row["model"], str) and row["model"], f"{mpath}.model: bad name")
        need(isinstance(row["resident"], bool), f"{mpath}.resident: not a bool")
        need(isinstance(row["pinned"], bool), f"{mpath}.pinned: not a bool")
        need(is_finite_number(row["engine_bytes"]) and row["engine_bytes"] >= 0,
             f"{mpath}.engine_bytes: bad value")
        need(is_finite_number(row["req_per_s"]) and row["req_per_s"] >= 0,
             f"{mpath}.req_per_s: bad value")
        check_metrics(row["metrics"], f"{mpath}.metrics")


def check_serve(doc):
    models = doc.get("models")
    need(isinstance(models, list) and models, "missing/empty 'models' axis")
    need(all(isinstance(m, str) and m for m in models), "bad model name in 'models'")
    for i, row in enumerate(doc["results"]):
        for key in SERVE_ROW_KEYS:
            need(key in row, f"results[{i}]: missing '{key}'")
            need(is_finite_number(row[key]), f"results[{i}].{key}: not finite: {row[key]!r}")
        need(isinstance(row.get("model"), str) and row["model"] in models,
             f"results[{i}].model: not in the models axis")
        need(row["workers"] >= 1 and row["max_batch"] >= 1, f"results[{i}]: bad config")
        need(1 <= row["models"] <= len(models), f"results[{i}]: bad models count")
        need(row["requests"] >= 1, f"results[{i}]: no requests")
        need(row["req_per_s"] > 0, f"results[{i}]: non-positive throughput")
        need(row["p99_us"] >= row["p50_us"], f"results[{i}]: p99 < p50")
    snap = doc.get("snapshot")
    need(isinstance(snap, dict), "missing embedded 'snapshot'")
    need(snap.get("schema") == "ttrv-serve-snapshot", "snapshot: bad schema stamp")
    need(snap.get("schema_version") == EXPECTED_VERSIONS["ttrv-serve-snapshot"],
         "snapshot: bad schema_version")
    check_snapshot(snap, "snapshot")


def check_dse_solution(s, path, swept=False):
    need(isinstance(s, dict), f"{path}: not an object")
    for key in DSE_SOLUTION_KEYS:
        need(key in s, f"{path}: missing '{key}'")
    for key in ("m_shape", "n_shape"):
        shape = s[key]
        need(isinstance(shape, list) and shape, f"{path}.{key}: empty shape")
        need(all(is_finite_number(v) and v >= 1 for v in shape),
             f"{path}.{key}: bad factor in {shape!r}")
    for key in ("rank", "d", "params", "flops"):
        need(is_finite_number(s[key]) and s[key] >= 1, f"{path}.{key}: {s[key]!r}")
    for key in ("modeled_time_s", "speedup_vs_dense"):
        need(is_finite_number(s[key]) and s[key] > 0, f"{path}.{key}: {s[key]!r}")
    if swept:
        # sweep rows carry the two accuracy axes on top of the timed vocab
        for key in ("rel_error", "quant_error"):
            need(key in s, f"{path}: missing '{key}'")
            need(is_finite_number(s[key]) and s[key] >= 0, f"{path}.{key}: {s[key]!r}")


def check_dse_report(doc):
    for key in ("n", "m", "rank"):
        need(is_finite_number(doc.get(key)) and doc[key] >= 1, f"{key}: bad value")
    need(isinstance(doc.get("policy"), str) and doc["policy"], "policy: bad value")
    need(isinstance(doc.get("machine"), str) and doc["machine"], "machine: bad value")
    counts = doc.get("counts")
    need(isinstance(counts, dict), "counts: not an object")
    for key in DSE_COUNT_KEYS:
        need(is_finite_number(counts.get(key)) and counts[key] >= 0,
             f"counts.{key}: bad value")
    need(is_finite_number(doc.get("dense_modeled_time_s"))
         and doc["dense_modeled_time_s"] > 0, "dense_modeled_time_s: bad value")
    for key in ("dense_flops", "dense_params"):
        need(is_finite_number(doc.get(key)) and doc[key] >= 1, f"{key}: bad value")
    frontier = doc.get("frontier")
    need(isinstance(frontier, list) and frontier, "frontier: empty")
    for i, s in enumerate(frontier):
        check_dse_solution(s, f"frontier[{i}]")
    if doc.get("selected") is not None:
        check_dse_solution(doc["selected"], "selected")
    # the rank-sweep block: all-null when the sweep did not run; when the
    # accuracy budget produced a pick, selected_rank must be a rank the
    # sweep actually measured and rel_error must fit the budget
    budget = doc.get("accuracy_budget")
    need(budget is None or (is_finite_number(budget) and budget > 0),
         f"accuracy_budget: {budget!r}")
    sweep = doc.get("rank_sweep")
    need(sweep is None or isinstance(sweep, list), "rank_sweep: not a list")
    if isinstance(sweep, list):
        for i, s in enumerate(sweep):
            check_dse_solution(s, f"rank_sweep[{i}]", swept=True)
    sel_rank = doc.get("selected_rank")
    rel = doc.get("rel_error")
    need((sel_rank is None) == (rel is None),
         "selected_rank and rel_error must be null together")
    if sel_rank is not None:
        need(isinstance(sweep, list) and budget is not None,
             "selected_rank without a rank_sweep + accuracy_budget")
        need(is_finite_number(sel_rank) and sel_rank >= 1, f"selected_rank: {sel_rank!r}")
        need(is_finite_number(rel) and 0 <= rel <= budget,
             f"rel_error {rel!r} outside the accuracy budget {budget!r}")
        need(any(s["rank"] == sel_rank for s in sweep),
             "selected_rank is not a rank the sweep measured")
    return len(frontier)


LINT_ROW_KEYS = (
    "layer", "step", "source", "kind", "m", "b", "n", "r", "k", "layout",
    "vector_loop", "vl", "rm", "rb", "rr", "rk", "registers", "threads",
    "quant", "status", "violations",
)

LINT_LAYOUTS = ("Canonical", "PackedR", "PackedK")
LINT_KINDS = ("First", "Middle", "Final")
LINT_VECTOR_LOOPS = ("R", "K", "None")


def check_lint_report(doc):
    for key in ("source", "model", "machine"):
        need(isinstance(doc.get(key), str) and doc[key], f"{key}: bad value")
    need(isinstance(doc.get("machine_known"), bool), "machine_known: not a bool")
    need(isinstance(doc.get("clean"), bool), "clean: not a bool")
    results = doc.get("results")
    need(isinstance(results, list) and results, "results: empty")
    need(doc.get("plans_checked") == len(results),
         "plans_checked disagrees with len(results)")
    total = 0
    for i, row in enumerate(results):
        rpath = f"results[{i}]"
        need(isinstance(row, dict), f"{rpath}: not an object")
        for key in LINT_ROW_KEYS:
            need(key in row, f"{rpath}: missing '{key}'")
        need(row["source"] in ("selected", "tuned"), f"{rpath}.source: {row['source']!r}")
        need(row["kind"] in LINT_KINDS, f"{rpath}.kind: {row['kind']!r}")
        need(row["layout"] in LINT_LAYOUTS, f"{rpath}.layout: {row['layout']!r}")
        need(row["vector_loop"] in LINT_VECTOR_LOOPS,
             f"{rpath}.vector_loop: {row['vector_loop']!r}")
        for key in ("m", "b", "n", "r", "k", "vl", "rm", "rb", "rr", "rk", "threads"):
            need(is_finite_number(row[key]) and row[key] >= 1, f"{rpath}.{key}: bad value")
        need(is_finite_number(row["layer"]) and row["layer"] >= 0, f"{rpath}.layer: bad value")
        need(is_finite_number(row["step"]) and row["step"] >= 0, f"{rpath}.step: bad value")
        # Eq. 19: rm*rb*rr + min(rb*rk, rm*rr) + 1 >= 1*1*1 + 1 + 1 = 3
        need(is_finite_number(row["registers"]) and row["registers"] >= 3,
             f"{rpath}.registers: bad value")
        need(isinstance(row["quant"], bool), f"{rpath}.quant: not a bool")
        vs = row["violations"]
        need(isinstance(vs, list), f"{rpath}.violations: not a list")
        for j, v in enumerate(vs):
            need(isinstance(v, dict), f"{rpath}.violations[{j}]: not an object")
            need(isinstance(v.get("invariant"), str) and v["invariant"],
                 f"{rpath}.violations[{j}].invariant: bad value")
            need(isinstance(v.get("detail"), str) and v["detail"],
                 f"{rpath}.violations[{j}].detail: bad value")
        need(row["status"] == ("ok" if not vs else "violated"),
             f"{rpath}.status: disagrees with its violations list")
        total += len(vs)
    need(doc.get("violations") == total,
         f"violations {doc.get('violations')!r} != counted {total}")
    need(doc["clean"] == (total == 0), "clean disagrees with the violation count")
    return len(results)


def check_file(path):
    with open(path) as fh:
        doc = json.load(fh)
    need(isinstance(doc, dict), "top level is not an object")
    schema = doc.get("schema")
    need(schema in EXPECTED_VERSIONS, f"unknown schema {schema!r}")
    expected = EXPECTED_VERSIONS[schema]
    need(doc.get("schema_version") == expected,
         f"schema_version {doc.get('schema_version')!r} != {expected}")
    if schema == "ttrv-serve-snapshot":
        # a standalone snapshot dump (no quick/results envelope)
        check_snapshot(doc, "snapshot")
        return len(doc["models"])
    if schema == "ttrv-dse-report":
        # a `ttrv dse --json` report (no quick/results envelope either)
        return check_dse_report(doc)
    if schema == "ttrv-lint-report":
        # a `ttrv lint --json` report (envelope-free, like the DSE report)
        return check_lint_report(doc)
    need(isinstance(doc.get("quick"), bool), "missing/bad 'quick' flag")
    need(isinstance(doc.get("results"), list) and doc["results"], "empty results")
    need(is_finite_number(doc.get("host_threads")) and doc["host_threads"] >= 1,
         "bad host_threads")
    if schema == "ttrv-bench-kernels":
        check_kernels(doc)
    else:
        check_serve(doc)
    return len(doc["results"])


def main(argv):
    if len(argv) < 2:
        print(__doc__, file=sys.stderr)
        return 2
    failed = False
    for path in argv[1:]:
        try:
            n = check_file(path)
            print(f"{path}: ok ({n} result rows)")
        except (Violation, OSError, json.JSONDecodeError, KeyError, TypeError) as e:
            print(f"{path}: INVALID: {e}", file=sys.stderr)
            failed = True
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
