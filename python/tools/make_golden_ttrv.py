#!/usr/bin/env python3
"""Generate the pinned golden bundle `rust/tests/data/lenet300.ttrv`.

The golden artifact is the forward-compat tripwire for the `.ttrv` format
(see rust/src/artifact/format.rs): the Rust reader must load this exact
byte stream and serve the exact output vector pinned in
rust/tests/artifact_suite.rs. Regenerate it ONLY on a *breaking* format
change (one that raises MIN_FORMAT_VERSION). Additive changes — like the
optional TUNE section of format version 2 — deliberately leave this file
at version 1: it then doubles as the pre-bump-bundles-still-load pin.

Construction notes:
* Every stored value (cores, biases, dense weights, the test input) is a
  small integer, and the script asserts that the sum of absolute values of
  every contraction stays below 2^24. Integer f32 arithmetic below that
  bound is exact in ANY summation order, so the pinned outputs are
  independent of kernel/blocking/threading details — the pin survives
  legitimate kernel refactors and only trips on format breaks.
* The TT layers carry naive (Canonical-layout, scalar) plans, exercising
  the third `G` layout; the pinned forward runs at batch 1 so the
  pre-seeded batch-1 plans are the only ones used.
"""

import struct
import zlib
from pathlib import Path

import numpy as np

OUT = Path(__file__).resolve().parents[2] / "rust" / "tests" / "data" / "lenet300.ttrv"

MAGIC = b"TTRV"
VERSION = 1
SEC_META, SEC_OPS, SEC_REPORT = 1, 2, 3
EXACT_BOUND = 1 << 24

u8 = lambda v: struct.pack("<B", v)
u32 = lambda v: struct.pack("<I", v)
u64 = lambda v: struct.pack("<Q", v)
f64 = lambda v: struct.pack("<d", v)


def f32s(arr):
    a = np.asarray(arr, dtype=np.int64).ravel()
    # every stored value must be integer-exact in f32
    assert np.abs(a).max(initial=0) < EXACT_BOUND
    return np.asarray(a, dtype="<f4").tobytes()


def pattern(n, salt, lo=-1, hi=1, density_mod=7, nonzero=(0, 2, 4)):
    """Deterministic sparse integer pattern in [lo, hi]."""
    idx = np.arange(n, dtype=np.int64)
    phase = (idx * 31 + salt) % density_mod
    vals = ((idx * 13 + salt * 7) % (hi - lo + 1)) + lo
    return np.where(np.isin(phase, nonzero), vals, 0)


class TtLayer:
    def __init__(self, m_shape, n_shape, ranks, salt):
        self.m_shape, self.n_shape, self.ranks = m_shape, n_shape, ranks
        d = len(m_shape)
        self.cores = []
        for t in range(d):
            shape = (ranks[t], n_shape[t], m_shape[t], ranks[t + 1])
            self.cores.append(
                pattern(int(np.prod(shape)), salt + 101 * t).reshape(shape)
            )
        self.m_total = int(np.prod(m_shape))
        self.n_total = int(np.prod(n_shape))
        self.bias = pattern(self.m_total, salt + 997, lo=-2, hi=2)

    def chain(self, batch):
        """(kind, m, b, n, r, k) per processing step — mirrors
        ttd::cost::einsum_chain (kind: 0 First, 1 Middle, 2 Final)."""
        d = len(self.m_shape)
        cur = batch * self.n_total
        steps = []
        for t in reversed(range(d)):
            r_prev, n_t, m_t, r_t = (
                self.ranks[t], self.n_shape[t], self.m_shape[t], self.ranks[t + 1],
            )
            b_t = cur // (n_t * r_t)
            kind = 0 if (t == d - 1 and d > 1) else (2 if t == 0 else 1)
            steps.append((kind, m_t, b_t, n_t, r_prev, r_t))
            cur = m_t * b_t * r_prev
        return steps

    def forward(self, x):
        """Mirror of TtFcShared::forward_with over naive kernels, int64."""
        batch = x.shape[0]
        assert x.shape[1] == self.n_total
        flat = x.astype(np.int64).ravel()
        d = len(self.m_shape)
        for step, (kind, m, b, n, r, k) in enumerate(self.chain(batch)):
            core = self.cores[d - 1 - step]
            assert core.shape == (r, n, m, k)
            xs = flat.reshape(b, n, k)
            # exactness: any partial sum is bounded by the abs-sum
            bound = np.einsum("rnmk,bnk->mbr", np.abs(core), np.abs(xs))
            assert bound.max() < EXACT_BOUND, f"step {step}: bound {bound.max()}"
            flat = np.einsum("rnmk,bnk->mbr", core, xs).ravel()
        # final slab is (M, B) row-major -> (B, M), plus bias
        y = flat.reshape(self.m_total, batch).T + self.bias
        assert np.abs(y).max() < EXACT_BOUND
        return y


def encode_layout(m_shape, n_shape, ranks):
    out = u32(len(m_shape))
    for v in list(m_shape) + list(n_shape) + list(ranks):
        out += u64(v)
    return out


def encode_naive_plan(kind, m, b, n, r, k):
    out = u8(kind)
    for v in (m, b, n, r, k):
        out += u64(v)
    out += u8(0)          # pack_g = false
    out += u8(2)          # VectorLoop::None
    out += u64(1)         # vl
    out += u64(1) * 4     # rb factors
    out += u8(0)          # LoopOrder::Mbrk
    out += u8(0) + u64(0) # no btl
    out += u32(1)         # threads
    out += u64(0)         # ls_estimate
    return out


def encode_canonical_packed(core):
    r, n, m, k = core.shape
    out = u8(0)  # GLayout::Canonical
    for v in (r, n, m, k, r):  # dims + r_pad = r
        out += u64(v)
    out += u64(core.size)
    out += f32s(core)
    return out


def encode_tt(layer):
    out = u8(0)  # op tag
    lay = encode_layout(layer.m_shape, layer.n_shape, layer.ranks)
    out += lay + lay  # achieved layout == selected layout
    params = sum(c.size for c in layer.cores) + layer.m_total
    flops = layer.m_total + sum(
        2 * m * b * n * r * k for (_, m, b, n, r, k) in layer.chain(1)
    )
    out += u64(max(layer.ranks)) + u64(params) + u64(flops)
    out += f64(1e-4) + f64(2.0)
    out += u8(1) + u64(layer.m_total) + f32s(layer.bias)
    steps = layer.chain(1)
    out += u32(len(steps))
    d = len(layer.m_shape)
    for step, dims in enumerate(steps):
        out += encode_naive_plan(*dims)
        out += encode_canonical_packed(layer.cores[d - 1 - step])
    return out


def encode_dense(w, bias):
    m, n = w.shape
    return u8(1) + u64(m) + u64(n) + f32s(w) + u8(1) + u64(m) + f32s(bias)


def main():
    tt1 = TtLayer([20, 15], [28, 28], [1, 4, 1], salt=5)
    tt2 = TtLayer([10, 10], [20, 15], [1, 3, 1], salt=60)
    w3 = pattern(10 * 100, 900).reshape(10, 100)
    b3 = pattern(10, 901, lo=-2, hi=2)

    # --- expected output for the pinned input -----------------------------
    x = (((np.arange(784, dtype=np.int64) * 37) % 7) - 3).reshape(1, 784)
    h = np.maximum(tt1.forward(x), 0)
    h = np.maximum(tt2.forward(h), 0)
    bound = np.abs(h) @ np.abs(w3).T + np.abs(b3)
    assert bound.max() < EXACT_BOUND, f"dense bound {bound.max()}"
    y = h @ w3.T + b3
    print("pinned output:", y[0].tolist())

    # --- sections ---------------------------------------------------------
    meta = (
        b'{"format":"ttrv-bundle","model":"lenet300-golden",'
        b'"machine":"SpacemiT-K1","in_dim":784,"out_dim":10,'
        b'"rank":4,"seed":0,"shapes":[[784,300],[300,100],[100,10]]}'
    )
    ops = u32(5)
    ops += encode_tt(tt1)
    ops += u8(2)  # relu
    ops += encode_tt(tt2)
    ops += u8(2)  # relu
    ops += encode_dense(w3, b3)
    report = b"[]"

    sections = [(SEC_META, meta), (SEC_OPS, ops), (SEC_REPORT, report)]
    toc = b""
    offset = 16 + 24 * len(sections)
    for sid, payload in sections:
        toc += u32(sid) + u32(zlib.crc32(payload)) + u64(offset) + u64(len(payload))
        offset += len(payload)
    blob = MAGIC + u32(VERSION) + u32(len(sections)) + u32(zlib.crc32(toc)) + toc
    for _, payload in sections:
        blob += payload

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_bytes(blob)
    print(f"wrote {OUT} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
