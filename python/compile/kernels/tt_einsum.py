# L1 — Pallas kernel for the T3F Einsum hot-spot.
#
#     Out[m, b, r] = sum_{n, k} G[r, n, m, k] * In[b, n, k]
#
# Hardware adaptation (paper targets RISC-V RVV; we target the TPU model —
# see DESIGN.md §Hardware-Adaptation):
#
#   * The paper vectorizes the r-loop and array-packs G so that the vector
#     lanes read contiguous memory. Here r is the trailing (lane) dimension of
#     every block, so stores are lane-contiguous for the same reason.
#   * The paper's register blocking (Rm x Rb output accumulators) becomes the
#     per-grid-cell output tile (TM, TB, r) living in VMEM.
#   * The paper's L2 cache tiling over bt (Eq. 26-28) becomes the grid over b
#     with VMEM-bounded block shapes: each grid cell stages one (r,n,TM,k)
#     G tile and one (TB,n,k) input tile HBM->VMEM via BlockSpec.
#   * The contraction itself is phrased as a single (TB, n*k) @ (n*k, TM*r)
#     matmul so it maps onto the MXU systolic array instead of the paper's
#     vfmacc chains.
#
# interpret=True is mandatory in this image: real-TPU lowering emits a Mosaic
# custom-call the CPU PJRT plugin cannot execute.
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of the modeled vector unit (paper: 256-bit RVV / f32 -> 8).
VL = 8


def _kernel(g_ref, x_ref, o_ref, *, acc_dtype):
    """One grid cell: full contraction for an (TM, TB, r) output tile."""
    g = g_ref[...]  # (r, n, TM, k)
    x = x_ref[...]  # (TB, n, k)
    r, n, tm, k = g.shape
    tb = x.shape[0]
    # (n, k, TM, r) -> (n*k, TM*r): contiguous-in-r layout, the Pallas
    # analogue of the paper's array-packing of G (done at trace time, i.e.
    # "compile time" in the paper's sense — G is a constant weight).
    gm = jnp.transpose(g, (1, 3, 2, 0)).reshape(n * k, tm * r)
    xm = x.reshape(tb, n * k)
    out = jnp.dot(
        xm.astype(acc_dtype), gm.astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )  # (TB, TM*r) on the MXU
    out = out.reshape(tb, tm, r).transpose(1, 0, 2)  # (TM, TB, r)
    o_ref[...] = out.astype(o_ref.dtype)


def _round_up(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


def tt_einsum_pallas(g, x, *, tm: int | None = None, tb: int | None = None,
                     interpret: bool = True, acc_dtype=jnp.float32):
    """Pallas implementation of ``einsum("rnmk,bnk->mbr", G, In)``.

    Args:
      g: core, shape ``(r, n, m, k)``.
      x: input slab, shape ``(b, n, k)``.
      tm, tb: output tile sizes along m and b (grid block shape). Defaults
        chosen to keep the per-cell VMEM footprint modest; inputs are
        zero-padded up to tile multiples and the output is sliced back, so
        arbitrary (non-dividing) shapes are supported.
      interpret: must stay True on CPU (Mosaic custom-calls do not run here).

    Returns:
      Output of shape ``(m, b, r)``.
    """
    r, n, m, k = g.shape
    b = x.shape[0]
    if x.shape != (b, n, k):
        raise ValueError(f"input slab {x.shape} incompatible with core {g.shape}")
    if tm is None:
        tm = min(m, 128)
    if tb is None:
        tb = min(b, 128)
    tm = max(1, min(tm, m))
    tb = max(1, min(tb, b))

    m_pad = _round_up(m, tm)
    b_pad = _round_up(b, tb)
    if m_pad != m:
        g = jnp.pad(g, ((0, 0), (0, 0), (0, m_pad - m), (0, 0)))
    if b_pad != b:
        x = jnp.pad(x, ((0, b_pad - b), (0, 0), (0, 0)))

    grid = (m_pad // tm, b_pad // tb)
    out = pl.pallas_call(
        functools.partial(_kernel, acc_dtype=acc_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((r, n, tm, k), lambda i, j: (0, 0, i, 0)),
            pl.BlockSpec((tb, n, k), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tb, r), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, b_pad, r), x.dtype),
        interpret=interpret,
    )(g, x)
    return out[:m, :b, :]


def first_einsum_pallas(g, x, **kw):
    """First-processed core (t = d): right-rank extent k = 1."""
    if g.shape[3] != 1:
        raise ValueError("first einsum requires k (= r_d) == 1")
    return tt_einsum_pallas(g, x, **kw)


def middle_einsum_pallas(g, x, **kw):
    return tt_einsum_pallas(g, x, **kw)


def final_einsum_pallas(g, x, **kw):
    """Last-processed core (t = 1): left-rank extent r = 1."""
    if g.shape[0] != 1:
        raise ValueError("final einsum requires r (= r_0) == 1")
    return tt_einsum_pallas(g, x, **kw)


def tt_forward_pallas(x, cores, bias=None, *, tm=None, tb=None,
                      interpret=True):
    """TT FC-layer forward (paper Listing 1) with every einsum on Pallas.

    Mirrors ref.tt_forward_ref exactly; see there for the layout derivation.
    """
    d = len(cores)
    batch = x.shape[0]
    cur = x.reshape(-1)
    total_m = 1
    for t in range(d - 1, -1, -1):
        g = cores[t]
        r_prev, n_t, m_t, r_t = g.shape
        bt = cur.size // (n_t * r_t)
        slab = cur.reshape(bt, n_t, r_t)
        out = tt_einsum_pallas(g, slab, tm=tm, tb=tb, interpret=interpret)
        cur = out.reshape(-1)
        total_m *= m_t
    y = cur.reshape(total_m, batch).T
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# TPU performance estimation (DESIGN.md §Perf / §Hardware-Adaptation).
# interpret=True gives CPU-numpy timings only, so real-TPU performance is
# estimated structurally from the BlockSpecs: VMEM footprint per grid cell and
# MXU utilization of the staged matmul.
# ---------------------------------------------------------------------------

def vmem_bytes_per_cell(r, n, m, k, tm, tb, dtype_bytes=4):
    """Bytes resident in VMEM for one grid cell (G tile + In tile + Out tile)."""
    g_tile = r * n * tm * k
    x_tile = tb * n * k
    o_tile = tm * tb * r
    return (g_tile + x_tile + o_tile) * dtype_bytes


def mxu_utilization_estimate(r, n, m, k, tm, tb, mxu=128):
    """Fraction of MXU lanes busy for the staged (TB, n*k) @ (n*k, TM*r) dot.

    The MXU processes mxu x mxu tiles; a dot of shape (A, C) @ (C, B) runs at
    min(A,mxu)/mxu * min(B,mxu)/mxu * min(C,mxu)/mxu efficiency for the
    partial tiles (crude but monotone in the right directions).
    """
    a, c, b = tb, n * k, tm * r
    eff = 1.0
    for dim in (a, b, c):
        frac = (dim % mxu) / mxu if dim % mxu else 1.0
        full = dim // mxu
        # weighted average of full tiles and the ragged remainder tile
        total = full + (1 if dim % mxu else 0)
        eff *= (full + frac * (1 if dim % mxu else 0)) / total if total else 1.0
    return eff


def block_choice_report(r, n, m, k, b, candidates=((32, 32), (64, 64),
                                                   (128, 128), (256, 128))):
    """Sweep candidate (TM, TB) block shapes; returns list of dicts."""
    rows = []
    for tm, tb in candidates:
        tm_c, tb_c = min(tm, m), min(tb, b)
        rows.append({
            "tm": tm_c,
            "tb": tb_c,
            "vmem_bytes": vmem_bytes_per_cell(r, n, m, k, tm_c, tb_c),
            "mxu_util": mxu_utilization_estimate(r, n, m, k, tm_c, tb_c),
            "grid": (math.ceil(m / tm_c)) * (math.ceil(b / tb_c)),
        })
    return rows
