# Pure-jnp correctness oracle for the T3F Einsum kernel family.
#
# The paper's hot-spot kernel (Listing 2) is, for each TT core t:
#
#     Out[m, b, r] = sum_{n, k} G[r, n, m, k] * In[b, n, k]
#
# where, in tensor-index terms, r is the *left* rank r_{t-1} (the paper's
# ``rt``) and k is the *right* rank r_t (the paper's ``rt_1``, the rank shared
# with the previously-processed core — cores are processed t = d .. 1).
#
# Three variants appear in a TT chain:
#   * first  (t = d): k-extent 1  (r_d = 1)   — no k loop
#   * middle (1<t<d): both rank extents > 1
#   * final  (t = 1): r-extent 1  (r_0 = 1)   — no r loop
#
# The generic einsum covers all three; the variants only matter for the
# optimized implementations (different microkernels).
from __future__ import annotations

import jax.numpy as jnp


def einsum_ref(g: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Reference for ``einsum("rnmk,bnk->mbr", G, In)``.

    Args:
      g: TT core, shape ``(r, n, m, k)`` = ``(r_{t-1}, n_t, m_t, r_t)``.
      x: input slab, shape ``(b, n, k)``.

    Returns:
      Output slab of shape ``(m, b, r)``.
    """
    return jnp.einsum("rnmk,bnk->mbr", g, x)


def einsum_loop_ref(g, x):
    """Second oracle mirroring the paper's Listing 2 loop nest.

    The same contraction expressed through explicit transpose/reshape/matmul
    so it exercises a *different* lowering than einsum_ref; used to
    cross-check the oracle itself.
    """
    r, n, m, k = g.shape
    b = x.shape[0]
    # G[r,n,m,k] -> (m, r, n*k); In[b,n,k] -> (n*k, b)
    gm = jnp.transpose(g, (2, 0, 1, 3)).reshape(m, r, n * k)
    xm = x.reshape(b, n * k).T
    out = jnp.einsum("mrq,qb->mbr", gm, xm)
    return out


def tt_forward_ref(x, cores, bias=None):
    """Reference forward pass of a TT-decomposed FC layer (paper Listing 1).

    Args:
      x: input of shape ``(B, N)`` with ``N = prod(n_t)``.
      cores: list of d arrays, core t (0-based) of shape
        ``(r_t, n_{t+1}, m_{t+1}, r_{t+1})`` with ``r_0 = r_d = 1``.
      bias: optional ``(M,)`` bias.

    Returns:
      ``(B, M)`` output, equal to ``x @ W.T + bias`` where W is the
      TT-reconstructed ``(M, N)`` matrix (row-major multi-index convention).
    """
    d = len(cores)
    batch = x.shape[0]
    cur = x.reshape(-1)  # row-major (batch, j_1, ..., j_d)
    total_m = 1
    for t in range(d - 1, -1, -1):
        g = cores[t]
        r_prev, n_t, m_t, r_t = g.shape
        bt = cur.size // (n_t * r_t)
        slab = cur.reshape(bt, n_t, r_t)
        out = einsum_ref(g, slab)  # (m_t, bt, r_prev)
        cur = out.reshape(-1)
        total_m *= m_t
    # Final layout is (i_1, ..., i_d, batch) = (M, B) row-major.
    y = cur.reshape(total_m, batch).T
    if bias is not None:
        y = y + bias
    return y


def tt_reconstruct(cores):
    """Materialize the dense ``(M, N)`` matrix a TT-core chain represents.

    W[(i_1..i_d), (j_1..j_d)] = G_1[:, j_1, i_1, :] @ ... @ G_d[:, j_d, i_d, :]
    with row-major multi-indices (i_1, j_1 most significant).
    """
    # acc carries (i_1..i_t, j_1..j_t, r_t) flattened as (Mt, Nt, r_t)
    acc = jnp.ones((1, 1, 1), dtype=cores[0].dtype)
    for g in cores:
        r_prev, n_t, m_t, r_t = g.shape
        # acc (Mp, Np, r_prev) x g (r_prev, n, m, r) -> (Mp, m, Np, n, r)
        acc = jnp.einsum("PQr,rnms->PmQns", acc, g)
        mp, m, np_, n, r = acc.shape
        acc = acc.reshape(mp * m, np_ * n, r)
    return acc[:, :, 0]


def tt_params(m_shape, n_shape, ranks):
    """Paper Eq. (4): parameter count of the factorized layer (incl. bias)."""
    d = len(m_shape)
    total = 1
    for m in m_shape:
        total *= m  # bias
    for t in range(d):
        total += ranks[t] * m_shape[t] * n_shape[t] * ranks[t + 1]
    return total


def tt_flops(m_shape, n_shape, ranks):
    """Paper Eq. (11): total FLOPs of the einsum chain (incl. bias adds)."""
    d = len(m_shape)
    total = 1
    for m in m_shape:
        total *= m  # bias adds
    for t in range(1, d + 1):  # paper is 1-based
        term = 2 * ranks[t] * ranks[t - 1]
        for u in range(t, d + 1):
            term *= m_shape[u - 1]
        for u in range(1, t + 1):
            term *= n_shape[u - 1]
        total += term
    return total
