# AOT bridge: lower the L2 entry points (model.py, which call the L1 Pallas
# kernels) to HLO *text* artifacts the Rust runtime loads via PJRT.
#
# HLO text — NOT ``lowered.compiler_ir().serialize()`` — is the interchange
# format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
# image's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
# parser reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
#
# Usage:  cd python && python -m compile.aot --outdir ../artifacts
# Python runs ONCE here; it is never on the request path.
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shape_entry(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(fn, arg_specs):
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def build_artifacts(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "artifacts": []}

    def emit(name: str, fn, arg_specs, note: str):
        text = lower_entry(fn, arg_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "args": [_shape_entry(s) for s in arg_specs],
            "note": note,
        })
        print(f"  wrote {fname} ({len(text)} chars, {len(arg_specs)} args)")

    # --- raw L1 kernel (middle-einsum, CB5-like size from paper Table 3) ---
    emit(
        "tt_einsum_middle_cb5", model.tt_einsum_flat,
        [spec((8, 7, 32, 8)), spec((9, 7, 8))],
        "einsum('rnmk,bnk->mbr') Pallas kernel, paper Table 3 CB5 middle",
    )

    # --- single TT FC layer: the paper's running example (LeNet300 l1,
    #     784 -> 300, d = 5, m = [5,5,3,2,2], n = [2,2,2,7,14], R = 8) ------
    m_shape, n_shape = (5, 5, 3, 2, 2), (2, 2, 2, 7, 14)
    ranks = (1, 8, 8, 8, 8, 1)
    cs = model.core_shapes(m_shape, n_shape, ranks)
    for batch in (1, 16):
        emit(
            f"tt_fc_784x300_d5_r8_b{batch}", model.tt_fc_forward_flat,
            [spec((batch, 784))] + [spec(s) for s in cs] + [spec((300,))],
            "paper Sec.2 running example layer, d=5 rank=8",
        )

    # --- single TT FC layer, d = 2 (the paper's Sec. 6.4 selection policy) -
    m2, n2, r2 = (20, 15), (28, 28), (1, 8, 1)
    cs2 = model.core_shapes(m2, n2, r2)
    for batch in (1, 16):
        emit(
            f"tt_fc_784x300_d2_r8_b{batch}", model.tt_fc_forward_flat,
            [spec((batch, 784))] + [spec(s) for s in cs2] + [spec((300,))],
            "Sec. 6.4 policy: min-FLOPs aligned d=2 solution, rank 8",
        )

    # --- dense FC baseline, same shape ------------------------------------
    for batch in (1, 16):
        emit(
            f"dense_fc_784x300_b{batch}", model.dense_fc_forward_flat,
            [spec((batch, 784)), spec((300, 784)), spec((300,))],
            "uncompressed FC baseline",
        )

    # --- full LeNet300 MLP, TT and dense, weights as runtime args ---------
    tt_params = model.init_mlp_tt(jax.random.PRNGKey(0))
    flat_specs = [spec(p.shape) for p in model.flatten_tt_mlp_params(tt_params)]
    for batch in (1, 16):
        emit(
            f"mlp_tt_b{batch}", model.mlp_tt_forward_flat,
            [spec((batch, 784))] + flat_specs,
            "LeNet300 MLP, l1+l2 TT-factorized (d=2, rank 8), l3 dense",
        )
        emit(
            f"mlp_dense_b{batch}", model.mlp_dense_forward_flat,
            [spec((batch, 784)), spec((300, 784)), spec((300,)),
             spec((100, 300)), spec((100,)), spec((10, 100)), spec((10,))],
            "LeNet300 MLP, dense",
        )

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description="AOT-lower L2 graphs to HLO text")
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None,
                    help="compat: also copy the mlp_tt_b16 artifact here")
    args = ap.parse_args()
    manifest = build_artifacts(args.outdir)
    if args.out:
        src = os.path.join(args.outdir, "mlp_tt_b16.hlo.txt")
        with open(src) as f, open(args.out, "w") as g:
            g.write(f.read())
    print(f"AOT done: {len(manifest['artifacts'])} artifacts in {args.outdir}")


if __name__ == "__main__":
    main()
