# L2 — the paper's compute graph in JAX: TT-decomposed FC layers (T3F
# formulation) composed into a LeNet300-style MLP, in both dense and TT form.
#
# Build-time only: aot.py lowers the jitted entry points below to HLO text;
# the Rust runtime (rust/src/runtime) loads and executes them via PJRT.
# Python is never on the request path.
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels import tt_einsum


# ---------------------------------------------------------------------------
# TT layer
# ---------------------------------------------------------------------------

def core_shapes(m_shape: Sequence[int], n_shape: Sequence[int],
                ranks: Sequence[int]):
    """T3F core shapes ``(r_{t-1}, n_t, m_t, r_t)`` for a TT-matrix."""
    d = len(m_shape)
    assert len(n_shape) == d and len(ranks) == d + 1
    assert ranks[0] == 1 and ranks[d] == 1
    return [(ranks[t], n_shape[t], m_shape[t], ranks[t + 1]) for t in range(d)]


def init_tt_cores(key, m_shape, n_shape, ranks, dtype=jnp.float32):
    """Glorot-style init matched to the reconstructed matrix variance.

    The reconstructed W entry is a sum over prod(ranks[1:-1]) paths of
    products of d core entries, so per-core std is chosen to give W roughly
    the variance of a Glorot-initialized (M, N) dense matrix.
    """
    m_total = 1
    for m in m_shape:
        m_total *= m
    n_total = 1
    for n in n_shape:
        n_total *= n
    d = len(m_shape)
    target_var = 2.0 / (m_total + n_total)
    rank_paths = 1
    for r in ranks[1:-1]:
        rank_paths *= r
    core_var = (target_var / rank_paths) ** (1.0 / d)
    cores = []
    for shape in core_shapes(m_shape, n_shape, ranks):
        key, sub = jax.random.split(key)
        cores.append(jax.random.normal(sub, shape, dtype) * jnp.sqrt(core_var))
    return cores


def tt_linear_apply(cores, bias, x, *, impl: str = "pallas",
                    interpret: bool = True):
    """Forward pass of a TT FC layer. ``impl`` in {"pallas", "jnp"}."""
    if impl == "pallas":
        return tt_einsum.tt_forward_pallas(x, cores, bias, interpret=interpret)
    if impl == "jnp":
        return ref.tt_forward_ref(x, cores, bias)
    raise ValueError(f"unknown impl {impl!r}")


def dense_apply(w, b, x):
    """Dense FC reference: ``x @ w.T + b`` with w of shape (M, N)."""
    return x @ w.T + b


# ---------------------------------------------------------------------------
# LeNet300-style MLP (784 -> 300 -> 100 -> 10), dense and TT variants.
# Layer factorizations follow the paper's §6.4 policy: minimum-FLOPs aligned
# solutions of configuration length two, rank a multiple of vl = 8. The final
# 100 -> 10 layer is left dense (the paper does not factorize tiny layers).
# ---------------------------------------------------------------------------

LENET300_TT_SPEC = {
    "l1": {"n_shape": (28, 28), "m_shape": (20, 15), "ranks": (1, 8, 1)},
    "l2": {"n_shape": (20, 15), "m_shape": (10, 10), "ranks": (1, 8, 1)},
    "l3_dense": {"n": 100, "m": 10},
}


def init_mlp_dense(key, dtype=jnp.float32):
    sizes = [(300, 784), (100, 300), (10, 100)]
    params = []
    for m, n in sizes:
        key, k1 = jax.random.split(key)
        w = jax.random.normal(k1, (m, n), dtype) * jnp.sqrt(2.0 / (m + n))
        params.append((w, jnp.zeros((m,), dtype)))
    return params


def init_mlp_tt(key, dtype=jnp.float32):
    spec = LENET300_TT_SPEC
    key, k1, k2, k3 = jax.random.split(key, 4)
    l1 = (init_tt_cores(k1, spec["l1"]["m_shape"], spec["l1"]["n_shape"],
                        spec["l1"]["ranks"], dtype),
          jnp.zeros((300,), dtype))
    l2 = (init_tt_cores(k2, spec["l2"]["m_shape"], spec["l2"]["n_shape"],
                        spec["l2"]["ranks"], dtype),
          jnp.zeros((100,), dtype))
    w3 = jax.random.normal(k3, (10, 100), dtype) * jnp.sqrt(2.0 / 110)
    l3 = (w3, jnp.zeros((10,), dtype))
    return (l1, l2, l3)


def mlp_dense_apply(params, x):
    (w1, b1), (w2, b2), (w3, b3) = params
    h = jax.nn.relu(dense_apply(w1, b1, x))
    h = jax.nn.relu(dense_apply(w2, b2, h))
    return dense_apply(w3, b3, h)


def mlp_tt_apply(params, x, *, impl="pallas", interpret=True):
    (c1, b1), (c2, b2), (w3, b3) = params
    h = jax.nn.relu(tt_linear_apply(c1, b1, x, impl=impl, interpret=interpret))
    h = jax.nn.relu(tt_linear_apply(c2, b2, h, impl=impl, interpret=interpret))
    return dense_apply(w3, b3, h)


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def mlp_tt_loss(params, x, labels, *, impl="jnp"):
    # jnp impl for the grad path: pallas interpret-mode grads are slow and
    # numerically identical (both lower to the same contraction).
    return cross_entropy(mlp_tt_apply(params, x, impl=impl), labels)


mlp_tt_grad = jax.grad(mlp_tt_loss)


# ---------------------------------------------------------------------------
# Flat-argument entry points for AOT lowering. PJRT executables take a flat
# list of buffers; these wrappers define the calling convention recorded in
# artifacts/manifest.json and relied upon by rust/src/runtime.
# ---------------------------------------------------------------------------

def flatten_tt_mlp_params(params):
    (c1, b1), (c2, b2), (w3, b3) = params
    return list(c1) + [b1] + list(c2) + [b2] + [w3, b3]


def unflatten_tt_mlp_params(flat):
    d1 = len(LENET300_TT_SPEC["l1"]["m_shape"])
    d2 = len(LENET300_TT_SPEC["l2"]["m_shape"])
    i = 0
    c1 = flat[i:i + d1]; i += d1
    b1 = flat[i]; i += 1
    c2 = flat[i:i + d2]; i += d2
    b2 = flat[i]; i += 1
    w3, b3 = flat[i], flat[i + 1]
    return ((c1, b1), (c2, b2), (w3, b3))


def mlp_tt_forward_flat(x, *flat_params):
    return (mlp_tt_apply(unflatten_tt_mlp_params(list(flat_params)), x),)


def mlp_dense_forward_flat(x, w1, b1, w2, b2, w3, b3):
    return (mlp_dense_apply(((w1, b1), (w2, b2), (w3, b3)), x),)


def tt_fc_forward_flat(x, *cores_and_bias):
    """Single TT FC layer: args are d cores followed by the bias."""
    cores, bias = list(cores_and_bias[:-1]), cores_and_bias[-1]
    return (tt_linear_apply(cores, bias, x, impl="pallas"),)


def dense_fc_forward_flat(x, w, b):
    return (dense_apply(w, b, x),)


def tt_einsum_flat(g, x):
    """The raw L1 kernel as its own artifact (kernel-level PJRT benches)."""
    return (tt_einsum.tt_einsum_pallas(g, x),)
