# L1 performance estimation report (DESIGN.md §Perf).
#
# interpret=True gives CPU-numpy timings that are NOT a TPU proxy, so the
# Pallas kernel is optimized *structurally*: for each candidate (TM, TB)
# block shape we report the per-grid-cell VMEM footprint and an MXU
# utilization estimate, and pick the best shape that fits VMEM.
#
# Usage: cd python && python -m compile.perf_report

from __future__ import annotations

from compile.kernels import tt_einsum as tk

# TPU-v4-ish envelope used for the estimates.
VMEM_BUDGET = 16 * 1024 * 1024  # bytes per core
MXU = 128

# The paper's Table 3 kernel instances (middle einsum; r = k = 8).
CASES = [
    ("CB0", 8, 2, 48, 8, 224),
    ("CB1", 8, 4, 64, 8, 3582),
    ("CB2", 8, 14, 96, 8, 128),
    ("CB3", 8, 32, 64, 8, 64),
    ("CB4", 8, 4, 256, 8, 128),
    ("CB5", 8, 7, 32, 8, 9),
    ("CB6", 8, 28, 4, 8, 16383),
    ("CB7", 8, 28, 64, 8, 1020),
]


def pick_block(r, n, m, k, b):
    """Best candidate: max MXU utilization among shapes fitting VMEM."""
    rows = tk.block_choice_report(r, n, m, k, b)
    fitting = [x for x in rows if x["vmem_bytes"] <= VMEM_BUDGET]
    pool = fitting or rows
    return max(pool, key=lambda x: (x["mxu_util"], -x["grid"])), rows


def main():
    print("== L1 Pallas BlockSpec sweep (structural TPU estimates) ==")
    print(f"{'case':<6} {'chosen TMxTB':>12} {'VMEM/cell':>12} {'MXU util':>9} {'grid':>6}")
    for name, r, n, m, k, b in CASES:
        best, _ = pick_block(r, n, m, k, b)
        print(
            f"{name:<6} {best['tm']:>5}x{best['tb']:<6} "
            f"{best['vmem_bytes'] / 1024:>9.1f}KB {best['mxu_util']:>8.2%} "
            f"{best['grid']:>6}"
        )
    print("\nfull sweep for CB1:")
    _, rows = pick_block(8, 4, 64, 8, 3582)
    for x in rows:
        print(
            f"  TM={x['tm']:<4} TB={x['tb']:<4} vmem={x['vmem_bytes'] / 1024:>8.1f}KB "
            f"mxu={x['mxu_util']:.2%} grid={x['grid']}"
        )


if __name__ == "__main__":
    main()
