//! Fig. 7 (ratio boxplots, Eq. 16-17) and Fig. 8 (aligned-vs-min/max
//! memory scatter) over a benchmark of aligned TTD configurations drawn
//! from the studied layers.
//!
//! The paper sweeps 374,256 configurations on all Table-1/2 layers; this
//! harness sweeps a representative subset (configurable via
//! TTRV_FIG7_CONFIGS, default 400) — the statistics it reports are the same
//! quantities.

use ttrv::dse::alignment_stats::{layer_ratio_study, sweep_permutations, AlignmentRatios};
use ttrv::factor;
use ttrv::util::stats;

fn main() {
    let max_configs: usize = std::env::var("TTRV_FIG7_CONFIGS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let layers: &[(u64, u64)] = &[
        (120, 400),   // LeNet5
        (300, 784),   // LeNet300
        (512, 512),   // VGG
        (1000, 2048), // ResNet/Xception
        (1024, 4096), // GPT2-Medium ffn
        (2048, 2048), // GPT3-Curie proj
    ];
    let ranks: Vec<u64> = (1..=48).step_by(8).collect();
    let mut all: Vec<AlignmentRatios> = Vec::new();
    for &(m, n) in layers {
        for d in 2..=4 {
            let budget = max_configs.saturating_sub(all.len());
            if budget == 0 {
                break;
            }
            all.extend(layer_ratio_study(m, n, d, &ranks, budget / layers.len().max(1) + 1));
        }
    }
    let flops: Vec<f64> = all.iter().map(|r| r.flops).collect();
    let mem: Vec<f64> = all.iter().map(|r| r.memory).collect();

    println!("== Fig. 7: normalized ratios over {} configurations ==", all.len());
    for (name, xs) in [("FLOPs ratio", &flops), ("memory ratio", &mem)] {
        println!(
            "{name}: min={:.4} p25={:.4} median={:.4} p75={:.4} max={:.4} mean={:.4}",
            stats::min_max(xs).0,
            stats::percentile(xs, 25.0),
            stats::median(xs),
            stats::percentile(xs, 75.0),
            stats::min_max(xs).1,
            stats::mean(xs)
        );
    }
    let flops_all_one = flops.iter().all(|&f| (f - 1.0).abs() < 1e-9);
    let mem_optimal_frac = mem.iter().filter(|&&m| (m - 1.0).abs() < 1e-9).count() as f64
        / mem.len().max(1) as f64;
    println!("FLOPs ratio collapses to 1.0 (paper Fig. 7): {flops_all_one}");
    println!(
        "fraction of configs with memory ratio == 1: {:.1}% (paper: ~30%)",
        100.0 * mem_optimal_frac
    );

    // ---- Fig. 8: aligned vs min/max memory in absolute terms ------------
    println!("\n== Fig. 8: aligned vs min/max memory (sample scatter rows) ==");
    println!("{:>12} {:>12} {:>12}", "aligned", "min(perm)", "max(perm)");
    let mut shown = 0;
    for &(m, n) in layers {
        for ms in factor::factor_multisets(m, 3).into_iter().take(2) {
            for ns in factor::factor_multisets(n, 3).into_iter().take(2) {
                let sweep = sweep_permutations(&ms, &ns, 8);
                if sweep.aligned_memory == u64::MAX {
                    continue;
                }
                let mmin = sweep.points.iter().map(|p| p.1).min().unwrap();
                let mmax = sweep.points.iter().map(|p| p.1).max().unwrap();
                println!("{:>12} {:>12} {:>12}", sweep.aligned_memory, mmin, mmax);
                assert!(sweep.aligned_memory <= mmax);
                shown += 1;
            }
        }
    }
    println!("({shown} configurations; aligned memory tracks the minimum, paper Fig. 8)");
}
