//! Fig. 9: speedup vs thread count for Einsum kernels of increasing FLOPs
//! on the (modeled) SpacemiT K1.
//!
//! The CI host has one core, so multi-thread *speedups* come from the
//! calibrated cost model (DESIGN.md §3); the thread-selection heuristic the
//! paper derives from this figure is reproduced exactly and the measured
//! single-core numbers anchor the model.

use ttrv::compiler::{compile, threads};
use ttrv::machine::costmodel::thread_speedup;
use ttrv::machine::MachineSpec;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};

fn dims_for_flops(target: u64) -> EinsumDims {
    let m = (target / (2 * 256 * 8 * 8 * 4)).max(1) as usize;
    EinsumDims { kind: EinsumKind::Middle, m, b: 256, n: 4, r: 8, k: 8 }
}

fn main() {
    let machine = MachineSpec::spacemit_k1();
    println!("== Fig. 9: modeled speedup vs threads (SpacemiT K1) ==");
    println!("{:>12} {:>8} {:>8} {:>8} {:>8}  best", "FLOPs", "T=1", "T=2", "T=3", "T=4");
    for target in [5e5, 1e6, 2e6, 3e6, 4e6, 6e6, 8e6, 2e7, 1e8] {
        let d = dims_for_flops(target as u64);
        let plan = compile(&d, &machine).unwrap();
        let speedups: Vec<f64> = (1..=4).map(|t| thread_speedup(&plan, &machine, t)).collect();
        let best = 1 + speedups
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        let heuristic = threads::threads_for(&d, &machine);
        println!(
            "{:>12} {:>8.2} {:>8.2} {:>8.2} {:>8.2}  model={best} heuristic={heuristic}",
            d.flops(),
            speedups[0],
            speedups[1],
            speedups[2],
            speedups[3]
        );
    }
    println!("\npaper thresholds: <2e6 -> 1T, 2-4e6 -> 2T, 4-8e6 -> 3T, >8e6 -> 4T");
}
