//! §Perf harness: register-blocking factor sweep on representative middle
//! Einsum kernels — validates the analytical RB solver's choice against
//! brute-force measurement on the host (EXPERIMENTS.md §Perf).

use ttrv::bench::{measure, BenchCfg};
use ttrv::compiler::plan::RbFactors;
use ttrv::compiler::{cb_suite, compile};
use ttrv::kernels::{pack, Executor};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::EinsumKind;
use ttrv::util::prng::Rng;

fn main() {
    let host = MachineSpec::host();
    let bcfg = BenchCfg::from_env();
    let mut rng = Rng::new(99);
    let candidates = [
        (1usize, 8usize),
        (2, 4),
        (2, 6),
        (2, 8),
        (4, 2),
        (4, 3),
        (4, 4),
        (4, 6),
        (8, 1),
        (8, 2),
    ];
    for idx in [3usize, 7] {
        let entry = cb_suite(EinsumKind::Middle)[idx];
        let mut dims = entry.dims;
        dims.b = dims.b.min(1024);
        let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, &mut rng);
        let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, &mut rng);
        let base = compile(&dims, &host).expect("plan");
        println!(
            "== RB sweep {} (m={} b={} n={} r={} k={}); solver chose ({}, {}) ==",
            entry.id, dims.m, dims.b, dims.n, dims.r, dims.k, base.rb.rm, base.rb.rb
        );
        let mut ex = Executor::new(&host);
        for (rm, rb) in candidates {
            let mut plan = base;
            plan.rb = RbFactors { rm, rb, rr: 1, rk: 1 };
            plan.threads = 1;
            ex.set_plan(plan).expect("plan");
            let pg = pack(&g, &plan).expect("pack");
            let m = measure(&format!("rm={rm} rb={rb}"), dims.flops(), &bcfg, || {
                ex.execute(&dims, &pg, &x).expect("exec");
            });
            let mark = if (rm, rb) == (base.rb.rm, base.rb.rb) { " <= solver" } else { "" };
            println!("  rm={rm} rb={rb}: {:>7.2} GF  (regs {}){mark}", m.gflops(), plan.rb.registers());
        }
    }
}
