//! Fig. 11: percentage of total execution time spent in FC layers.
//!
//! The paper uses the TFLite layer profiler on-device; here each layer's
//! latency comes from the same memory-bound machine model used throughout
//! (modeled K1, batch 1): t = max(flops / (vl * f), bytes / BW).

use ttrv::machine::MachineSpec;
use ttrv::models::{self, LayerSpec};

/// Modeled single-core latency of one layer at batch 1.
fn layer_seconds(l: &LayerSpec, machine: &MachineSpec) -> f64 {
    let flops = l.flops() as f64;
    let bytes = match *l {
        // weights + activations streamed once
        LayerSpec::Conv { c_in, c_out, k, out_h, out_w } => {
            4.0 * (c_in * c_out * k * k + c_out * out_h * out_w + c_in * out_h * out_w * 4) as f64
        }
        LayerSpec::Fc { n, m, tokens } => 4.0 * (n * m + (n + m) * tokens) as f64,
        LayerSpec::Embed { dim, .. } => 4.0 * dim as f64,
        LayerSpec::Norm { dim, tokens } => 4.0 * (2 * dim * tokens) as f64,
        LayerSpec::AttnMatmul { seq, dim } => 4.0 * (2 * seq * dim + seq * seq) as f64,
    };
    let compute = flops / (machine.peak_gflops_core() * 1e9);
    let memory = bytes / (machine.dram_gbps * 1e9);
    compute.max(memory)
}

fn main() {
    let machine = MachineSpec::spacemit_k1();
    println!("== Fig. 11: modeled FC share of execution time (K1, batch 1) ==");
    println!("{:<22} {:>12}", "model", "FC time %");
    for m in models::all_models() {
        // very large LLMs don't fit the device in the paper either; keep the
        // same set but note the substitution
        let mut fc = 0.0;
        let mut other = 0.0;
        for (l, count) in &m.layers {
            let t = layer_seconds(l, &machine) * *count as f64;
            if l.is_fc() {
                fc += t;
            } else {
                other += t;
            }
        }
        let share = 100.0 * fc / (fc + other);
        println!("{:<22} {:>11.1}%", m.name, share);
    }
    println!("\npaper anchors: LeNet300 97.6% | LLMs up to 86.1% | conv-heavy CNNs lower");
}
