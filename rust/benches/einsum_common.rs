//! Shared harness for Figs. 12-14: one CB-suite sweep of a kernel variant
//! measuring ours vs IREE-like vs Pluto-like, with modeled-K1 columns.
//! All three strategies run through the [`Executor`] entry point.

use ttrv::baselines::iree_like;
use ttrv::bench::{measure, BenchCfg, Measurement};
use ttrv::compiler::{cb_suite, compile};
use ttrv::kernels::{pack, tune_plan, Executor};
use ttrv::machine::{costmodel, MachineSpec};
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::EinsumKind;
use ttrv::util::prng::Rng;
use ttrv::util::stats;

pub struct FigRow {
    pub id: &'static str,
    pub flops: u64,
    pub ours: Measurement,
    pub iree: Measurement,
    pub pluto: Measurement,
    pub k1_model_gflops: f64,
}

pub fn run_suite(kind: EinsumKind, fig: &str) {
    let machine = MachineSpec::spacemit_k1();
    let host = MachineSpec::host();
    let bcfg = BenchCfg::from_env();
    let mut rng = Rng::new(12);
    let mut rows = Vec::new();
    let mut ex = Executor::new(&host);
    for entry in cb_suite(kind) {
        let d = entry.dims;
        let g = Tensor::randn(vec![d.r, d.n, d.m, d.k], 1.0, &mut rng);
        let x = Tensor::randn(vec![d.b, d.n, d.k], 1.0, &mut rng);
        let plan = compile(&d, &machine).expect("plan");
        // measured path: plan against the *host* description (16 vregs,
        // 1 core) — the compiler is machine-parameterized, so the measured
        // numbers reflect what it would deploy on this CPU, while the
        // modeled column uses the K1 plan (DESIGN.md §3)
        let mut host_plan = compile(&d, &host).expect("host plan");
        host_plan.threads = 1;
        // measured autotune over the solver's top candidates (§Perf iter 2)
        host_plan = tune_plan(&host_plan, &host, &g, &x, 6).expect("tune");
        ex.set_plan(host_plan).expect("plan");
        let pg = pack(&g, &host_plan).expect("pack");
        let gm = iree_like::prepare_g(&g).expect("prep");
        let ours = measure(&format!("{} ours", entry.id), d.flops(), &bcfg, || {
            ex.execute(&d, &pg, &x).expect("kernel");
        });
        let iree = measure(&format!("{} iree", entry.id), d.flops(), &bcfg, || {
            ex.execute_iree_prepared(&gm, d.r, &x).expect("iree");
        });
        let pluto = measure(&format!("{} pluto", entry.id), d.flops(), &bcfg, || {
            ex.execute_pluto_like(&g, &x).expect("pluto");
        });
        rows.push(FigRow {
            id: entry.id,
            flops: d.flops(),
            ours,
            iree,
            pluto,
            k1_model_gflops: costmodel::gflops(&plan, &machine),
        });
    }

    println!("== {fig}: {kind:?} Einsum kernel, CB0-CB7 (measured host + modeled K1) ==");
    println!(
        "{:<5} {:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "CB", "FLOPs", "ours", "iree", "pluto", "vs iree", "vs pluto", "K1 model"
    );
    let mut s_iree = Vec::new();
    let mut s_pluto = Vec::new();
    for r in &rows {
        let vi = r.iree.seconds / r.ours.seconds;
        let vp = r.pluto.seconds / r.ours.seconds;
        s_iree.push(vi);
        s_pluto.push(vp);
        println!(
            "{:<5} {:>10} {:>7.2}GF {:>7.2}GF {:>7.2}GF {:>8.2}x {:>8.2}x {:>8.2}GF",
            r.id,
            r.flops,
            r.ours.gflops(),
            r.iree.gflops(),
            r.pluto.gflops(),
            vi,
            vp,
            r.k1_model_gflops
        );
    }
    println!(
        "geomean speedup: vs IREE-like {:.2}x | vs Pluto-like {:.2}x  (paper avg: ~3x / ~8x overall)",
        geomean(&s_iree),
        geomean(&s_pluto)
    );
    println!(
        "mean measured GFLOP/s (ours): {:.2}",
        stats::mean(&rows.iter().map(|r| r.ours.gflops()).collect::<Vec<_>>())
    );
}

pub fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}
