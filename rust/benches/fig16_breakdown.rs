//! Fig. 16: progressive-optimization breakdown on the factorized layers of
//! Sec. 6.4 at rank 16 — GCC-O3-style naive, +vectorization/packing,
//! +RB/tiling, +parallelization (modeled; 1 host core).

use ttrv::bench::{measure, BenchCfg};
use ttrv::compiler::pipeline::{compile_stage, OptStage};
use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::dse;
use ttrv::kernels::{pack, Executor};
use ttrv::machine::{costmodel, MachineSpec};
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::einsum_chain;
use ttrv::util::prng::Rng;

fn main() {
    let machine = MachineSpec::spacemit_k1();
    // the paper uses rank 16 here
    let cfg = DseConfig { ranks: vec![16], ..Default::default() };
    let bcfg = BenchCfg::from_env();
    let mut rng = Rng::new(16);
    let models: Vec<(&str, Vec<(u64, u64)>)> = vec![
        ("ResNet", vec![(2048, 1000)]),
        ("VGG", vec![(512, 512), (512, 256)]),
        ("AlexNet", vec![(4096, 2048), (2048, 2048)]),
        ("GPT2-M", vec![(1024, 1024)]),
    ];
    let stages = [OptStage::Naive, OptStage::VecPack, OptStage::RbTile, OptStage::Parallel];

    println!("== Fig. 16: speedup over naive per optimization stage (rank 16) ==");
    println!(
        "{:<10} {:>9} {:>12} {:>12} {:>14}",
        "model", "naive", "+vec/pack", "+RB/tile", "+par (modeled)"
    );
    let mut geo: Vec<[f64; 4]> = Vec::new();
    for (name, layers) in &models {
        let mut totals = [0.0f64; 4];
        for &(n, m) in layers {
            let e = dse::explore_timed(m, n, &machine, &cfg);
            let Ok(sol) = dse::select_solution(&e, 16, SelectionPolicy::Balance) else {
                continue;
            };
            let chain = einsum_chain(sol.layout(), 1);
            let cores: Vec<Tensor> = sol
                .layout()
                .core_shapes()
                .into_iter()
                .map(|s| Tensor::randn(s.to_vec(), 0.2, &mut rng))
                .collect();
            let x0 = rng.normal_vec(sol.layout().n_total() as usize, 1.0);
            let mut layer_rbtile = 0.0f64;
            for (si, stage) in stages.iter().enumerate() {
                let plans: Vec<_> = chain
                    .iter()
                    .map(|d| compile_stage(d, &machine, *stage).unwrap())
                    .collect();
                if *stage == OptStage::Parallel {
                    // 1-core host: take THIS layer's measured RbTile time and
                    // apply the modeled parallel speedup (DESIGN.md §3)
                    let model_speedup: f64 = plans
                        .iter()
                        .map(|p| {
                            let single = ttrv::compiler::OptimizationPlan { threads: 1, ..*p };
                            costmodel::estimate(&single, &machine).seconds()
                                / costmodel::estimate(p, &machine).seconds()
                        })
                        .sum::<f64>()
                        / plans.len() as f64;
                    totals[si] += layer_rbtile / model_speedup.max(1.0);
                    continue;
                }
                let packed: Vec<_> = plans
                    .iter()
                    .enumerate()
                    .map(|(i, p)| pack(&cores[sol.layout().d() - 1 - i], p).unwrap())
                    .collect();
                // one Executor per stage: the staged plans override the
                // cache for the same chain dims
                let mut ex = Executor::new(&machine);
                for p in &plans {
                    ex.set_plan(*p).expect("plan");
                }
                let mes = measure("stage", sol.solution.flops, &bcfg, || {
                    let mut cur = x0.clone();
                    let mut out = Vec::new();
                    for (d, g) in chain.iter().zip(&packed) {
                        ex.execute_into(d, g, &cur, &mut out).unwrap();
                        std::mem::swap(&mut cur, &mut out);
                    }
                });
                totals[si] += mes.seconds;
                if *stage == OptStage::RbTile {
                    layer_rbtile = mes.seconds;
                }
            }
        }
        let s = |i: usize| totals[0] / totals[i];
        println!(
            "{:<10} {:>8.2}x {:>11.2}x {:>11.2}x {:>13.2}x",
            name,
            1.0,
            s(1),
            s(2),
            s(3)
        );
        geo.push([1.0, s(1), s(2), s(3)]);
    }
    let gm = |i: usize| {
        (geo.iter().map(|g| g[i].ln()).sum::<f64>() / geo.len() as f64).exp()
    };
    println!(
        "\ngeomean: +vec/pack {:.1}x | +RB/tile {:.1}x | +par {:.1}x \
         (paper: ~9x, ~2x more, ~1.7x more; overall ~37x)",
        gm(1),
        gm(2),
        gm(3)
    );
}
