//! Fig. 13: Middle-Einsum kernel (r = k = 8), CB0-CB7 — ours vs IREE-like
//! vs Pluto-like, GFLOP/s.

#[path = "einsum_common.rs"]
mod einsum_common;

fn main() {
    einsum_common::run_suite(ttrv::ttd::cost::EinsumKind::Middle, "Fig. 13");
}
