//! Fig. 12: First-Einsum kernel (k = r_d = 1), CB0-CB7 — ours vs IREE-like
//! vs Pluto-like, GFLOP/s.

#[path = "einsum_common.rs"]
mod einsum_common;

fn main() {
    einsum_common::run_suite(ttrv::ttd::cost::EinsumKind::First, "Fig. 12");
}
