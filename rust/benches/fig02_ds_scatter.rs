//! Fig. 2: (a) the params-vs-FLOPs design space of the FC layer 120x84,
//! full and filtered to solutions beating the initial layer; (b) FLOPs vs
//! *measured* execution time for sampled solutions (showing FLOPs and time
//! do not always align).

use ttrv::bench::{measure, BenchCfg};
use ttrv::config::DseConfig;
use ttrv::kernels::Executor;
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{self, einsum_chain};
use ttrv::util::prng::Rng;

fn main() {
    // ---- Fig. 2a: the design space of [120, 84] -------------------------
    let mut cfg = DseConfig::default();
    // admit every rank 1..=max for the scatter (the paper plots all)
    cfg.ranks = (1..=64).collect();
    cfg.vl = 1; // no vectorization filter for the raw scatter
    let sols = ttrv::dse::space::enumerate_aligned(84, 120, &cfg);
    let dense_p = cost::dense_params(84, 120);
    let dense_f = cost::dense_flops(84, 120);
    let better = sols
        .iter()
        .filter(|s| s.params < dense_p && s.flops < dense_f)
        .count();
    println!("== Fig. 2a: DS of FC 120x84 (aligned configurations) ==");
    println!("initial layer: params={dense_p} flops={dense_f}");
    println!("aligned solutions: {} | beating the initial layer: {}", sols.len(), better);
    println!("sample (params, flops) points:");
    for s in sols.iter().step_by((sols.len() / 15).max(1)) {
        let mark = if s.params < dense_p && s.flops < dense_f { "*" } else { " " };
        println!("  {mark} params={:<8} flops={:<8} {}", s.params, s.flops, s.layout.describe());
    }

    // ---- Fig. 2b: FLOPs vs measured time --------------------------------
    println!("\n== Fig. 2b: FLOPs vs measured execution time (rank-8 solutions) ==");
    let machine = MachineSpec::spacemit_k1();
    let bcfg = BenchCfg::from_env();
    let mut rng = Rng::new(2);
    let cfg8 = DseConfig::default();
    let sols8 = ttrv::dse::space::enumerate_aligned(84, 120, &cfg8);
    println!("{:>10} {:>12} {:>10}", "flops", "time", "layout");
    let mut rows: Vec<(u64, f64, String)> = Vec::new();
    let mut ex = Executor::new(&machine);
    for s in sols8.iter().take(12) {
        // execute the whole einsum chain at batch 1 through the Executor
        let chain = einsum_chain(&s.layout, 1);
        let cores: Vec<Tensor> = s
            .layout
            .core_shapes()
            .into_iter()
            .map(|sh| Tensor::randn(sh.to_vec(), 0.3, &mut rng))
            .collect();
        let packed: Vec<_> = chain
            .iter()
            .enumerate()
            .map(|(i, d)| ex.pack(&cores[s.layout.d() - 1 - i], d).unwrap())
            .collect();
        let x0 = rng.normal_vec(s.layout.n_total() as usize, 1.0);
        let mes = measure("chain", s.flops, &bcfg, || {
            ex.run_tt_chain(&s.layout, 1, &packed, &x0).unwrap();
        });
        rows.push((s.flops, mes.seconds, s.layout.describe()));
    }
    rows.sort_by_key(|r| r.0);
    for (f, t, l) in &rows {
        println!("{:>10} {:>12} {}", f, ttrv::bench::format_secs(*t), l);
    }
    // the Fig. 2b observation: time is not monotone in FLOPs
    let monotone = rows.windows(2).all(|w| w[0].1 <= w[1].1 * 1.05);
    println!("\ntime monotone in FLOPs? {monotone} (paper: No — Fig. 2b)");
}
