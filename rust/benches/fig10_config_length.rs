//! Fig. 10: FLOPs of all aligned solutions of the largest AlexNet FC layer
//! (9216 -> 4096) at fixed rank 8, grouped by configuration length —
//! demonstrating that lengths beyond four stop reducing FLOPs.

use ttrv::config::DseConfig;
use ttrv::dse::space::enumerate_aligned;

fn main() {
    let mut cfg = DseConfig::default();
    cfg.ranks = vec![8];
    cfg.d_max = 12;
    let sols = enumerate_aligned(4096, 9216, &cfg);
    println!("== Fig. 10: FLOPs by configuration length (AlexNet 9216x4096, R=8) ==");
    println!("{:>3} {:>8} {:>14} {:>14} {:>14}", "d", "#sols", "min FLOPs", "median", "max");
    let mut mins = Vec::new();
    for d in 2..=12usize {
        let mut flops: Vec<u64> = sols
            .iter()
            .filter(|s| s.layout.d() == d)
            .map(|s| s.flops)
            .collect();
        if flops.is_empty() {
            continue;
        }
        flops.sort_unstable();
        let min = flops[0];
        println!(
            "{:>3} {:>8} {:>14} {:>14} {:>14}",
            d,
            flops.len(),
            min,
            flops[flops.len() / 2],
            flops[flops.len() - 1]
        );
        mins.push((d, min));
    }
    // paper claim: d > 4 yields no significant further FLOPs reduction
    if let (Some(&(_, min4)), Some(last)) =
        (mins.iter().find(|(d, _)| *d == 4), mins.last())
    {
        let gain = min4 as f64 / last.1 as f64;
        println!(
            "\nmin-FLOPs(d=4) / min-FLOPs(d={}) = {:.2} (paper: lengths > 4 do not \
             yield significant reductions)",
            last.0, gain
        );
    }
}
