//! Fig. 1: parameters and FLOPs percentage of FC vs non-FC parts across the
//! model zoo. Regenerates both bar series.

fn main() {
    println!("== Fig. 1: FC vs non-FC share (params | FLOPs) ==");
    println!("{:<22} {:>12} {:>12} {:>14} {:>12}", "model", "params", "FC-param%", "FLOPs", "FC-FLOPs%");
    for m in ttrv::models::all_models() {
        let (fc_p, other_p) = m.params_split();
        let (fc_f, other_f) = m.flops_split();
        println!(
            "{:<22} {:>12} {:>11.1}% {:>14} {:>11.1}%",
            m.name,
            fc_p + other_p,
            m.fc_param_share(),
            fc_f + other_f,
            m.fc_flops_share()
        );
    }
    println!("\npaper shape check: LLMs ~100% FC FLOPs; ImageNet CNNs <15% FC FLOPs;");
    println!("VGG16/AlexNet param share dominated by FC. See EXPERIMENTS.md Fig.1.");
}
