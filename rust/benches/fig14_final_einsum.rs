//! Fig. 14: Final-Einsum kernel (r = r_0 = 1, k-loop vectorized with
//! horizontal adds), CB0-CB7 — ours vs IREE-like vs Pluto-like, GFLOP/s.

#[path = "einsum_common.rs"]
mod einsum_common;

fn main() {
    einsum_common::run_suite(ttrv::ttd::cost::EinsumKind::Final, "Fig. 14");
}
