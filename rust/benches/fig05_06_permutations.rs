//! Figs. 5-6: FLOPs and memory across all shape permutations for the
//! paper's two studied layers — CNN (9216, 4096) and LLM (2048, 2048) —
//! at three configurations each, aligned permutation highlighted.

use ttrv::dse::alignment_stats::{ratios, sweep_permutations};

fn run_config(title: &str, ms: &[u64], ns: &[u64], rank: u64) {
    let sweep = sweep_permutations(ms, ns, rank);
    let fmin = sweep.points.iter().map(|p| p.0).min().unwrap();
    let fmax = sweep.points.iter().map(|p| p.0).max().unwrap();
    let mmin = sweep.points.iter().map(|p| p.1).min().unwrap();
    let mmax = sweep.points.iter().map(|p| p.1).max().unwrap();
    let r = ratios(&sweep);
    println!("-- {title}: m={ms:?} n={ns:?} R={rank} ({} permutation pairs)", sweep.points.len());
    println!(
        "   FLOPs : aligned={:<12} min={:<12} max={:<12} ratio={:.3}",
        sweep.aligned_flops, fmin, fmax, r.flops
    );
    println!(
        "   memory: aligned={:<12} min={:<12} max={:<12} ratio={:.3}",
        sweep.aligned_memory, mmin, mmax, r.memory
    );
    assert_eq!(sweep.aligned_flops, fmin, "paper claim: aligned is FLOPs-minimal");
}

fn main() {
    println!("== Fig. 5: CNN layer (M,N) = (4096, 9216) permutation sweeps ==");
    // three d=3/d=4 configurations of the AlexNet ImageNet layer
    run_config("cfg1", &[16, 16, 16], &[24, 24, 16], 4);
    run_config("cfg2", &[32, 16, 8], &[32, 18, 16], 4);
    run_config("cfg3", &[64, 8, 8], &[96, 32, 3], 4);
    println!("\n== Fig. 6: LLM layer (M,N) = (2048, 2048) permutation sweeps ==");
    run_config("cfg1", &[16, 16, 8], &[16, 16, 8], 4);
    run_config("cfg2", &[32, 8, 8], &[8, 16, 16], 4);
    run_config("cfg3", &[128, 16], &[32, 64], 8);
    println!("\nshape check: aligned permutation always achieves minimum FLOPs");
    println!("and near-minimum memory (paper Figs. 5-6).");
}
