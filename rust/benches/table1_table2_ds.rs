//! Tables 1-2: design-space reduction per FC layer for every CNN and LLM
//! in the paper's evaluation.

use ttrv::config::DseConfig;
use ttrv::dse::report::{format_rows, rows_for_model};
use ttrv::models;

fn main() {
    let cfg = DseConfig::default();
    let mut cnn_rows = Vec::new();
    for m in models::cnn_models() {
        cnn_rows.extend(rows_for_model(&m, &cfg));
    }
    print!("{}", format_rows("Table 1: design-space reduction (CNN models)", &cnn_rows));

    let mut llm_rows = Vec::new();
    for m in models::llm_models() {
        llm_rows.extend(rows_for_model(&m, &cfg));
    }
    print!("{}", format_rows("Table 2: design-space reduction (LLM models)", &llm_rows));

    // shape checks the paper states in Sec. 6.2
    let max_all = cnn_rows
        .iter()
        .chain(&llm_rows)
        .map(|r| r.counts.all)
        .fold(0.0f64, f64::max);
    println!("\nlargest raw design space: {:.1e} (paper: up to ~4.9e33 under its counting model)", max_all);
    let all_reduce: Vec<f64> = cnn_rows
        .iter()
        .chain(&llm_rows)
        .filter(|r| r.counts.aligned > 0.0)
        .map(|r| r.counts.all / r.counts.aligned)
        .collect();
    let geo = (all_reduce.iter().map(|x| x.ln()).sum::<f64>() / all_reduce.len() as f64).exp();
    println!(
        "alignment-stage reduction: geomean {:.1}x across {} layers (paper: 2.1x-92x)",
        geo,
        all_reduce.len()
    );
}
