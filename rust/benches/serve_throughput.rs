//! Serving throughput under load: sweep `workers x max_batch` on the
//! TT-compressed LeNet300 coordinator and report requests/sec plus
//! p50/p99 end-to-end latency per configuration.
//!
//! This is the scaling companion to the paper's kernel figures: Figs.
//! 12-16 show the TT kernels are fast in isolation; this harness shows
//! the worker pool keeps them fed. On a multi-core host, req/s at
//! `workers = 4` should clearly exceed `workers = 1` for the same
//! `max_batch` (each worker owns its own executor over the shared
//! compiled model, so scaling is lock-free on the hot path).
//!
//! Run: `cargo bench --bench serve_throughput` (honors TTRV_BENCH_QUICK=1).

use std::sync::Arc;
use std::time::Instant;

use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{InferenceRequest, LayerOp, ModelEngine, Route, Server, TtFcEngine};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// DSE-routed TT LeNet300, built once; every sweep point serves a
/// [`ModelEngine::worker_clone`] of it, so identical weights are
/// guaranteed by `Arc` sharing rather than by seed discipline.
fn build_engine() -> ModelEngine {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut rng = Rng::new(42);
    let mut ops = Vec::new();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg).expect("policy") {
            Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), &mut rng);
                tt.bias = Some(vec![0.0; m as usize]);
                ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).expect("compile layer")));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
                ops.push(LayerOp::Dense(
                    ttrv::baselines::dense::DenseFc::new(&w, None).expect("dense layer"),
                ));
            }
        }
        if i + 1 < shapes.len() {
            ops.push(LayerOp::Relu);
        }
    }
    ModelEngine::new("lenet300-tt", ops, 784, 10)
}

struct Outcome {
    workers: usize,
    max_batch: usize,
    reqs_per_sec: f64,
    p50_us: u64,
    p99_us: u64,
    mean_batch: f64,
}

/// Fire `requests` total from `clients` submitter threads (tight burst per
/// client, then drain replies) and measure wall time to the last reply.
fn run_config(
    model: &ModelEngine,
    workers: usize,
    max_batch: usize,
    requests: usize,
    clients: usize,
) -> Outcome {
    let cfg = ServeConfig {
        max_batch,
        max_wait_us: 200,
        queue_cap: requests.max(1024),
        workers,
    };
    cfg.validate().expect("bench config");
    let server = Arc::new(Server::start(model.worker_clone(), cfg));

    // pre-generate every input so the measured window is submission +
    // batching + execution, not RNG time
    let per_client = requests / clients;
    let traces: Vec<Vec<Vec<f32>>> = (0..clients)
        .map(|c| {
            let mut rng = Rng::new(1000 + c as u64);
            (0..per_client).map(|_| rng.normal_vec(784, 1.0)).collect()
        })
        .collect();

    let t0 = Instant::now();
    let handles: Vec<_> = traces
        .into_iter()
        .enumerate()
        .map(|(c, trace)| {
            let server = Arc::clone(&server);
            std::thread::spawn(move || {
                let mut rxs = Vec::with_capacity(trace.len());
                for (i, input) in trace.into_iter().enumerate() {
                    let id = (c * 1_000_000 + i) as u64;
                    // the queue is sized for the full burst, but stay
                    // correct under backpressure: retry politely on Full
                    loop {
                        match server.submit(InferenceRequest { id, input: input.clone() }) {
                            Ok(rx) => {
                                rxs.push(rx);
                                break;
                            }
                            Err(ttrv::Error::QueueFull) => std::thread::yield_now(),
                            Err(e) => panic!("submit failed: {e}"),
                        }
                    }
                }
                for rx in rxs {
                    rx.recv().expect("reply").expect("inference ok");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    let served = per_client * clients;
    Outcome {
        workers,
        max_batch,
        reqs_per_sec: served as f64 / elapsed,
        p50_us: m.latency.percentile_us(50.0),
        p99_us: m.latency.percentile_us(99.0),
        mean_batch: m.mean_batch(),
    }
}

fn main() {
    let quick = std::env::var("TTRV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let requests = if quick { 256 } else { 2000 };
    let clients = 4;
    let worker_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let batch_caps: &[usize] = if quick { &[8] } else { &[1, 8, 32] };

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "== serve_throughput: TT LeNet300, {requests} requests, {clients} clients, {cores} core(s) =="
    );
    println!(
        "{:>7} {:>9} {:>10} {:>9} {:>9} {:>10}",
        "workers", "max_batch", "req/s", "p50(us)", "p99(us)", "mean_batch"
    );

    let model = build_engine();
    let mut outcomes: Vec<Outcome> = Vec::new();
    for &mb in batch_caps {
        for &w in worker_counts {
            let o = run_config(&model, w, mb, requests, clients);
            println!(
                "{:>7} {:>9} {:>10.0} {:>9} {:>9} {:>10.2}",
                o.workers, o.max_batch, o.reqs_per_sec, o.p50_us, o.p99_us, o.mean_batch
            );
            outcomes.push(o);
        }
    }

    // scaling digest: best pool vs single worker at each batch cap
    for &mb in batch_caps {
        let single = outcomes
            .iter()
            .find(|o| o.max_batch == mb && o.workers == 1)
            .expect("single-worker point");
        let best = outcomes
            .iter()
            .filter(|o| o.max_batch == mb)
            .max_by(|a, b| a.reqs_per_sec.total_cmp(&b.reqs_per_sec))
            .expect("sweep point");
        println!(
            "max_batch {:>3}: {:>4.2}x scaling ({} -> {} workers, {:.0} -> {:.0} req/s)",
            mb,
            best.reqs_per_sec / single.reqs_per_sec,
            single.workers,
            best.workers,
            single.reqs_per_sec,
            best.reqs_per_sec
        );
    }
    if cores == 1 {
        println!("note: single-core host — pool scaling is not expected here");
    }
}
