//! Serving throughput under load: sweep `workers x max_batch x models` on
//! TT-compressed LeNet300 + LeNet5 co-hosted in one coordinator and report
//! requests/sec plus p50/p99 end-to-end latency per (configuration, model).
//!
//! This is the scaling companion to the paper's kernel figures: Figs.
//! 12-16 show the TT kernels are fast in isolation; this harness shows
//! the worker pool keeps them fed. On a multi-core host, req/s at
//! `workers = 4` should clearly exceed `workers = 1` for the same
//! `max_batch` (each worker owns its own executor over the shared
//! compiled model, so scaling is lock-free on the hot path). The
//! two-model points show what co-hosting costs: batches never mix
//! models, so per-model throughput at `models = 2` is the sharing tax.
//!
//! The sweep is written to `BENCH_serve.json` (schema `ttrv-bench-serve`
//! v2: one row per point x hosted model, plus the final server's
//! machine-readable snapshot), the same file `ttrv bench` maintains.
//!
//! Run: `cargo bench --bench serve_throughput` (honors TTRV_BENCH_QUICK=1).

use ttrv::bench::harness::{self, run_serve_sweep, serve_report_json, write_report, ServePoint};
use ttrv::config::DseConfig;
use ttrv::coordinator::{LayerOp, ModelEngine, Route, TtFcEngine};
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// DSE-route an FC stack into a TT/dense engine with seeded random
/// weights; built once per model, every sweep point serves a
/// [`ModelEngine::worker_clone`], so identical weights are guaranteed by
/// `Arc` sharing rather than by seed discipline.
fn build_engine(name: &str, shapes: &[(u64, u64)], seed: u64) -> ModelEngine {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut rng = Rng::new(seed);
    let mut ops = Vec::new();
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg).expect("policy") {
            Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), &mut rng);
                tt.bias = Some(vec![0.0; m as usize]);
                ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine).expect("compile layer")));
            }
            Route::Dense => {
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
                ops.push(LayerOp::Dense(
                    ttrv::baselines::dense::DenseFc::new(&w, None).expect("dense layer"),
                ));
            }
        }
        if i + 1 < shapes.len() {
            ops.push(LayerOp::Relu);
        }
    }
    let in_dim = shapes[0].0 as usize;
    let out_dim = shapes[shapes.len() - 1].1 as usize;
    ModelEngine::new(name, ops, in_dim, out_dim)
}

fn main() {
    let quick = std::env::var("TTRV_BENCH_QUICK").map(|v| v == "1").unwrap_or(false);
    let requests = if quick { 256 } else { 2000 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    let models =
        [build_engine("lenet300-tt", &[(784, 300), (300, 100), (100, 10)], 42), build_engine(
            "lenet5-tt",
            &[(400, 120), (120, 84), (84, 10)],
            43,
        )];
    let points = harness::default_serve_points(quick);
    println!(
        "== serve_throughput: TT LeNet300 + LeNet5, {requests} requests/point, {} point(s), {cores} core(s) ==",
        points.len()
    );
    println!(
        "{:>7} {:>9} {:>7} {:>12} {:>10} {:>9} {:>9} {:>10}",
        "workers", "max_batch", "models", "model", "req/s", "p50(us)", "p99(us)", "mean_batch"
    );

    let (rows, snapshot) = run_serve_sweep(&models, &points, requests).expect("serve sweep");
    for r in &rows {
        println!(
            "{:>7} {:>9} {:>7} {:>12} {:>10.0} {:>9} {:>9} {:>10.2}",
            r.point.workers,
            r.point.max_batch,
            r.point.models,
            r.model,
            r.req_per_s,
            r.p50_us,
            r.p99_us,
            r.mean_batch
        );
    }

    // scaling digest over the single-model rows: best pool vs one worker
    // at each batch cap
    let single_model: Vec<_> = rows.iter().filter(|r| r.point.models == 1).collect();
    let mut caps: Vec<usize> = single_model.iter().map(|r| r.point.max_batch).collect();
    caps.sort_unstable();
    caps.dedup();
    for mb in caps {
        let Some(one) = single_model
            .iter()
            .find(|r| r.point.max_batch == mb && r.point.workers == 1)
        else {
            continue;
        };
        let best = single_model
            .iter()
            .filter(|r| r.point.max_batch == mb)
            .max_by(|a, b| a.req_per_s.total_cmp(&b.req_per_s))
            .expect("sweep point");
        println!(
            "max_batch {:>3}: {:>4.2}x scaling ({} -> {} workers, {:.0} -> {:.0} req/s)",
            mb,
            best.req_per_s / one.req_per_s,
            one.point.workers,
            best.point.workers,
            one.req_per_s,
            best.req_per_s
        );
    }
    // co-hosting digest: per-model throughput with a neighbor present
    for r in rows.iter().filter(|r| r.point.models > 1) {
        println!(
            "co-hosted {} @ workers {} max_batch {}: {:.0} req/s",
            r.model, r.point.workers, r.point.max_batch, r.req_per_s
        );
    }
    if cores == 1 {
        println!("note: single-core host — pool scaling is not expected here");
    }

    let report = serve_report_json(&rows, quick, &snapshot);
    write_report(harness::BENCH_SERVE_FILE, &report).expect("write BENCH_serve.json");
    println!("wrote {} ({} rows)", harness::BENCH_SERVE_FILE, rows.len());
}
