//! Fig. 15: end-to-end FC-portion speedup of the TT-factorized models
//! (DSE-selected d=2, rank-8 solutions) over the uncompressed dense MMM
//! baseline ("IREE without LRF"), across the paper's six models.

use ttrv::baselines::dense::DenseFc;
use ttrv::bench::{format_secs, measure, BenchCfg};
use ttrv::config::{DseConfig, SelectionPolicy};
use ttrv::coordinator::TtFcEngine;
use ttrv::dse;
use ttrv::machine::MachineSpec;
use ttrv::tensor::Tensor;
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// The paper's Fig. 15 model set with their factorized FC layers
/// (Sec. 6.4 list; tiny heads excluded as in the paper).
fn model_layers() -> Vec<(&'static str, Vec<(u64, u64)>)> {
    vec![
        ("ResNet", vec![(2048, 1000)]),
        ("Xception", vec![(2048, 1000)]),
        ("VGG", vec![(512, 512), (512, 256), (256, 100)]),
        ("GoogleNet", vec![(1024, 1000)]),
        ("AlexNet", vec![(4096, 2048), (2048, 2048)]),
        ("GPT2-M", vec![(1024, 1024), (4096, 1024), (1024, 4096)]),
    ]
}

fn main() {
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let bcfg = BenchCfg::from_env();
    let mut rng = Rng::new(15);
    let batch = 1usize;

    println!("== Fig. 15: FC speedup over uncompressed dense MMM (batch {batch}) ==");
    println!(
        "{:<10} {:>12} {:>12} {:>9} {:>10} {:>10}",
        "model", "dense", "TT (ours)", "speedup", "K1 model", "compress"
    );
    let mut speedups = Vec::new();
    let mut k1_speedups = Vec::new();
    for (name, layers) in model_layers() {
        let mut dense_total = 0.0;
        let mut tt_total = 0.0;
        let mut dense_k1 = 0.0;
        let mut tt_k1 = 0.0;
        let mut dense_params = 0u64;
        let mut tt_params = 0u64;
        for &(n, m) in &layers {
            // dense baseline
            let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
            let fc = DenseFc::new(&w, None).unwrap();
            let x = Tensor::randn(vec![batch, n as usize], 1.0, &mut rng);
            dense_total += measure("dense", fc.flops(batch), &bcfg, || {
                fc.forward(&x).expect("dense");
            })
            .seconds;
            dense_params += ttrv::ttd::cost::dense_params(m, n);

            // TT path with the engine-selected, time-qualified solution
            let e = dse::explore_timed(m, n, &machine, &cfg);
            let sol =
                dse::select_solution(&e, 8, SelectionPolicy::Balance).expect("solution");
            let tt = random_cores(sol.layout(), &mut rng);
            // measured path: host-planned + autotuned engine (§Perf iter 2)
            let mut engine = TtFcEngine::new(&tt, &MachineSpec::host())
                .unwrap()
                .with_tuning();
            tt_total += measure("tt", sol.solution.flops, &bcfg, || {
                engine.forward(&x).expect("tt");
            })
            .seconds;
            tt_params += sol.solution.params;

            // modeled-K1 comparison straight from the stage-6 pricing: the
            // engine already ran dense MMM (an r=k=1 einsum) and the TT
            // chain through the same cost model; an unschedulable dense
            // layer reports as infinity and is skipped, as the old
            // per-kernel compile guard did
            if e.dense_time_s.is_finite() {
                dense_k1 += e.dense_time_s;
                tt_k1 += sol.time_s;
            }
        }
        let speedup = dense_total / tt_total;
        let k1_speedup = dense_k1 / tt_k1.max(1e-12);
        speedups.push(speedup);
        k1_speedups.push(k1_speedup);
        println!(
            "{:<10} {:>12} {:>12} {:>8.2}x {:>9.1}x {:>9.1}x",
            name,
            format_secs(dense_total),
            format_secs(tt_total),
            speedup,
            k1_speedup,
            dense_params as f64 / tt_params as f64
        );
    }
    let geo = |v: &[f64]| (v.iter().map(|s| s.ln()).sum::<f64>() / v.len() as f64).exp();
    println!(
        "\ngeomean FC speedup: measured-host {:.2}x | modeled-K1 {:.2}x \
         (paper: ~12x on the K1; VGG lowest — small layers)",
        geo(&speedups),
        geo(&k1_speedups)
    );
}
