//! The `ttrv bench` measurement subsystem: kernel-level and serving-level
//! sweeps with machine-readable, schema-versioned reports.
//!
//! Two sweeps, two files (written at the repo root by the CLI so every
//! future PR appends a point to the perf trajectory):
//!
//! * **`BENCH_kernels.json`** — the paper's pinned Table-3 einsum shapes
//!   (first/middle/final, [`crate::compiler::cb_suite`]), each measured as
//!   *ours* vs the *IREE-like* and *Pluto-like* baselines through the one
//!   [`Executor`] entry point. Warmup + a minimum-elapsed/minimum-iteration
//!   budget per cell, 20%-trimmed mean as the primary estimator with the
//!   fastest iteration alongside ([`crate::bench::measure`]).
//! * **`BENCH_serve.json`** — the serving sweep: `workers x max_batch x
//!   co-hosted models` through a real [`Server`] pool over deterministic
//!   compressed engines, reporting one row per `(point, model)` with
//!   req/s and p50/p99 end-to-end latency, plus the last point's
//!   [`Server::snapshot`] embedded for the schema gate.
//!
//! Reports are emitted via [`crate::util::json`] (sorted object keys =
//! deterministic field order) and validated in CI by
//! `python/tools/check_bench_json.py`. Only non-`quick` runs are
//! comparable across machines/PRs; `quick` runs shrink the heavy batch
//! extents ([`QUICK_B_CAP`]) and are marked as such in the report.

use std::path::Path;

use crate::baselines::iree_like;
use crate::compiler::{cb_suite, CbEntry};
use crate::config::ServeConfig;
use crate::coordinator::{InferenceRequest, ModelEngine, Server};
use crate::error::{Error, Result};
use crate::kernels::{dispatch, quantize, Executor};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost::{EinsumDims, EinsumKind};
use crate::util::json::{self, Json};
use crate::util::prng::Rng;
use crate::util::stats;

use super::{measure, BenchCfg, Measurement};

/// Version of the `BENCH_kernels.json` schema; bump on any field change
/// so the trajectory tooling can tell report generations apart. v2 added
/// the per-row `kernel` key: which microkernel the `ours` executor
/// dispatched to on the measuring host. v3 added the per-row `per_kernel`
/// array: the same instance measured on every compiled-in candidate
/// kernel — f32 candidates over the packed core, int8 candidates over its
/// quantized shadow — so one report compares dispatch choices side by
/// side.
pub const BENCH_KERNELS_SCHEMA_VERSION: u64 = 3;

/// Version of the `BENCH_serve.json` schema. v2 (serving v2): per-model
/// result rows, a `models` axis on every point, and an embedded metrics
/// snapshot.
pub const BENCH_SERVE_SCHEMA_VERSION: u64 = 2;

/// Default file name of the kernel-sweep report.
pub const BENCH_KERNELS_FILE: &str = "BENCH_kernels.json";

/// Default file name of the serving-sweep report.
pub const BENCH_SERVE_FILE: &str = "BENCH_serve.json";

/// Batch-extent cap applied by `--quick` runs so CI smoke finishes in
/// seconds (recorded in the report; quick rows are not cross-PR
/// comparable).
pub const QUICK_B_CAP: usize = 256;

/// Lowercase tag of an einsum kind, as the reports spell it.
pub fn kind_tag(kind: EinsumKind) -> &'static str {
    match kind {
        EinsumKind::First => "first",
        EinsumKind::Middle => "middle",
        EinsumKind::Final => "final",
    }
}

/// One cell of the schema-v3 per-kernel comparison: one candidate
/// microkernel measured on one pinned einsum instance.
#[derive(Debug, Clone)]
pub struct KernelCell {
    /// The candidate kernel's stable name (`"portable"`, `"avx2-fma"`,
    /// `"int8-portable"`, ...).
    pub kernel: &'static str,
    /// Whether the cell ran the int8 path (quantized core, f32
    /// accumulation) rather than the f32 packed core.
    pub int8: bool,
    /// The measurement.
    pub measurement: Measurement,
}

/// One kernel-sweep row: the three implementations measured on one pinned
/// einsum instance.
#[derive(Debug, Clone)]
pub struct KernelRow {
    /// `"<kind>/<CBi>"` label.
    pub id: String,
    /// The measured einsum instance (post any quick-mode `b` cap).
    pub dims: EinsumDims,
    /// Name of the microkernel the `ours` executor dispatched to (schema
    /// v2; comparing rows across hosts is meaningless without it).
    pub kernel: &'static str,
    /// The optimized plan-driven kernel.
    pub ours: Measurement,
    /// The IREE-like baseline (const-folded G, runtime matmul half).
    pub iree_like: Measurement,
    /// The Pluto-like baseline (polyhedral tiling, scalar).
    pub pluto_like: Measurement,
    /// Schema v3: every candidate kernel this host can run, measured on
    /// the same instance (f32 roster over `pg`, int8 roster over its
    /// quantized shadow).
    pub per_kernel: Vec<KernelCell>,
}

impl KernelRow {
    /// Measured speedup of ours vs a baseline time (`None` when either
    /// estimate is degenerate — a zero or non-finite time on *either*
    /// side flags the cell as unmeasurable rather than emitting 0 or
    /// NaN/inf into a report).
    pub fn speedup(&self, baseline: &Measurement) -> Option<f64> {
        let s = baseline.seconds / self.ours.seconds;
        (self.ours.seconds > 0.0 && baseline.seconds > 0.0 && s.is_finite()).then_some(s)
    }
}

/// Measure one suite entry (all three implementations).
fn kernel_row(
    entry: &CbEntry,
    b_cap: Option<usize>,
    cfg: &BenchCfg,
    rng: &mut Rng,
) -> Result<KernelRow> {
    let mut dims = entry.dims;
    if let Some(cap) = b_cap {
        dims.b = dims.b.min(cap);
    }
    let machine = MachineSpec::spacemit_k1();
    let mut ex = Executor::new(&machine);
    let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, rng);
    let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, rng);
    let pg = ex.pack(&g, &dims)?;
    let gm = iree_like::prepare_g(&g)?;
    let id = format!("{}/{}", kind_tag(dims.kind), entry.id);
    // validate each implementation once with `?` so a bad suite entry is a
    // typed error; the measured closures then only repeat calls that
    // already succeeded (same warm-then-measure shape as try_min_secs)
    ex.execute(&dims, &pg, &x)?;
    ex.execute_iree_prepared(&gm, dims.r, &x)?;
    ex.execute_pluto_like(&g, &x)?;
    let ours = measure(&format!("{id} ours"), dims.flops(), cfg, || {
        ex.execute(&dims, &pg, &x).expect("validated kernel");
    });
    let iree = measure(&format!("{id} iree-like"), dims.flops(), cfg, || {
        ex.execute_iree_prepared(&gm, dims.r, &x).expect("validated kernel");
    });
    let pluto = measure(&format!("{id} pluto-like"), dims.flops(), cfg, || {
        ex.execute_pluto_like(&g, &x).expect("validated kernel");
    });
    // schema v3: the same instance on every candidate kernel, so the
    // report compares dispatch choices (portable vs vector, f32 vs int8)
    // side by side on one host. Int8 cells run the quantized shadow of
    // the *same* packed core — identical layout, ~4x fewer core bytes.
    let qg = quantize(&pg);
    let mut per_kernel = Vec::new();
    for k in dispatch::candidate_kernels() {
        let mut ex_k = Executor::with_kernel(&machine, k)?;
        ex_k.execute(&dims, &pg, &x)?;
        let m = measure(&format!("{id} {}", k.name()), dims.flops(), cfg, || {
            ex_k.execute(&dims, &pg, &x).expect("validated kernel");
        });
        per_kernel.push(KernelCell { kernel: k.name(), int8: false, measurement: m });
    }
    for k in dispatch::candidate_kernels_q() {
        let mut ex_k = Executor::with_kernel(&machine, k)?;
        ex_k.execute_q(&dims, &qg, &x)?;
        let m = measure(&format!("{id} {}", k.name()), dims.flops(), cfg, || {
            ex_k.execute_q(&dims, &qg, &x).expect("validated kernel");
        });
        per_kernel.push(KernelCell { kernel: k.name(), int8: true, measurement: m });
    }
    Ok(KernelRow {
        id,
        dims,
        kernel: ex.kernel_name(),
        ours,
        iree_like: iree,
        pluto_like: pluto,
        per_kernel,
    })
}

/// Measure an explicit entry list (the testable core of the sweep).
pub fn kernel_rows(
    entries: &[CbEntry],
    b_cap: Option<usize>,
    cfg: &BenchCfg,
) -> Result<Vec<KernelRow>> {
    let mut rng = Rng::new(7);
    entries.iter().map(|e| kernel_row(e, b_cap, cfg, &mut rng)).collect()
}

/// The full kernel sweep: every pinned Table-3 shape of all three einsum
/// kinds. `quick` caps the heavy batch extents at [`QUICK_B_CAP`].
pub fn run_kernel_sweep(cfg: &BenchCfg, quick: bool) -> Result<Vec<KernelRow>> {
    let b_cap = quick.then_some(QUICK_B_CAP);
    let mut entries = Vec::new();
    for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
        entries.extend(cb_suite(kind));
    }
    kernel_rows(&entries, b_cap, cfg)
}

fn measurement_json(m: &Measurement) -> Json {
    Json::obj(vec![
        ("seconds", Json::from(m.seconds)),
        ("min_seconds", Json::from(m.min)),
        ("mad", Json::from(m.mad)),
        ("iters", Json::from(m.iters)),
        ("gflops", Json::from(m.gflops())),
    ])
}

fn opt_f64(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::from(x),
        None => Json::Null,
    }
}

/// The `BENCH_kernels.json` document for a sweep result.
pub fn kernel_report_json(rows: &[KernelRow], quick: bool) -> Json {
    let results = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("id", Json::from(r.id.as_str())),
                ("kind", Json::from(kind_tag(r.dims.kind))),
                ("m", Json::from(r.dims.m)),
                ("b", Json::from(r.dims.b)),
                ("n", Json::from(r.dims.n)),
                ("r", Json::from(r.dims.r)),
                ("k", Json::from(r.dims.k)),
                ("flops", Json::from(r.dims.flops() as usize)),
                ("kernel", Json::from(r.kernel)),
                ("ours", measurement_json(&r.ours)),
                ("iree_like", measurement_json(&r.iree_like)),
                ("pluto_like", measurement_json(&r.pluto_like)),
                ("speedup_vs_iree", opt_f64(r.speedup(&r.iree_like))),
                ("speedup_vs_pluto", opt_f64(r.speedup(&r.pluto_like))),
                (
                    "per_kernel",
                    Json::Arr(
                        r.per_kernel
                            .iter()
                            .map(|c| {
                                let s = r.ours.seconds / c.measurement.seconds;
                                let vs_ours = (c.measurement.seconds > 0.0
                                    && r.ours.seconds > 0.0
                                    && s.is_finite())
                                .then_some(s);
                                Json::obj(vec![
                                    ("kernel", Json::from(c.kernel)),
                                    ("int8", Json::from(c.int8)),
                                    ("measurement", measurement_json(&c.measurement)),
                                    ("speedup_vs_ours", opt_f64(vs_ours)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::from("ttrv-bench-kernels")),
        ("schema_version", Json::from(BENCH_KERNELS_SCHEMA_VERSION as usize)),
        ("quick", Json::from(quick)),
        ("b_cap", opt_f64(quick.then_some(QUICK_B_CAP as f64))),
        ("machine_planned", Json::from(MachineSpec::spacemit_k1().name)),
        ("host_threads", Json::from(host_threads())),
        ("results", Json::Arr(results)),
    ])
}

/// One point of the serving sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServePoint {
    /// Worker-pool size.
    pub workers: usize,
    /// Dynamic-batching cap.
    pub max_batch: usize,
    /// Number of co-hosted models served from one process at this point.
    pub models: usize,
}

/// The default `workers x max_batch x models` grid (`quick` trims it for
/// CI but keeps one multi-model point so the co-hosting path stays
/// smoke-tested).
pub fn default_serve_points(quick: bool) -> Vec<ServePoint> {
    let mut points = Vec::new();
    if quick {
        points.push(ServePoint { workers: 1, max_batch: 8, models: 1 });
        points.push(ServePoint { workers: 2, max_batch: 8, models: 1 });
        points.push(ServePoint { workers: 2, max_batch: 8, models: 2 });
    } else {
        for &w in &[1usize, 2, 4] {
            for &b in &[1usize, 8, 32] {
                points.push(ServePoint { workers: w, max_batch: b, models: 1 });
            }
        }
        for &w in &[1usize, 2, 4] {
            points.push(ServePoint { workers: w, max_batch: 8, models: 2 });
        }
    }
    points
}

/// Measured outcome of one model at one serving configuration.
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// The configuration measured.
    pub point: ServePoint,
    /// The model this row's requests targeted.
    pub model: String,
    /// Requests served to this model.
    pub requests: usize,
    /// Wall-clock from first submission to last reply (shared by every
    /// model row of one point — the burst is interleaved).
    pub elapsed_s: f64,
    /// This model's throughput over that window.
    pub req_per_s: f64,
    /// Median end-to-end latency (interpolated over the measured burst's
    /// replies), microseconds.
    pub p50_us: u64,
    /// 99th-percentile end-to-end latency, microseconds.
    pub p99_us: u64,
    /// Mean executed batch size.
    pub mean_batch: f64,
}

/// Sweep `points` over a set of candidate models: each point co-hosts the
/// first `point.models` engines in one [`Server`] (worker clones, so
/// every point sees identical `Arc`-shared weights), fires a burst of
/// `requests` seeded inputs round-robined across the hosted models, and
/// times to the last reply. The queue is sized to admit the whole burst,
/// so the sweep measures batching + execution, never admission
/// rejections. Returns one [`ServeRow`] per `(point, hosted model)` plus
/// the last point's [`Server::snapshot`].
pub fn run_serve_sweep(
    models: &[ModelEngine],
    points: &[ServePoint],
    requests: usize,
) -> Result<(Vec<ServeRow>, Json)> {
    if models.is_empty() {
        return Err(Error::serve("serve sweep needs at least one model"));
    }
    let mut rows = Vec::new();
    let mut snapshot = Json::Null;
    for &point in points {
        if point.models == 0 || point.models > models.len() {
            return Err(Error::serve(format!(
                "serve point wants {} co-hosted models, {} available",
                point.models,
                models.len()
            )));
        }
        let hosted = &models[..point.models];
        // Warmup (below) is shaped like the real burst: enough concurrent
        // requests per model that every worker sees full batches, so the
        // one-off plan compiles for the swept batch sizes (the engines are
        // preseeded with batch-1 plans only) cannot land inside the timed
        // window and spike p99.
        let hi = requests.max(16).max(point.workers);
        let warm = (point.workers * point.max_batch * 4).clamp(point.workers, hi);
        let cfg = ServeConfig {
            max_batch: point.max_batch,
            max_wait_us: 200,
            queue_cap: (requests + warm * point.models).max(16),
            workers: point.workers,
            ..ServeConfig::default()
        };
        cfg.validate()?;
        let server =
            Server::start_multi(hosted.iter().map(ModelEngine::worker_clone).collect(), cfg)?;
        let mut warm_rxs = Vec::new();
        for engine in hosted {
            for id in 0..warm as u64 {
                warm_rxs.push(server.submit(
                    InferenceRequest::new(id, vec![0.1; engine.in_dim()])
                        .for_model(engine.name()),
                )?);
            }
        }
        for rx in warm_rxs {
            rx.recv()
                .map_err(|_| Error::serve("bench worker dropped a warmup reply"))??;
        }
        let mut rng = Rng::new(0xbe9c);
        // round-robin the burst across the co-hosted models
        let targets: Vec<usize> = (0..requests).map(|i| i % point.models).collect();
        let inputs: Vec<Vec<f32>> =
            targets.iter().map(|&t| rng.normal_vec(hosted[t].in_dim(), 1.0)).collect();
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = inputs
            .into_iter()
            .zip(&targets)
            .enumerate()
            .map(|(id, (input, &t))| {
                server.submit(
                    InferenceRequest::new(id as u64, input).for_model(hosted[t].name()),
                )
            })
            .collect::<Result<_>>()?;
        // latency/batch stats come from the measured burst's own replies
        // (exact interpolated percentiles, and the warmup requests above
        // cannot pollute them the way server-wide metrics would)
        let mut lat_us: Vec<Vec<f64>> = vec![Vec::new(); point.models];
        let mut batch_sum: Vec<usize> = vec![0; point.models];
        for (rx, &t) in rxs.into_iter().zip(&targets) {
            let resp = rx
                .recv()
                .map_err(|_| Error::serve("bench worker dropped a reply"))??;
            lat_us[t].push(resp.latency.as_secs_f64() * 1e6);
            batch_sum[t] += resp.batch_size;
        }
        let elapsed_s = t0.elapsed().as_secs_f64();
        snapshot = server.snapshot();
        server.shutdown();
        for (t, engine) in hosted.iter().enumerate() {
            let n = lat_us[t].len();
            rows.push(ServeRow {
                point,
                model: engine.name().to_string(),
                requests: n,
                elapsed_s,
                req_per_s: if elapsed_s > 0.0 { n as f64 / elapsed_s } else { 0.0 },
                p50_us: if n > 0 { stats::percentile(&lat_us[t], 50.0) as u64 } else { 0 },
                p99_us: if n > 0 { stats::percentile(&lat_us[t], 99.0) as u64 } else { 0 },
                mean_batch: batch_sum[t] as f64 / n.max(1) as f64,
            });
        }
    }
    Ok((rows, snapshot))
}

/// The `BENCH_serve.json` document (schema v2) for a sweep result:
/// per-model rows, the swept model names as a top-level axis, and the
/// final server's metrics snapshot embedded.
pub fn serve_report_json(rows: &[ServeRow], quick: bool, snapshot: &Json) -> Json {
    let mut model_names: Vec<&str> = Vec::new();
    for r in rows {
        if !model_names.contains(&r.model.as_str()) {
            model_names.push(&r.model);
        }
    }
    let results = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("workers", Json::from(r.point.workers)),
                ("max_batch", Json::from(r.point.max_batch)),
                ("models", Json::from(r.point.models)),
                ("model", Json::from(r.model.as_str())),
                ("requests", Json::from(r.requests)),
                ("elapsed_s", Json::from(r.elapsed_s)),
                ("req_per_s", Json::from(r.req_per_s)),
                ("p50_us", Json::from(r.p50_us as usize)),
                ("p99_us", Json::from(r.p99_us as usize)),
                ("mean_batch", Json::from(r.mean_batch)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::from("ttrv-bench-serve")),
        ("schema_version", Json::from(BENCH_SERVE_SCHEMA_VERSION as usize)),
        ("quick", Json::from(quick)),
        ("models", Json::Arr(model_names.into_iter().map(Json::from).collect())),
        ("host_threads", Json::from(host_threads())),
        ("snapshot", snapshot.clone()),
        ("results", Json::Arr(results)),
    ])
}

/// Write a report document as pretty JSON (trailing newline, so the files
/// diff cleanly in the trajectory).
pub fn write_report(path: impl AsRef<Path>, report: &Json) -> Result<()> {
    let mut text = json::to_string_pretty(report);
    text.push('\n');
    Ok(std::fs::write(path, text)?)
}

fn host_threads() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dense::DenseFc;
    use crate::coordinator::LayerOp;
    use std::time::Duration;

    fn tiny_cfg() -> BenchCfg {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 2,
            min_time: Duration::from_millis(1),
            trim: 0.2,
        }
    }

    #[test]
    fn kernel_rows_measure_all_three_impls() {
        let suite = cb_suite(EinsumKind::Middle);
        let rows = kernel_rows(&suite[..1], Some(16), &tiny_cfg()).unwrap();
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.dims.b, 16, "b capped");
        assert!(r.id.starts_with("middle/CB0"));
        for m in [&r.ours, &r.iree_like, &r.pluto_like] {
            assert!(m.iters >= 2);
            assert!(m.seconds.is_finite() && m.seconds >= 0.0);
            assert!(m.min.is_finite());
        }
        // schema v3: the candidate comparison always includes both
        // portable references (every host runs them), int8 cells flagged
        assert!(r.per_kernel.iter().any(|c| c.kernel == crate::kernels::PORTABLE_KERNEL_NAME
            && !c.int8));
        assert!(r.per_kernel.iter().any(
            |c| c.kernel == crate::kernels::INT8_PORTABLE_KERNEL_NAME && c.int8
        ));
        for c in &r.per_kernel {
            assert!(c.measurement.seconds.is_finite() && c.measurement.seconds >= 0.0);
        }
    }

    #[test]
    fn kernel_report_is_schema_valid_json() {
        let suite = cb_suite(EinsumKind::Final);
        let rows = kernel_rows(&suite[..2], Some(8), &tiny_cfg()).unwrap();
        let doc = kernel_report_json(&rows, true);
        // round-trips through our own parser and carries the schema keys
        let back = json::parse(&json::to_string_pretty(&doc)).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str(), Some("ttrv-bench-kernels"));
        assert_eq!(
            back.get("schema_version").unwrap().as_u64(),
            Some(BENCH_KERNELS_SCHEMA_VERSION)
        );
        assert_eq!(back.get("quick").unwrap().as_bool(), Some(true));
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        for r in results {
            for key in [
                "id", "kind", "m", "b", "n", "r", "k", "flops", "kernel", "ours",
                "iree_like", "pluto_like", "speedup_vs_iree", "speedup_vs_pluto",
                "per_kernel",
            ] {
                assert!(r.get(key).is_some(), "missing {key}");
            }
            let kernel = r.get("kernel").unwrap().as_str().unwrap();
            assert!(
                crate::kernels::all_kernels().iter().any(|k| k.name() == kernel),
                "row kernel {kernel:?} is not a registered kernel"
            );
            for impl_key in ["ours", "iree_like", "pluto_like"] {
                let m = r.get(impl_key).unwrap();
                for key in ["seconds", "min_seconds", "mad", "iters", "gflops"] {
                    assert!(m.get(key).is_some(), "{impl_key} missing {key}");
                }
            }
            let cells = r.get("per_kernel").unwrap().as_arr().unwrap();
            assert!(!cells.is_empty());
            for c in cells {
                let name = c.get("kernel").unwrap().as_str().unwrap();
                assert!(
                    crate::kernels::all_kernels().iter().any(|k| k.name() == name),
                    "per_kernel cell {name:?} is not a registered kernel"
                );
                assert!(c.get("int8").unwrap().as_bool().is_some());
                assert!(c.get("speedup_vs_ours").is_some());
                let m = c.get("measurement").unwrap();
                for key in ["seconds", "min_seconds", "mad", "iters", "gflops"] {
                    assert!(m.get(key).is_some(), "per_kernel missing {key}");
                }
            }
        }
    }

    fn toy_engine(name: &str) -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new(name, vec![LayerOp::Dense(fc)], 4, 2)
    }

    #[test]
    fn serve_sweep_answers_everything_and_reports() {
        let models = [toy_engine("toy-a"), toy_engine("toy-b")];
        let points = [
            ServePoint { workers: 1, max_batch: 4, models: 1 },
            ServePoint { workers: 2, max_batch: 8, models: 2 },
        ];
        let (rows, snapshot) = run_serve_sweep(&models, &points, 24).unwrap();
        // one row for the single-model point + two for the co-hosted one
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].requests, 24);
        assert_eq!(rows[1].requests + rows[2].requests, 24);
        for r in &rows {
            assert!(r.elapsed_s > 0.0);
            assert!(r.req_per_s > 0.0);
            assert!(r.mean_batch >= 1.0);
            assert!(r.p99_us >= r.p50_us);
        }
        assert_eq!(
            snapshot.get("schema").and_then(Json::as_str),
            Some("ttrv-serve-snapshot"),
            "sweep must return the last server's snapshot"
        );
        let doc = serve_report_json(&rows, true, &snapshot);
        let back = json::parse(&json::to_string(&doc)).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str(), Some("ttrv-bench-serve"));
        assert_eq!(
            back.get("schema_version").unwrap().as_u64(),
            Some(BENCH_SERVE_SCHEMA_VERSION)
        );
        let names = back.get("models").unwrap().as_arr().unwrap();
        assert_eq!(names.len(), 2, "both swept models are a top-level axis");
        assert!(back.get("snapshot").unwrap().get("process").is_some());
        let results = back.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 3);
        for r in results {
            for key in [
                "workers", "max_batch", "models", "model", "requests", "elapsed_s",
                "req_per_s", "p50_us", "p99_us", "mean_batch",
            ] {
                assert!(r.get(key).is_some(), "missing {key}");
            }
        }
    }

    #[test]
    fn serve_sweep_rejects_a_point_wanting_more_models_than_given() {
        let models = [toy_engine("only")];
        let points = [ServePoint { workers: 1, max_batch: 4, models: 2 }];
        assert!(run_serve_sweep(&models, &points, 8).is_err());
    }

    #[test]
    fn default_grids_cover_quick_and_full() {
        assert_eq!(default_serve_points(true).len(), 3);
        assert_eq!(default_serve_points(false).len(), 12);
        assert!(
            default_serve_points(true).iter().any(|p| p.models > 1),
            "quick grid must keep a co-hosting point"
        );
    }

    #[test]
    fn degenerate_speedup_is_null_not_nan() {
        let m = |secs: f64| Measurement {
            name: "x".into(),
            seconds: secs,
            min: secs,
            mad: 0.0,
            iters: 1,
            flops: 0,
        };
        let row = KernelRow {
            id: "t".into(),
            dims: EinsumDims { kind: EinsumKind::Middle, m: 1, b: 1, n: 1, r: 1, k: 1 },
            kernel: crate::kernels::PORTABLE_KERNEL_NAME,
            ours: m(0.0),
            iree_like: m(1.0),
            pluto_like: m(1.0),
            // a degenerate per-kernel cell too: zero `ours` must emit a
            // null speedup_vs_ours, never NaN/inf
            per_kernel: vec![KernelCell {
                kernel: crate::kernels::INT8_PORTABLE_KERNEL_NAME,
                int8: true,
                measurement: m(1.0),
            }],
        };
        assert_eq!(row.speedup(&row.iree_like), None);
        // a zero *baseline* is equally degenerate: Some(0.0) would fail
        // the CI schema gate (speedups must be null or > 0)
        let zero_base = KernelRow {
            ours: m(1.0),
            iree_like: m(0.0),
            ..row.clone()
        };
        assert_eq!(zero_base.speedup(&zero_base.iree_like), None);
        let doc = kernel_report_json(&[row], false);
        let text = json::to_string(&doc);
        assert!(text.contains("\"speedup_vs_iree\":null"), "{text}");
        assert!(text.contains("\"speedup_vs_ours\":null"), "{text}");
        json::parse(&text).unwrap();
    }
}
