//! Measurement harness (criterion is unavailable offline).
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall time are reached; the reported
//! estimate is the 20%-trimmed mean with MAD spread — robust against
//! scheduler noise on the shared CI host.

use std::time::Duration;

use crate::util::{stats, timer};

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Trimmed-mean seconds per iteration.
    pub seconds: f64,
    /// Median absolute deviation of the samples.
    pub mad: f64,
    /// Timed iterations actually run.
    pub iters: usize,
    /// Work per iteration, used for GFLOP/s reporting (0 = unknown).
    pub flops: u64,
}

impl Measurement {
    /// Throughput implied by `seconds` and `flops`.
    pub fn gflops(&self) -> f64 {
        if self.seconds > 0.0 {
            self.flops as f64 / self.seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Minimum timed iterations.
    pub min_iters: usize,
    /// Minimum total timed wall-clock.
    pub min_time: Duration,
    /// Fraction trimmed from each tail of the sample set.
    pub trim: f64,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(200),
            trim: 0.2,
        }
    }
}

impl BenchCfg {
    /// Quick preset for CI / smoke runs.
    pub fn quick() -> Self {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(30),
            trim: 0.2,
        }
    }

    /// Honor `TTRV_BENCH_QUICK=1` for fast end-to-end runs.
    pub fn from_env() -> Self {
        match std::env::var("TTRV_BENCH_QUICK") {
            Ok(v) if v == "1" || v.eq_ignore_ascii_case("true") => BenchCfg::quick(),
            _ => BenchCfg::default(),
        }
    }
}

/// Measure a closure. `flops` is the per-iteration work for GFLOP/s output.
pub fn measure(name: &str, flops: u64, cfg: &BenchCfg, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let samples = timer::time_iters(&mut f, cfg.min_iters, cfg.min_time);
    Measurement {
        name: name.to_string(),
        seconds: stats::trimmed_mean(&samples, cfg.trim),
        mad: stats::mad(&samples),
        iters: samples.len(),
        flops,
    }
}

/// Format a table of measurements, one row per entry, with a speedup column
/// relative to `baseline_idx` when given.
pub fn format_table(title: &str, rows: &[Measurement], baseline_idx: Option<usize>) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<40} {:>12} {:>10} {:>10} {:>8}\n",
        "name", "time", "GFLOP/s", "speedup", "iters"
    ));
    let base = baseline_idx.map(|i| rows[i].seconds);
    for r in rows {
        let speedup = match base {
            Some(b) if r.seconds > 0.0 => format!("{:.2}x", b / r.seconds),
            _ => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<40} {:>12} {:>10.2} {:>10} {:>8}\n",
            r.name,
            format_secs(r.seconds),
            r.gflops(),
            speedup,
            r.iters
        ));
    }
    out
}

/// Human-readable seconds.
pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters_and_reports_gflops() {
        let cfg = BenchCfg { warmup_iters: 0, min_iters: 5, min_time: Duration::ZERO, trim: 0.2 };
        let mut n = 0u64;
        let m = measure("noop", 1_000_000, &cfg, || n += 1);
        assert!(m.iters >= 5);
        assert!(n >= 5);
        assert!(m.seconds >= 0.0);
        assert!(m.gflops() >= 0.0);
    }

    #[test]
    fn table_formats_speedups() {
        let rows = vec![
            Measurement { name: "base".into(), seconds: 1.0, mad: 0.0, iters: 3, flops: 0 },
            Measurement { name: "fast".into(), seconds: 0.25, mad: 0.0, iters: 3, flops: 0 },
        ];
        let t = format_table("t", &rows, Some(0));
        assert!(t.contains("4.00x"));
        assert!(t.contains("base"));
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(format_secs(2.5), "2.500 s");
        assert_eq!(format_secs(0.0025), "2.500 ms");
        assert_eq!(format_secs(2.5e-6), "2.5 us");
    }
}
