//! Measurement harness (criterion is unavailable offline).
//!
//! Methodology: warmup runs, then timed iterations until both a minimum
//! iteration count and a minimum wall time are reached; the reported
//! estimate is the 20%-trimmed mean with MAD spread — robust against
//! scheduler noise on the shared CI host.

use std::time::Duration;

use crate::util::{stats, timer};

pub mod harness;

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark label.
    pub name: String,
    /// Trimmed-mean seconds per iteration (the primary estimator).
    pub seconds: f64,
    /// Fastest per-iteration sample (the tuning comparators' estimator,
    /// reported alongside so BENCH json carries both).
    pub min: f64,
    /// Median absolute deviation of the samples.
    pub mad: f64,
    /// Timed samples collected (one per batch; equals the iteration count
    /// on fine-grained clocks, where batches stay at size 1).
    pub iters: usize,
    /// Work per iteration, used for GFLOP/s reporting (0 = unknown).
    pub flops: u64,
}

impl Measurement {
    /// Throughput implied by `seconds` and `flops`.
    pub fn gflops(&self) -> f64 {
        if self.seconds > 0.0 {
            self.flops as f64 / self.seconds / 1e9
        } else {
            0.0
        }
    }
}

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchCfg {
    /// Untimed warmup iterations.
    pub warmup_iters: usize,
    /// Minimum timed iterations.
    pub min_iters: usize,
    /// Minimum total timed wall-clock.
    pub min_time: Duration,
    /// Fraction trimmed from each tail of the sample set.
    pub trim: f64,
}

impl Default for BenchCfg {
    fn default() -> Self {
        BenchCfg {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(200),
            trim: 0.2,
        }
    }
}

impl BenchCfg {
    /// Quick preset for CI / smoke runs.
    pub fn quick() -> Self {
        BenchCfg {
            warmup_iters: 1,
            min_iters: 3,
            min_time: Duration::from_millis(30),
            trim: 0.2,
        }
    }

    /// Realize a typed `[bench]` config section
    /// ([`crate::config::BenchConfig`], already validated on load).
    pub fn from_config(cfg: &crate::config::BenchConfig) -> Self {
        BenchCfg {
            warmup_iters: cfg.warmup_iters,
            min_iters: cfg.min_iters,
            min_time: Duration::from_millis(cfg.min_time_ms),
            trim: cfg.trim,
        }
    }

    /// Honor `TTRV_BENCH_QUICK=1` for fast end-to-end runs (the same
    /// switch [`crate::util::timer::MeasureFloor::from_env`] reads).
    pub fn from_env() -> Self {
        if crate::util::bench_quick_env() {
            BenchCfg::quick()
        } else {
            BenchCfg::default()
        }
    }
}

/// Measure a closure. `flops` is the per-iteration work for GFLOP/s output.
///
/// Sampling is batched ([`timer::time_iters_batched`]): on coarse-clock
/// hosts the batch grows until each sample is clock-resolvable, so a
/// sub-granularity kernel can never record an all-zero sample set and
/// write `seconds = 0` rows into the BENCH trajectory — the same floor
/// discipline the tuning comparators use. Non-finite samples (impossible
/// from `Instant`, but the stats layer is shared with synthetic sample
/// sets) are dropped before any estimator runs, so a poisoned sample can
/// never put NaN in a report.
pub fn measure(name: &str, flops: u64, cfg: &BenchCfg, mut f: impl FnMut()) -> Measurement {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let raw = timer::time_iters_batched(&mut f, cfg.min_iters, cfg.min_time);
    let (samples, _dropped) = stats::finite_samples(&raw);
    let (min, _max) = stats::min_max(&samples);
    Measurement {
        name: name.to_string(),
        seconds: stats::trimmed_mean(&samples, cfg.trim),
        min: if min.is_finite() { min } else { 0.0 },
        mad: stats::mad(&samples),
        iters: samples.len(),
        flops,
    }
}

/// Format a table of measurements, one row per entry, with a speedup column
/// relative to `baseline_idx` when given.
pub fn format_table(title: &str, rows: &[Measurement], baseline_idx: Option<usize>) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    out.push_str(&format!(
        "{:<40} {:>12} {:>10} {:>10} {:>8}\n",
        "name", "time", "GFLOP/s", "speedup", "iters"
    ));
    let base = baseline_idx.map(|i| rows[i].seconds);
    for r in rows {
        let speedup = match base {
            Some(b) if r.seconds > 0.0 => format!("{:.2}x", b / r.seconds),
            _ => "-".to_string(),
        };
        out.push_str(&format!(
            "{:<40} {:>12} {:>10.2} {:>10} {:>8}\n",
            r.name,
            format_secs(r.seconds),
            r.gflops(),
            speedup,
            r.iters
        ));
    }
    out
}

/// Human-readable seconds.
pub fn format_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_iters_and_reports_gflops() {
        let cfg = BenchCfg { warmup_iters: 0, min_iters: 5, min_time: Duration::ZERO, trim: 0.2 };
        let mut n = 0u64;
        let m = measure("noop", 1_000_000, &cfg, || n += 1);
        assert!(m.iters >= 5);
        assert!(n >= 5);
        assert!(m.seconds >= 0.0);
        assert!(m.gflops() >= 0.0);
    }

    #[test]
    fn table_formats_speedups() {
        let m = |name: &str, seconds: f64| Measurement {
            name: name.into(),
            seconds,
            min: seconds,
            mad: 0.0,
            iters: 3,
            flops: 0,
        };
        let rows = vec![m("base", 1.0), m("fast", 0.25)];
        let t = format_table("t", &rows, Some(0));
        assert!(t.contains("4.00x"));
        assert!(t.contains("base"));
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(format_secs(2.5), "2.500 s");
        assert_eq!(format_secs(0.0025), "2.500 ms");
        assert_eq!(format_secs(2.5e-6), "2.5 us");
    }
}
