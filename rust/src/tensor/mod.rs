//! Dense row-major f32 tensors — the substrate under the kernel engine,
//! TT decomposition, and the serving data path.

mod shape;
mod dense;
pub mod einsum;

pub use dense::Tensor;
pub use shape::Shape;
