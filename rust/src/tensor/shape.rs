//! Shapes and row-major stride arithmetic.

use crate::error::{Error, Result};

/// A tensor shape (row-major layout throughout the crate).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    /// A shape from dimension extents.
    pub fn new(dims: impl Into<Vec<usize>>) -> Self {
        Shape(dims.into())
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.0.len()
    }

    /// Total element count (empty shape = scalar = 1).
    pub fn numel(&self) -> usize {
        self.0.iter().product()
    }

    /// Dimension extents.
    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    /// Row-major strides (in elements).
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![1; self.0.len()];
        for i in (0..self.0.len().saturating_sub(1)).rev() {
            strides[i] = strides[i + 1] * self.0[i + 1];
        }
        strides
    }

    /// Linear offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> Result<usize> {
        if idx.len() != self.0.len() {
            return Err(Error::shape(format!(
                "index rank {} != shape rank {}",
                idx.len(),
                self.0.len()
            )));
        }
        let strides = self.strides();
        let mut off = 0;
        for ((&i, &d), &s) in idx.iter().zip(&self.0).zip(&strides) {
            if i >= d {
                return Err(Error::shape(format!("index {i} out of bounds {d}")));
            }
            off += i * s;
        }
        Ok(off)
    }

    /// Validate that a reshape preserves the element count.
    pub fn check_reshape(&self, new: &[usize]) -> Result<()> {
        let n: usize = new.iter().product();
        if n != self.numel() {
            return Err(Error::shape(format!(
                "cannot reshape {:?} ({}) into {:?} ({})",
                self.0,
                self.numel(),
                new,
                n
            )));
        }
        Ok(())
    }

    /// Shape after applying a permutation of axes.
    pub fn permuted(&self, perm: &[usize]) -> Result<Shape> {
        if perm.len() != self.rank() {
            return Err(Error::shape("permutation rank mismatch"));
        }
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            if p >= perm.len() || seen[p] {
                return Err(Error::shape(format!("invalid permutation {perm:?}")));
            }
            seen[p] = true;
        }
        Ok(Shape(perm.iter().map(|&p| self.0[p]).collect()))
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Self {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Self {
        Shape(v.to_vec())
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}]", self.0.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strides_row_major() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
        assert_eq!(s.numel(), 24);
    }

    #[test]
    fn offset_and_bounds() {
        let s = Shape::new(vec![2, 3]);
        assert_eq!(s.offset(&[1, 2]).unwrap(), 5);
        assert!(s.offset(&[2, 0]).is_err());
        assert!(s.offset(&[0]).is_err());
    }

    #[test]
    fn reshape_check() {
        let s = Shape::new(vec![6, 4]);
        assert!(s.check_reshape(&[2, 3, 4]).is_ok());
        assert!(s.check_reshape(&[5, 5]).is_err());
    }

    #[test]
    fn permutation_validation() {
        let s = Shape::new(vec![2, 3, 4]);
        assert_eq!(s.permuted(&[2, 0, 1]).unwrap().dims(), &[4, 2, 3]);
        assert!(s.permuted(&[0, 0, 1]).is_err());
        assert!(s.permuted(&[0, 1]).is_err());
    }

    #[test]
    fn scalar_shape() {
        let s = Shape::new(Vec::new());
        assert_eq!(s.numel(), 1);
        assert_eq!(s.offset(&[]).unwrap(), 0);
    }
}
