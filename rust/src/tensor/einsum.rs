//! Reference (unoptimized) einsum implementations for the contractions the
//! system uses. These are the Rust-side oracles: every optimized kernel in
//! [`crate::kernels`] and every baseline in [`crate::baselines`] is tested
//! against them, and they mirror `python/compile/kernels/ref.py` bit-for-bit
//! in structure.

use crate::error::{Error, Result};
use crate::tensor::Tensor;

/// The paper's hot-spot contraction (Listing 2):
///
/// `Out[m, b, r] = sum_{n, k} G[r, n, m, k] * In[b, n, k]`
///
/// Index conventions are documented once in [`crate::kernels`] (§ Data
/// layout conventions).
pub fn tt_einsum_ref(g: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let b = slab_dims(x, n, k)?;
    let gd = g.data();
    let xd = x.data();
    let mut out = Tensor::zeros(vec![m, b, r]);
    let od = out.data_mut();
    // literal translation of the paper's Listing 2 loop nest
    for mi in 0..m {
        for bi in 0..b {
            for ri in 0..r {
                let mut acc = 0.0f32;
                for ni in 0..n {
                    for ki in 0..k {
                        let gidx = ((ri * n + ni) * m + mi) * k + ki;
                        let xidx = (bi * n + ni) * k + ki;
                        acc += gd[gidx] * xd[xidx];
                    }
                }
                od[(mi * b + bi) * r + ri] = acc;
            }
        }
    }
    Ok(out)
}

/// Validate a TT-core tensor and return `(r, n, m, k)`.
pub fn core_dims(g: &Tensor) -> Result<(usize, usize, usize, usize)> {
    let d = g.dims();
    if d.len() != 4 {
        return Err(Error::shape(format!("core must be rank 4, got {:?}", d)));
    }
    Ok((d[0], d[1], d[2], d[3]))
}

/// Validate an input slab against core dims and return `b`.
pub fn slab_dims(x: &Tensor, n: usize, k: usize) -> Result<usize> {
    let d = x.dims();
    if d.len() != 3 || d[1] != n || d[2] != k {
        return Err(Error::shape(format!(
            "slab {:?} incompatible with core (n={n}, k={k})",
            d
        )));
    }
    Ok(d[0])
}

/// Dense matrix-vector product `y = W x + b` with `W (M, N)` — the
/// unfactorized FC layer (paper Eq. 1).
pub fn fc_ref(w: &Tensor, x: &[f32], bias: Option<&[f32]>) -> Result<Vec<f32>> {
    let d = w.dims();
    if d.len() != 2 || d[1] != x.len() {
        return Err(Error::shape(format!("fc: W {:?} vs x len {}", d, x.len())));
    }
    let (m, n) = (d[0], d[1]);
    let wd = w.data();
    let mut y = vec![0.0f32; m];
    for i in 0..m {
        let row = &wd[i * n..(i + 1) * n];
        let mut acc = 0.0;
        for (wv, xv) in row.iter().zip(x) {
            acc += wv * xv;
        }
        y[i] = acc + bias.map_or(0.0, |b| b[i]);
    }
    Ok(y)
}

/// Batched dense FC: `Y = X W^T + b`, X `(B, N)`, W `(M, N)`, Y `(B, M)`.
pub fn fc_batched_ref(w: &Tensor, x: &Tensor, bias: Option<&[f32]>) -> Result<Tensor> {
    let (m, n) = {
        let d = w.dims();
        (d[0], d[1])
    };
    let dx = x.dims();
    if dx.len() != 2 || dx[1] != n {
        return Err(Error::shape(format!("fc_batched: X {:?} vs W {:?}", dx, w.dims())));
    }
    let b = dx[0];
    let mut out = Tensor::zeros(vec![b, m]);
    for bi in 0..b {
        let row = &x.data()[bi * n..(bi + 1) * n];
        let y = fc_ref(w, row, bias)?;
        out.data_mut()[bi * m..(bi + 1) * m].copy_from_slice(&y);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn tt_einsum_tiny_by_hand() {
        // r=1, n=2, m=1, k=1; b=1 -> out = g0*x0 + g1*x1
        let g = Tensor::from_vec(vec![1, 2, 1, 1], vec![3.0, 5.0]).unwrap();
        let x = Tensor::from_vec(vec![1, 2, 1], vec![2.0, 7.0]).unwrap();
        let out = tt_einsum_ref(&g, &x).unwrap();
        assert_eq!(out.dims(), &[1, 1, 1]);
        assert_eq!(out.data()[0], 3.0 * 2.0 + 5.0 * 7.0);
    }

    #[test]
    fn tt_einsum_matches_independent_formula() {
        let mut rng = Rng::new(3);
        let (r, n, m, k, b) = (3, 4, 5, 2, 6);
        let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
        let out = tt_einsum_ref(&g, &x).unwrap();
        // independent check through at() indexing (different code path)
        for mi in 0..m {
            for bi in 0..b {
                for ri in 0..r {
                    let mut acc = 0.0f32;
                    for ni in 0..n {
                        for ki in 0..k {
                            acc += g.at(&[ri, ni, mi, ki]).unwrap()
                                * x.at(&[bi, ni, ki]).unwrap();
                        }
                    }
                    let got = out.at(&[mi, bi, ri]).unwrap();
                    assert!((got - acc).abs() < 1e-4, "{got} vs {acc}");
                }
            }
        }
    }

    #[test]
    fn shape_validation() {
        let g = Tensor::zeros(vec![2, 3, 4, 5]);
        let bad = Tensor::zeros(vec![2, 3, 4]); // n mismatch
        assert!(tt_einsum_ref(&g, &bad).is_err());
        let g3 = Tensor::zeros(vec![2, 3, 4]);
        assert!(core_dims(&g3).is_err());
    }

    #[test]
    fn fc_matches_manual() {
        let w = Tensor::from_vec(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let y = fc_ref(&w, &[1.0, 0.0, -1.0], Some(&[10.0, 20.0])).unwrap();
        assert_eq!(y, vec![1.0 - 3.0 + 10.0, 4.0 - 6.0 + 20.0]);
        assert!(fc_ref(&w, &[1.0], None).is_err());
    }

    #[test]
    fn fc_batched_consistent_with_single() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(vec![5, 7], 1.0, &mut rng);
        let x = Tensor::randn(vec![3, 7], 1.0, &mut rng);
        let bias: Vec<f32> = (0..5).map(|i| i as f32).collect();
        let out = fc_batched_ref(&w, &x, Some(&bias)).unwrap();
        for bi in 0..3 {
            let row = fc_ref(&w, &x.data()[bi * 7..(bi + 1) * 7], Some(&bias)).unwrap();
            assert_eq!(&out.data()[bi * 5..(bi + 1) * 5], &row[..]);
        }
    }
}
