//! Dense row-major f32 tensor.

use crate::error::{Error, Result};
use crate::util::prng::Rng;

use super::shape::Shape;

/// A dense, row-major, owned f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Shape,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(dims: impl Into<Vec<usize>>) -> Self {
        let shape = Shape::new(dims);
        let data = vec![0.0; shape.numel()];
        Tensor { shape, data }
    }

    /// Tensor from existing data (length must match the shape).
    pub fn from_vec(dims: impl Into<Vec<usize>>, data: Vec<f32>) -> Result<Self> {
        let shape = Shape::new(dims);
        if data.len() != shape.numel() {
            return Err(Error::shape(format!(
                "data length {} != shape {} numel {}",
                data.len(),
                shape,
                shape.numel()
            )));
        }
        Ok(Tensor { shape, data })
    }

    /// N(0, sigma^2) random tensor.
    pub fn randn(dims: impl Into<Vec<usize>>, sigma: f32, rng: &mut Rng) -> Self {
        let shape = Shape::new(dims);
        let data = rng.normal_vec(shape.numel(), sigma);
        Tensor { shape, data }
    }

    /// Tensor filled with a single value.
    pub fn full(dims: impl Into<Vec<usize>>, value: f32) -> Self {
        let shape = Shape::new(dims);
        let data = vec![value; shape.numel()];
        Tensor { shape, data }
    }

    /// The shape object.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Dimension extents.
    pub fn dims(&self) -> &[usize] {
        self.shape.dims()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major element slice.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable row-major element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw row-major buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Element accessor by multi-index (bounds-checked).
    pub fn at(&self, idx: &[usize]) -> Result<f32> {
        Ok(self.data[self.shape.offset(idx)?])
    }

    /// Mutable element accessor by multi-index (bounds-checked).
    pub fn at_mut(&mut self, idx: &[usize]) -> Result<&mut f32> {
        let off = self.shape.offset(idx)?;
        Ok(&mut self.data[off])
    }

    /// Reshape in place (free: row-major data is unchanged).
    pub fn reshape(mut self, dims: impl Into<Vec<usize>>) -> Result<Self> {
        let new: Vec<usize> = dims.into();
        self.shape.check_reshape(&new)?;
        self.shape = Shape::new(new);
        Ok(self)
    }

    /// Materialized axis permutation (copies data into the new layout).
    pub fn transpose(&self, perm: &[usize]) -> Result<Tensor> {
        let out_shape = self.shape.permuted(perm)?;
        let in_strides = self.shape.strides();
        let out_dims = out_shape.dims().to_vec();
        let mut out = vec![0.0f32; self.numel()];
        // walk the output in order; compute the source offset incrementally
        let rank = out_dims.len();
        if rank == 0 {
            out.clone_from_slice(&self.data);
            return Tensor::from_vec(Vec::new(), out);
        }
        let src_stride_for_out: Vec<usize> = perm.iter().map(|&p| in_strides[p]).collect();
        let mut idx = vec![0usize; rank];
        let mut src = 0usize;
        for slot in out.iter_mut() {
            *slot = self.data[src];
            // increment multi-index, updating src incrementally
            for ax in (0..rank).rev() {
                idx[ax] += 1;
                src += src_stride_for_out[ax];
                if idx[ax] < out_dims[ax] {
                    break;
                }
                src -= src_stride_for_out[ax] * out_dims[ax];
                idx[ax] = 0;
            }
        }
        Ok(Tensor { shape: out_shape, data: out })
    }

    /// Elementwise maximum absolute difference against another tensor.
    pub fn max_abs_diff(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::shape(format!(
                "shape mismatch {} vs {}",
                self.shape, other.shape
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Relative L2 error ||a-b|| / max(||b||, eps).
    pub fn rel_l2_error(&self, other: &Tensor) -> Result<f32> {
        if self.shape != other.shape {
            return Err(Error::shape("shape mismatch in rel_l2_error"));
        }
        let num: f32 = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum();
        let den: f32 = other.data.iter().map(|b| b * b).sum();
        Ok((num.sqrt()) / den.sqrt().max(1e-20))
    }

    /// True when all elements are within `atol + rtol*|other|`.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        assert_eq!(t.at(&[1, 2]).unwrap(), 5.0);
        assert!(t.at(&[2, 0]).is_err());
        assert!(Tensor::from_vec(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_is_free_and_checked() {
        let t = Tensor::from_vec(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let r = t.clone().reshape(vec![3, 2]).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshape(vec![4, 2]).is_err());
    }

    #[test]
    fn transpose_2d_matches_manual() {
        let t = Tensor::from_vec(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose(&[1, 0]).unwrap();
        assert_eq!(tt.dims(), &[3, 2]);
        assert_eq!(tt.data(), &[1., 4., 2., 5., 3., 6.]);
    }

    #[test]
    fn transpose_roundtrip_nd() {
        let mut rng = Rng::new(1);
        let t = Tensor::randn(vec![3, 4, 5], 1.0, &mut rng);
        let perm = [2, 0, 1];
        let inv = [1, 2, 0];
        let back = t.transpose(&perm).unwrap().transpose(&inv).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn transpose_matches_naive_gather() {
        let mut rng = Rng::new(2);
        let t = Tensor::randn(vec![2, 3, 4, 5], 1.0, &mut rng);
        let perm = [3, 1, 0, 2];
        let fast = t.transpose(&perm).unwrap();
        // naive gather
        let d = t.dims().to_vec();
        let mut naive = Tensor::zeros(vec![d[3], d[1], d[0], d[2]]);
        for i0 in 0..d[0] {
            for i1 in 0..d[1] {
                for i2 in 0..d[2] {
                    for i3 in 0..d[3] {
                        *naive.at_mut(&[i3, i1, i0, i2]).unwrap() =
                            t.at(&[i0, i1, i2, i3]).unwrap();
                    }
                }
            }
        }
        assert_eq!(fast, naive);
    }

    #[test]
    fn diff_metrics() {
        let a = Tensor::from_vec(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(vec![2], vec![1.0, 2.5]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 0.5);
        assert!(a.allclose(&b, 0.3, 0.0));
        assert!(!a.allclose(&b, 0.1, 0.0));
        let c = Tensor::from_vec(vec![3], vec![0.0; 3]).unwrap();
        assert!(a.max_abs_diff(&c).is_err());
    }
}
