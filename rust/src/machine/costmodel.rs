//! Analytical execution-time model for planned Einsum kernels.
//!
//! Produces the "modeled-K1" series reported next to measured-host numbers
//! in Figs. 9 and 12-16 (the physical board is unavailable — DESIGN.md §3).
//! The model combines:
//!
//! * a compute term: MACs through the vector unit, derated by the
//!   microkernel's vectorization efficiency (§4.3.3 analysis);
//! * a load/store term: the register-blocking L/S count (Eq. 20-25), one
//!   L/S per cycle, with an un-packed-G locality penalty when array packing
//!   is disabled (ablations);
//! * a DRAM term: compulsory traffic, or thrash traffic when the schedule's
//!   working set violates the L2 inequalities (Eq. 26-28);
//! * a parallel term: near-linear scaling with a per-thread spawn/sync
//!   overhead — this term reproduces the paper's Fig. 9 thresholds.

use crate::compiler::plan::{OptimizationPlan, VectorLoop};
use crate::compiler::regblock;
use crate::compiler::tiling;
use crate::machine::MachineSpec;

/// Seconds of one-off overhead per extra thread (spawn + barrier), the
/// paper's "thread creation and synchronization overheads". Calibrated so
/// the model's thread crossovers land at the paper's Fig. 9 FLOPs
/// thresholds (2e6 / 4e6 / 8e6 at the K1's achieved memory-bound rate).
pub const SPAWN_SECONDS: f64 = 100e-6;

/// Relative efficiency of the k-vectorized microkernel (horizontal
/// reductions + scalar stores; paper §4.3.3 item 3 and Fig. 14).
pub const K_LOOP_EFF: f64 = 0.55;

/// Locality penalty multiplier on G loads when array packing is off.
pub const UNPACKED_G_PENALTY: f64 = 4.0;

/// Decomposed time estimate.
#[derive(Debug, Clone, Copy)]
pub struct TimeEstimate {
    /// Arithmetic-bound seconds.
    pub compute_s: f64,
    /// Load/store-bound seconds.
    pub ls_s: f64,
    /// DRAM-traffic-bound seconds.
    pub dram_s: f64,
    /// Fixed thread-spawn overhead seconds.
    pub spawn_s: f64,
}

impl TimeEstimate {
    /// Total wall-clock estimate: bottleneck of the per-cycle terms plus
    /// the fixed spawn overhead.
    pub fn seconds(&self) -> f64 {
        self.compute_s.max(self.ls_s).max(self.dram_s) + self.spawn_s
    }

    /// Throughput implied by the estimate for `flops` of work.
    pub fn gflops(&self, flops: u64) -> f64 {
        flops as f64 / self.seconds() / 1e9
    }
}

/// Estimate execution time of `plan` on `machine`.
pub fn estimate(plan: &OptimizationPlan, machine: &MachineSpec) -> TimeEstimate {
    let d = &plan.dims;
    let macs = (d.m * d.b * d.n * d.r * d.k) as f64;
    let vl = machine.vl_f32() as f64;

    // --- compute term ---------------------------------------------------
    let vec_eff = match plan.vector_loop {
        VectorLoop::R => 1.0,
        VectorLoop::K => K_LOOP_EFF,
        VectorLoop::None => 1.0 / vl,
    };
    let lanes = vl * machine.fma_per_cycle * vec_eff;
    let compute_cycles = macs / lanes;

    // --- load/store term --------------------------------------------------
    let eff_vl = if plan.vector_loop == VectorLoop::None { 1 } else { machine.vl_f32() };
    let ls = regblock::ls_counts(d, eff_vl, &plan.rb, plan.vector_loop);
    let g_pen = if plan.pack_g { 1.0 } else { UNPACKED_G_PENALTY };
    let ls_cycles = ls.g as f64 * g_pen + ls.input as f64 + ls.output as f64;

    // --- DRAM term --------------------------------------------------------
    let compulsory = d.min_bytes() as f64;
    let t = plan.threads;
    let resident = match plan.tile.btl {
        Some(btl) => tiling::eq28_holds(d, machine, t, btl),
        None => match plan.tile.order {
            crate::compiler::plan::LoopOrder::Mbrk => tiling::eq26_holds(d, machine, t),
            crate::compiler::plan::LoopOrder::Bmrk => tiling::eq27_holds(d, machine, t),
        },
    };
    let dram_bytes = if resident {
        compulsory
    } else {
        // input re-streamed once per m-block sweep (dominant thrash mode)
        let reload = (d.m as f64 / plan.rb.rm as f64).max(1.0).min(64.0);
        4.0 * (d.b * d.n * d.k) as f64 * reload + compulsory
    };

    // --- combine ----------------------------------------------------------
    let hz = machine.ghz * 1e9;
    let threads = plan.threads.max(1) as f64;
    let par_eff = 0.95f64.powi(plan.threads.saturating_sub(1) as i32);
    let scale = threads * par_eff;
    TimeEstimate {
        compute_s: compute_cycles / hz / scale,
        ls_s: ls_cycles / hz / scale,
        dram_s: dram_bytes / (machine.dram_gbps * 1e9), // bandwidth is shared
        spawn_s: if plan.threads > 1 { SPAWN_SECONDS * (threads - 1.0) } else { 0.0 },
    }
}

/// Modeled GFLOP/s for a plan.
pub fn gflops(plan: &OptimizationPlan, machine: &MachineSpec) -> f64 {
    estimate(plan, machine).gflops(plan.dims.flops())
}

/// Fig. 9 helper: modeled speedup of running `plan` with `t` threads
/// relative to single-threaded execution.
pub fn thread_speedup(plan: &OptimizationPlan, machine: &MachineSpec, t: u32) -> f64 {
    let single = OptimizationPlan { threads: 1, ..*plan };
    let multi = OptimizationPlan { threads: t.min(machine.cores), ..*plan };
    estimate(&single, machine).seconds() / estimate(&multi, machine).seconds()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, pipeline::compile_stage, pipeline::OptStage};
    use crate::ttd::cost::{EinsumDims, EinsumKind};

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    fn middle(m: usize, b: usize, n: usize) -> EinsumDims {
        EinsumDims { kind: EinsumKind::Middle, m, b, n, r: 8, k: 8 }
    }

    #[test]
    fn modeled_gflops_below_peak_and_positive() {
        let machine = k1();
        for e in crate::compiler::cb_suite(EinsumKind::Middle) {
            let plan = compile(&e.dims, &machine).unwrap();
            let g = gflops(&plan, &machine);
            assert!(g > 0.1, "{}: {g}", e.id);
            assert!(
                g < machine.peak_gflops(plan.threads),
                "{}: {g} exceeds peak",
                e.id
            );
        }
    }

    #[test]
    fn ablation_stages_are_monotone() {
        // Fig. 16 shape: each optimization family must not slow things down
        let machine = k1();
        let d = middle(100, 512, 64); // ~5e7 FLOPs
        let mut last = f64::INFINITY;
        for stage in [OptStage::Naive, OptStage::VecPack, OptStage::RbTile, OptStage::Parallel] {
            let plan = compile_stage(&d, &machine, stage).unwrap();
            let s = estimate(&plan, &machine).seconds();
            assert!(s <= last * 1.001, "{stage:?}: {s} > {last}");
            last = s;
        }
    }

    #[test]
    fn fig9_thresholds_qualitative() {
        // small kernels prefer 1 thread; large kernels prefer 4
        let machine = k1();
        let small = compile(&middle(32, 9, 7), &machine).unwrap(); // 2.6e5 flops
        let large = compile(&middle(64, 1020, 28), &machine).unwrap(); // 2.3e8
        let best_t = |plan: &OptimizationPlan| {
            (1..=4u32)
                .max_by(|&a, &b| {
                    thread_speedup(plan, &machine, a)
                        .partial_cmp(&thread_speedup(plan, &machine, b))
                        .unwrap()
                })
                .unwrap()
        };
        assert_eq!(best_t(&small), 1);
        assert_eq!(best_t(&large), 4);
        // speedup of the big kernel at 4 threads is substantial
        assert!(thread_speedup(&large, &machine, 4) > 2.0);
    }

    #[test]
    fn optimal_thread_count_nondecreasing_in_flops() {
        let machine = k1();
        let mut last_best = 1;
        for scale in [1usize, 4, 16, 64, 256] {
            let d = middle(16 * scale, 128, 16);
            let plan = compile(&d, &machine).unwrap();
            let best = (1..=4u32)
                .max_by(|&a, &b| {
                    thread_speedup(&plan, &machine, a)
                        .partial_cmp(&thread_speedup(&plan, &machine, b))
                        .unwrap()
                })
                .unwrap();
            assert!(best >= last_best, "flops {} best {best} < {last_best}", d.flops());
            last_best = best;
        }
    }

    #[test]
    fn k_vectorized_final_is_slower_per_flop() {
        // Fig. 14 observation: final einsums utilize hardware worse
        let machine = k1();
        let mid = compile(&middle(64, 512, 32), &machine).unwrap();
        let fin_dims = EinsumDims { kind: EinsumKind::Final, m: 64, b: 512, n: 32, r: 1, k: 8 };
        let fin = compile(&fin_dims, &machine).unwrap();
        assert!(gflops(&fin, &machine) < gflops(&mid, &machine));
    }

    #[test]
    fn unpacked_g_costs_more() {
        // without register blocking the G stream dominates the L/S term, so
        // the packing penalty must show up in the estimate
        let machine = k1();
        let d = middle(128, 256, 16);
        let mut packed = compile(&d, &machine).unwrap();
        packed.rb = crate::compiler::plan::RbFactors::NONE;
        let unpacked = OptimizationPlan { pack_g: false, ..packed };
        assert!(
            estimate(&unpacked, &machine).seconds() > estimate(&packed, &machine).seconds()
        );
    }
}
