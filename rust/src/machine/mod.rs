//! Target-machine description and performance models.
//!
//! The paper's methodology is parameterized by the hardware: vector length,
//! register file size, L2 geometry, core count (SpacemiT K1). Every compiler
//! pass in [`crate::compiler`] consumes a [`MachineSpec`], so retargeting is
//! a data change (the paper: "this methodology can be extended to other
//! processor families").
//!
//! The actual K1 board is unavailable in this environment; [`cache`] and
//! [`costmodel`] provide the simulation substrate (set-associative cache
//! simulator + analytical cycle model) used to produce "modeled-K1" numbers
//! alongside measured-host numbers (DESIGN.md §3).

pub mod cache;
pub mod costmodel;

/// Description of a target CPU for the analytical compiler.
#[derive(Debug, Clone, PartialEq)]
pub struct MachineSpec {
    /// Marketing name of the modeled CPU.
    pub name: &'static str,
    /// Vector width in bits (RVV VLEN / AVX width).
    pub vector_bits: u32,
    /// Number of architectural vector registers.
    pub vector_regs: u32,
    /// Physical cores available to the schedule.
    pub cores: u32,
    /// Clock in GHz.
    pub ghz: f64,
    /// FMA throughput: fused multiply-adds per lane per cycle.
    pub fma_per_cycle: f64,
    /// Sustained main-memory bandwidth in GB/s (per socket).
    pub dram_gbps: f64,
    /// L1 data cache per core, bytes.
    pub l1_bytes: u64,
    /// Last-level (shared) cache size in bytes.
    pub l2_bytes: u64,
    /// LLC associativity (paper Eq. 26-28 `L2.assoc`).
    pub l2_assoc: u32,
    /// Cache line size in bytes.
    pub line_bytes: u32,
}

impl MachineSpec {
    /// Vector lanes for f32 (`vl` in the paper; 8 on the K1).
    pub fn vl_f32(&self) -> usize {
        (self.vector_bits / 32) as usize
    }

    /// Bytes per L2 way (paper Eq. 26 `L2.way`).
    pub fn l2_way_bytes(&self) -> u64 {
        self.l2_bytes / self.l2_assoc as u64
    }

    /// Theoretical peak GFLOP/s of one core (paper: 25.6 on the K1).
    pub fn peak_gflops_core(&self) -> f64 {
        self.ghz * self.vl_f32() as f64 * self.fma_per_cycle * 2.0
    }

    /// Theoretical peak GFLOP/s across `t` cores.
    pub fn peak_gflops(&self, t: u32) -> f64 {
        self.peak_gflops_core() * t.min(self.cores) as f64
    }

    /// The paper's evaluation platform: SpacemiT K1 (Banana Pi BPI-F3),
    /// cluster 0 = 4 cores @ 1.6 GHz, RVV 256-bit, 32 KB L1, 1 MB shared L2.
    /// DRAM bandwidth per the paper's measurement: ~8x lower than a
    /// high-performance x86 (~3 GB/s sustained).
    pub fn spacemit_k1() -> Self {
        MachineSpec {
            name: "SpacemiT-K1",
            vector_bits: 256,
            vector_regs: 32,
            cores: 4,
            ghz: 1.6,
            fma_per_cycle: 1.0,
            dram_gbps: 3.0,
            l1_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
            l2_assoc: 16,
            line_bytes: 64,
        }
    }

    /// Look up a preset by its marketing name (the string `.ttrv` bundles
    /// store in their META `machine` key) — `None` for machines this build
    /// does not know, so callers can skip machine-specific checks instead
    /// of guessing a register budget.
    pub fn by_name(name: &str) -> Option<MachineSpec> {
        match name {
            "SpacemiT-K1" => Some(MachineSpec::spacemit_k1()),
            "host-x86" => Some(MachineSpec::host()),
            _ => None,
        }
    }

    /// The build/CI host this reproduction measures on: modeled as a single
    /// generic x86-64 core with 256-bit vectors (AVX2-class).
    pub fn host() -> Self {
        MachineSpec {
            name: "host-x86",
            vector_bits: 256,
            vector_regs: 16,
            cores: 1,
            ghz: 3.0,
            fma_per_cycle: 2.0,
            dram_gbps: 24.0,
            l1_bytes: 32 * 1024,
            l2_bytes: 1024 * 1024,
            l2_assoc: 16,
            line_bytes: 64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn k1_matches_paper_numbers() {
        let k1 = MachineSpec::spacemit_k1();
        assert_eq!(k1.vl_f32(), 8); // 256-bit / f32, paper Sec. 4.3.3
        // paper Sec. 6.3: theoretical peak 25.6 GFLOP/s per core
        assert!((k1.peak_gflops_core() - 25.6).abs() < 1e-9);
        assert_eq!(k1.l2_way_bytes(), 65536);
        assert_eq!(k1.vector_regs, 32);
    }

    #[test]
    fn peak_scales_with_cores_capped() {
        let k1 = MachineSpec::spacemit_k1();
        assert_eq!(k1.peak_gflops(2), 2.0 * k1.peak_gflops_core());
        assert_eq!(k1.peak_gflops(99), 4.0 * k1.peak_gflops_core());
    }

    #[test]
    fn host_spec_is_sane() {
        let h = MachineSpec::host();
        assert_eq!(h.vl_f32(), 8);
        assert!(h.peak_gflops_core() > 0.0);
    }

    #[test]
    fn by_name_roundtrips_presets() {
        for spec in [MachineSpec::spacemit_k1(), MachineSpec::host()] {
            assert_eq!(MachineSpec::by_name(spec.name), Some(spec));
        }
        assert_eq!(MachineSpec::by_name("riscv-unknown"), None);
    }
}
