//! Set-associative LRU cache simulator.
//!
//! Validates the paper's L2-occupancy tiling inequalities (Eq. 26-28): the
//! compiler *predicts* whether a loop schedule's working set stays resident;
//! this simulator *replays* the schedule's address trace and counts misses,
//! so the prediction can be unit-tested instead of trusted.

/// A single-level, set-associative, write-allocate LRU cache model.
#[derive(Debug, Clone)]
pub struct CacheSim {
    sets: Vec<Vec<u64>>, // per-set stack of line tags, front = MRU
    assoc: usize,
    line_bytes: u64,
    n_sets: u64,
    /// Total simulated accesses.
    pub accesses: u64,
    /// Accesses that missed.
    pub misses: u64,
}

impl CacheSim {
    /// Build a simulator for `size_bytes` capacity, `assoc` ways,
    /// `line_bytes` lines. `size_bytes` must be divisible by
    /// `assoc * line_bytes`.
    pub fn new(size_bytes: u64, assoc: u32, line_bytes: u32) -> Self {
        let n_sets = size_bytes / (assoc as u64 * line_bytes as u64);
        assert!(n_sets >= 1, "cache too small for geometry");
        CacheSim {
            sets: vec![Vec::with_capacity(assoc as usize); n_sets as usize],
            assoc: assoc as usize,
            line_bytes: line_bytes as u64,
            n_sets,
            accesses: 0,
            misses: 0,
        }
    }

    /// Touch one byte address (read or write — the occupancy model does not
    /// distinguish). Returns true on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        let line = addr / self.line_bytes;
        let set = (line % self.n_sets) as usize;
        let ways = &mut self.sets[set];
        if let Some(pos) = ways.iter().position(|&t| t == line) {
            let tag = ways.remove(pos);
            ways.insert(0, tag); // move to MRU
            true
        } else {
            self.misses += 1;
            if ways.len() == self.assoc {
                ways.pop(); // evict LRU
            }
            ways.insert(0, line);
            false
        }
    }

    /// Touch a contiguous `[addr, addr+len)` byte range, one access per line.
    pub fn access_range(&mut self, addr: u64, len: u64) {
        let first = addr / self.line_bytes;
        let last = (addr + len.max(1) - 1) / self.line_bytes;
        for line in first..=last {
            self.access(line * self.line_bytes);
        }
    }

    /// Miss ratio so far.
    pub fn miss_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.misses as f64 / self.accesses as f64
        }
    }

    /// Reset counters but keep contents (for warm-up separation).
    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.misses = 0;
    }

    /// Total bytes of traffic to the next level (misses x line size).
    pub fn miss_bytes(&self) -> u64 {
        self.misses * self.line_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = CacheSim::new(1024, 4, 64);
        assert!(!c.access(0)); // cold miss
        assert!(c.access(0));
        assert!(c.access(63)); // same line
        assert!(!c.access(64)); // next line
        assert_eq!(c.misses, 2);
        assert_eq!(c.accesses, 4);
    }

    #[test]
    fn lru_eviction_within_set() {
        // 4-way, 1 set: capacity 4 lines
        let mut c = CacheSim::new(4 * 64, 4, 64);
        for i in 0..4u64 {
            c.access(i * 64);
        }
        c.access(0); // make line 0 MRU
        c.access(4 * 64); // evicts LRU = line 1
        assert!(c.access(0), "line 0 must still be resident");
        assert!(!c.access(64), "line 1 must have been evicted");
    }

    #[test]
    fn working_set_within_capacity_has_no_steady_state_misses() {
        let mut c = CacheSim::new(64 * 1024, 16, 64);
        // 32 KiB working set scanned repeatedly
        for _ in 0..3 {
            for addr in (0..32 * 1024).step_by(64) {
                c.access(addr);
            }
        }
        c.reset_counters();
        for addr in (0..32 * 1024).step_by(64) {
            c.access(addr);
        }
        assert_eq!(c.misses, 0, "resident set must not miss");
    }

    #[test]
    fn working_set_exceeding_capacity_thrashes_under_lru() {
        let mut c = CacheSim::new(4 * 1024, 4, 64);
        // 8 KiB streamed cyclically: LRU worst case = every access misses
        for _ in 0..4 {
            for addr in (0..8 * 1024).step_by(64) {
                c.access(addr);
            }
        }
        c.reset_counters();
        for addr in (0..8 * 1024).step_by(64) {
            c.access(addr);
        }
        assert!(c.miss_ratio() > 0.99, "ratio {}", c.miss_ratio());
    }

    #[test]
    fn access_range_touches_each_line_once() {
        let mut c = CacheSim::new(1024, 4, 64);
        c.access_range(10, 200); // spans lines 0..=3
        assert_eq!(c.accesses, 4);
        assert_eq!(c.miss_bytes(), 4 * 64);
    }
}
