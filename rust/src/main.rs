//! `ttrv` — CLI for the TTD DSE + RISC-V compiler-optimization system.
//!
//! Subcommands:
//!   tables                 print the Tables 1-2 design-space reduction rows
//!   dse --n N --m M        run the six-stage DSE engine on one FC layer:
//!                          stage counts, the Pareto frontier with modeled
//!                          times, and the policy-selected solution
//!                          (--rank R --policy balance|min-time --workers W
//!                           --top K --measure H --json)
//!   plan --m .. --b ..     show the compiler plan for one Einsum instance
//!   kernel-bench           measure ours vs IREE-like vs Pluto-like (Figs 12-14)
//!   serve-demo             start the serving coordinator on a TT LeNet300,
//!                          fire synthetic load, print metrics
//!                          (--workers N --max-batch B --wait-us T --queue-cap Q)
//!   artifacts-check        load + execute the PJRT artifacts (needs `make artifacts`)
//!
//! Arg parsing is hand-rolled (clap unavailable offline): `--key value`.

use std::collections::HashMap;

use ttrv::baselines::iree_like;
use ttrv::bench::{format_table, measure, BenchCfg};
use ttrv::compiler::{cb_suite, compile};
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{InferenceRequest, LayerOp, ModelEngine, Server, TtFcEngine};
use ttrv::dse;
use ttrv::dse::report::{format_rows, rows_for_model};
use ttrv::kernels::Executor;
use ttrv::machine::MachineSpec;
use ttrv::util::json::Json;
use ttrv::models;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

fn parse_args(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn get<T: std::str::FromStr>(args: &HashMap<String, String>, key: &str, default: T) -> T {
    args.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = parse_args(&argv[argv.len().min(1)..]);
    let result = match cmd {
        "tables" => cmd_tables(&args),
        "dse" => cmd_dse(&args),
        "plan" => cmd_plan(&args),
        "kernel-bench" => cmd_kernel_bench(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ttrv — TT decomposition DSE + compiler optimization for RISC-V\n\
         usage: ttrv <command> [--key value ...]\n\
         commands: tables | dse | plan | kernel-bench | serve-demo | artifacts-check\n\
         see `cargo bench` for the per-figure reproduction harnesses"
    );
}

fn cmd_tables(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let cfg = DseConfig::default();
    let llm_only = args.contains_key("llm");
    let cnn_only = args.contains_key("cnn");
    if !llm_only {
        let mut rows = Vec::new();
        for m in models::cnn_models() {
            rows.extend(rows_for_model(&m, &cfg));
        }
        print!("{}", format_rows("Table 1: DS reduction (CNNs)", &rows));
    }
    if !cnn_only {
        let mut rows = Vec::new();
        for m in models::llm_models() {
            rows.extend(rows_for_model(&m, &cfg));
        }
        print!("{}", format_rows("Table 2: DS reduction (LLMs)", &rows));
    }
    Ok(())
}

fn shape_json(shape: &[u64]) -> Json {
    Json::Arr(shape.iter().map(|&v| Json::from(v as usize)).collect())
}

fn timed_solution_json(s: &ttrv::dse::TimedSolution) -> Json {
    Json::obj(vec![
        ("m_shape", shape_json(s.layout().m_shape())),
        ("n_shape", shape_json(s.layout().n_shape())),
        ("rank", Json::from(s.solution.rank as usize)),
        ("d", Json::from(s.layout().d())),
        ("params", Json::from(s.solution.params as usize)),
        ("flops", Json::from(s.solution.flops as usize)),
        ("modeled_time_s", Json::from(s.time_s)),
        ("speedup_vs_dense", Json::from(s.speedup)),
    ])
}

fn cmd_dse(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let n: u64 = get(args, "n", 784);
    let m: u64 = get(args, "m", 300);
    let rank: u64 = get(args, "rank", 8);
    let top: usize = get(args, "top", 10);
    let base = DseConfig::default();
    let cfg = DseConfig {
        dse_workers: get(args, "workers", base.dse_workers),
        selection_policy: args
            .get("policy")
            .cloned()
            .unwrap_or_else(|| base.selection_policy.clone()),
        ..base
    };
    cfg.validate()?;
    let machine = MachineSpec::spacemit_k1();
    let e = dse::explore_timed(m, n, &machine, &cfg);
    let c = &e.explored.counts;
    let sel = dse::select_solution(&e, rank, cfg.policy()?);

    // measured re-rank of the frontier head (runs on the build host, not
    // the modeled target); resolved up front so --json includes it too
    let measured = match args.get("measure") {
        None => None,
        Some(v) => {
            let head: usize = v.parse().map_err(|_| {
                ttrv::Error::config(format!("--measure expects a candidate count, got '{v}'"))
            })?;
            let head = &e.frontier[..head.min(e.frontier.len())];
            Some(ttrv::dse::select::rerank_measured(head, &MachineSpec::host(), cfg.batch)?)
        }
    };

    if args.contains_key("json") {
        let report = Json::obj(vec![
            ("n", Json::from(n as usize)),
            ("m", Json::from(m as usize)),
            ("rank", Json::from(rank as usize)),
            ("policy", Json::from(cfg.selection_policy.as_str())),
            ("machine", Json::from(machine.name)),
            (
                "counts",
                Json::obj(vec![
                    ("all", Json::from(c.all)),
                    ("aligned", Json::from(c.aligned)),
                    ("vectorized", Json::from(c.vectorized)),
                    ("initial", Json::from(c.initial)),
                    ("scalability", Json::from(c.scalability)),
                    ("timed", Json::from(e.timed.len())),
                ]),
            ),
            ("dense_modeled_time_s", Json::from(e.dense_time_s)),
            ("dense_flops", Json::from(ttrv::ttd::cost::dense_flops(m, n) as usize)),
            ("dense_params", Json::from(ttrv::ttd::cost::dense_params(m, n) as usize)),
            ("frontier", Json::Arr(e.frontier.iter().map(timed_solution_json).collect())),
            (
                "measured_rerank",
                match &measured {
                    None => Json::Null,
                    Some(ranked) => Json::Arr(
                        ranked
                            .iter()
                            .map(|(s, secs)| {
                                let mut o = timed_solution_json(s);
                                if let Json::Obj(map) = &mut o {
                                    map.insert("measured_time_s".into(), Json::from(*secs));
                                }
                                o
                            })
                            .collect(),
                    ),
                },
            ),
            (
                "selected",
                match &sel {
                    Ok(s) => timed_solution_json(s),
                    Err(_) => Json::Null,
                },
            ),
        ]);
        println!("{}", ttrv::util::json::to_string_pretty(&report));
        return sel.map(|_| ());
    }

    println!(
        "FC [{n}, {m}]: all={} aligned={} vectorized={} initial={} scalability={} timed={}",
        ttrv::util::sci(c.all),
        ttrv::util::sci(c.aligned),
        c.vectorized,
        c.initial,
        c.scalability,
        e.timed.len(),
    );
    println!(
        "dense baseline: {} FLOPs, modeled {:.3} ms on {}",
        ttrv::ttd::cost::dense_flops(m, n),
        e.dense_time_s * 1e3,
        machine.name
    );
    println!(
        "Pareto frontier over (modeled time, params, FLOPs): {} of {} qualified solutions",
        e.frontier.len(),
        e.timed.len()
    );
    for s in e.frontier.iter().take(top) {
        println!(
            "  {}  params={} flops={} modeled={:.1} us ({:.1}x vs dense)",
            s.layout().describe(),
            s.solution.params,
            s.solution.flops,
            s.time_s * 1e6,
            s.speedup,
        );
    }
    let sel = sel?;
    println!(
        "selected ({} policy, rank {rank}): {}",
        cfg.selection_policy,
        sel.layout().describe()
    );
    println!(
        "  params={} flops={} modeled inference {:.1} us = {:.1}x speedup vs dense",
        sel.solution.params,
        sel.solution.flops,
        sel.time_s * 1e6,
        sel.speedup,
    );
    if let Some(ranked) = &measured {
        println!("measured re-rank of the frontier head (host, autotuned):");
        for (s, secs) in ranked {
            println!("  {:9.1} us  {}", secs * 1e6, s.layout().describe());
        }
    }
    Ok(())
}

fn cmd_plan(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let dims = EinsumDims {
        kind: EinsumKind::Middle,
        m: get(args, "m", 64),
        b: get(args, "b", 64),
        n: get(args, "n", 8),
        r: get(args, "r", 8),
        k: get(args, "k", 8),
    };
    let machine = MachineSpec::spacemit_k1();
    let plan = compile(&dims, &machine)?;
    println!("machine: {} (vl={}, {} vregs)", machine.name, machine.vl_f32(), machine.vector_regs);
    println!("dims:    {dims:?} ({} FLOPs)", dims.flops());
    println!("plan:    vector_loop={:?} rb={:?}", plan.vector_loop, plan.rb);
    println!("         tile={:?} threads={}", plan.tile, plan.threads);
    println!("         predicted L/S = {}", plan.ls_estimate);
    let est = ttrv::machine::costmodel::estimate(&plan, &machine);
    println!(
        "modeled-K1: {:.3} ms, {:.2} GFLOP/s",
        est.seconds() * 1e3,
        est.gflops(dims.flops())
    );
    Ok(())
}

fn cmd_kernel_bench(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let kind = match args.get("kind").map(String::as_str) {
        Some("first") => EinsumKind::First,
        Some("final") => EinsumKind::Final,
        _ => EinsumKind::Middle,
    };
    let bcfg = if args.contains_key("quick") { BenchCfg::quick() } else { BenchCfg::from_env() };
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(7);
    let mut ex = Executor::new(&machine);
    for entry in cb_suite(kind) {
        let d = entry.dims;
        let g = Tensor::randn(vec![d.r, d.n, d.m, d.k], 1.0, &mut rng);
        let x = Tensor::randn(vec![d.b, d.n, d.k], 1.0, &mut rng);
        let pg = ex.pack(&g, &d)?;
        let gm = iree_like::prepare_g(&g)?;
        let mut rows = Vec::new();
        rows.push(measure(&format!("{} ours", entry.id), d.flops(), &bcfg, || {
            ex.execute(&d, &pg, &x).expect("kernel");
        }));
        rows.push(measure(&format!("{} iree-like", entry.id), d.flops(), &bcfg, || {
            ex.execute_iree_prepared(&gm, d.r, &x).expect("iree");
        }));
        rows.push(measure(&format!("{} pluto-like", entry.id), d.flops(), &bcfg, || {
            ex.execute_pluto_like(&g, &x).expect("pluto");
        }));
        print!("{}", format_table(&format!("{:?} einsum {}", kind, entry.id), &rows, Some(1)));
    }
    Ok(())
}

fn cmd_serve_demo(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let requests: usize = get(args, "requests", 200);
    let serve_cfg = ServeConfig {
        max_batch: get(args, "max-batch", ServeConfig::default().max_batch),
        max_wait_us: get(args, "wait-us", ServeConfig::default().max_wait_us),
        queue_cap: get(args, "queue-cap", ServeConfig::default().queue_cap),
        workers: get(args, "workers", ServeConfig::default().workers),
    };
    serve_cfg.validate()?;
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    let mut rng = Rng::new(1);

    // Build a TT LeNet300 from DSE-routed layers.
    let mut ops = Vec::new();
    let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
    for (i, &(n, m)) in shapes.iter().enumerate() {
        match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg)? {
            ttrv::coordinator::Route::Tt(sol) => {
                let mut tt = random_cores(sol.layout(), &mut rng);
                tt.bias = Some(vec![0.0; m as usize]);
                println!(
                    "layer {i}: TT {} (modeled {:.1}x vs dense)",
                    sol.layout().describe(),
                    sol.speedup
                );
                ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine)?));
            }
            ttrv::coordinator::Route::Dense => {
                println!("layer {i}: dense [{n} -> {m}]");
                let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
                ops.push(LayerOp::Dense(ttrv::baselines::dense::DenseFc::new(&w, None)?));
            }
        }
        if i + 1 < shapes.len() {
            ops.push(LayerOp::Relu);
        }
    }
    let engine = ModelEngine::new("lenet300-tt", ops, 784, 10);
    println!(
        "serving with {} worker(s), max_batch {}, wait {}us, queue {}",
        serve_cfg.workers, serve_cfg.max_batch, serve_cfg.max_wait_us, serve_cfg.queue_cap
    );
    let server = Server::start(engine, serve_cfg);

    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|id| {
            server
                .submit(InferenceRequest { id: id as u64, input: rng.normal_vec(784, 1.0) })
                .expect("queue should admit")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("inference ok");
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served {requests} requests in {:.1} ms ({:.0} req/s)", dt * 1e3, requests as f64 / dt);
    println!("{}", server.metrics().summary());
    server.shutdown();
    Ok(())
}

fn cmd_artifacts_check(args: &HashMap<String, String>) -> ttrv::Result<()> {
    let dir = args
        .get("dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = ttrv::runtime::Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {:?}", rt.manifest().names());
    // smoke-execute the batch-1 dense FC: zero weights + bias 0.5 -> all 0.5
    let exe = rt.compile("dense_fc_784x300_b1")?;
    let x = Tensor::zeros(vec![1, 784]);
    let w = Tensor::zeros(vec![300, 784]);
    let b = Tensor::full(vec![300], 0.5);
    let out = exe.run(&[x, w, b])?;
    assert_eq!(out[0].dims(), &[1, 300]);
    assert!(out[0].data().iter().all(|&v| (v - 0.5).abs() < 1e-6));
    println!("dense_fc artifact executes correctly (bias-only check passed)");
    Ok(())
}
