//! `ttrv` — CLI for the TTD DSE + RISC-V compiler-optimization system.
//!
//! Subcommands:
//!   tables                 print the Tables 1-2 design-space reduction rows
//!   dse --n N --m M        run the six-stage DSE engine on one FC layer:
//!                          stage counts, the Pareto frontier with modeled
//!                          times, and the policy-selected solution
//!                          (--rank R --policy balance|min-time --workers W
//!                           --top K --measure H --json)
//!   plan --m .. --b ..     show the compiler plan for one Einsum instance
//!   kernel-bench           measure ours vs IREE-like vs Pluto-like (Figs 12-14)
//!   bench                  the measured-performance subsystem: kernel sweep
//!                          (pinned Table-3 shapes) + serving sweep
//!                          (workers x max_batch x co-hosted models), written
//!                          as schema-versioned BENCH_kernels.json /
//!                          BENCH_serve.json (per-model rows + an embedded
//!                          serve snapshot) so the perf trajectory
//!                          accumulates PR over PR
//!                          (--quick --out-dir D --kernels-only --serve-only
//!                           --config bench.toml)
//!   compress               run DSE + TT-SVD over a model's FC stack and
//!                          write a versioned `.ttrv` bundle
//!                          (--model <zoo-name|spec.toml> --out model.ttrv
//!                           --rank R --seed S --tune: persist measured
//!                           autotuned plans in the TUNE section;
//!                           --quantize [--max-quant-error EPS]: persist
//!                           int8 cores in the QUANT section when the
//!                           measured output error fits the budget)
//!   serve-demo             start the serving coordinator on a TT LeNet300
//!                          (or warm-start it from one or more repeated
//!                          --artifact model.ttrv flags, co-hosted in one
//!                          registry), fire synthetic round-robin load,
//!                          print per-model metrics
//!                          (--workers N --max-batch B --wait-us T
//!                           --queue-cap Q --shards S --steal ring|off
//!                           --slo-us T --cache-bytes B
//!                           --snapshot-json out.json --kernel NAME)
//!
//! `bench` and `serve-demo` take `--kernel NAME` to pin every engine onto
//! one compiled-in microkernel (portable | avx2-fma | neon |
//! int8-portable | int8-avx2 | int8-neon); unknown or host-unsupported
//! names are a typed kernel error before any work starts.
//!   artifacts-check        --verify model.ttrv: validate a `.ttrv` bundle
//!                          (CRCs + bitwise replay against a fresh
//!                          compression); without --verify, load + execute
//!                          the PJRT artifacts (needs `make artifacts`)
//!   lint                   static plan/layout safety verification: run the
//!                          strict tier of `compiler::verify` over every
//!                          plan x core pair of a bundle or zoo model and
//!                          print per-plan diagnostics; exit 0 iff clean
//!                          (--artifact model.ttrv | --model zoo-name
//!                           [--rank R --seed S] [--json])
//!
//! Arg parsing is hand-rolled (clap unavailable offline): `--key value`.
//! Flags are repeatable — scalar lookups take the last value (the usual
//! last-one-wins CLI rule) and list lookups (`--artifact a --artifact b`)
//! keep every value in order. A flag value that fails to parse is a hard
//! CLI error naming the flag — never a silent fallback to the default.

use std::collections::HashMap;

use ttrv::baselines::iree_like;
use ttrv::bench::{format_table, measure, BenchCfg};
use ttrv::compiler::{cb_suite, compile};
use ttrv::config::{DseConfig, ServeConfig};
use ttrv::coordinator::{InferenceRequest, LayerOp, ModelEngine, Server, TtFcEngine};
use ttrv::dse;
use ttrv::dse::report::{format_rows, rows_for_model, swept_solution_json, timed_solution_json};
use ttrv::kernels::Executor;
use ttrv::machine::MachineSpec;
use ttrv::util::json::Json;
use ttrv::models;
use ttrv::tensor::Tensor;
use ttrv::ttd::cost::{EinsumDims, EinsumKind};
use ttrv::ttd::decompose::random_cores;
use ttrv::util::prng::Rng;

/// Parsed command line: every `--key` maps to *all* its values in order,
/// so repeatable flags (`serve-demo --artifact a.ttrv --artifact b.ttrv`)
/// survive parsing instead of last-one clobbering the map entry.
type Args = HashMap<String, Vec<String>>;

fn parse_args(args: &[String]) -> Args {
    let mut map: Args = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.entry(key.to_string()).or_default().push(args[i + 1].clone());
                i += 2;
            } else {
                map.entry(key.to_string()).or_default().push("true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

/// Last value of a (possibly repeated) scalar flag — the usual
/// last-one-wins CLI rule.
fn last<'a>(args: &'a Args, key: &str) -> Option<&'a String> {
    args.get(key).and_then(|v| v.last())
}

/// Every value of a repeatable flag in command-line order; empty when the
/// flag is absent.
fn get_all<'a>(args: &'a Args, key: &str) -> &'a [String] {
    args.get(key).map(Vec::as_slice).unwrap_or(&[])
}

/// Typed flag lookup: absent -> `default`; present but unparsable -> a hard
/// CLI error naming the flag and the offending value (a silently swallowed
/// `--workers abc` used to serve with the default worker count).
fn get<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> ttrv::Result<T> {
    match last(args, key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| {
            ttrv::Error::config(format!(
                "flag --{key}: cannot parse value '{v}' as {}",
                std::any::type_name::<T>()
            ))
        }),
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = parse_args(&argv[argv.len().min(1)..]);
    let result = match cmd {
        "tables" => cmd_tables(&args),
        "dse" => cmd_dse(&args),
        "plan" => cmd_plan(&args),
        "kernel-bench" => cmd_kernel_bench(&args),
        "bench" => cmd_bench(&args),
        "compress" => cmd_compress(&args),
        "serve-demo" => cmd_serve_demo(&args),
        "artifacts-check" => cmd_artifacts_check(&args),
        "lint" => cmd_lint(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "ttrv — TT decomposition DSE + compiler optimization for RISC-V\n\
         usage: ttrv <command> [--key value ...]\n\
         commands: tables | dse | plan | kernel-bench | bench | compress | serve-demo |\n\
         \u{20}         artifacts-check | lint\n\
         \n\
         dse [--n N --m M --rank R] [--ranks 2,4,8] [--accuracy-budget EPS] [--seed S]\n\
         \u{20}        [--policy P] [--measure K] [--json]\n\
         \u{20}        six-stage DSE for one FC layer; --ranks / --accuracy-budget add the\n\
         \u{20}        weight-aware rank sweep and the fastest-within-budget pick\n\
         bench [--quick] [--out-dir D] [--kernels-only|--serve-only] [--config bench.toml]\n\
         \u{20}        [--kernel NAME]\n\
         \u{20}        measured kernel + serving sweeps -> BENCH_kernels.json / BENCH_serve.json\n\
         compress --model <zoo-name|spec.toml> --out model.ttrv [--rank R|auto] [--seed S]\n\
         \u{20}        [--accuracy-budget EPS] [--tune] [--quantize [--max-quant-error EPS]]\n\
         \u{20}        DSE-route + TT-SVD a model's FC stack into a versioned .ttrv bundle\n\
         \u{20}        (--rank auto: per-layer rank from the accuracy sweep, fastest layout\n\
         \u{20}         with TT-SVD rel error <= EPS;\n\
         \u{20}         --tune: measure RB/thread candidates per einsum, persist the winners;\n\
         \u{20}         --quantize: persist int8 cores when measured error fits the budget)\n\
         serve-demo [--artifact a.ttrv [--artifact b.ttrv ...]] [--workers N] [--max-batch B]\n\
         \u{20}        [--shards S] [--steal ring|off] [--slo-us T] [--cache-bytes B]\n\
         \u{20}        [--snapshot-json out.json] [--kernel NAME]\n\
         \u{20}        serve a TT LeNet300, or co-host every --artifact bundle in one\n\
         \u{20}        registry (round-robin load, per-model metrics, JSON snapshot)\n\
         artifacts-check --verify model.ttrv\n\
         \u{20}        validate bundle CRCs and replay it bitwise against a fresh compression\n\
         lint --artifact model.ttrv | --model <zoo-name> [--rank R] [--seed S] [--json]\n\
         \u{20}        static safety verification: prove every plan x core pair in-bounds\n\
         \u{20}        (packed geometry, zeroed pad lanes, register budget, quant scales);\n\
         \u{20}        per-plan diagnostics name the violated invariant; exit 0 iff clean\n\
         \n\
         see `cargo bench` for the per-figure reproduction harnesses"
    );
}

fn cmd_tables(args: &Args) -> ttrv::Result<()> {
    let cfg = DseConfig::default();
    let llm_only = args.contains_key("llm");
    let cnn_only = args.contains_key("cnn");
    if !llm_only {
        let mut rows = Vec::new();
        for m in models::cnn_models() {
            rows.extend(rows_for_model(&m, &cfg));
        }
        print!("{}", format_rows("Table 1: DS reduction (CNNs)", &rows));
    }
    if !cnn_only {
        let mut rows = Vec::new();
        for m in models::llm_models() {
            rows.extend(rows_for_model(&m, &cfg));
        }
        print!("{}", format_rows("Table 2: DS reduction (LLMs)", &rows));
    }
    Ok(())
}

fn cmd_dse(args: &Args) -> ttrv::Result<()> {
    let n: u64 = get(args, "n", 784)?;
    let m: u64 = get(args, "m", 300)?;
    let rank: u64 = get(args, "rank", 8)?;
    let top: usize = get(args, "top", 10)?;
    let seed: u64 = get(args, "seed", 42)?;
    let base = DseConfig::default();
    let cfg = DseConfig {
        dse_workers: get(args, "workers", base.dse_workers)?,
        selection_policy: last(args, "policy")
            .cloned()
            .unwrap_or_else(|| base.selection_policy.clone()),
        rank_candidates: match last(args, "ranks") {
            Some(s) => parse_rank_list(s)?,
            None => base.rank_candidates.clone(),
        },
        accuracy_budget: match last(args, "accuracy-budget") {
            Some(_) => Some(get(args, "accuracy-budget", 0.0f64)?),
            None => base.accuracy_budget,
        },
        ..base
    };
    cfg.validate()?;
    let machine = MachineSpec::spacemit_k1();
    let e = dse::explore_timed(m, n, &machine, &cfg);
    let c = &e.explored.counts;
    let sel = dse::select_solution(&e, rank, cfg.policy()?);

    // weight-aware rank sweep (stage 7), on request: --ranks and/or
    // --accuracy-budget turn it on. The CLI has no trained weights, so it
    // sweeps a seeded TT-structured demo matrix (planted at the ladder's
    // median rank on the policy pick's shape) — low ranks then carry real
    // reconstruction-error signal instead of the flat error of pure noise.
    let sweep = if args.contains_key("ranks") || cfg.accuracy_budget.is_some() {
        let w = dse_demo_weights(m, n, sel.as_ref().ok(), &cfg, seed);
        Some(dse::sweep_ranks(&e, &w, &machine, &cfg)?)
    } else {
        None
    };
    let budget_pick = match (&sweep, cfg.accuracy_budget) {
        (Some(sw), Some(b)) => Some(dse::select_within_accuracy_budget(sw, b)),
        _ => None,
    };

    // measured re-rank of the frontier head (runs on the build host, not
    // the modeled target) plus a measured host dense baseline, so modeled
    // and measured speedups sit side by side; resolved up front so --json
    // includes it too
    let measured = match last(args, "measure") {
        None => None,
        Some(v) => {
            let head: usize = v.parse().map_err(|_| {
                ttrv::Error::config(format!("--measure expects a candidate count, got '{v}'"))
            })?;
            let head = &e.frontier[..head.min(e.frontier.len())];
            let floor = ttrv::util::timer::MeasureFloor::from_env();
            let ranked =
                ttrv::dse::select::rerank_measured(head, &MachineSpec::host(), cfg.batch, &floor)?;
            let dense_secs = measure_dense_host(m, n, cfg.batch, &floor)?;
            Some((ranked, dense_secs))
        }
    };

    if args.contains_key("json") {
        if let Some(Err(err)) = &budget_pick {
            eprintln!("warning: accuracy budget not met: {err}");
        }
        let report = Json::obj(vec![
            ("schema", Json::from("ttrv-dse-report")),
            ("schema_version", Json::from(1usize)),
            ("n", Json::from(n as usize)),
            ("m", Json::from(m as usize)),
            ("rank", Json::from(rank as usize)),
            ("policy", Json::from(cfg.selection_policy.as_str())),
            ("machine", Json::from(machine.name)),
            (
                "counts",
                Json::obj(vec![
                    ("all", Json::from(c.all)),
                    ("aligned", Json::from(c.aligned)),
                    ("vectorized", Json::from(c.vectorized)),
                    ("initial", Json::from(c.initial)),
                    ("scalability", Json::from(c.scalability)),
                    ("timed", Json::from(e.timed.len())),
                ]),
            ),
            ("dense_modeled_time_s", Json::from(e.dense_time_s)),
            ("dense_flops", Json::from(ttrv::ttd::cost::dense_flops(m, n) as usize)),
            ("dense_params", Json::from(ttrv::ttd::cost::dense_params(m, n) as usize)),
            ("frontier", Json::Arr(e.frontier.iter().map(timed_solution_json).collect())),
            (
                "dense_measured_time_s",
                match &measured {
                    None => Json::Null,
                    Some((_, dense_secs)) => Json::from(*dense_secs),
                },
            ),
            (
                "measured_rerank",
                match &measured {
                    None => Json::Null,
                    Some((ranked, dense_secs)) => Json::Arr(
                        ranked
                            .iter()
                            .map(|(s, secs)| {
                                let mut o = timed_solution_json(s);
                                if let Json::Obj(map) = &mut o {
                                    map.insert("measured_time_s".into(), Json::from(*secs));
                                    // modeled `speedup_vs_dense` is already
                                    // in the object; this is its measured
                                    // twin, host-dense over host-chain
                                    map.insert(
                                        "measured_speedup_vs_dense".into(),
                                        Json::from(dense_secs / secs),
                                    );
                                }
                                o
                            })
                            .collect(),
                    ),
                },
            ),
            (
                "selected",
                match &sel {
                    Ok(s) => timed_solution_json(s),
                    Err(_) => Json::Null,
                },
            ),
            (
                "accuracy_budget",
                match cfg.accuracy_budget {
                    Some(b) => Json::from(b),
                    None => Json::Null,
                },
            ),
            (
                "rank_sweep",
                match &sweep {
                    Some(sw) => Json::Arr(sw.swept.iter().map(swept_solution_json).collect()),
                    None => Json::Null,
                },
            ),
            (
                "selected_rank",
                match &budget_pick {
                    Some(Ok(sw)) => Json::from(sw.timed.solution.rank as usize),
                    _ => Json::Null,
                },
            ),
            (
                "rel_error",
                match &budget_pick {
                    Some(Ok(sw)) => Json::from(sw.rel_error),
                    _ => Json::Null,
                },
            ),
        ]);
        println!("{}", ttrv::util::json::to_string_pretty(&report));
        return sel.map(|_| ());
    }

    println!(
        "FC [{n}, {m}]: all={} aligned={} vectorized={} initial={} scalability={} timed={}",
        ttrv::util::sci(c.all),
        ttrv::util::sci(c.aligned),
        c.vectorized,
        c.initial,
        c.scalability,
        e.timed.len(),
    );
    println!(
        "dense baseline: {} FLOPs, modeled {:.3} ms on {}",
        ttrv::ttd::cost::dense_flops(m, n),
        e.dense_time_s * 1e3,
        machine.name
    );
    println!(
        "Pareto frontier over (modeled time, params, FLOPs): {} of {} qualified solutions",
        e.frontier.len(),
        e.timed.len()
    );
    for s in e.frontier.iter().take(top) {
        println!(
            "  {}  params={} flops={} modeled={:.1} us ({:.1}x vs dense)",
            s.layout().describe(),
            s.solution.params,
            s.solution.flops,
            s.time_s * 1e6,
            s.speedup,
        );
    }
    let sel = sel?;
    println!(
        "selected ({} policy, rank {rank}): {}",
        cfg.selection_policy,
        sel.layout().describe()
    );
    println!(
        "  params={} flops={} modeled inference {:.1} us = {:.1}x speedup vs dense",
        sel.solution.params,
        sel.solution.flops,
        sel.time_s * 1e6,
        sel.speedup,
    );
    if let Some(sw) = &sweep {
        println!(
            "rank sweep over ranks {:?} ({} of {} shapes swept): {} decompositions, \
             {} on the accuracy frontier",
            cfg.rank_candidates,
            sw.shapes_swept,
            sw.shapes_total,
            sw.swept.len(),
            sw.frontier.len(),
        );
        for s in &sw.swept {
            println!(
                "  rank {:>3}  rel_error={:.4}  modeled={:.1} us ({:.1}x)  {}",
                s.timed.solution.rank,
                s.rel_error,
                s.timed.time_s * 1e6,
                s.timed.speedup,
                s.timed.layout().describe(),
            );
        }
        match &budget_pick {
            Some(Ok(pick)) => println!(
                "accuracy-budget pick (rel_error <= {}): rank {} rel_error={:.4} \
                 modeled={:.1} us  {}",
                cfg.accuracy_budget.unwrap_or(f64::NAN),
                pick.timed.solution.rank,
                pick.rel_error,
                pick.timed.time_s * 1e6,
                pick.timed.layout().describe(),
            ),
            Some(Err(err)) => println!("accuracy budget not met: {err}"),
            None => {}
        }
    }
    if let Some((ranked, dense_secs)) = &measured {
        println!(
            "measured re-rank of the frontier head (host, chain-autotuned; host dense \
             baseline {:.1} us):",
            dense_secs * 1e6
        );
        for (s, secs) in ranked {
            println!(
                "  {:9.1} us  {:>6.1}x measured  {:>6.1}x modeled  {}",
                secs * 1e6,
                dense_secs / secs,
                s.speedup,
                s.layout().describe()
            );
        }
    }
    Ok(())
}

/// Measured host time of the unfactorized dense layer at `batch` — the
/// measured twin of [`ttrv::dse::explore_timed`]'s modeled
/// `dense_time_s`, so `dse --measure --json` reports modeled and measured
/// speedup side by side.
fn measure_dense_host(
    m: u64,
    n: u64,
    batch: usize,
    floor: &ttrv::util::timer::MeasureFloor,
) -> ttrv::Result<f64> {
    let mut rng = Rng::new(0xd05e);
    let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
    let fc = ttrv::baselines::dense::DenseFc::new(&w, None)?;
    let x = Tensor::randn(vec![batch, n as usize], 1.0, &mut rng);
    ttrv::util::timer::try_min_secs("host dense baseline", || fc.forward(&x).map(|_| ()), floor)
}

/// `--ranks` value parser: a non-empty comma list of TT ranks.
fn parse_rank_list(s: &str) -> ttrv::Result<Vec<u64>> {
    let ranks: Vec<u64> = s
        .split(',')
        .map(|t| {
            t.trim().parse::<u64>().map_err(|_| {
                ttrv::Error::config(format!(
                    "--ranks expects a comma list of positive integers (e.g. 2,4,8), got '{s}'"
                ))
            })
        })
        .collect::<ttrv::Result<_>>()?;
    if ranks.is_empty() {
        return Err(ttrv::Error::config("--ranks expects at least one rank"));
    }
    Ok(ranks)
}

/// Seeded demo weights for the CLI rank sweep. Real deployments sweep the
/// trained weight matrix; the CLI plants a TT-structured matrix (the
/// ladder's median rank, on the policy pick's factorization shape) so low
/// ranks carry genuine reconstruction-error signal — a pure-noise matrix
/// would show near-flat error across the whole ladder.
fn dse_demo_weights(
    m: u64,
    n: u64,
    sel: Option<&ttrv::dse::TimedSolution>,
    cfg: &DseConfig,
    seed: u64,
) -> Tensor {
    let mut rng = Rng::new(seed);
    let mut ladder = cfg.rank_candidates.clone();
    ladder.sort_unstable();
    let plant_rank = ladder.get(ladder.len() / 2).copied().unwrap_or(4);
    if let Some(s) = sel {
        let layout = ttrv::ttd::TtLayout::with_uniform_rank(
            s.layout().m_shape().to_vec(),
            s.layout().n_shape().to_vec(),
            plant_rank,
        );
        if let Ok(layout) = layout {
            if let Ok(w) = random_cores(&layout, &mut rng).reconstruct() {
                return w;
            }
        }
    }
    Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng)
}

fn cmd_plan(args: &Args) -> ttrv::Result<()> {
    let dims = EinsumDims {
        kind: EinsumKind::Middle,
        m: get(args, "m", 64)?,
        b: get(args, "b", 64)?,
        n: get(args, "n", 8)?,
        r: get(args, "r", 8)?,
        k: get(args, "k", 8)?,
    };
    let machine = MachineSpec::spacemit_k1();
    let plan = compile(&dims, &machine)?;
    println!("machine: {} (vl={}, {} vregs)", machine.name, machine.vl_f32(), machine.vector_regs);
    println!("dims:    {dims:?} ({} FLOPs)", dims.flops());
    println!("plan:    vector_loop={:?} rb={:?}", plan.vector_loop, plan.rb);
    println!("         tile={:?} threads={}", plan.tile, plan.threads);
    println!("         predicted L/S = {}", plan.ls_estimate);
    let est = ttrv::machine::costmodel::estimate(&plan, &machine);
    println!(
        "modeled-K1: {:.3} ms, {:.2} GFLOP/s",
        est.seconds() * 1e3,
        est.gflops(dims.flops())
    );
    Ok(())
}

fn cmd_kernel_bench(args: &Args) -> ttrv::Result<()> {
    let kind = match last(args, "kind").map(String::as_str) {
        Some("first") => EinsumKind::First,
        Some("final") => EinsumKind::Final,
        _ => EinsumKind::Middle,
    };
    let bcfg = if args.contains_key("quick") { BenchCfg::quick() } else { BenchCfg::from_env() };
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(7);
    let mut ex = Executor::new(&machine);
    for entry in cb_suite(kind) {
        let d = entry.dims;
        let g = Tensor::randn(vec![d.r, d.n, d.m, d.k], 1.0, &mut rng);
        let x = Tensor::randn(vec![d.b, d.n, d.k], 1.0, &mut rng);
        let pg = ex.pack(&g, &d)?;
        let gm = iree_like::prepare_g(&g)?;
        let mut rows = Vec::new();
        rows.push(measure(&format!("{} ours", entry.id), d.flops(), &bcfg, || {
            ex.execute(&d, &pg, &x).expect("kernel");
        }));
        rows.push(measure(&format!("{} iree-like", entry.id), d.flops(), &bcfg, || {
            ex.execute_iree_prepared(&gm, d.r, &x).expect("iree");
        }));
        rows.push(measure(&format!("{} pluto-like", entry.id), d.flops(), &bcfg, || {
            ex.execute_pluto_like(&g, &x).expect("pluto");
        }));
        print!("{}", format_table(&format!("{:?} einsum {}", kind, entry.id), &rows, Some(1)));
    }
    Ok(())
}

/// `ttrv bench`: the measured-performance subsystem. Runs the kernel-level
/// sweep (pinned Table-3 einsum shapes, ours vs IREE-like vs Pluto-like)
/// and the serving sweep (`workers x max_batch x models` through a real
/// pool over the deterministic compressed LeNet300 + LeNet5 pair), then
/// writes the schema-versioned `BENCH_kernels.json` / `BENCH_serve.json`
/// reports — per-model rows plus an embedded `ttrv-serve-snapshot` — so
/// every future run appends a point to the perf trajectory.
/// Apply the shared `--kernel NAME` flag: pin process-wide dispatch to the
/// named microkernel ([`ttrv::kernels::set_preferred_kernel`] — typed
/// `Error::Kernel` on an unknown name or one this host cannot run).
fn apply_kernel_flag(args: &Args) -> ttrv::Result<()> {
    match last(args, "kernel") {
        Some(name) => ttrv::kernels::set_preferred_kernel(Some(name)),
        None => Ok(()),
    }
}

/// The kernel name the banners report: the `--kernel` pin when present
/// (whichever family it names), else what f32 dispatch selects.
fn active_kernel_name() -> &'static str {
    ttrv::kernels::preferred_kernel()
        .map(|k| k.name())
        .unwrap_or_else(ttrv::kernels::default_kernel_name)
}

fn cmd_bench(args: &Args) -> ttrv::Result<()> {
    use ttrv::bench::harness;
    apply_kernel_flag(args)?;
    let quick = args.contains_key("quick") || ttrv::util::bench_quick_env();
    let kernels_only = args.contains_key("kernels-only");
    let serve_only = args.contains_key("serve-only");
    if kernels_only && serve_only {
        return Err(ttrv::Error::config(
            "--kernels-only and --serve-only are mutually exclusive",
        ));
    }
    // precedence: an explicit --config file > --quick / TTRV_BENCH_QUICK >
    // the defaults (same explicit-flag-wins rule as `compress`)
    let typed = match last(args, "config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).map_err(|e| {
                ttrv::Error::config(format!("cannot read bench config '{path}': {e}"))
            })?;
            Some(ttrv::config::load_bench(&text)?)
        }
        None => None,
    };
    let bcfg = match &typed {
        Some(t) => BenchCfg::from_config(t),
        None if quick => BenchCfg::quick(),
        None => BenchCfg::default(),
    };
    let out_dir = last(args, "out-dir").cloned().unwrap_or_else(|| ".".to_string());
    let out_dir = std::path::Path::new(&out_dir);

    if !serve_only {
        println!(
            "kernel sweep ({} mode): 3 einsum kinds x 8 pinned shapes x 3 implementations \
             [kernel: {}]",
            if quick { "quick" } else { "full" },
            active_kernel_name(),
        );
        let rows = harness::run_kernel_sweep(&bcfg, quick)?;
        for r in &rows {
            let fmt = |s: Option<f64>| match s {
                Some(v) => format!("{v:.2}x"),
                None => "-".to_string(),
            };
            println!(
                "  {:<14} ours {:>11} ({:>7.2} GFLOP/s)  vs iree {:>7}  vs pluto {:>7}",
                r.id,
                ttrv::bench::format_secs(r.ours.seconds),
                r.ours.gflops(),
                fmt(r.speedup(&r.iree_like)),
                fmt(r.speedup(&r.pluto_like)),
            );
        }
        let path = out_dir.join(harness::BENCH_KERNELS_FILE);
        harness::write_report(&path, &harness::kernel_report_json(&rows, quick))?;
        println!("wrote {} ({} rows)", path.display(), rows.len());
    }

    if !kernels_only {
        println!("serving sweep: compressing the deterministic two-model zoo (lenet300 + lenet5)...");
        let machine = MachineSpec::spacemit_k1();
        let dse_cfg = DseConfig::default();
        let mut engines = Vec::new();
        for name in ["lenet300", "lenet5"] {
            let spec = ttrv::artifact::CompressSpec::from_zoo(name, 8, 42)?;
            let bundle = ttrv::artifact::compress(&spec, &machine, &dse_cfg)?;
            engines.push(bundle.build_engine(&machine)?);
        }
        let default_requests = match &typed {
            Some(t) => t.serve_requests,
            None if quick => 128,
            None => ttrv::config::BenchConfig::default().serve_requests,
        };
        let requests: usize = get(args, "requests", default_requests)?;
        let points = harness::default_serve_points(quick);
        let (rows, snapshot) = harness::run_serve_sweep(&engines, &points, requests)?;
        for r in &rows {
            println!(
                "  workers={} max_batch={:<3} models={} {:<12} {:>8.0} req/s  p50 {:>6} us  p99 {:>6} us  mean batch {:.1}",
                r.point.workers,
                r.point.max_batch,
                r.point.models,
                r.model,
                r.req_per_s,
                r.p50_us,
                r.p99_us,
                r.mean_batch
            );
        }
        let path = out_dir.join(harness::BENCH_SERVE_FILE);
        harness::write_report(&path, &harness::serve_report_json(&rows, quick, &snapshot))?;
        println!("wrote {} ({} rows)", path.display(), rows.len());
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> ttrv::Result<()> {
    let model = last(args, "model")
        .ok_or_else(|| ttrv::Error::config("compress needs --model <zoo-name|spec.toml>"))?;
    let out = last(args, "out")
        .ok_or_else(|| ttrv::Error::config("compress needs --out <file.ttrv>"))?;
    // `--rank auto` switches to the weight-aware sweep: per layer the rank
    // comes from the accuracy-budget pick over `rank_candidates`, not from
    // a fixed CLI value. Checked as a string BEFORE the numeric parse,
    // which would otherwise hard-error on "auto".
    let rank_is_auto = last(args, "rank").map(String::as_str) == Some("auto");
    let rank: u64 = if rank_is_auto { 8 } else { get(args, "rank", 8)? };
    let seed: u64 = get(args, "seed", 42)?;
    let auto_budget = if rank_is_auto {
        let b = match last(args, "accuracy-budget") {
            Some(_) => Some(get(args, "accuracy-budget", 0.0f64)?),
            None => DseConfig::default().accuracy_budget,
        };
        Some(b.ok_or_else(|| {
            ttrv::Error::config(
                "--rank auto needs --accuracy-budget EPS (max relative TT-SVD \
                 reconstruction error, e.g. 0.1)",
            )
        })?)
    } else {
        None
    };
    // anything path-shaped is a spec file — a typo'd path must surface as
    // a missing file, never fall through to an "unknown zoo model" error
    let looks_like_path = model.ends_with(".toml") || model.contains(['/', '\\']);
    let spec = if looks_like_path || std::path::Path::new(model).is_file() {
        // precedence: an explicitly passed CLI flag > the spec file's
        // pins > the CLI defaults — an explicit --rank must never be
        // silently overridden (the same silent-flag class get() rejects)
        let text = std::fs::read_to_string(model).map_err(|e| {
            ttrv::Error::config(format!("cannot read model spec file '{model}': {e}"))
        })?;
        let file = ttrv::config::load_model_spec(&text)?;
        let spec = ttrv::artifact::CompressSpec {
            name: file.name,
            shapes: file.shapes,
            rank: if args.contains_key("rank") && !rank_is_auto {
                rank
            } else {
                file.rank.unwrap_or(rank)
            },
            seed: if args.contains_key("seed") { seed } else { file.seed.unwrap_or(seed) },
        };
        spec.validate()?;
        spec
    } else {
        ttrv::artifact::CompressSpec::from_zoo(model, rank, seed)?
    };
    let machine = MachineSpec::spacemit_k1();
    let cfg = DseConfig::default();
    match auto_budget {
        Some(b) => println!(
            "compressing {} ({} FC layers) for {} at rank auto (accuracy budget {b}), seed {}",
            spec.name,
            spec.shapes.len(),
            machine.name,
            spec.seed
        ),
        None => println!(
            "compressing {} ({} FC layers) for {} at rank {}, seed {}",
            spec.name,
            spec.shapes.len(),
            machine.name,
            spec.rank,
            spec.seed
        ),
    }
    let t0 = std::time::Instant::now();
    let mut bundle = match auto_budget {
        Some(b) => ttrv::artifact::compress_auto(&spec, &machine, &cfg, b)?,
        None => ttrv::artifact::compress(&spec, &machine, &cfg)?,
    };
    if args.contains_key("quantize") {
        // int8-quantize the packed cores per m slice; the shadows ride
        // along in the (optional, format v4) QUANT section and
        // `serve-demo --artifact` warm-starts straight onto the int8
        // engines. --max-quant-error gates shipping on the *measured*
        // output error of the seeded calibration batch.
        let budget = match last(args, "max-quant-error") {
            None => None,
            Some(_) => Some(get(args, "max-quant-error", 0.0f64)?),
        };
        let rep = ttrv::artifact::quantize_bundle(&mut bundle, &machine, budget)?;
        if rep.applied {
            println!(
                "quantized {} TT layer(s) ({} cores) into the QUANT section: \
                 {} -> {} core bytes ({:.1}x smaller), measured max rel error {:.2e}",
                rep.layers,
                rep.cores,
                rep.f32_core_bytes,
                rep.int8_core_bytes,
                rep.f32_core_bytes as f64 / rep.int8_core_bytes.max(1) as f64,
                rep.max_rel_error,
            );
        } else {
            println!(
                "quantization NOT applied: measured max rel error {:.2e} exceeds \
                 --max-quant-error {:.2e}; shipping f32 cores",
                rep.max_rel_error,
                budget.unwrap_or(0.0),
            );
        }
    }
    if args.contains_key("tune") {
        // measured autotuning over the stored packed cores; the winners
        // ride along in the (optional, format v2) TUNE section and
        // `serve-demo --artifact` warm-starts straight onto them
        let floor = ttrv::util::timer::MeasureFloor::from_env();
        let tt0 = std::time::Instant::now();
        let rep = ttrv::artifact::tune_bundle(&mut bundle, &machine, &floor)?;
        println!(
            "autotuned {} TT layer(s): {} measured plans persisted in the TUNE section \
             ({:.2}s, kernel: {})",
            rep.layers,
            rep.plans,
            tt0.elapsed().as_secs_f64(),
            bundle.tuned_kernel.as_deref().unwrap_or("-"),
        );
    }
    let dense_params: usize = spec.shapes.iter().map(|&(n, m)| (n * m + m) as usize).sum();
    for entry in bundle.report.as_arr().unwrap_or(&[]) {
        let n = entry.get("n").and_then(Json::as_usize).unwrap_or(0);
        let m = entry.get("m").and_then(Json::as_usize).unwrap_or(0);
        match entry.get("selected") {
            Some(Json::Null) | None => println!("  [{n} -> {m}] dense (no qualified solution)"),
            Some(sel) => {
                let swept = match (
                    entry.get("selected_rank").and_then(Json::as_usize),
                    entry.get("rel_error").and_then(Json::as_f64),
                ) {
                    (Some(r), Some(e)) => format!(", swept rank {r} rel_error={e:.4}"),
                    _ => String::new(),
                };
                println!(
                    "  [{n} -> {m}] TT d={} rank={} ({:.1}x modeled speedup{swept})",
                    sel.get("d").and_then(Json::as_usize).unwrap_or(0),
                    sel.get("rank").and_then(Json::as_usize).unwrap_or(0),
                    sel.get("speedup_vs_dense").and_then(Json::as_f64).unwrap_or(0.0),
                );
            }
        }
    }
    ttrv::artifact::write_bundle_file(out, &bundle)?;
    let bytes = std::fs::metadata(out)?.len();
    println!(
        "wrote {out}: {} bytes, {}/{} layers TT, {} params (dense stack: {dense_params}, {:.1}x smaller), {:.2}s",
        bytes,
        bundle.tt_layers(),
        spec.shapes.len(),
        bundle.param_count(),
        dense_params as f64 / bundle.param_count() as f64,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_serve_demo(args: &Args) -> ttrv::Result<()> {
    apply_kernel_flag(args)?;
    let requests: usize = get(args, "requests", 200)?;
    let d = ServeConfig::default();
    let serve_cfg = ServeConfig {
        max_batch: get(args, "max-batch", d.max_batch)?,
        max_wait_us: get(args, "wait-us", d.max_wait_us)?,
        queue_cap: get(args, "queue-cap", d.queue_cap)?,
        workers: get(args, "workers", d.workers)?,
        shards: get(args, "shards", d.shards)?,
        steal: last(args, "steal").cloned().unwrap_or(d.steal),
        slo_us: get(args, "slo-us", d.slo_us)?,
        cache_bytes: get(args, "cache-bytes", d.cache_bytes)?,
    };
    serve_cfg.validate()?;
    let machine = MachineSpec::spacemit_k1();
    let mut rng = Rng::new(1);

    let artifacts = get_all(args, "artifact");
    // per-model modeled per-request TT time (sum of the selected solutions'
    // batch-1 chain estimates) for the modeled-vs-measured lines below
    let mut modeled_tt: Vec<(String, f64)> = Vec::new();
    let server = if !artifacts.is_empty() {
        // warm start: no DSE, no decomposition — each bundle carries packed
        // cores and compiled (possibly measured-autotuned) plans; all of
        // them co-host in one registry, routed by model id
        let t0 = std::time::Instant::now();
        for path in artifacts {
            let bundle = ttrv::artifact::read_bundle_file(path)?;
            let tuned_layers = bundle
                .ops
                .iter()
                .filter(|op| matches!(op, ttrv::artifact::BundleOp::Tt(t) if t.tuned.is_some()))
                .count();
            let quant_layers = bundle
                .ops
                .iter()
                .filter(|op| matches!(op, ttrv::artifact::BundleOp::Tt(t) if t.quant.is_some()))
                .count();
            println!(
                "loaded {} from {path} ({} FC layers, {} TT, {}{})",
                bundle.name,
                bundle.shapes.len(),
                bundle.tt_layers(),
                if tuned_layers > 0 {
                    format!("{tuned_layers} serving measured TUNE plans")
                } else {
                    "analytic plans".to_string()
                },
                if quant_layers > 0 {
                    format!(", {quant_layers} int8 QUANT layer(s)")
                } else {
                    String::new()
                }
            );
            let modeled: f64 = bundle
                .ops
                .iter()
                .filter_map(|op| match op {
                    ttrv::artifact::BundleOp::Tt(t) => Some(t.selected.time_s),
                    _ => None,
                })
                .sum();
            if modeled.is_finite() && modeled > 0.0 {
                modeled_tt.push((bundle.name.clone(), modeled));
            }
        }
        let server = Server::from_artifacts(artifacts, &machine, serve_cfg.clone())?;
        println!(
            "warm-started {} model(s) in {:.1} ms",
            server.registry().len(),
            t0.elapsed().as_secs_f64() * 1e3
        );
        server
    } else {
        // cold start: DSE-route and decompose a TT LeNet300 in process
        let cfg = DseConfig::default();
        let mut ops = Vec::new();
        let shapes = [(784u64, 300u64), (300, 100), (100, 10)];
        for (i, &(n, m)) in shapes.iter().enumerate() {
            match ttrv::coordinator::router::route_layer(m, n, 8, &machine, &cfg)? {
                ttrv::coordinator::Route::Tt(sol) => {
                    let mut tt = random_cores(sol.layout(), &mut rng);
                    tt.bias = Some(vec![0.0; m as usize]);
                    println!(
                        "layer {i}: TT {} (modeled {:.1}x vs dense)",
                        sol.layout().describe(),
                        sol.speedup
                    );
                    ops.push(LayerOp::Tt(TtFcEngine::new(&tt, &machine)?));
                }
                ttrv::coordinator::Route::Dense => {
                    println!("layer {i}: dense [{n} -> {m}]");
                    let w = Tensor::randn(vec![m as usize, n as usize], 0.05, &mut rng);
                    ops.push(LayerOp::Dense(ttrv::baselines::dense::DenseFc::new(&w, None)?));
                }
            }
            if i + 1 < shapes.len() {
                ops.push(LayerOp::Relu);
            }
        }
        Server::start(ModelEngine::new("lenet300-tt", ops, 784, 10), serve_cfg.clone())
    };
    let infos = server.registry().models();
    println!(
        "serving {} model(s) with {} worker(s), max_batch {}, wait {}us, queue {}, steal {}{} \
         [kernel: {}]",
        infos.len(),
        serve_cfg.workers.max(1),
        serve_cfg.max_batch,
        serve_cfg.max_wait_us,
        serve_cfg.queue_cap,
        serve_cfg.steal,
        if serve_cfg.slo_us > 0 {
            format!(", slo {}us", serve_cfg.slo_us)
        } else {
            String::new()
        },
        active_kernel_name(),
    );

    // synthetic load, round-robined across the co-hosted models
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..requests)
        .map(|id| {
            let info = &infos[id % infos.len()];
            let req = InferenceRequest::new(id as u64, rng.normal_vec(info.in_dim, 1.0))
                .for_model(info.id.clone());
            server.submit(req).expect("queue should admit")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("inference ok");
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("served {requests} requests in {:.1} ms ({:.0} req/s)", dt * 1e3, requests as f64 / dt);
    for info in &infos {
        let m = server.metrics_for(&info.id)?;
        println!("model {}:\n{}", info.id, m.summary());
    }
    for (name, modeled) in &modeled_tt {
        // modeled (target cost model, batch 1) vs measured (this host's
        // exec histogram, amortized per request) — the serving half of the
        // analytic->measured loop the bench harness closes
        let m = server.metrics_for(name)?;
        let measured_us = m.exec.mean_us() / m.mean_batch().max(1.0);
        if measured_us > 0.0 {
            println!(
                "{name}: modeled TT chains {:.1} us/request vs measured exec {:.1} us/request \
                 ({:.2}x of the model, host vs modeled target)",
                modeled * 1e6,
                measured_us,
                measured_us / (modeled * 1e6)
            );
        }
    }
    if let Some(path) = last(args, "snapshot-json") {
        // the machine-readable state document: per-model rows + process
        // totals, schema-gated by python/tools/check_bench_json.py
        let mut text = ttrv::util::json::to_string_pretty(&server.snapshot());
        text.push('\n');
        std::fs::write(path, text).map_err(|e| {
            ttrv::Error::serve(format!("cannot write snapshot '{path}': {e}"))
        })?;
        println!("wrote snapshot {path}");
    }
    server.shutdown();
    Ok(())
}

fn cmd_artifacts_check(args: &Args) -> ttrv::Result<()> {
    if let Some(path) = last(args, "verify") {
        return cmd_verify_bundle(path);
    }
    let dir = last(args, "dir")
        .cloned()
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = ttrv::runtime::Runtime::open(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    println!("artifacts: {:?}", rt.manifest().names());
    // smoke-execute the batch-1 dense FC: zero weights + bias 0.5 -> all 0.5
    let exe = rt.compile("dense_fc_784x300_b1")?;
    let x = Tensor::zeros(vec![1, 784]);
    let w = Tensor::zeros(vec![300, 784]);
    let b = Tensor::full(vec![300], 0.5);
    let out = exe.run(&[x, w, b])?;
    assert_eq!(out[0].dims(), &[1, 300]);
    assert!(out[0].data().iter().all(|&v| (v - 0.5).abs() < 1e-6));
    println!("dense_fc artifact executes correctly (bias-only check passed)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_of(argv: &[&str]) -> Args {
        parse_args(&argv.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn get_returns_default_when_flag_absent() {
        let args = args_of(&["--other", "1"]);
        assert_eq!(get(&args, "workers", 3usize).unwrap(), 3);
    }

    #[test]
    fn get_parses_present_values() {
        let args = args_of(&["--workers", "8", "--rank", "16"]);
        assert_eq!(get(&args, "workers", 1usize).unwrap(), 8);
        assert_eq!(get(&args, "rank", 8u64).unwrap(), 16);
    }

    #[test]
    fn malformed_value_is_a_hard_error_naming_the_flag() {
        // the old behavior silently served with the default worker count
        let args = args_of(&["--workers", "abc"]);
        let err = get(&args, "workers", 1usize).unwrap_err().to_string();
        assert!(err.contains("--workers"), "{err}");
        assert!(err.contains("abc"), "{err}");
        // a value-less numeric flag (captured as "true") errors too
        let args = args_of(&["--workers", "--json"]);
        assert!(get(&args, "workers", 1usize).is_err());
        // negative where unsigned expected
        let args = args_of(&["--requests", "-5"]);
        assert!(get(&args, "requests", 10usize).is_err());
    }

    #[test]
    fn parse_args_pairs_and_flags() {
        let args = args_of(&["--n", "784", "--json", "--m", "300"]);
        assert_eq!(last(&args, "n").map(String::as_str), Some("784"));
        assert_eq!(last(&args, "m").map(String::as_str), Some("300"));
        assert_eq!(last(&args, "json").map(String::as_str), Some("true"));
    }

    #[test]
    fn repeated_flags_keep_every_value_in_order() {
        let args = args_of(&["--artifact", "a.ttrv", "--workers", "2", "--artifact", "b.ttrv"]);
        assert_eq!(get_all(&args, "artifact"), &["a.ttrv", "b.ttrv"]);
        // scalar lookups over a repeated flag take the last value
        let args = args_of(&["--workers", "2", "--workers", "8"]);
        assert_eq!(last(&args, "workers").map(String::as_str), Some("8"));
        assert_eq!(get(&args, "workers", 1usize).unwrap(), 8);
        // absent repeatable flag is an empty list, not a panic
        assert!(get_all(&args, "artifact").is_empty());
    }
}

/// `artifacts-check --verify model.ttrv`: container + CRC validation, then
/// the bitwise replay against a fresh in-process compression.
fn cmd_verify_bundle(path: &str) -> ttrv::Result<()> {
    if path == "true" {
        return Err(ttrv::Error::config("--verify needs a bundle path: --verify model.ttrv"));
    }
    let bytes = std::fs::read(path)
        .map_err(|e| ttrv::Error::artifact(format!("cannot read bundle {path}: {e}")))?;
    let sections = ttrv::artifact::list_sections(&bytes)?;
    // list_sections validated the header, so the version field is present
    let version = u32::from_le_bytes(bytes[4..8].try_into().expect("validated header"));
    println!(
        "{path}: format v{version} (reader supports v{}..=v{}), {} bytes, CRCs ok",
        ttrv::artifact::MIN_FORMAT_VERSION,
        ttrv::artifact::FORMAT_VERSION,
        bytes.len()
    );
    for s in &sections {
        println!("  section {:>2}: {:>9} bytes  crc32 {:#010x}", s.id, s.len, s.crc);
    }
    let bundle = ttrv::artifact::read_bundle_bytes(&bytes)?;
    println!(
        "decoded {}: {} FC layers ({} TT), rank {}, seed {}, machine {}{}",
        bundle.name,
        bundle.shapes.len(),
        bundle.tt_layers(),
        bundle.rank,
        bundle.seed,
        bundle.machine,
        match &bundle.tuned_kernel {
            Some(k) => format!(", tuned on kernel {k}"),
            None => String::new(),
        }
    );
    let machine = MachineSpec::spacemit_k1();
    let report = ttrv::artifact::verify(&bundle, &machine, &DseConfig::default())?;
    println!(
        "verify ok: re-compression is byte-identical ({} bytes) and a seeded batch \
         replays bitwise through both engines ({} outputs checked)",
        report.encoded_bytes, report.outputs_checked
    );
    Ok(())
}

/// `ttrv lint`: the CLI chokepoint of the static plan/layout verifier.
/// Runs [`ttrv::artifact::lint_bundle`] — the strict tier of
/// [`ttrv::compiler::verify`] over every plan × core pair — on a `.ttrv`
/// bundle (decoded *without* the reader's fail-fast gate, so a corrupt
/// bundle yields the full violation list, not just the first) or on a
/// fresh in-process compression of a zoo model. Exit 0 iff clean.
fn cmd_lint(args: &Args) -> ttrv::Result<()> {
    let (bundle, source) = match (last(args, "artifact"), last(args, "model")) {
        (Some(path), None) => {
            let bytes = std::fs::read(path)
                .map_err(|e| ttrv::Error::artifact(format!("cannot read bundle {path}: {e}")))?;
            (ttrv::artifact::read_bundle_bytes_unverified(&bytes)?, path.clone())
        }
        (None, Some(name)) => {
            let rank: u64 = get(args, "rank", 8)?;
            let seed: u64 = get(args, "seed", 42)?;
            let spec = ttrv::artifact::CompressSpec::from_zoo(name, rank, seed)?;
            let bundle =
                ttrv::artifact::compress(&spec, &MachineSpec::spacemit_k1(), &DseConfig::default())?;
            (bundle, format!("zoo:{name}"))
        }
        _ => {
            return Err(ttrv::Error::config(
                "lint needs exactly one of --artifact model.ttrv or --model <zoo-name>",
            ))
        }
    };
    let report = ttrv::artifact::lint_bundle(&bundle);
    if args.contains_key("json") {
        println!("{}", ttrv::util::json::to_string_pretty(&report.to_json(&source)));
    } else {
        println!(
            "lint {source}: model {} compiled for {}{}",
            report.model,
            report.machine,
            if report.machine_known {
                ""
            } else {
                " (unknown machine: register-budget check skipped)"
            }
        );
        for row in &report.rows {
            let d = &row.plan.dims;
            println!(
                "  layer {} step {} [{}] {:?} m={} b={} n={} r={} k={} {:?} rb=({},{},{},{}) \
                 regs={} threads={}{}: {}",
                row.layer,
                row.step,
                row.source.as_str(),
                d.kind,
                d.m,
                d.b,
                d.n,
                d.r,
                d.k,
                row.layout,
                row.plan.rb.rm,
                row.plan.rb.rb,
                row.plan.rb.rr,
                row.plan.rb.rk,
                row.registers,
                row.plan.threads,
                if row.quant { " +int8" } else { "" },
                if row.violations.is_empty() { "ok" } else { "VIOLATED" },
            );
            for v in &row.violations {
                println!("      {v}");
            }
        }
        println!(
            "{} plan(s) checked, {} violation(s): {}",
            report.plans_checked(),
            report.violations(),
            if report.clean() { "clean" } else { "UNSAFE" }
        );
    }
    if report.clean() {
        Ok(())
    } else {
        Err(ttrv::Error::plan(format!(
            "lint found {} violation(s) across {} plan(s)",
            report.violations(),
            report.plans_checked()
        )))
    }
}
