//! # ttrv — Tensor-Train DSE + analytical compiler optimization for RISC-V
//!
//! Reproduction of *"Optimizing Tensor Train Decomposition in DNNs for RISC-V
//! Architectures Using Design Space Exploration and Compiler Optimizations"*
//! (ACM TECS 2026, DOI 10.1145/3768624) as a three-layer Rust + JAX + Pallas
//! stack. See `DESIGN.md` for the full system inventory and experiment index.
//!
//! Layer map:
//! * **L3 (this crate)** — the paper's contribution: the design-space
//!   exploration engine ([`dse`]), the analytical compiler for T3F Einsum
//!   kernels ([`compiler`], [`machine`]), executable optimized kernels and
//!   baselines ([`kernels`], [`baselines`]), a serving coordinator
//!   ([`coordinator`]), a compressed-model artifact layer ([`artifact`]:
//!   `ttrv compress` → versioned `.ttrv` bundles → warm-start serving) and
//!   a PJRT runtime ([`runtime`]) that executes AOT-lowered JAX/Pallas
//!   artifacts.
//! * **L2** — `python/compile/model.py`: TT FC layers + MLP in JAX.
//! * **L1** — `python/compile/kernels/tt_einsum.py`: the Pallas hot-spot
//!   kernel, validated against `ref.py`.
//!
//! Quick tour:
//! ```
//! use ttrv::ttd::{TtLayout, cost};
//! // The paper's running example: FC 784 -> 300, d = 5, rank 8.
//! let layout = TtLayout::new(
//!     vec![5, 5, 3, 2, 2], vec![2, 2, 2, 7, 14],
//!     vec![1, 8, 8, 8, 8, 1]).unwrap();
//! assert!(cost::params(&layout) < 300 * 784 + 300);
//! assert!(cost::flops(&layout) < 2 * 300 * 784 + 300);
//! ```
//!
//! The serving entry point is [`coordinator::Server`]; the end-to-end
//! data-flow (models -> dse -> compiler -> kernels -> coordinator) is
//! documented in `docs/ARCHITECTURE.md`.

// Every public item carries rustdoc; CI builds docs with -D warnings so
// this cannot rot (see .github/workflows/ci.yml).
#![warn(missing_docs)]
// Unsafe hygiene: an `unsafe fn` body gets no blanket license — every
// unsafe operation inside it sits in its own `unsafe {}` block, and every
// such block carries a `// SAFETY:` comment naming the invariant it leans
// on (clippy runs with -D warnings in CI, so both are enforced). The
// invariants themselves are proven per-plan by `compiler::verify`.
#![deny(unsafe_op_in_unsafe_fn)]
#![warn(clippy::undocumented_unsafe_blocks)]

pub mod error;
pub mod util;
pub mod testkit;
pub mod tensor;
pub mod linalg;
pub mod factor;
pub mod ttd;
pub mod models;
pub mod machine;
pub mod compiler;
pub mod kernels;
pub mod baselines;
pub mod dse;
pub mod bench;
pub mod config;
pub mod runtime;
pub mod coordinator;
pub mod artifact;

pub use error::{Error, Result};
