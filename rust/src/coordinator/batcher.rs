//! Dynamic batching policy (pure logic, independently testable).
//!
//! Requests accumulate until the batch is full or the oldest request has
//! waited `max_wait`; then the batch closes. The same policy a serving
//! frontend (vLLM-style) applies, scaled to this system.

use std::time::{Duration, Instant};

/// Decision state for one in-flight batch.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    opened_at: Option<Instant>,
    pending: usize,
}

impl Batcher {
    /// A policy closing batches at `max_batch` requests or `max_wait`
    /// after the oldest pending request arrived, whichever comes first.
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Batcher { max_batch, max_wait, opened_at: None, pending: 0 }
    }

    /// Record an arriving request; returns true if the batch is now full
    /// and must be dispatched.
    pub fn push(&mut self, now: Instant) -> bool {
        if self.pending == 0 {
            self.opened_at = Some(now);
        }
        self.pending += 1;
        self.pending >= self.max_batch
    }

    /// Should a non-full batch be dispatched due to the wait deadline?
    pub fn deadline_reached(&self, now: Instant) -> bool {
        match self.opened_at {
            Some(t0) if self.pending > 0 => now.duration_since(t0) >= self.max_wait,
            _ => false,
        }
    }

    /// Time the queue worker may sleep before the deadline fires.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.opened_at.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.max_wait.saturating_sub(elapsed)
        })
    }

    /// Close the batch, returning its size.
    pub fn take(&mut self) -> usize {
        let n = self.pending;
        self.pending = 0;
        self.opened_at = None;
        n
    }

    /// Requests in the currently open batch.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// The configured batch-size cap.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(3, Duration::from_millis(10));
        let t = Instant::now();
        assert!(!b.push(t));
        assert!(!b.push(t));
        assert!(b.push(t)); // full
        assert_eq!(b.take(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_fires_only_with_pending() {
        let mut b = Batcher::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        assert!(!b.deadline_reached(t0 + Duration::from_secs(1)));
        b.push(t0);
        assert!(!b.deadline_reached(t0));
        assert!(b.deadline_reached(t0 + Duration::from_millis(5)));
        assert_eq!(b.take(), 1);
        assert!(!b.deadline_reached(t0 + Duration::from_secs(2)));
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push(t0);
        let left = b.time_to_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(left <= Duration::from_millis(6));
        let left2 = b.time_to_deadline(t0 + Duration::from_millis(40)).unwrap();
        assert_eq!(left2, Duration::ZERO);
    }

    #[test]
    fn property_deadline_fires_exactly_at_max_wait() {
        // the deadline must never fire before max_wait has elapsed since
        // the batch opened, and must always fire at/after it
        crate::testkit::check("deadline fires at max_wait", 50, |d| {
            let wait = Duration::from_micros(d.usize_in(1, 10_000) as u64);
            let mut b = Batcher::new(d.usize_in(2, 64), wait);
            let t0 = Instant::now();
            b.push(t0);
            // later pushes must not extend the deadline of the open batch
            for i in 0..d.usize_in(0, 5) {
                b.push(t0 + Duration::from_micros(i as u64));
            }
            let just_before = t0 + wait - Duration::from_nanos(1);
            if b.deadline_reached(just_before) {
                return Err(format!("fired {wait:?} early"));
            }
            if !b.deadline_reached(t0 + wait) {
                return Err(format!("missed deadline at {wait:?}"));
            }
            // the advertised sleep must never overshoot the deadline
            let probe = t0 + Duration::from_micros(d.usize_in(0, 20_000) as u64);
            let left = b.time_to_deadline(probe).expect("batch open");
            if probe + left < t0 + wait {
                return Err("time_to_deadline wakes before the deadline".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_full_batch_exactly_at_max() {
        // push must report full exactly on the max_batch-th request, never
        // earlier, regardless of interleaved takes
        crate::testkit::check("full exactly at max_batch", 50, |d| {
            let max = d.usize_in(1, 32);
            let mut b = Batcher::new(max, Duration::from_millis(1));
            let t = Instant::now();
            for _round in 0..d.usize_in(1, 4) {
                for i in 1..=max {
                    let full = b.push(t);
                    if full != (i == max) {
                        return Err(format!("push {i}/{max} reported full={full}"));
                    }
                }
                if b.take() != max {
                    return Err("take lost requests".into());
                }
                if b.pending() != 0 {
                    return Err("pending not reset by take".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_batch_never_exceeds_max() {
        crate::testkit::check("batch <= max_batch", 50, |d| {
            let max = d.usize_in(1, 16);
            let mut b = Batcher::new(max, Duration::from_millis(1));
            let t = Instant::now();
            let mut total_in = 0usize;
            let mut total_out = 0usize;
            for _ in 0..d.usize_in(0, 60) {
                total_in += 1;
                if b.push(t) {
                    let n = b.take();
                    if n > max {
                        return Err(format!("batch {n} > max {max}"));
                    }
                    total_out += n;
                }
            }
            total_out += b.take();
            if total_in != total_out {
                return Err(format!("lost requests: in {total_in} out {total_out}"));
            }
            Ok(())
        });
    }
}
