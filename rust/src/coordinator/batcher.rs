//! Dynamic batching policy (pure logic, independently testable).
//!
//! Requests accumulate until the batch is full or the oldest request has
//! waited `max_wait`; then the batch closes. The same policy a serving
//! frontend (vLLM-style) applies, scaled to this system.

use std::time::{Duration, Instant};

/// Decision state for one in-flight batch.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    opened_at: Option<Instant>,
    pending: usize,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Batcher { max_batch, max_wait, opened_at: None, pending: 0 }
    }

    /// Record an arriving request; returns true if the batch is now full
    /// and must be dispatched.
    pub fn push(&mut self, now: Instant) -> bool {
        if self.pending == 0 {
            self.opened_at = Some(now);
        }
        self.pending += 1;
        self.pending >= self.max_batch
    }

    /// Should a non-full batch be dispatched due to the wait deadline?
    pub fn deadline_reached(&self, now: Instant) -> bool {
        match self.opened_at {
            Some(t0) if self.pending > 0 => now.duration_since(t0) >= self.max_wait,
            _ => false,
        }
    }

    /// Time the queue worker may sleep before the deadline fires.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.opened_at.map(|t0| {
            let elapsed = now.duration_since(t0);
            self.max_wait.saturating_sub(elapsed)
        })
    }

    /// Close the batch, returning its size.
    pub fn take(&mut self) -> usize {
        let n = self.pending;
        self.pending = 0;
        self.opened_at = None;
        n
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(3, Duration::from_millis(10));
        let t = Instant::now();
        assert!(!b.push(t));
        assert!(!b.push(t));
        assert!(b.push(t)); // full
        assert_eq!(b.take(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_fires_only_with_pending() {
        let mut b = Batcher::new(8, Duration::from_millis(5));
        let t0 = Instant::now();
        assert!(!b.deadline_reached(t0 + Duration::from_secs(1)));
        b.push(t0);
        assert!(!b.deadline_reached(t0));
        assert!(b.deadline_reached(t0 + Duration::from_millis(5)));
        assert_eq!(b.take(), 1);
        assert!(!b.deadline_reached(t0 + Duration::from_secs(2)));
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b = Batcher::new(8, Duration::from_millis(10));
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push(t0);
        let left = b.time_to_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(left <= Duration::from_millis(6));
        let left2 = b.time_to_deadline(t0 + Duration::from_millis(40)).unwrap();
        assert_eq!(left2, Duration::ZERO);
    }

    #[test]
    fn property_batch_never_exceeds_max() {
        crate::testkit::check("batch <= max_batch", 50, |d| {
            let max = d.usize_in(1, 16);
            let mut b = Batcher::new(max, Duration::from_millis(1));
            let t = Instant::now();
            let mut total_in = 0usize;
            let mut total_out = 0usize;
            for _ in 0..d.usize_in(0, 60) {
                total_in += 1;
                if b.push(t) {
                    let n = b.take();
                    if n > max {
                        return Err(format!("batch {n} > max {max}"));
                    }
                    total_out += n;
                }
            }
            total_out += b.take();
            if total_in != total_out {
                return Err(format!("lost requests: in {total_in} out {total_out}"));
            }
            Ok(())
        });
    }
}
