//! Dynamic batching policy (pure logic, independently testable).
//!
//! Requests accumulate until the batch is full or the earliest *deadline*
//! among the admitted requests is reached; then the batch closes. Each
//! push carries its own wait budget — for an SLO-tagged request the server
//! passes a fraction of the remaining SLO (dispatch when the budget is
//! nearly spent, leaving headroom to execute), for an untagged request it
//! passes the configured `max_wait`, which reproduces the classic
//! oldest-request-waits-`max_wait` policy exactly. The same policy a
//! serving frontend (vLLM-style) applies, scaled to this system.

use std::time::{Duration, Instant};

/// Decision state for one in-flight batch.
#[derive(Debug)]
pub struct Batcher {
    max_batch: usize,
    max_wait: Duration,
    deadline: Option<Instant>,
    pending: usize,
}

impl Batcher {
    /// A policy closing batches at `max_batch` requests or at the earliest
    /// per-request deadline, whichever comes first. `max_wait` caps every
    /// wait budget, so no admitted request ever lingers longer than the
    /// configured maximum (clamped to one hour so extreme configs cannot
    /// overflow deadline arithmetic).
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        assert!(max_batch >= 1);
        Batcher {
            max_batch,
            max_wait: max_wait.min(Duration::from_secs(3600)),
            deadline: None,
            pending: 0,
        }
    }

    /// Record a request that arrived at `arrival` and is willing to wait
    /// `wait_budget` (capped by `max_wait`) for batch-mates; returns true
    /// if the batch is now full and must be dispatched. The batch deadline
    /// is the minimum over the admitted requests' deadlines, so one
    /// tight-SLO request pulls the whole batch forward and later pushes
    /// can never extend it.
    pub fn push(&mut self, arrival: Instant, wait_budget: Duration) -> bool {
        let d = arrival + wait_budget.min(self.max_wait);
        self.deadline = Some(match self.deadline {
            Some(cur) => cur.min(d),
            None => d,
        });
        self.pending += 1;
        self.pending >= self.max_batch
    }

    /// Should a non-full batch be dispatched due to its deadline?
    pub fn deadline_reached(&self, now: Instant) -> bool {
        match self.deadline {
            Some(d) if self.pending > 0 => now >= d,
            _ => false,
        }
    }

    /// Time the queue worker may sleep before the deadline fires.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.deadline.map(|d| d.saturating_duration_since(now))
    }

    /// Close the batch, returning its size.
    pub fn take(&mut self) -> usize {
        let n = self.pending;
        self.pending = 0;
        self.deadline = None;
        n
    }

    /// Requests in the currently open batch.
    pub fn pending(&self) -> usize {
        self.pending
    }

    /// The configured batch-size cap.
    pub fn max_batch(&self) -> usize {
        self.max_batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const WAIT: Duration = Duration::from_millis(10);

    #[test]
    fn fills_to_max_batch() {
        let mut b = Batcher::new(3, WAIT);
        let t = Instant::now();
        assert!(!b.push(t, WAIT));
        assert!(!b.push(t, WAIT));
        assert!(b.push(t, WAIT)); // full
        assert_eq!(b.take(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_fires_only_with_pending() {
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(8, wait);
        let t0 = Instant::now();
        assert!(!b.deadline_reached(t0 + Duration::from_secs(1)));
        b.push(t0, wait);
        assert!(!b.deadline_reached(t0));
        assert!(b.deadline_reached(t0 + Duration::from_millis(5)));
        assert_eq!(b.take(), 1);
        assert!(!b.deadline_reached(t0 + Duration::from_secs(2)));
    }

    #[test]
    fn time_to_deadline_counts_down() {
        let mut b = Batcher::new(8, WAIT);
        let t0 = Instant::now();
        assert!(b.time_to_deadline(t0).is_none());
        b.push(t0, WAIT);
        let left = b.time_to_deadline(t0 + Duration::from_millis(4)).unwrap();
        assert!(left <= Duration::from_millis(6));
        let left2 = b.time_to_deadline(t0 + Duration::from_millis(40)).unwrap();
        assert_eq!(left2, Duration::ZERO);
    }

    #[test]
    fn tighter_slo_budget_pulls_the_batch_deadline_forward() {
        // deadline-aware batching: a second request with a 1 ms budget
        // tightens a batch that opened with a 10 ms budget
        let mut b = Batcher::new(8, WAIT);
        let t0 = Instant::now();
        b.push(t0, WAIT);
        assert!(!b.deadline_reached(t0 + Duration::from_millis(2)));
        b.push(t0 + Duration::from_millis(1), Duration::from_millis(1));
        assert!(b.deadline_reached(t0 + Duration::from_millis(2)));
        assert_eq!(
            b.time_to_deadline(t0 + Duration::from_millis(1)).unwrap(),
            Duration::from_millis(1)
        );
        // taking the batch clears the tightened deadline
        b.take();
        assert!(b.time_to_deadline(t0).is_none());
    }

    #[test]
    fn wait_budget_is_capped_by_max_wait() {
        // a huge SLO must not let a request linger past the configured cap
        let wait = Duration::from_millis(5);
        let mut b = Batcher::new(8, wait);
        let t0 = Instant::now();
        b.push(t0, Duration::from_secs(3600));
        assert!(!b.deadline_reached(t0 + Duration::from_millis(4)));
        assert!(b.deadline_reached(t0 + Duration::from_millis(5)));
    }

    #[test]
    fn property_deadline_fires_exactly_at_max_wait() {
        // with every push carrying the full max_wait budget (the no-SLO
        // path), the deadline must never fire before max_wait has elapsed
        // since the batch opened, and must always fire at/after it
        crate::testkit::check("deadline fires at max_wait", 50, |d| {
            let wait = Duration::from_micros(d.usize_in(1, 10_000) as u64);
            let mut b = Batcher::new(d.usize_in(2, 64), wait);
            let t0 = Instant::now();
            b.push(t0, wait);
            // later pushes must not extend the deadline of the open batch
            for i in 0..d.usize_in(0, 5) {
                b.push(t0 + Duration::from_micros(i as u64), wait);
            }
            let just_before = t0 + wait - Duration::from_nanos(1);
            if b.deadline_reached(just_before) {
                return Err(format!("fired {wait:?} early"));
            }
            if !b.deadline_reached(t0 + wait) {
                return Err(format!("missed deadline at {wait:?}"));
            }
            // the advertised sleep must never overshoot the deadline
            let probe = t0 + Duration::from_micros(d.usize_in(0, 20_000) as u64);
            let left = b.time_to_deadline(probe).expect("batch open");
            if probe + left < t0 + wait {
                return Err("time_to_deadline wakes before the deadline".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_deadline_is_min_over_admitted_budgets() {
        // mixed SLO budgets: the batch deadline equals the earliest
        // (arrival + min(budget, max_wait)) among the admitted requests
        crate::testkit::check("deadline = min over budgets", 50, |d| {
            let max_wait = Duration::from_micros(d.usize_in(1, 5_000) as u64);
            let mut b = Batcher::new(64, max_wait);
            let t0 = Instant::now();
            let mut want: Option<Instant> = None;
            for _ in 0..d.usize_in(1, 8) {
                let arrival = t0 + Duration::from_micros(d.usize_in(0, 2_000) as u64);
                let budget = Duration::from_micros(d.usize_in(0, 10_000) as u64);
                b.push(arrival, budget);
                let deadline = arrival + budget.min(max_wait);
                want = Some(match want {
                    Some(w) => w.min(deadline),
                    None => deadline,
                });
            }
            let want = want.expect("at least one push");
            if b.deadline_reached(want - Duration::from_nanos(1)) {
                return Err("fired before the earliest budget was spent".into());
            }
            if !b.deadline_reached(want) {
                return Err("missed the earliest budget deadline".into());
            }
            Ok(())
        });
    }

    #[test]
    fn property_full_batch_exactly_at_max() {
        // push must report full exactly on the max_batch-th request, never
        // earlier, regardless of interleaved takes
        crate::testkit::check("full exactly at max_batch", 50, |d| {
            let max = d.usize_in(1, 32);
            let wait = Duration::from_millis(1);
            let mut b = Batcher::new(max, wait);
            let t = Instant::now();
            for _round in 0..d.usize_in(1, 4) {
                for i in 1..=max {
                    let full = b.push(t, wait);
                    if full != (i == max) {
                        return Err(format!("push {i}/{max} reported full={full}"));
                    }
                }
                if b.take() != max {
                    return Err("take lost requests".into());
                }
                if b.pending() != 0 {
                    return Err("pending not reset by take".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_batch_never_exceeds_max() {
        crate::testkit::check("batch <= max_batch", 50, |d| {
            let max = d.usize_in(1, 16);
            let wait = Duration::from_millis(1);
            let mut b = Batcher::new(max, wait);
            let t = Instant::now();
            let mut total_in = 0usize;
            let mut total_out = 0usize;
            for _ in 0..d.usize_in(0, 60) {
                total_in += 1;
                if b.push(t, wait) {
                    let n = b.take();
                    if n > max {
                        return Err(format!("batch {n} > max {max}"));
                    }
                    total_out += n;
                }
            }
            total_out += b.take();
            if total_in != total_out {
                return Err(format!("lost requests: in {total_in} out {total_out}"));
            }
            Ok(())
        });
    }
}
