//! Layer routing: run the time-aware DSE per FC layer and decide TT vs
//! dense (the paper factorizes layers where a surviving solution beats the
//! dense layer; tiny layers stay dense).
//!
//! Routing runs the full six-stage engine
//! ([`crate::dse::explore_timed`]), so a `Tt` route always carries a
//! [`TimedSolution`] whose modeled speedup over the dense layer met
//! `DseConfig::time_speedup_min` — the serving stack never deploys a
//! factorization the machine model predicts to be a slowdown.
//!
//! This is *layer* routing (compile-time: which kernel implements an FC).
//! Request-to-model routing at serve time is the
//! [`registry`](super::registry)'s job.

use crate::config::DseConfig;
use crate::dse::{self, TimedSolution};
use crate::dse::report::MIN_FC_DIM;
use crate::error::Result;
use crate::machine::MachineSpec;

/// Routing decision for one FC layer.
#[derive(Debug, Clone)]
pub enum Route {
    /// Factorize with this DSE-selected, time-qualified solution.
    Tt(TimedSolution),
    /// Keep the dense MMM path.
    Dense,
}

impl Route {
    /// Whether this route factorizes the layer.
    pub fn is_tt(&self) -> bool {
        matches!(self, Route::Tt(_))
    }
}

/// Decide the route for an FC layer `(m_out, n_in)` at the given rank,
/// selecting by the policy in `cfg.selection_policy` over the engine's
/// output on `machine`. Errors on an unknown policy name (a config that
/// [`DseConfig::validate`] would reject) rather than silently falling back
/// — a layer with no qualified solution routes `Dense`, never `Err`.
pub fn route_layer(
    m_out: u64,
    n_in: u64,
    rank: u64,
    machine: &MachineSpec,
    cfg: &DseConfig,
) -> Result<Route> {
    Ok(route_layer_explored(m_out, n_in, rank, machine, cfg)?.0)
}

/// [`route_layer`], additionally returning the full engine output the
/// decision was made from — `None` when the layer was too small to explore
/// at all. The artifact compressor ([`crate::artifact::compress`]) embeds
/// this as the bundle's DSE-report section instead of re-running the
/// engine.
pub fn route_layer_explored(
    m_out: u64,
    n_in: u64,
    rank: u64,
    machine: &MachineSpec,
    cfg: &DseConfig,
) -> Result<(Route, Option<dse::TimedExplored>)> {
    if m_out < MIN_FC_DIM || n_in < MIN_FC_DIM {
        return Ok((Route::Dense, None));
    }
    let policy = cfg.policy()?;
    let explored = dse::explore_timed(m_out, n_in, machine, cfg);
    // qualification happens entirely in the engine: any selectable solution
    // already beat dense on FLOPs + params (stage 4) and on modeled time
    // (stage 6), so selection failure is the only reason to stay dense
    let route = match dse::select_solution(&explored, rank, policy) {
        Ok(sol) => Route::Tt(sol),
        Err(_) => Route::Dense,
    };
    Ok((route, Some(explored)))
}

/// Route every FC layer of a model architecture.
pub fn route_model(
    shapes: &[(u64, u64)], // (n_in, m_out) pairs, paper table order
    rank: u64,
    machine: &MachineSpec,
    cfg: &DseConfig,
) -> Result<Vec<Route>> {
    shapes
        .iter()
        .map(|&(n, m)| route_layer(m, n, rank, machine, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    #[test]
    fn large_layers_get_factorized() {
        let cfg = DseConfig::default();
        let r = route_layer(300, 784, 8, &k1(), &cfg).unwrap();
        assert!(r.is_tt());
        if let Route::Tt(sol) = r {
            assert!(sol.solution.flops < cost::dense_flops(300, 784));
            assert_eq!(sol.layout().d(), 2); // Sec. 6.4 selection policy
            assert!(sol.speedup >= cfg.time_speedup_min);
        }
    }

    #[test]
    fn tiny_layers_stay_dense() {
        let cfg = DseConfig::default();
        assert!(!route_layer(10, 100, 8, &k1(), &cfg).unwrap().is_tt()); // 10-class head
        assert!(!route_layer(100, 10, 8, &k1(), &cfg).unwrap().is_tt());
    }

    #[test]
    fn prime_dims_stay_dense() {
        let cfg = DseConfig::default();
        assert!(!route_layer(101, 784, 8, &k1(), &cfg).unwrap().is_tt()); // 101 prime
    }

    #[test]
    fn lenet300_routing_matches_examples() {
        let cfg = DseConfig::default();
        let routes =
            route_model(&[(784, 300), (300, 100), (100, 10)], 8, &k1(), &cfg).unwrap();
        assert!(routes[0].is_tt());
        assert!(routes[1].is_tt());
        assert!(!routes[2].is_tt()); // 100 -> 10 too small
    }

    #[test]
    fn explored_variant_returns_the_engine_output() {
        let cfg = DseConfig::default();
        // tiny layer: dense without exploring
        let (r, e) = route_layer_explored(10, 100, 8, &k1(), &cfg).unwrap();
        assert!(!r.is_tt());
        assert!(e.is_none());
        // real layer: the returned exploration is the decision substrate
        let (r, e) = route_layer_explored(300, 784, 8, &k1(), &cfg).unwrap();
        let e = e.expect("explored");
        match r {
            Route::Tt(sol) => assert!(e.timed.contains(&sol)),
            Route::Dense => panic!("expected TT"),
        }
        assert!(!e.frontier.is_empty());
    }

    #[test]
    fn strict_speedup_threshold_can_force_dense() {
        // an absurd required speedup disqualifies every solution -> dense
        let cfg = DseConfig { time_speedup_min: 1e9, ..Default::default() };
        assert!(!route_layer(300, 784, 8, &k1(), &cfg).unwrap().is_tt());
    }

    #[test]
    fn unknown_policy_is_a_routing_error_not_a_silent_fallback() {
        let cfg = DseConfig { selection_policy: "fastest".into(), ..Default::default() };
        assert!(route_layer(300, 784, 8, &k1(), &cfg).is_err());
        assert!(route_model(&[(784, 300)], 8, &k1(), &cfg).is_err());
    }

    #[test]
    fn min_time_policy_routes_to_the_modeled_fastest() {
        let cfg = DseConfig {
            selection_policy: "min-time".into(),
            ..Default::default()
        };
        match route_layer(300, 784, 8, &k1(), &cfg).unwrap() {
            Route::Tt(sol) => {
                let e = dse::explore_timed(300, 784, &k1(), &cfg);
                assert!(e.timed.iter().all(|t| sol.time_s <= t.time_s));
            }
            Route::Dense => panic!("expected a TT route"),
        }
    }
}
