//! Layer routing: run the DSE per FC layer and decide TT vs dense
//! (the paper factorizes layers where a surviving solution beats the dense
//! layer; tiny layers stay dense).

use crate::config::DseConfig;
use crate::dse::{self, Solution};
use crate::dse::report::MIN_FC_DIM;
use crate::error::Result;
use crate::ttd::cost;

/// Routing decision for one FC layer.
#[derive(Debug, Clone)]
pub enum Route {
    /// Factorize with this DSE-selected solution.
    Tt(Solution),
    /// Keep the dense MMM path.
    Dense,
}

impl Route {
    /// Whether this route factorizes the layer.
    pub fn is_tt(&self) -> bool {
        matches!(self, Route::Tt(_))
    }
}

/// Decide the route for an FC layer `(m_out, n_in)` at the given rank.
pub fn route_layer(m_out: u64, n_in: u64, rank: u64, cfg: &DseConfig) -> Route {
    if m_out < MIN_FC_DIM || n_in < MIN_FC_DIM {
        return Route::Dense;
    }
    let explored = dse::explore(m_out, n_in, cfg);
    match dse::select_solution(&explored, rank) {
        Ok(sol) if sol.flops < cost::dense_flops(m_out, n_in) => Route::Tt(sol),
        _ => Route::Dense,
    }
}

/// Route every FC layer of a model architecture.
pub fn route_model(
    shapes: &[(u64, u64)], // (n_in, m_out) pairs, paper table order
    rank: u64,
    cfg: &DseConfig,
) -> Result<Vec<Route>> {
    Ok(shapes
        .iter()
        .map(|&(n, m)| route_layer(m, n, rank, cfg))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn large_layers_get_factorized() {
        let cfg = DseConfig::default();
        let r = route_layer(300, 784, 8, &cfg);
        assert!(r.is_tt());
        if let Route::Tt(sol) = r {
            assert!(sol.flops < cost::dense_flops(300, 784));
            assert_eq!(sol.layout.d(), 2); // Sec. 6.4 selection policy
        }
    }

    #[test]
    fn tiny_layers_stay_dense() {
        let cfg = DseConfig::default();
        assert!(!route_layer(10, 100, 8, &cfg).is_tt()); // 10-class head
        assert!(!route_layer(100, 10, 8, &cfg).is_tt());
    }

    #[test]
    fn prime_dims_stay_dense() {
        let cfg = DseConfig::default();
        assert!(!route_layer(101, 784, 8, &cfg).is_tt()); // 101 prime
    }

    #[test]
    fn lenet300_routing_matches_examples() {
        let cfg = DseConfig::default();
        let routes =
            route_model(&[(784, 300), (300, 100), (100, 10)], 8, &cfg).unwrap();
        assert!(routes[0].is_tt());
        assert!(routes[1].is_tt());
        assert!(!routes[2].is_tt()); // 100 -> 10 too small
    }
}
