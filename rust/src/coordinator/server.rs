//! The serving event loop, v2: multi-model registry, sharded
//! work-stealing admission, deadline-aware batching, machine-readable
//! metrics. Hand-rolled on std (tokio is unavailable offline); the
//! structure is the standard serving shape: admission -> per-worker
//! shard -> per-model batch -> execute -> fan-out.
//!
//! What changed from the single-model server (ISSUE 2):
//!
//! * **Registry** — a [`Server`] now fronts a [`ModelRegistry`]: several
//!   `.ttrv` artifacts (or pinned engines) co-hosted in one process,
//!   requests routed by [`InferenceRequest::model`], engines cached under
//!   a byte budget with LRU eviction and lazy warm-start reload.
//! * **Queues** — admission round-robins across a [`ShardedQueue`] (one
//!   shard per worker, clamped) instead of serializing on one global
//!   lock; idle workers steal from busy shards when
//!   [`crate::config::StealPolicy::Ring`] is on. `Error::QueueFull`
//!   backpressure and drain-then-exit shutdown are unchanged contracts.
//! * **Batching** — each request carries an SLO budget
//!   ([`InferenceRequest::slo_us`], defaulted from `ServeConfig.slo_us`);
//!   a batch dispatches when full, or when the *tightest* admitted
//!   budget is nearly spent (half the SLO, capped by `max_wait`), so a
//!   tight-deadline request cannot starve behind the configured wait.
//! * **Observability** — [`Server::snapshot`] returns a versioned JSON
//!   document (`ttrv-serve-snapshot` v1) with process-wide and per-model
//!   counters; [`Server::metrics_for`] exposes one model's merged shard.
//!
//! Batches never mix models: each worker keeps one open [`Batcher`] per
//! registry slot. Responses are bit-identical across shard counts, steal
//! schedules, worker counts, and co-hosted models — batch *composition*
//! varies with timing, but the kernels' per-element reduction order is
//! batch-invariant (pinned by `rust/tests/serving.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::{ServeConfig, StealPolicy};
use crate::error::{Error, Result};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::util::json::Json;

use super::batcher::Batcher;
use super::engine::ModelEngine;
use super::metrics::Metrics;
use super::queue::{Pop, PushError, ShardedQueue, Steal};
use super::registry::ModelRegistry;

/// Snapshot document name ([`Server::snapshot`]).
pub const SNAPSHOT_SCHEMA: &str = "ttrv-serve-snapshot";
/// Snapshot document version. v2 added the top-level `kernel` key (the
/// microkernel name worker executors dispatch to on this host).
pub const SNAPSHOT_SCHEMA_VERSION: usize = 2;

/// How often an idle worker re-scans other shards for stealable work.
/// Stealing is polling-based (a cross-shard Condvar web would reintroduce
/// the global lock the shards removed); one wake per tick costs a handful
/// of uncontended lock acquisitions.
const STEAL_TICK: Duration = Duration::from_micros(200);
/// Idle block time when stealing is off: effectively "until woken".
const IDLE_WAIT: Duration = Duration::from_secs(3600);
/// A batch holding an SLO'd request dispatches once `slo / 2` has been
/// spent queueing — "nearly spent" with headroom for execution itself.
const SLO_WAIT_DIVISOR: u64 = 2;

/// A single inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Caller-chosen identifier, echoed back in the response.
    pub id: u64,
    /// Flat input row (length = target model's in_dim).
    pub input: Vec<f32>,
    /// Target model id; `None` routes to the server's default (first
    /// registered) model.
    pub model: Option<String>,
    /// Per-request latency budget in microseconds; overrides the server's
    /// configured `slo_us`. `None` falls back to the config (0 = none).
    pub slo_us: Option<u64>,
}

impl InferenceRequest {
    /// A request for the default model with no SLO.
    pub fn new(id: u64, input: Vec<f32>) -> Self {
        InferenceRequest { id, input, model: None, slo_us: None }
    }

    /// Route this request to a named model.
    pub fn for_model(mut self, model: impl Into<String>) -> Self {
        self.model = Some(model.into());
        self
    }

    /// Attach a latency budget in microseconds.
    pub fn with_slo_us(mut self, slo_us: u64) -> Self {
        self.slo_us = Some(slo_us);
        self
    }
}

/// The reply.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    /// The request's identifier.
    pub id: u64,
    /// Flat output row (length = model out_dim).
    pub output: Vec<f32>,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Time from enqueue to reply.
    pub latency: Duration,
}

struct Envelope {
    req: InferenceRequest,
    /// Registry slot, resolved at admission so workers never fail routing.
    slot: usize,
    /// Effective SLO (request override, else config default, else none).
    slo_us: Option<u64>,
    enqueued: Instant,
    reply: Sender<Result<InferenceResponse>>,
}

/// Handle to a running server: the model registry, the sharded admission
/// queue, and the worker pool.
pub struct Server {
    queue: Arc<ShardedQueue<Envelope>>,
    registry: Arc<ModelRegistry>,
    workers: Vec<JoinHandle<()>>,
    /// Per-worker metrics shards, each holding one [`Metrics`] per model
    /// slot; only the owning worker writes a shard.
    shards: Vec<Arc<Mutex<Vec<Metrics>>>>,
    /// Per-model admission rejections (caller threads, outside any shard).
    rejected: Vec<AtomicU64>,
    started: Instant,
    cfg: ServeConfig,
}

impl Server {
    /// Start `cfg.workers` batching workers over a single pinned model
    /// engine (the v1 entry point; the engine becomes the registry's
    /// default model and is never evicted). Out-of-range config values
    /// are clamped here as a last line of defense; [`crate::config::load`]
    /// rejects them loudly.
    pub fn start(engine: ModelEngine, cfg: ServeConfig) -> Server {
        let mut registry = ModelRegistry::new(cfg.cache_bytes);
        registry.add_pinned(engine).expect("fresh registry cannot hold a duplicate id");
        Server::spawn(registry, cfg)
    }

    /// Start a server co-hosting several pinned engines; requests route
    /// between them via [`InferenceRequest::model`]. Fails on duplicate
    /// model names.
    pub fn start_multi(engines: Vec<ModelEngine>, cfg: ServeConfig) -> Result<Server> {
        if engines.is_empty() {
            return Err(Error::serve("cannot start a server with no models"));
        }
        let mut registry = ModelRegistry::new(cfg.cache_bytes);
        for engine in engines {
            registry.add_pinned(engine)?;
        }
        Ok(Server::spawn(registry, cfg))
    }

    /// Warm-start a server from one compressed-model `.ttrv` bundle. See
    /// [`Server::from_artifacts`].
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        machine: &MachineSpec,
        cfg: ServeConfig,
    ) -> Result<Server> {
        Server::from_artifacts(&[path], machine, cfg)
    }

    /// Warm-start a server co-hosting several `.ttrv` bundles
    /// ([`crate::artifact`]): each file is decoded + checksum-validated
    /// and registered with the model registry; engines are built lazily
    /// with pre-seeded plan caches (no DSE, no decomposition, no
    /// compilation), so cold-start cost scales with model size, not
    /// design-space size. All bundles must have been compressed for
    /// `machine`, and `cfg.cache_bytes` bounds how many engines stay
    /// resident at once.
    pub fn from_artifacts(
        paths: &[impl AsRef<std::path::Path>],
        machine: &MachineSpec,
        cfg: ServeConfig,
    ) -> Result<Server> {
        cfg.validate()?;
        if paths.is_empty() {
            return Err(Error::serve("no artifacts given"));
        }
        let mut registry = ModelRegistry::new(cfg.cache_bytes);
        for path in paths {
            let bundle = crate::artifact::read_bundle_file(path)?;
            registry.add_bundle(bundle, machine)?;
        }
        Ok(Server::spawn(registry, cfg))
    }

    fn spawn(registry: ModelRegistry, cfg: ServeConfig) -> Server {
        let n_workers = cfg.workers.max(1);
        let n_shards = cfg.effective_shards(n_workers);
        let steal = match cfg.steal_policy().unwrap_or(StealPolicy::Ring) {
            StealPolicy::Ring => Steal::Ring,
            StealPolicy::Off => Steal::Off,
        };
        let queue = Arc::new(ShardedQueue::new(n_shards, cfg.queue_cap.max(1), steal));
        let registry = Arc::new(registry);
        let n_models = registry.len();

        let mut workers = Vec::with_capacity(n_workers);
        let mut shards = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let shard = Arc::new(Mutex::new(vec![Metrics::default(); n_models]));
            let q = Arc::clone(&queue);
            let r = Arc::clone(&registry);
            let m = Arc::clone(&shard);
            let wcfg = cfg.clone();
            workers.push(std::thread::spawn(move || worker_loop(w, r, wcfg, steal, q, m)));
            shards.push(shard);
        }
        Server {
            queue,
            registry,
            workers,
            shards,
            rejected: (0..n_models).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
            cfg,
        }
    }

    /// Number of workers in the pool.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// The model registry backing this server (routing table, residency,
    /// load/eviction counters).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    /// Submit without blocking on execution; returns the reply channel.
    /// Fails fast on an unknown model, a wrong input width, a full queue
    /// (admission control, [`Error::QueueFull`]), or a stopped server.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<Result<InferenceResponse>>> {
        let slot = self.registry.resolve(req.model.as_deref())?;
        let in_dim = self.registry.in_dim(slot);
        if req.input.len() != in_dim {
            return Err(Error::serve(format!(
                "input width {} != model {}",
                req.input.len(),
                in_dim
            )));
        }
        let slo_us = req
            .slo_us
            .or_else(|| (self.cfg.slo_us > 0).then_some(self.cfg.slo_us));
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let env = Envelope { req, slot, slo_us, enqueued: Instant::now(), reply: reply_tx };
        match self.queue.try_push(env) {
            Ok(()) => Ok(reply_rx),
            Err(PushError::Full(_)) => {
                self.rejected[slot].fetch_add(1, Ordering::Relaxed);
                Err(Error::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(Error::serve("server stopped")),
        }
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| Error::serve("worker dropped reply"))?
    }

    /// Process-wide metrics: every worker shard and every model merged,
    /// plus all admission rejections.
    pub fn metrics(&self) -> Metrics {
        let mut total = Metrics::default();
        for shard in &self.shards {
            for m in shard.lock().expect("metrics lock").iter() {
                total.merge(m);
            }
        }
        total.rejected += self.rejected.iter().map(|r| r.load(Ordering::Relaxed)).sum::<u64>();
        total
    }

    /// One model's metrics, merged across worker shards.
    pub fn metrics_for(&self, model: &str) -> Result<Metrics> {
        let slot = self.registry.resolve(Some(model))?;
        let mut total = Metrics::default();
        for shard in &self.shards {
            total.merge(&shard.lock().expect("metrics lock")[slot]);
        }
        total.rejected += self.rejected[slot].load(Ordering::Relaxed);
        Ok(total)
    }

    /// Machine-readable state snapshot: schema-versioned JSON with
    /// process-wide rates and histograms, registry cache counters, and one
    /// row per co-hosted model. The schema is validated by
    /// `python/tools/check_bench_json.py` in CI.
    pub fn snapshot(&self) -> Json {
        let uptime = self.started.elapsed().as_secs_f64().max(1e-9);
        let process = self.metrics();
        let infos = self.registry.models();
        let models: Vec<Json> = infos
            .iter()
            .map(|info| {
                let m = self.metrics_for(&info.id).expect("registered model resolves");
                Json::obj(vec![
                    ("model", Json::from(info.id.as_str())),
                    ("resident", Json::from(info.resident)),
                    ("pinned", Json::from(info.pinned)),
                    ("engine_bytes", Json::from(info.bytes as f64)),
                    ("req_per_s", Json::from(m.requests as f64 / uptime)),
                    ("metrics", m.to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::from(SNAPSHOT_SCHEMA)),
            ("schema_version", Json::from(SNAPSHOT_SCHEMA_VERSION)),
            ("uptime_s", Json::from(uptime)),
            ("workers", Json::from(self.workers())),
            ("shards", Json::from(self.queue.shard_count())),
            ("steal", Json::from(self.cfg.steal.as_str())),
            // host-wide dispatch choice (all worker executors select the
            // same kernel at construction), for correlating latency rows
            // across machines
            ("kernel", Json::from(crate::kernels::default_kernel_name())),
            ("queue_depth", Json::from(self.queue.len())),
            ("req_per_s", Json::from(process.requests as f64 / uptime)),
            ("process", process.to_json()),
            (
                "registry",
                Json::obj(vec![
                    ("models", Json::from(self.registry.len())),
                    (
                        "resident",
                        Json::from(infos.iter().filter(|i| i.resident).count()),
                    ),
                    ("loads", Json::from(self.registry.loads() as f64)),
                    ("evictions", Json::from(self.registry.evictions() as f64)),
                    ("cache_bytes", Json::from(self.registry.cache_bytes() as f64)),
                    ("resident_bytes", Json::from(self.registry.resident_bytes() as f64)),
                ]),
            ),
            ("models", Json::Arr(models)),
        ])
    }

    /// Graceful shutdown: admission stops, every shard is drained by its
    /// owner, every in-flight request is answered, all workers are joined.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One worker's open (not yet dispatched) batch for one model slot.
struct OpenBatch {
    batcher: Batcher,
    envs: Vec<Envelope>,
}

impl OpenBatch {
    /// Admit an envelope; returns `true` when the batch is now full.
    fn admit(&mut self, env: Envelope, max_wait: Duration) -> bool {
        let budget = match env.slo_us {
            Some(slo) => Duration::from_micros(slo / SLO_WAIT_DIVISOR),
            None => max_wait,
        };
        let full = self.batcher.push(env.enqueued, budget);
        self.envs.push(env);
        full
    }
}

/// One pool worker: absorb from its shard (stealing when idle), keep one
/// open batch per model, dispatch batches when full or due.
fn worker_loop(
    w: usize,
    registry: Arc<ModelRegistry>,
    cfg: ServeConfig,
    steal: Steal,
    queue: Arc<ShardedQueue<Envelope>>,
    metrics: Arc<Mutex<Vec<Metrics>>>,
) {
    let max_batch = cfg.max_batch.max(1);
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let n_models = registry.len();
    let mut open: Vec<OpenBatch> = (0..n_models)
        .map(|_| OpenBatch { batcher: Batcher::new(max_batch, max_wait), envs: Vec::with_capacity(max_batch) })
        .collect();
    // worker-local engine views, re-leased from the registry per batch
    // (zero-cost while the epoch is unchanged)
    let mut engines: Vec<Option<(u64, ModelEngine)>> = (0..n_models).map(|_| None).collect();
    let mut shutdown = false;
    loop {
        // absorb everything immediately visible (home shard, then steals);
        // a batch that fills dispatches at once so it never overshoots
        // max_batch — this is also the greedy top-up: under backlog the
        // batch goes out full, not as the size-1 remnant of an overdue
        // deadline
        while let Some(env) = queue.try_pop(w) {
            let slot = env.slot;
            if open[slot].admit(env, max_wait) {
                dispatch(slot, &registry, &mut engines, &mut open[slot], &metrics);
            }
        }
        // dispatch every batch whose deadline has passed
        let now = Instant::now();
        let mut fired = false;
        for slot in 0..n_models {
            if !open[slot].envs.is_empty() && open[slot].batcher.deadline_reached(now) {
                dispatch(slot, &registry, &mut engines, &mut open[slot], &metrics);
                fired = true;
            }
        }
        if fired {
            continue; // execution took time: re-absorb before blocking
        }
        if shutdown {
            for slot in 0..n_models {
                if !open[slot].envs.is_empty() {
                    dispatch(slot, &registry, &mut engines, &mut open[slot], &metrics);
                }
            }
            break;
        }
        // block on the home shard until the next batch deadline, the steal
        // tick (work may appear on other shards without a wakeup here), or
        // a push/close wakeup
        let now = Instant::now();
        let mut wait = if steal == Steal::Ring { STEAL_TICK } else { IDLE_WAIT };
        for b in &open {
            if !b.envs.is_empty() {
                wait = wait.min(b.batcher.time_to_deadline(now).unwrap_or(Duration::ZERO));
            }
        }
        match queue.pop_home(w, wait) {
            Pop::Item(env) => {
                let slot = env.slot;
                if open[slot].admit(env, max_wait) {
                    dispatch(slot, &registry, &mut engines, &mut open[slot], &metrics);
                }
            }
            Pop::TimedOut => {}
            Pop::Closed => shutdown = true,
        }
    }
}

/// Execute one model's batch and fan the rows back out.
fn dispatch(
    slot: usize,
    registry: &ModelRegistry,
    engines: &mut [Option<(u64, ModelEngine)>],
    open: &mut OpenBatch,
    metrics: &Mutex<Vec<Metrics>>,
) {
    open.batcher.take();
    let batch = open.envs.len();
    if batch == 0 {
        return;
    }
    // lease the engine: free while our epoch matches, a worker_clone after
    // a (re)load, a full bundle build if the engine was evicted
    let have = engines[slot].as_ref().map(|(epoch, _)| *epoch);
    match registry.lease(slot, have) {
        Ok((epoch, Some(fresh))) => engines[slot] = Some((epoch, fresh)),
        Ok((_, None)) => {}
        Err(e) => {
            let msg = e.to_string();
            for env in open.envs.drain(..) {
                let _ = env.reply.send(Err(Error::serve(msg.clone())));
            }
            return;
        }
    }
    let (_, engine) = engines[slot].as_mut().expect("lease leaves an engine in place");

    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let mut flat = Vec::with_capacity(batch * in_dim);
    for env in open.envs.iter() {
        flat.extend_from_slice(&env.req.input);
    }
    let exec_start = Instant::now();
    let result = Tensor::from_vec(vec![batch, in_dim], flat).and_then(|x| engine.forward(&x));
    let exec_time = exec_start.elapsed();

    {
        let mut shard = metrics.lock().expect("metrics lock");
        let m = &mut shard[slot];
        m.batches += 1;
        m.requests += batch as u64;
        m.batch_size_sum += batch as u64;
        m.batch_sizes.record_value(batch as u64);
        m.exec.record(exec_time);
        for env in open.envs.iter() {
            let latency = env.enqueued.elapsed();
            m.queue_wait.record(exec_start.duration_since(env.enqueued));
            m.latency.record(latency);
            if let Some(slo) = env.slo_us {
                if latency > Duration::from_micros(slo) {
                    m.slo_missed += 1;
                }
            }
        }
    }

    match result {
        Ok(y) => {
            for (i, env) in open.envs.drain(..).enumerate() {
                let output = y.data()[i * out_dim..(i + 1) * out_dim].to_vec();
                let _ = env.reply.send(Ok(InferenceResponse {
                    id: env.req.id,
                    output,
                    batch_size: batch,
                    latency: env.enqueued.elapsed(),
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for env in open.envs.drain(..) {
                let _ = env.reply.send(Err(Error::serve(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dense::DenseFc;
    use crate::coordinator::engine::{LayerOp, ModelEngine};
    use crate::util::prng::Rng;

    /// Tiny deterministic model: y = x @ W^T with known W (4 -> 2).
    fn toy_engine() -> ModelEngine {
        toy_named("toy")
    }

    fn toy_named(name: &str) -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new(name, vec![LayerOp::Dense(fc)], 4, 2)
    }

    /// A second toy with different math: y = 2x (first two coords).
    fn toy_doubler(name: &str) -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![2., 0., 0., 0., 0., 2., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new(name, vec![LayerOp::Dense(fc)], 4, 2)
    }

    fn serve_cfg(max_batch: usize, wait_us: u64) -> ServeConfig {
        ServeConfig {
            max_batch,
            max_wait_us: wait_us,
            queue_cap: 256,
            workers: 1,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn admission_control_rejects_when_queue_full() {
        // a 1-slot queue with a slow wait window fills immediately
        let cfg = ServeConfig {
            max_batch: 64,
            max_wait_us: 50_000,
            queue_cap: 1,
            workers: 1,
            ..ServeConfig::default()
        };
        let server = Server::start(toy_engine(), cfg);
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for id in 0..50u64 {
            match server.submit(InferenceRequest::new(id, vec![0.0; 4])) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // every accepted request still gets exactly one reply
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        if rejected > 0 {
            assert!(server.metrics().rejected >= 1);
        }
        server.shutdown();
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(toy_engine(), serve_cfg(4, 100));
        let resp = server.infer(InferenceRequest::new(7, vec![1.0, 2.0, 3.0, 4.0])).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output, vec![1.0, 2.0]);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        server.shutdown();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_load() {
        let server = Server::start(toy_engine(), serve_cfg(8, 200));
        let mut rng = Rng::new(110);
        let mut receivers = Vec::new();
        for id in 0..100u64 {
            let input = rng.normal_vec(4, 1.0);
            let rx = server.submit(InferenceRequest::new(id, input.clone())).unwrap();
            receivers.push((id, input, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (id, input, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            assert!(seen.insert(id), "duplicate reply {id}");
            // batched output equals the single-request math
            assert!((resp.output[0] - input[0]).abs() < 1e-6);
            assert!((resp.output[1] - input[1]).abs() < 1e-6);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        assert_eq!(seen.len(), 100);
        let m = server.metrics();
        assert_eq!(m.requests, 100);
        assert!(m.mean_batch() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn worker_pool_answers_every_request() {
        // the pool case of the no-lost-no-duplicated invariant, now across
        // sharded queues with stealing on
        let cfg = ServeConfig {
            max_batch: 8,
            max_wait_us: 200,
            queue_cap: 512,
            workers: 4,
            ..ServeConfig::default()
        };
        let server = Server::start(toy_engine(), cfg);
        assert_eq!(server.workers(), 4);
        let mut rng = Rng::new(111);
        let mut receivers = Vec::new();
        for id in 0..200u64 {
            let input = rng.normal_vec(4, 1.0);
            let rx = server.submit(InferenceRequest::new(id, input.clone())).unwrap();
            receivers.push((id, input, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (id, input, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            assert!(seen.insert(id), "duplicate reply {id}");
            assert!((resp.output[0] - input[0]).abs() < 1e-6);
        }
        assert_eq!(seen.len(), 200);
        // shard merge: totals must add up across workers
        let m = server.metrics();
        assert_eq!(m.requests, 200);
        assert_eq!(m.batch_size_sum, 200);
        assert!(m.batches >= 1);
        server.shutdown();
    }

    #[test]
    fn steal_off_still_answers_everything() {
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 512,
            workers: 4,
            steal: "off".to_string(),
            ..ServeConfig::default()
        };
        let server = Server::start(toy_engine(), cfg);
        let rxs: Vec<_> = (0..64u64)
            .map(|id| server.submit(InferenceRequest::new(id, vec![1.0; 4])).unwrap())
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        assert_eq!(server.metrics().requests, 64);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_width_and_reports() {
        let server = Server::start(toy_engine(), serve_cfg(4, 50));
        let err = server.infer(InferenceRequest::new(0, vec![1.0; 3]));
        assert!(err.is_err());
        server.shutdown();
    }

    #[test]
    fn requests_route_to_their_model() {
        let server = Server::start_multi(
            vec![toy_named("identity"), toy_doubler("doubler")],
            serve_cfg(4, 100),
        )
        .unwrap();
        let x = vec![1.0, 2.0, 0.0, 0.0];
        // default = first registered
        let r = server.infer(InferenceRequest::new(0, x.clone())).unwrap();
        assert_eq!(r.output, vec![1.0, 2.0]);
        let r = server
            .infer(InferenceRequest::new(1, x.clone()).for_model("identity"))
            .unwrap();
        assert_eq!(r.output, vec![1.0, 2.0]);
        let r = server.infer(InferenceRequest::new(2, x).for_model("doubler")).unwrap();
        assert_eq!(r.output, vec![2.0, 4.0]);
        // per-model metrics see only their own traffic
        assert_eq!(server.metrics_for("identity").unwrap().requests, 2);
        assert_eq!(server.metrics_for("doubler").unwrap().requests, 1);
        assert_eq!(server.metrics().requests, 3);
        server.shutdown();
    }

    #[test]
    fn unknown_model_fails_fast_naming_known_ones() {
        let server = Server::start(toy_engine(), serve_cfg(4, 50));
        let err = server
            .submit(InferenceRequest::new(0, vec![0.0; 4]).for_model("nope"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("nope") && err.contains("toy"), "{err}");
        server.shutdown();
    }

    #[test]
    fn duplicate_model_names_fail_start_multi() {
        let err = Server::start_multi(
            vec![toy_named("same"), toy_named("same")],
            serve_cfg(4, 50),
        );
        assert!(err.is_err());
    }

    #[test]
    fn batching_groups_requests() {
        // long wait window + burst submit => batches bigger than 1
        let server = Server::start(toy_engine(), serve_cfg(16, 50_000));
        let rxs: Vec<_> = (0..16)
            .map(|id| server.submit(InferenceRequest::new(id, vec![0.5; 4])).unwrap())
            .collect();
        let sizes: Vec<usize> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().batch_size).collect();
        // at least one multi-request batch must have formed
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        server.shutdown();
    }

    #[test]
    fn slo_budget_dispatches_ahead_of_max_wait() {
        // max_wait is 5 s: without an SLO a lone request would sit in the
        // batcher until the window closed. A 20 ms SLO must pull the
        // dispatch to ~10 ms (half the budget).
        let server = Server::start(toy_engine(), serve_cfg(64, 5_000_000));
        let t0 = Instant::now();
        let resp = server
            .infer(InferenceRequest::new(1, vec![1.0; 4]).with_slo_us(20_000))
            .unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "SLO'd request waited {:?}, deadline ignored",
            t0.elapsed()
        );
        assert_eq!(resp.output, vec![1.0, 1.0]);
        server.shutdown();
    }

    #[test]
    fn config_slo_applies_as_default_and_misses_are_counted() {
        // an SLO of 1 µs is unmeetable: the request must still be answered
        // and the miss must land in the metrics
        let cfg = ServeConfig { slo_us: 1, ..serve_cfg(4, 100) };
        let server = Server::start(toy_engine(), cfg);
        server.infer(InferenceRequest::new(0, vec![1.0; 4])).unwrap();
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.slo_missed, 1);
        server.shutdown();
    }

    #[test]
    fn snapshot_has_schema_and_per_model_rows() {
        let server = Server::start_multi(
            vec![toy_named("a"), toy_named("b")],
            serve_cfg(4, 100),
        )
        .unwrap();
        server.infer(InferenceRequest::new(0, vec![1.0; 4]).for_model("b")).unwrap();
        let snap = server.snapshot();
        assert_eq!(snap.get("schema").and_then(Json::as_str), Some(SNAPSHOT_SCHEMA));
        assert_eq!(
            snap.get("schema_version").and_then(Json::as_usize),
            Some(SNAPSHOT_SCHEMA_VERSION)
        );
        assert_eq!(snap.get("workers").and_then(Json::as_usize), Some(1));
        // v2: the dispatch choice is part of the document and names a
        // kernel the dispatch layer actually knows about
        let kernel = snap.get("kernel").and_then(Json::as_str).unwrap();
        assert!(
            crate::kernels::all_kernels().iter().any(|k| k.name() == kernel),
            "snapshot kernel {kernel:?} is not a registered kernel"
        );
        let models = snap.get("models").and_then(Json::as_arr).unwrap();
        assert_eq!(models.len(), 2);
        assert_eq!(models[0].get("model").and_then(Json::as_str), Some("a"));
        let b = &models[1];
        assert_eq!(b.get("model").and_then(Json::as_str), Some("b"));
        let b_reqs = b
            .get("metrics")
            .and_then(|m| m.get("requests"))
            .and_then(Json::as_u64)
            .unwrap();
        assert_eq!(b_reqs, 1);
        let reg = snap.get("registry").unwrap();
        assert_eq!(reg.get("models").and_then(Json::as_usize), Some(2));
        // the document round-trips through the parser
        let text = crate::util::json::to_string_pretty(&snap);
        let back = crate::util::json::parse(&text).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SNAPSHOT_SCHEMA));
        server.shutdown();
    }

    #[test]
    fn shutdown_answers_inflight() {
        let server = Server::start(toy_engine(), serve_cfg(64, 1_000_000));
        let rx = server.submit(InferenceRequest::new(1, vec![1.0; 4])).unwrap();
        // batch not full, deadline far away: shutdown must still flush it
        server.shutdown();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn shutdown_answers_inflight_across_pool() {
        let cfg = ServeConfig {
            max_batch: 64,
            max_wait_us: 1_000_000,
            queue_cap: 256,
            workers: 3,
            ..ServeConfig::default()
        };
        let server = Server::start(toy_engine(), cfg);
        let rxs: Vec<_> = (0..32u64)
            .map(|id| server.submit(InferenceRequest::new(id, vec![1.0; 4])).unwrap())
            .collect();
        server.shutdown();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    }

    #[test]
    fn submit_after_shutdown_fails_loudly() {
        let server = Server::start(toy_engine(), serve_cfg(4, 100));
        // shutting down via an aliased handle is not possible (shutdown
        // consumes self), so exercise the closed path through Drop order:
        // close the queue first, then submit.
        server.queue.close();
        let err = server.submit(InferenceRequest::new(0, vec![0.0; 4]));
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("stopped"));
    }

    #[test]
    fn workers_zero_is_clamped_to_one() {
        let cfg = ServeConfig {
            max_batch: 4,
            max_wait_us: 100,
            queue_cap: 16,
            workers: 0,
            ..ServeConfig::default()
        };
        let server = Server::start(toy_engine(), cfg);
        assert_eq!(server.workers(), 1);
        let resp = server.infer(InferenceRequest::new(3, vec![1.0; 4])).unwrap();
        assert_eq!(resp.id, 3);
        server.shutdown();
    }
}
