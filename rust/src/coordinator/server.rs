//! The serving event loop: bounded admission queue, a pool of dynamic
//! batching workers, channel-based replies. Hand-rolled on std (tokio is
//! unavailable offline); the structure is the standard serving shape:
//! admission -> shared queue -> per-worker batch -> execute -> fan-out.
//!
//! `ServeConfig.workers` is honored: [`Server::start`] spawns that many
//! workers, each owning a worker view of the model
//! ([`ModelEngine::worker_clone`] — `Arc`-shared weights, private
//! [`crate::kernels::Executor`] so the zero-allocation warm path is
//! preserved per worker) and its own [`Metrics`] shard (uncontended;
//! merged on [`Server::metrics`]). Admission control (`try_push` -> loud
//! rejection when full) and graceful shutdown (close the queue, drain it,
//! join every worker) are unchanged from the single-worker design.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::batcher::Batcher;
use super::engine::ModelEngine;
use super::metrics::Metrics;
use super::queue::{Pop, PushError, SharedQueue};

/// A single inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    /// Caller-chosen identifier, echoed back in the response.
    pub id: u64,
    /// Flat input row (length = model in_dim).
    pub input: Vec<f32>,
}

/// The reply.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    /// The request's identifier.
    pub id: u64,
    /// Flat output row (length = model out_dim).
    pub output: Vec<f32>,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Time from enqueue to reply.
    pub latency: Duration,
}

struct Envelope {
    req: InferenceRequest,
    enqueued: Instant,
    reply: Sender<Result<InferenceResponse>>,
}

/// Handle to a running server (the worker pool plus its admission queue).
pub struct Server {
    queue: Arc<SharedQueue<Envelope>>,
    workers: Vec<JoinHandle<()>>,
    /// One metrics shard per worker; only that worker writes it.
    shards: Vec<Arc<Mutex<Metrics>>>,
    /// Admission rejections happen on caller threads, outside any shard.
    rejected: AtomicU64,
    in_dim: usize,
}

impl Server {
    /// Start `cfg.workers` batching workers over a model engine.
    ///
    /// The passed engine becomes worker 0; each additional worker is a
    /// [`ModelEngine::worker_clone`] — same `Arc`-shared weights, private
    /// executor. Out-of-range config values are clamped to 1 here as a
    /// last line of defense; [`crate::config::load`] rejects them loudly.
    pub fn start(engine: ModelEngine, cfg: ServeConfig) -> Server {
        let n_workers = cfg.workers.max(1);
        let queue = Arc::new(SharedQueue::new(cfg.queue_cap.max(1)));
        let in_dim = engine.in_dim();

        let mut engines = Vec::with_capacity(n_workers);
        for _ in 1..n_workers {
            engines.push(engine.worker_clone());
        }
        engines.insert(0, engine); // worker 0 is the original engine

        let mut workers = Vec::with_capacity(n_workers);
        let mut shards = Vec::with_capacity(n_workers);
        for engine in engines {
            let shard = Arc::new(Mutex::new(Metrics::default()));
            let q = Arc::clone(&queue);
            let m = Arc::clone(&shard);
            let wcfg = cfg.clone();
            workers.push(std::thread::spawn(move || worker_loop(engine, wcfg, q, m)));
            shards.push(shard);
        }
        Server { queue, workers, shards, rejected: AtomicU64::new(0), in_dim }
    }

    /// Warm-start a server from a compressed-model `.ttrv` bundle
    /// ([`crate::artifact`]): decode + checksum-validate the file, build
    /// the engine with pre-seeded plan caches (no DSE, no decomposition,
    /// no compilation), and spawn the pool — cold-start cost scales with
    /// model size, not design-space size. The bundle must have been
    /// compressed for `machine`.
    pub fn from_artifact(
        path: impl AsRef<std::path::Path>,
        machine: &crate::machine::MachineSpec,
        cfg: ServeConfig,
    ) -> Result<Server> {
        cfg.validate()?;
        let bundle = crate::artifact::read_bundle_file(path)?;
        let engine = bundle.build_engine(machine)?;
        Ok(Server::start(engine, cfg))
    }

    /// Number of workers in the pool.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit without blocking on execution; returns the reply channel.
    /// Fails fast when the queue is full (admission control) or the input
    /// width is wrong.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<Result<InferenceResponse>>> {
        if req.input.len() != self.in_dim {
            return Err(Error::serve(format!(
                "input width {} != model {}",
                req.input.len(),
                self.in_dim
            )));
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let env = Envelope { req, enqueued: Instant::now(), reply: reply_tx };
        match self.queue.try_push(env) {
            Ok(()) => Ok(reply_rx),
            Err(PushError::Full(_)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(Error::QueueFull)
            }
            Err(PushError::Closed(_)) => Err(Error::serve("server stopped")),
        }
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| Error::serve("worker dropped reply"))?
    }

    /// Snapshot of the metrics: per-worker shards merged, plus the
    /// admission-rejection count.
    pub fn metrics(&self) -> Metrics {
        let mut total = Metrics::default();
        for shard in &self.shards {
            total.merge(&shard.lock().expect("metrics lock"));
        }
        total.rejected += self.rejected.load(Ordering::Relaxed);
        total
    }

    /// Graceful shutdown: admission stops, the queue is drained, every
    /// in-flight request is answered, all workers are joined.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        self.queue.close();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

/// One pool worker: pull from the shared queue, batch, execute, fan out.
fn worker_loop(
    mut engine: ModelEngine,
    cfg: ServeConfig,
    queue: Arc<SharedQueue<Envelope>>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let mut batcher = Batcher::new(cfg.max_batch.max(1), max_wait);
    let mut pending: Vec<Envelope> = Vec::with_capacity(cfg.max_batch.max(1));
    loop {
        // wait for work (or the batch deadline of already-pending work)
        let pop = if pending.is_empty() {
            queue.pop()
        } else {
            let wait = batcher
                .time_to_deadline(Instant::now())
                .unwrap_or(Duration::ZERO);
            queue.pop_timeout(wait)
        };
        let mut shutdown = false;
        match pop {
            Pop::Item(env) => {
                let full = batcher.push(env.enqueued);
                pending.push(env);
                if !full && !batcher.deadline_reached(Instant::now()) {
                    continue;
                }
            }
            Pop::TimedOut => {} // deadline fired
            Pop::Closed => shutdown = true,
        }
        if !pending.is_empty() {
            // The batch is due (full, deadline, or shutdown). Under backlog
            // the deadline is often already overdue when the first envelope
            // is popped, which would dispatch a batch of 1 at exactly peak
            // load — so first top the batch up with whatever is immediately
            // poppable (zero-timeout: never waits).
            while pending.len() < batcher.max_batch() {
                match queue.pop_timeout(Duration::ZERO) {
                    Pop::Item(env) => {
                        batcher.push(env.enqueued);
                        pending.push(env);
                    }
                    Pop::TimedOut => break,
                    Pop::Closed => {
                        shutdown = true;
                        break;
                    }
                }
            }
            batcher.take();
            dispatch(&mut engine, &mut pending, &metrics);
        }
        if shutdown {
            break;
        }
    }
}

/// Execute one batch and fan the rows back out to the reply channels.
fn dispatch(engine: &mut ModelEngine, pending: &mut Vec<Envelope>, metrics: &Arc<Mutex<Metrics>>) {
    let batch = pending.len();
    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let mut flat = Vec::with_capacity(batch * in_dim);
    for env in pending.iter() {
        flat.extend_from_slice(&env.req.input);
    }
    let exec_start = Instant::now();
    let result = Tensor::from_vec(vec![batch, in_dim], flat).and_then(|x| engine.forward(&x));
    let exec_time = exec_start.elapsed();

    {
        let mut m = metrics.lock().expect("metrics lock");
        m.batches += 1;
        m.requests += batch as u64;
        m.batch_size_sum += batch as u64;
        m.exec.record(exec_time);
        for env in pending.iter() {
            m.queue_wait.record(exec_start.duration_since(env.enqueued));
            m.latency.record(env.enqueued.elapsed());
        }
    }

    match result {
        Ok(y) => {
            for (i, env) in pending.drain(..).enumerate() {
                let output = y.data()[i * out_dim..(i + 1) * out_dim].to_vec();
                let _ = env.reply.send(Ok(InferenceResponse {
                    id: env.req.id,
                    output,
                    batch_size: batch,
                    latency: env.enqueued.elapsed(),
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for env in pending.drain(..) {
                let _ = env.reply.send(Err(Error::serve(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dense::DenseFc;
    use crate::coordinator::engine::{LayerOp, ModelEngine};
    use crate::util::prng::Rng;

    /// Tiny deterministic model: y = x @ W^T with known W (4 -> 2).
    fn toy_engine() -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new("toy", vec![LayerOp::Dense(fc)], 4, 2)
    }

    fn serve_cfg(max_batch: usize, wait_us: u64) -> ServeConfig {
        ServeConfig { max_batch, max_wait_us: wait_us, queue_cap: 256, workers: 1 }
    }

    #[test]
    fn admission_control_rejects_when_queue_full() {
        // a 1-slot queue with a slow wait window fills immediately
        let cfg = ServeConfig { max_batch: 64, max_wait_us: 50_000, queue_cap: 1, workers: 1 };
        let server = Server::start(toy_engine(), cfg);
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for id in 0..50u64 {
            match server.submit(InferenceRequest { id, input: vec![0.0; 4] }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // every accepted request still gets exactly one reply
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        if rejected > 0 {
            assert!(server.metrics().rejected >= 1);
        }
        server.shutdown();
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(toy_engine(), serve_cfg(4, 100));
        let resp = server
            .infer(InferenceRequest { id: 7, input: vec![1.0, 2.0, 3.0, 4.0] })
            .unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output, vec![1.0, 2.0]);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        server.shutdown();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_load() {
        let server = Server::start(toy_engine(), serve_cfg(8, 200));
        let mut rng = Rng::new(110);
        let mut receivers = Vec::new();
        for id in 0..100u64 {
            let input = rng.normal_vec(4, 1.0);
            let rx = server.submit(InferenceRequest { id, input: input.clone() }).unwrap();
            receivers.push((id, input, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (id, input, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            assert!(seen.insert(id), "duplicate reply {id}");
            // batched output equals the single-request math
            assert!((resp.output[0] - input[0]).abs() < 1e-6);
            assert!((resp.output[1] - input[1]).abs() < 1e-6);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        assert_eq!(seen.len(), 100);
        let m = server.metrics();
        assert_eq!(m.requests, 100);
        assert!(m.mean_batch() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn worker_pool_answers_every_request() {
        // the pool case of the no-lost-no-duplicated invariant
        let cfg = ServeConfig { max_batch: 8, max_wait_us: 200, queue_cap: 512, workers: 4 };
        let server = Server::start(toy_engine(), cfg);
        assert_eq!(server.workers(), 4);
        let mut rng = Rng::new(111);
        let mut receivers = Vec::new();
        for id in 0..200u64 {
            let input = rng.normal_vec(4, 1.0);
            let rx = server.submit(InferenceRequest { id, input: input.clone() }).unwrap();
            receivers.push((id, input, rx));
        }
        let mut seen = std::collections::HashSet::new();
        for (id, input, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            assert!(seen.insert(id), "duplicate reply {id}");
            assert!((resp.output[0] - input[0]).abs() < 1e-6);
        }
        assert_eq!(seen.len(), 200);
        // shard merge: totals must add up across workers
        let m = server.metrics();
        assert_eq!(m.requests, 200);
        assert_eq!(m.batch_size_sum, 200);
        assert!(m.batches >= 1);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_width_and_reports() {
        let server = Server::start(toy_engine(), serve_cfg(4, 50));
        let err = server.infer(InferenceRequest { id: 0, input: vec![1.0; 3] });
        assert!(err.is_err());
        server.shutdown();
    }

    #[test]
    fn batching_groups_requests() {
        // long wait window + burst submit => batches bigger than 1
        let server = Server::start(toy_engine(), serve_cfg(16, 50_000));
        let rxs: Vec<_> = (0..16)
            .map(|id| {
                server
                    .submit(InferenceRequest { id, input: vec![0.5; 4] })
                    .unwrap()
            })
            .collect();
        let sizes: Vec<usize> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().batch_size)
            .collect();
        // at least one multi-request batch must have formed
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        server.shutdown();
    }

    #[test]
    fn shutdown_answers_inflight() {
        let server = Server::start(toy_engine(), serve_cfg(64, 1_000_000));
        let rx = server
            .submit(InferenceRequest { id: 1, input: vec![1.0; 4] })
            .unwrap();
        // batch not full, deadline far away: shutdown must still flush it
        server.shutdown();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1);
    }

    #[test]
    fn shutdown_answers_inflight_across_pool() {
        let cfg = ServeConfig { max_batch: 64, max_wait_us: 1_000_000, queue_cap: 256, workers: 3 };
        let server = Server::start(toy_engine(), cfg);
        let rxs: Vec<_> = (0..32u64)
            .map(|id| server.submit(InferenceRequest { id, input: vec![1.0; 4] }).unwrap())
            .collect();
        server.shutdown();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    }

    #[test]
    fn submit_after_shutdown_fails_loudly() {
        let server = Server::start(toy_engine(), serve_cfg(4, 100));
        // shutting down via an aliased handle is not possible (shutdown
        // consumes self), so exercise the closed path through Drop order:
        // close the queue first, then submit.
        server.queue.close();
        let err = server.submit(InferenceRequest { id: 0, input: vec![0.0; 4] });
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("stopped"));
    }

    #[test]
    fn workers_zero_is_clamped_to_one() {
        let cfg = ServeConfig { max_batch: 4, max_wait_us: 100, queue_cap: 16, workers: 0 };
        let server = Server::start(toy_engine(), cfg);
        assert_eq!(server.workers(), 1);
        let resp = server.infer(InferenceRequest { id: 3, input: vec![1.0; 4] }).unwrap();
        assert_eq!(resp.id, 3);
        server.shutdown();
    }
}
