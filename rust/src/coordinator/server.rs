//! The serving event loop: bounded request queue, dynamic batching worker,
//! channel-based replies. Hand-rolled on std (tokio is unavailable
//! offline); the loop structure is the standard serving shape: admission ->
//! queue -> batch -> execute -> fan-out.

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::batcher::Batcher;
use super::engine::ModelEngine;
use super::metrics::Metrics;

/// A single inference request.
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub id: u64,
    /// Flat input row (length = model in_dim).
    pub input: Vec<f32>,
}

/// The reply.
#[derive(Debug, Clone)]
pub struct InferenceResponse {
    pub id: u64,
    pub output: Vec<f32>,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Time from enqueue to reply.
    pub latency: Duration,
}

struct Envelope {
    req: InferenceRequest,
    enqueued: Instant,
    reply: Sender<Result<InferenceResponse>>,
}

enum Msg {
    Request(Envelope),
    Shutdown,
}

/// Handle to a running server.
pub struct Server {
    tx: SyncSender<Msg>,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    in_dim: usize,
}

impl Server {
    /// Start the event loop over a model engine.
    pub fn start(engine: ModelEngine, cfg: ServeConfig) -> Server {
        let (tx, rx) = sync_channel::<Msg>(cfg.queue_cap.max(1));
        let metrics = Arc::new(Mutex::new(Metrics::default()));
        let in_dim = engine.in_dim();
        let m2 = Arc::clone(&metrics);
        let worker = std::thread::spawn(move || worker_loop(engine, cfg, rx, m2));
        Server { tx, worker: Some(worker), metrics, in_dim }
    }

    /// Submit without blocking on execution; returns the reply channel.
    /// Fails fast when the queue is full (admission control) or the input
    /// width is wrong.
    pub fn submit(&self, req: InferenceRequest) -> Result<Receiver<Result<InferenceResponse>>> {
        if req.input.len() != self.in_dim {
            return Err(Error::serve(format!(
                "input width {} != model {}",
                req.input.len(),
                self.in_dim
            )));
        }
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let env = Envelope { req, enqueued: Instant::now(), reply: reply_tx };
        match self.tx.try_send(Msg::Request(env)) {
            Ok(()) => Ok(reply_rx),
            Err(TrySendError::Full(_)) => {
                self.metrics.lock().expect("metrics lock").rejected += 1;
                Err(Error::serve("queue full (admission control)"))
            }
            Err(TrySendError::Disconnected(_)) => Err(Error::serve("server stopped")),
        }
    }

    /// Convenience: submit and wait.
    pub fn infer(&self, req: InferenceRequest) -> Result<InferenceResponse> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| Error::serve("worker dropped reply"))?
    }

    /// Snapshot of the metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().expect("metrics lock").clone()
    }

    /// Graceful shutdown: in-flight requests are answered first.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Shutdown);
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn worker_loop(
    mut engine: ModelEngine,
    cfg: ServeConfig,
    rx: Receiver<Msg>,
    metrics: Arc<Mutex<Metrics>>,
) {
    let max_wait = Duration::from_micros(cfg.max_wait_us);
    let mut batcher = Batcher::new(cfg.max_batch.max(1), max_wait);
    let mut pending: Vec<Envelope> = Vec::with_capacity(cfg.max_batch);
    loop {
        // wait for work (or the batch deadline of already-pending work)
        let msg = if pending.is_empty() {
            match rx.recv() {
                Ok(m) => Some(m),
                Err(_) => break, // all senders gone
            }
        } else {
            let wait = batcher
                .time_to_deadline(Instant::now())
                .unwrap_or(Duration::ZERO);
            match rx.recv_timeout(wait) {
                Ok(m) => Some(m),
                Err(RecvTimeoutError::Timeout) => None,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        };
        let mut shutdown = false;
        match msg {
            Some(Msg::Request(env)) => {
                let full = batcher.push(env.enqueued);
                pending.push(env);
                if !full && !batcher.deadline_reached(Instant::now()) {
                    continue;
                }
            }
            Some(Msg::Shutdown) => shutdown = true,
            None => {} // deadline fired
        }
        if !pending.is_empty() {
            batcher.take();
            dispatch(&mut engine, &mut pending, &metrics);
        }
        if shutdown {
            break;
        }
    }
    // answer any stragglers before exiting
    if !pending.is_empty() {
        dispatch(&mut engine, &mut pending, &metrics);
    }
}

fn dispatch(engine: &mut ModelEngine, pending: &mut Vec<Envelope>, metrics: &Arc<Mutex<Metrics>>) {
    let batch = pending.len();
    let in_dim = engine.in_dim();
    let out_dim = engine.out_dim();
    let mut flat = Vec::with_capacity(batch * in_dim);
    for env in pending.iter() {
        flat.extend_from_slice(&env.req.input);
    }
    let exec_start = Instant::now();
    let result = Tensor::from_vec(vec![batch, in_dim], flat).and_then(|x| engine.forward(&x));
    let exec_time = exec_start.elapsed();

    {
        let mut m = metrics.lock().expect("metrics lock");
        m.batches += 1;
        m.requests += batch as u64;
        m.batch_size_sum += batch as u64;
        m.exec.record(exec_time);
        for env in pending.iter() {
            m.queue_wait.record(exec_start.duration_since(env.enqueued));
            m.latency.record(env.enqueued.elapsed());
        }
    }

    match result {
        Ok(y) => {
            for (i, env) in pending.drain(..).enumerate() {
                let output = y.data()[i * out_dim..(i + 1) * out_dim].to_vec();
                let _ = env.reply.send(Ok(InferenceResponse {
                    id: env.req.id,
                    output,
                    batch_size: batch,
                    latency: env.enqueued.elapsed(),
                }));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for env in pending.drain(..) {
                let _ = env.reply.send(Err(Error::serve(msg.clone())));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::dense::DenseFc;
    use crate::coordinator::engine::{LayerOp, ModelEngine};
    use crate::util::prng::Rng;

    /// Tiny deterministic model: y = x @ W^T with known W (4 -> 2).
    fn toy_engine() -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new("toy", vec![LayerOp::Dense(fc)], 4, 2)
    }

    fn serve_cfg(max_batch: usize, wait_us: u64) -> ServeConfig {
        ServeConfig { max_batch, max_wait_us: wait_us, queue_cap: 256, workers: 1 }
    }

    #[test]
    fn admission_control_rejects_when_queue_full() {
        // a 1-slot queue with a slow wait window fills immediately
        let cfg = ServeConfig { max_batch: 64, max_wait_us: 50_000, queue_cap: 1, workers: 1 };
        let server = Server::start(toy_engine(), cfg);
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for id in 0..50u64 {
            match server.submit(InferenceRequest { id, input: vec![0.0; 4] }) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        // every accepted request still gets exactly one reply
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        if rejected > 0 {
            assert!(server.metrics().rejected >= 1);
        }
        server.shutdown();
    }

    #[test]
    fn single_request_roundtrip() {
        let server = Server::start(toy_engine(), serve_cfg(4, 100));
        let resp = server
            .infer(InferenceRequest { id: 7, input: vec![1.0, 2.0, 3.0, 4.0] })
            .unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.output, vec![1.0, 2.0]);
        let m = server.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.batches, 1);
        server.shutdown();
    }

    #[test]
    fn no_request_lost_or_duplicated_under_load() {
        let server = Server::start(toy_engine(), serve_cfg(8, 200));
        let mut rng = Rng::new(110);
        let mut receivers = Vec::new();
        for id in 0..100u64 {
            let input = rng.normal_vec(4, 1.0);
            receivers.push((id, input.clone(), server.submit(InferenceRequest { id, input }).unwrap()));
        }
        let mut seen = std::collections::HashSet::new();
        for (id, input, rx) in receivers {
            let resp = rx.recv().unwrap().unwrap();
            assert_eq!(resp.id, id);
            assert!(seen.insert(id), "duplicate reply {id}");
            // batched output equals the single-request math
            assert!((resp.output[0] - input[0]).abs() < 1e-6);
            assert!((resp.output[1] - input[1]).abs() < 1e-6);
            assert!(resp.batch_size >= 1 && resp.batch_size <= 8);
        }
        assert_eq!(seen.len(), 100);
        let m = server.metrics();
        assert_eq!(m.requests, 100);
        assert!(m.mean_batch() >= 1.0);
        server.shutdown();
    }

    #[test]
    fn rejects_wrong_width_and_reports() {
        let server = Server::start(toy_engine(), serve_cfg(4, 50));
        let err = server.infer(InferenceRequest { id: 0, input: vec![1.0; 3] });
        assert!(err.is_err());
        server.shutdown();
    }

    #[test]
    fn batching_groups_requests() {
        // long wait window + burst submit => batches bigger than 1
        let server = Server::start(toy_engine(), serve_cfg(16, 50_000));
        let rxs: Vec<_> = (0..16)
            .map(|id| {
                server
                    .submit(InferenceRequest { id, input: vec![0.5; 4] })
                    .unwrap()
            })
            .collect();
        let sizes: Vec<usize> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().batch_size)
            .collect();
        // at least one multi-request batch must have formed
        assert!(sizes.iter().any(|&s| s > 1), "sizes {sizes:?}");
        server.shutdown();
    }

    #[test]
    fn shutdown_answers_inflight() {
        let server = Server::start(toy_engine(), serve_cfg(64, 1_000_000));
        let rx = server
            .submit(InferenceRequest { id: 1, input: vec![1.0; 4] })
            .unwrap();
        // batch not full, deadline far away: shutdown must still flush it
        server.shutdown();
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 1);
    }
}
