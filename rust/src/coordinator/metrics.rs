//! Serving metrics: latency histograms and throughput counters.

use std::time::Duration;

/// Log-scaled latency histogram (microseconds, factor-2 buckets from 1us).
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    pub fn record(&mut self, d: Duration) {
        let us = d.as_micros() as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from bucket boundaries (upper bound).
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (p / 100.0 * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return 1u64 << (i + 1); // bucket upper bound
            }
        }
        self.max_us
    }
}

/// Aggregated serving metrics.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// End-to-end request latency (enqueue -> reply).
    pub latency: Histogram,
    /// Time spent waiting for batch-mates.
    pub queue_wait: Histogram,
    /// Model execution time per batch.
    pub exec: Histogram,
    pub requests: u64,
    pub batches: u64,
    pub rejected: u64,
    pub batch_size_sum: u64,
}

impl Metrics {
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} rejected={} mean_batch={:.2} \
             p50={}us p99={}us mean={:.0}us max={}us",
            self.requests,
            self.batches,
            self.rejected,
            self.mean_batch(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.mean_us(),
            self.latency.max_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_summarizes() {
        let mut h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_us(), 10000);
        assert!((h.mean_us() - 11111.0 / 5.0).abs() < 1.0);
        // p100 spans the largest bucket
        assert!(h.percentile_us(100.0) >= 10000);
        assert!(h.percentile_us(1.0) <= 4);
    }

    #[test]
    fn percentiles_monotone() {
        let mut h = Histogram::default();
        for i in 0..1000u64 {
            h.record(Duration::from_micros(i + 1));
        }
        let p50 = h.percentile_us(50.0);
        let p90 = h.percentile_us(90.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p90 && p90 <= p99);
    }

    #[test]
    fn metrics_mean_batch() {
        let mut m = Metrics::default();
        m.batches = 4;
        m.batch_size_sum = 10;
        assert_eq!(m.mean_batch(), 2.5);
        assert!(m.summary().contains("mean_batch=2.50"));
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = Histogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }
}
