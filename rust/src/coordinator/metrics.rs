//! Serving metrics: latency histograms, throughput counters, and the
//! machine-readable JSON forms the snapshot endpoint is built from.

use std::time::Duration;

use crate::util::json::Json;

/// Log-scaled histogram (factor-2 buckets from 1). Time histograms record
/// microseconds via [`record`](Self::record); the batch-size histogram
/// feeds raw counts through [`record_value`](Self::record_value), where
/// the `_us` accessors read as unitless values.
#[derive(Debug, Clone)]
pub struct Histogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: vec![0; 32], count: 0, sum_us: 0, max_us: 0 }
    }
}

impl Histogram {
    /// Record one duration sample.
    pub fn record(&mut self, d: Duration) {
        self.record_value(d.as_micros() as u64);
    }

    /// Record one raw sample (batch sizes, queue depths).
    pub fn record_value(&mut self, v: u64) {
        let idx = (64 - v.max(1).leading_zeros() as usize - 1).min(self.buckets.len() - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += v;
        self.max_us = self.max_us.max(v);
    }

    /// Fold another histogram into this one (shard merging). Buckets are
    /// log-scaled with identical boundaries, so merging is exact: the
    /// result is what a single histogram fed both sample streams would
    /// hold.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, ob) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += ob;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean sample in microseconds (0 when empty).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Largest recorded sample in microseconds.
    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate percentile from bucket boundaries (upper bound, never
    /// above the largest recorded sample). Defensive by construction so
    /// snapshot JSON can never carry garbage quantiles: an empty histogram
    /// answers 0 for every `p`, `p` is clamped into `[0, 100]`, and a
    /// non-finite `p` reads as 100.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let p = if p.is_finite() { p.clamp(0.0, 100.0) } else { 100.0 };
        // rank of the sample to report; >= 1 so p = 0 describes the
        // smallest recorded sample instead of blindly reading bucket 0
        let target = ((p / 100.0 * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)).min(self.max_us.max(1));
            }
        }
        self.max_us
    }

    /// Machine-readable form: counts, mean/max, p50/p99, and the non-empty
    /// `[upper_bound, count]` bucket pairs.
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![Json::from((1u64 << (i + 1)) as usize), Json::from(c as usize)])
            })
            .collect();
        Json::obj(vec![
            ("count", Json::from(self.count as usize)),
            ("mean", Json::from(self.mean_us())),
            ("p50", Json::from(self.percentile_us(50.0) as usize)),
            ("p99", Json::from(self.percentile_us(99.0) as usize)),
            ("max", Json::from(self.max_us as usize)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Aggregated serving metrics.
///
/// With a worker pool each worker owns a private per-model `Metrics` shard
/// (no cross-worker contention on the hot path); [`super::Server::metrics`]
/// merges the shards into one snapshot via [`Metrics::merge`], and
/// [`super::Server::snapshot`] exports the per-model and process-wide
/// views as versioned JSON.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// End-to-end request latency (enqueue -> reply).
    pub latency: Histogram,
    /// Time spent waiting for batch-mates.
    pub queue_wait: Histogram,
    /// Model execution time per batch.
    pub exec: Histogram,
    /// Executed batch sizes (one sample per dispatched batch).
    pub batch_sizes: Histogram,
    /// Requests answered.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Requests refused by admission control (queue full).
    pub rejected: u64,
    /// Requests answered later than their SLO budget allowed.
    pub slo_missed: u64,
    /// Sum of executed batch sizes (`requests`, kept separate so the
    /// invariant `batch_size_sum == requests` is checkable after merging).
    pub batch_size_sum: u64,
}

impl Metrics {
    /// Fold another worker's shard into this snapshot.
    pub fn merge(&mut self, other: &Metrics) {
        self.latency.merge(&other.latency);
        self.queue_wait.merge(&other.queue_wait);
        self.exec.merge(&other.exec);
        self.batch_sizes.merge(&other.batch_sizes);
        self.requests += other.requests;
        self.batches += other.batches;
        self.rejected += other.rejected;
        self.slo_missed += other.slo_missed;
        self.batch_size_sum += other.batch_size_sum;
    }

    /// Mean executed batch size (0 when nothing ran).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches as f64
        }
    }

    /// One-line human-readable digest (used by the CLI and benches).
    pub fn summary(&self) -> String {
        format!(
            "requests={} batches={} rejected={} mean_batch={:.2} \
             p50={}us p99={}us mean={:.0}us max={}us",
            self.requests,
            self.batches,
            self.rejected,
            self.mean_batch(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(99.0),
            self.latency.mean_us(),
            self.latency.max_us(),
        )
    }

    /// Machine-readable form used by the snapshot endpoint: every counter
    /// plus the four histograms.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::from(self.requests as usize)),
            ("batches", Json::from(self.batches as usize)),
            ("rejected", Json::from(self.rejected as usize)),
            ("slo_missed", Json::from(self.slo_missed as usize)),
            ("mean_batch", Json::from(self.mean_batch())),
            ("latency_us", self.latency.to_json()),
            ("queue_wait_us", self.queue_wait.to_json()),
            ("exec_us", self.exec.to_json()),
            ("batch_size", self.batch_sizes.to_json()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_summarizes() {
        let mut h = Histogram::default();
        for us in [1u64, 10, 100, 1000, 10000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max_us(), 10000);
        assert!((h.mean_us() - 11111.0 / 5.0).abs() < 1.0);
        // p100 spans the largest bucket
        assert!(h.percentile_us(100.0) >= 10000);
        assert!(h.percentile_us(1.0) <= 4);
    }

    #[test]
    fn percentiles_monotone() {
        let mut h = Histogram::default();
        for i in 0..1000u64 {
            h.record(Duration::from_micros(i + 1));
        }
        let p50 = h.percentile_us(50.0);
        let p90 = h.percentile_us(90.0);
        let p99 = h.percentile_us(99.0);
        assert!(p50 <= p90 && p90 <= p99);
    }

    #[test]
    fn percentile_edge_cases_empty_and_single_sample() {
        // ISSUE 6 satellite: n = 0 and n = 1 with p in {-1, 0, 100, 101}
        // must never emit garbage into snapshot JSON.
        let h = Histogram::default();
        for p in [-1.0, 0.0, 100.0, 101.0, f64::NAN] {
            assert_eq!(h.percentile_us(p), 0, "empty histogram must stay quiet at p={p}");
        }
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.max_us(), 0);

        let mut h = Histogram::default();
        h.record(Duration::from_micros(1500));
        for p in [-1.0, 0.0, 100.0, 101.0, f64::NAN] {
            assert_eq!(h.percentile_us(p), 1500, "n=1: every percentile is the sample (p={p})");
        }
        assert_eq!(h.mean_us(), 1500.0);
    }

    #[test]
    fn percentile_p_is_clamped_into_range() {
        let mut h = Histogram::default();
        for i in 0..100u64 {
            h.record(Duration::from_micros(i + 1));
        }
        assert_eq!(h.percentile_us(-5.0), h.percentile_us(0.0));
        assert_eq!(h.percentile_us(250.0), h.percentile_us(100.0));
        // p = 0 must describe the smallest sample's bucket, not report a
        // phantom value out of empty bucket 0
        assert!(h.percentile_us(0.0) >= 1);
        assert!(h.percentile_us(0.0) <= h.percentile_us(50.0));
        // the upper-bound estimate is clamped to the observed maximum
        assert!(h.percentile_us(100.0) <= h.max_us());
    }

    #[test]
    fn record_value_feeds_batch_size_histograms() {
        let mut h = Histogram::default();
        for v in [1u64, 4, 8, 8] {
            h.record_value(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.max_us(), 8);
        assert!((h.mean_us() - 5.25).abs() < 1e-9);
        assert!(h.percentile_us(99.0) >= 8);
    }

    #[test]
    fn metrics_mean_batch() {
        let mut m = Metrics::default();
        m.batches = 4;
        m.batch_size_sum = 10;
        assert_eq!(m.mean_batch(), 2.5);
        assert!(m.summary().contains("mean_batch=2.50"));
    }

    #[test]
    fn empty_histogram_is_quiet() {
        let h = Histogram::default();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn histogram_merge_equals_single_stream() {
        // merging shards must be indistinguishable from one histogram
        // having recorded every sample
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut whole = Histogram::default();
        for (i, us) in [1u64, 3, 9, 27, 81, 243, 729, 2187].into_iter().enumerate() {
            let d = Duration::from_micros(us);
            if i % 2 == 0 { a.record(d) } else { b.record(d) }
            whole.record(d);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.max_us(), whole.max_us());
        assert!((a.mean_us() - whole.mean_us()).abs() < 1e-9);
        for p in [1.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(a.percentile_us(p), whole.percentile_us(p), "p{p}");
        }
    }

    #[test]
    fn metrics_merge_sums_counters() {
        let mut a = Metrics {
            requests: 10,
            batches: 3,
            rejected: 1,
            batch_size_sum: 10,
            ..Default::default()
        };
        a.latency.record(Duration::from_micros(100));
        a.batch_sizes.record_value(4);
        let mut b = Metrics {
            requests: 5,
            batches: 2,
            slo_missed: 2,
            batch_size_sum: 5,
            ..Default::default()
        };
        b.latency.record(Duration::from_micros(400));
        a.merge(&b);
        assert_eq!(a.requests, 15);
        assert_eq!(a.batches, 5);
        assert_eq!(a.rejected, 1);
        assert_eq!(a.slo_missed, 2);
        assert_eq!(a.batch_size_sum, 15);
        assert_eq!(a.latency.count(), 2);
        assert_eq!(a.batch_sizes.count(), 1);
        assert_eq!(a.mean_batch(), 3.0);
    }

    #[test]
    fn json_forms_round_trip_finite_fields() {
        let mut m = Metrics::default();
        m.requests = 3;
        m.batches = 2;
        m.batch_size_sum = 3;
        m.latency.record(Duration::from_micros(120));
        m.batch_sizes.record_value(2);
        let j = m.to_json();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("batches").and_then(Json::as_usize), Some(2));
        let lat = j.get("latency_us").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_usize), Some(1));
        assert!(lat.get("p99").and_then(Json::as_u64).unwrap() >= 120);
        // empty histograms serialize as zeros with no buckets, never NaN
        let exec = j.get("exec_us").unwrap();
        assert_eq!(exec.get("count").and_then(Json::as_usize), Some(0));
        assert_eq!(exec.get("mean").and_then(Json::as_f64), Some(0.0));
        assert_eq!(exec.get("buckets").and_then(Json::as_arr).map(<[Json]>::len), Some(0));
    }
}
