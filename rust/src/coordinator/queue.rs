//! Bounded admission queues: the single MPMC primitive and the sharded
//! work-stealing front built on top of it.
//!
//! `std::sync::mpsc` is single-consumer, so a worker *pool* sharing one
//! queue needs its own primitive: a `Mutex<VecDeque>` + `Condvar` bounded
//! queue ([`SharedQueue`]) with non-blocking admission (`try_push`) and
//! deadline-aware consumption (`pop_timeout`). Serving v2 no longer admits
//! through one global queue: [`ShardedQueue`] round-robins admission
//! across per-worker shards and lets idle workers steal from busy ones,
//! so the submit path stops serializing on a single lock at high worker
//! counts. `SharedQueue` remains the shard primitive (and the DSE worker
//! pool's queue).
//!
//! Semantics:
//!
//! * `try_push` never blocks: a full queue is an admission-control
//!   rejection ([`PushError::Full`]), a closed queue is a shutdown
//!   rejection ([`PushError::Closed`]). This preserves the coordinator's
//!   fail-fast backpressure contract. The sharded front rejects `Full`
//!   only once **every** shard is full.
//! * `pop` / `pop_timeout` drain remaining items even after [`close`]
//!   (graceful shutdown answers everything that was admitted); only a
//!   queue that is both closed **and** empty reports [`Pop::Closed`].
//!   This holds under spurious Condvar wakeups and under wakeups raced
//!   with `close`: the item check always precedes the closed check.
//! * FIFO order within one shard. With several consumers or shards, items
//!   are handed out in arrival order per shard but may complete out of
//!   order — that is the point of the pool.
//!
//! [`close`]: SharedQueue::close

use std::collections::VecDeque;
use std::time::{Duration, Instant};

// Under `--cfg loom` the sync primitives come from the loom model checker
// (`loom_tests` below exhaustively interleaves them); the dev-dependency is
// injected by the CI loom job, so regular builds stay dependency-free.
#[cfg(loom)]
use loom::sync::atomic::{AtomicUsize, Ordering};
#[cfg(loom)]
use loom::sync::{Condvar, Mutex};
#[cfg(not(loom))]
use std::sync::atomic::{AtomicUsize, Ordering};
#[cfg(not(loom))]
use std::sync::{Condvar, Mutex};

/// Why a [`SharedQueue::try_push`] was refused. The item is handed back
/// rather than dropped so `T` need not be `Clone` and callers can decide
/// its fate.
#[derive(Debug)]
pub(crate) enum PushError<T> {
    /// The queue is at capacity (admission control).
    Full(T),
    /// The queue was closed by shutdown.
    Closed(T),
}

/// Outcome of a [`SharedQueue::pop`] / [`SharedQueue::pop_timeout`].
#[derive(Debug)]
pub(crate) enum Pop<T> {
    /// The oldest queued item.
    Item(T),
    /// The timeout elapsed with the queue still empty (batch deadline).
    TimedOut,
    /// The queue is closed and fully drained: the consumer should flush
    /// its pending batch and exit.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// The bounded MPMC queue shared by the submit path and the worker pool.
pub(crate) struct SharedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

impl<T> SharedQueue<T> {
    /// A queue admitting at most `cap >= 1` items.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "queue capacity must be >= 1");
        SharedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(cap.min(4096)),
                closed: false,
            }),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Non-blocking admission; hands the item back on refusal.
    pub fn try_push(&self, item: T) -> std::result::Result<(), PushError<T>> {
        let mut g = self.inner.lock().expect("queue lock");
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        // one new item -> one consumer needs waking; a consumer that wakes
        // to an already-taken item re-checks and re-sleeps (loop in pop)
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking pop: `Some` item or `None` if the queue is currently
    /// empty (whether closed or not). This is the steal primitive — a
    /// stealing worker must not confuse a neighbor's drained-and-closed
    /// shard with its own shutdown signal.
    pub fn try_pop(&self) -> Option<T> {
        self.inner.lock().expect("queue lock").items.pop_front()
    }

    /// Items currently queued (snapshot; may be stale by return time).
    pub fn len(&self) -> usize {
        self.inner.lock().expect("queue lock").items.len()
    }

    /// Block until an item arrives or the queue is closed and drained.
    pub fn pop(&self) -> Pop<T> {
        let mut g = self.inner.lock().expect("queue lock");
        loop {
            if let Some(v) = g.items.pop_front() {
                return Pop::Item(v);
            }
            if g.closed {
                return Pop::Closed;
            }
            g = self.not_empty.wait(g).expect("queue lock");
        }
    }

    /// Block at most `timeout` for an item. Consumers holding a non-empty
    /// pending batch use this so the batch deadline can fire while the
    /// queue is idle. Timeouts are clamped to one hour so an extreme
    /// `max_wait_us` cannot overflow the deadline arithmetic.
    ///
    /// Close-vs-pending contract: after [`close`](Self::close), queued
    /// items are still returned (in FIFO order) before [`Pop::Closed`] is
    /// ever reported — on every wakeup path, spurious or not, the item
    /// check precedes the closed check.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let timeout = timeout.min(Duration::from_secs(3600));
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue lock");
        loop {
            if let Some(v) = g.items.pop_front() {
                return Pop::Item(v);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue lock");
            g = guard;
            if res.timed_out() {
                // final re-check: an item may have landed exactly as the
                // wait expired
                if let Some(v) = g.items.pop_front() {
                    return Pop::Item(v);
                }
                return if g.closed { Pop::Closed } else { Pop::TimedOut };
            }
        }
    }

    /// Close the queue: admission stops immediately, consumers drain what
    /// remains, then observe [`Pop::Closed`]. Idempotent.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue lock");
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
    }
}

/// How an idle worker scans other shards for work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Steal {
    /// Scan the other shards in ring order starting after the home shard.
    Ring,
    /// Never steal: each worker consumes only its home shard.
    Off,
}

/// Sharded admission front: one [`SharedQueue`] per shard, round-robin
/// placement on push, per-worker home shards on pop, optional ring
/// stealing for idle workers.
///
/// Invariants carried over from the single-queue server:
///
/// * **Backpressure** — [`try_push`](Self::try_push) tries the round-robin
///   home shard first and then every other shard once; it reports
///   [`PushError::Full`] only when *all* shards are full, so `queue_cap`
///   keeps meaning "total in-flight admissions" (per-shard caps are
///   `ceil(cap / shards)`, so the total can overshoot `cap` by at most
///   `shards - 1`).
/// * **Drain-then-exit** — [`close`](Self::close) closes every shard;
///   each shard is drained by its owning worker(s) before they observe
///   [`Pop::Closed`], so shutdown still answers everything admitted.
///   The server clamps `shards <= workers`, so every shard has an owner.
pub(crate) struct ShardedQueue<T> {
    shards: Vec<SharedQueue<T>>,
    rr: AtomicUsize,
    steal: Steal,
}

impl<T> ShardedQueue<T> {
    /// `shards >= 1` shards with a *total* capacity of `cap >= 1`.
    pub fn new(shards: usize, cap: usize, steal: Steal) -> Self {
        assert!(shards >= 1, "shard count must be >= 1");
        assert!(cap >= 1, "queue capacity must be >= 1");
        let per_shard = cap.div_ceil(shards);
        ShardedQueue {
            shards: (0..shards).map(|_| SharedQueue::new(per_shard)).collect(),
            rr: AtomicUsize::new(0),
            steal,
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Total queued items across all shards (snapshot).
    pub fn len(&self) -> usize {
        self.shards.iter().map(SharedQueue::len).sum()
    }

    /// Non-blocking admission: round-robin home shard first, then every
    /// other shard once. `Closed` wins over `Full` (shutdown is global),
    /// `Full` only when no shard has room.
    pub fn try_push(&self, item: T) -> std::result::Result<(), PushError<T>> {
        let s = self.shards.len();
        let home = self.rr.fetch_add(1, Ordering::Relaxed) % s;
        let mut item = item;
        for i in 0..s {
            match self.shards[(home + i) % s].try_push(item) {
                Ok(()) => return Ok(()),
                Err(PushError::Closed(v)) => return Err(PushError::Closed(v)),
                Err(PushError::Full(v)) => item = v,
            }
        }
        Err(PushError::Full(item))
    }

    /// Non-blocking pop for `worker`: its home shard first, then (steal
    /// permitting) the other shards in ring order. `None` means no work
    /// was visible anywhere this worker may look.
    pub fn try_pop(&self, worker: usize) -> Option<T> {
        let s = self.shards.len();
        let home = worker % s;
        if let Some(v) = self.shards[home].try_pop() {
            return Some(v);
        }
        if self.steal == Steal::Ring {
            for i in 1..s {
                if let Some(v) = self.shards[(home + i) % s].try_pop() {
                    return Some(v);
                }
            }
        }
        None
    }

    /// Blocking pop on `worker`'s home shard with a timeout. Returns
    /// [`Pop::Closed`] only when the home shard is closed **and**
    /// drained — the worker's cue to flush pending batches and exit
    /// (other shards are drained by their own owners).
    pub fn pop_home(&self, worker: usize, timeout: Duration) -> Pop<T> {
        self.shards[worker % self.shards.len()].pop_timeout(timeout)
    }

    /// Close every shard. Idempotent; admission stops immediately, owners
    /// drain what remains.
    pub fn close(&self) {
        for shard in &self.shards {
            shard.close();
        }
    }
}

// The std tests use real threads, sleeps and `Instant` deadlines, none of
// which exist inside the loom model; they are compiled out under
// `--cfg loom` (the loom job runs only `loom_model_*` tests anyway).
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let q = SharedQueue::new(4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        assert!(matches!(q.try_push(9), Err(PushError::Full(9))));
        for want in 0..4 {
            match q.pop() {
                Pop::Item(v) => assert_eq!(v, want),
                other => panic!("expected item, got {other:?}"),
            }
        }
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::TimedOut));
    }

    #[test]
    fn close_rejects_pushes_but_drains_items() {
        let q = SharedQueue::new(8);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert!(matches!(q.pop(), Pop::Item(1)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::Item(2)));
        assert!(matches!(q.pop(), Pop::Closed));
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
        q.close(); // idempotent
    }

    #[test]
    fn pop_timeout_after_close_drains_in_fifo_order_before_closed() {
        // ISSUE 6 satellite: queued items at close time must all come out,
        // in order, through pop_timeout — including with a zero timeout,
        // which exercises the deadline-expired re-check path where the item
        // check must precede the closed check.
        let q = SharedQueue::new(8);
        for i in 0..3 {
            q.try_push(i).unwrap();
        }
        q.close();
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Item(0)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(10)), Pop::Item(1)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Item(2)));
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::Closed));
    }

    #[test]
    fn consumer_woken_by_close_still_receives_item_raced_in_before_close() {
        // Two consumers block in pop_timeout on an empty queue. One item is
        // pushed (notify_one wakes an arbitrary consumer) and the queue is
        // closed immediately after (notify_all wakes the rest). Whatever
        // wakeup each consumer gets — the push's, the close's, or a
        // spurious one — exactly one must return the item and the other
        // must report Closed, never TimedOut and never a lost item.
        let q: Arc<SharedQueue<u32>> = Arc::new(SharedQueue::new(4));
        let consumers: Vec<_> = (0..2)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || loop {
                    match q.pop_timeout(Duration::from_secs(5)) {
                        Pop::Item(v) => return Some(v),
                        Pop::Closed => return None,
                        Pop::TimedOut => panic!("woken consumer timed out"),
                    }
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(41).unwrap();
        q.close();
        let mut got: Vec<Option<u32>> =
            consumers.into_iter().map(|c| c.join().unwrap()).collect();
        got.sort();
        assert_eq!(got, vec![None, Some(41)]);
    }

    #[test]
    fn pop_timeout_expires_on_empty_queue() {
        let q: SharedQueue<u32> = SharedQueue::new(1);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(20)), Pop::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn blocking_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(SharedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                match q2.pop() {
                    Pop::Item(v) => got.push(v),
                    Pop::Closed => break,
                    Pop::TimedOut => unreachable!("blocking pop cannot time out"),
                }
            }
            got
        });
        std::thread::sleep(Duration::from_millis(10));
        q.try_push(7).unwrap();
        q.try_push(8).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), vec![7, 8]);
    }

    #[test]
    fn multi_consumer_loses_and_duplicates_nothing() {
        let q = Arc::new(SharedQueue::new(1024));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Pop::Item(v) => got.push(v),
                            Pop::Closed => break,
                            Pop::TimedOut => unreachable!(),
                        }
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        let v = p * 1000 + i;
                        loop {
                            match q.try_push(v) {
                                Ok(()) => break,
                                Err(PushError::Full(_)) => std::thread::yield_now(),
                                Err(PushError::Closed(_)) => panic!("closed early"),
                            }
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut want: Vec<u32> = (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want);
    }

    #[test]
    fn sharded_push_spills_before_rejecting_and_full_only_when_all_full() {
        // 2 shards x total cap 2 -> per-shard cap 1: two pushes land (the
        // second spills past its full round-robin home), the third is Full.
        let q = ShardedQueue::new(2, 2, Steal::Ring);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        assert!(matches!(q.try_push(3), Err(PushError::Full(3))));
        assert_eq!(q.len(), 2);
        // draining one slot re-opens admission
        assert!(q.try_pop(0).is_some());
        q.try_push(4).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn sharded_steal_ring_finds_remote_items_and_off_does_not() {
        // one item round-robins onto shard 0; worker 1's home is shard 1
        let q = ShardedQueue::new(2, 8, Steal::Off);
        q.try_push(7).unwrap();
        assert!(q.try_pop(1).is_none(), "steal=off must not cross shards");
        assert_eq!(q.try_pop(0), Some(7));

        let q = ShardedQueue::new(2, 8, Steal::Ring);
        q.try_push(9).unwrap();
        assert_eq!(q.try_pop(1), Some(9), "steal=ring must find remote items");
    }

    #[test]
    fn sharded_close_drains_every_shard_through_its_owner() {
        // items spread across 4 shards, closed with all of them non-empty;
        // 4 owner threads must between them drain everything exactly once
        let q = Arc::new(ShardedQueue::new(4, 64, Steal::Ring));
        for i in 0..32u32 {
            q.try_push(i).unwrap();
        }
        q.close();
        assert!(matches!(q.try_push(99), Err(PushError::Closed(99))));
        let owners: Vec<_> = (0..4)
            .map(|w| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop_home(w, Duration::from_millis(50)) {
                            Pop::Item(v) => got.push(v),
                            Pop::Closed => break,
                            Pop::TimedOut => panic!("closed shard cannot time out"),
                        }
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<u32> = owners.into_iter().flat_map(|o| o.join().unwrap()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..32).collect::<Vec<_>>());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn sharded_single_shard_behaves_like_shared_queue() {
        let q = ShardedQueue::new(1, 1, Steal::Ring);
        q.try_push(1).unwrap();
        assert!(matches!(q.try_push(2), Err(PushError::Full(2))));
        assert_eq!(q.try_pop(3), Some(1)); // any worker maps to shard 0
        assert!(q.try_pop(0).is_none());
    }
}

// Loom model-checking tests: every interleaving of the lock/Condvar/atomic
// operations is explored, which is how the drain-then-exit and
// Full-only-when-all-full invariants documented above are actually pinned.
// Run via the CI loom job: `RUSTFLAGS="--cfg loom" cargo test --lib loom_model_`.
// `pop_timeout`/`pop_home` are deliberately not modelled: they take real
// `Instant` deadlines, which loom cannot schedule.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use loom::sync::Arc;
    use loom::thread;

    /// Drain what `pop` hands back until the queue reports `Closed`.
    fn drain(q: &SharedQueue<u32>) -> Vec<u32> {
        let mut got = Vec::new();
        loop {
            match q.pop() {
                Pop::Item(v) => got.push(v),
                Pop::Closed => return got,
                Pop::TimedOut => unreachable!("pop() never times out"),
            }
        }
    }

    #[test]
    fn loom_model_close_racing_consumer_never_loses_pending_item() {
        loom::model(|| {
            let q = Arc::new(SharedQueue::new(2));
            q.try_push(1).unwrap();
            let consumer = {
                let q = Arc::clone(&q);
                thread::spawn(move || drain(&q))
            };
            q.close();
            // Whatever order close() and the consumer's pop() land in, the
            // admitted item is answered before Closed is observed.
            assert_eq!(consumer.join().unwrap(), vec![1]);
        });
    }

    #[test]
    fn loom_model_push_racing_close_admitted_iff_drained() {
        loom::model(|| {
            let q = Arc::new(SharedQueue::new(2));
            let producer = {
                let q = Arc::clone(&q);
                thread::spawn(move || q.try_push(7).is_ok())
            };
            q.close();
            let admitted = producer.join().unwrap();
            // An admission that raced close() either lost (Closed, nothing
            // queued) or won (item queued) — never a third state where the
            // push reported Ok but the item vanished.
            let drained = drain(&q);
            assert_eq!(admitted, drained == vec![7]);
        });
    }

    #[test]
    fn loom_model_two_consumers_receive_one_item_exactly_once() {
        loom::model(|| {
            let q = Arc::new(SharedQueue::new(2));
            q.try_push(41).unwrap();
            q.close();
            let consumers: Vec<_> = (0..2)
                .map(|_| {
                    let q = Arc::clone(&q);
                    thread::spawn(move || drain(&q))
                })
                .collect();
            let mut got: Vec<u32> = consumers
                .into_iter()
                .flat_map(|c| c.join().unwrap())
                .collect();
            got.sort_unstable();
            // Exactly one of the racing consumers was handed the item; the
            // other saw Closed. No loss, no duplication.
            assert_eq!(got, vec![41]);
        });
    }

    #[test]
    fn loom_model_steal_ring_race_hands_item_to_exactly_one_worker() {
        loom::model(|| {
            let q = Arc::new(ShardedQueue::new(2, 4, Steal::Ring));
            q.try_push(9).unwrap(); // rr starts at 0 -> lands on shard 0
            let a = {
                let q = Arc::clone(&q);
                thread::spawn(move || q.try_pop(0)) // home shard 0
            };
            let b = {
                let q = Arc::clone(&q);
                thread::spawn(move || q.try_pop(1)) // home shard 1, steals from 0
            };
            let (ra, rb) = (a.join().unwrap(), b.join().unwrap());
            // The owner and the stealing worker race on shard 0's lock:
            // exactly one wins the item, the ring never duplicates it.
            assert!(matches!((ra, rb), (Some(9), None) | (None, Some(9))));
            assert_eq!(q.len(), 0);
        });
    }

    #[test]
    fn loom_model_sharded_push_full_only_when_every_shard_full() {
        loom::model(|| {
            // 2 shards, total cap 2 -> per-shard cap 1. One slot taken, two
            // pushes race for the last one.
            let q = Arc::new(ShardedQueue::new(2, 2, Steal::Ring));
            q.try_push(1).unwrap();
            let racer = {
                let q = Arc::clone(&q);
                thread::spawn(move || q.try_push(2))
            };
            let local = q.try_push(3);
            let remote = racer.join().unwrap();
            // Exactly one of the racing pushes lands; the loser spilled
            // across both shards before reporting Full (never Closed).
            match (local, remote) {
                (Ok(()), Err(PushError::Full(3))) | (Err(PushError::Full(3)), Ok(())) => {}
                other => panic!("expected exactly one Full(3) rejection, got {other:?}"),
            }
            assert_eq!(q.len(), 2);
        });
    }
}
