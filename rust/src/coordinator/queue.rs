//! Bounded multi-producer / multi-consumer admission queue.
//!
//! `std::sync::mpsc` is single-consumer, so a worker *pool* sharing one
//! queue needs its own primitive: a `Mutex<VecDeque>` + `Condvar` bounded
//! queue with non-blocking admission (`try_push`) and deadline-aware
//! consumption (`pop_timeout`), the two operations the serving loop is
//! built from.
//!
//! Semantics:
//!
//! * `try_push` never blocks: a full queue is an admission-control
//!   rejection ([`PushError::Full`]), a closed queue is a shutdown
//!   rejection ([`PushError::Closed`]). This preserves the coordinator's
//!   fail-fast backpressure contract.
//! * `pop` / `pop_timeout` drain remaining items even after [`close`]
//!   (graceful shutdown answers everything that was admitted); only a
//!   queue that is both closed **and** empty reports [`Pop::Closed`].
//! * FIFO order within the queue. With several consumers, items are
//!   handed out in arrival order but may complete out of order — that is
//!   the point of the pool.
//!
//! [`close`]: SharedQueue::close

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Why a [`SharedQueue::try_push`] was refused. The item is handed back
/// rather than dropped so `T` need not be `Clone` and callers can decide
/// its fate.
#[derive(Debug)]
pub(crate) enum PushError<T> {
    /// The queue is at capacity (admission control).
    Full(T),
    /// The queue was closed by shutdown.
    Closed(T),
}

/// Outcome of a [`SharedQueue::pop`] / [`SharedQueue::pop_timeout`].
#[derive(Debug)]
pub(crate) enum Pop<T> {
    /// The oldest queued item.
    Item(T),
    /// The timeout elapsed with the queue still empty (batch deadline).
    TimedOut,
    /// The queue is closed and fully drained: the consumer should flush
    /// its pending batch and exit.
    Closed,
}

struct Inner<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// The bounded MPMC queue shared by the submit path and the worker pool.
pub(crate) struct SharedQueue<T> {
    inner: Mutex<Inner<T>>,
    not_empty: Condvar,
    cap: usize,
}

impl<T> SharedQueue<T> {
    /// A queue admitting at most `cap >= 1` items.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "queue capacity must be >= 1");
        SharedQueue {
            inner: Mutex::new(Inner {
                items: VecDeque::with_capacity(cap.min(4096)),
                closed: false,
            }),
            not_empty: Condvar::new(),
            cap,
        }
    }

    /// Non-blocking admission; hands the item back on refusal.
    pub fn try_push(&self, item: T) -> std::result::Result<(), PushError<T>> {
        let mut g = self.inner.lock().expect("queue lock");
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        drop(g);
        // one new item -> one consumer needs waking; a consumer that wakes
        // to an already-taken item re-checks and re-sleeps (loop in pop)
        self.not_empty.notify_one();
        Ok(())
    }

    /// Block until an item arrives or the queue is closed and drained.
    pub fn pop(&self) -> Pop<T> {
        let mut g = self.inner.lock().expect("queue lock");
        loop {
            if let Some(v) = g.items.pop_front() {
                return Pop::Item(v);
            }
            if g.closed {
                return Pop::Closed;
            }
            g = self.not_empty.wait(g).expect("queue lock");
        }
    }

    /// Block at most `timeout` for an item. Consumers holding a non-empty
    /// pending batch use this so the batch deadline can fire while the
    /// queue is idle. Timeouts are clamped to one hour so an extreme
    /// `max_wait_us` cannot overflow the deadline arithmetic.
    pub fn pop_timeout(&self, timeout: Duration) -> Pop<T> {
        let timeout = timeout.min(Duration::from_secs(3600));
        let deadline = Instant::now() + timeout;
        let mut g = self.inner.lock().expect("queue lock");
        loop {
            if let Some(v) = g.items.pop_front() {
                return Pop::Item(v);
            }
            if g.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, res) = self
                .not_empty
                .wait_timeout(g, deadline - now)
                .expect("queue lock");
            g = guard;
            if res.timed_out() {
                // final re-check: an item may have landed exactly as the
                // wait expired
                if let Some(v) = g.items.pop_front() {
                    return Pop::Item(v);
                }
                return if g.closed { Pop::Closed } else { Pop::TimedOut };
            }
        }
    }

    /// Close the queue: admission stops immediately, consumers drain what
    /// remains, then observe [`Pop::Closed`]. Idempotent.
    pub fn close(&self) {
        let mut g = self.inner.lock().expect("queue lock");
        g.closed = true;
        drop(g);
        self.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_capacity() {
        let q = SharedQueue::new(4);
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        assert!(matches!(q.try_push(9), Err(PushError::Full(9))));
        for want in 0..4 {
            match q.pop() {
                Pop::Item(v) => assert_eq!(v, want),
                other => panic!("expected item, got {other:?}"),
            }
        }
        assert!(matches!(q.pop_timeout(Duration::ZERO), Pop::TimedOut));
    }

    #[test]
    fn close_rejects_pushes_but_drains_items() {
        let q = SharedQueue::new(8);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        q.close();
        assert!(matches!(q.try_push(3), Err(PushError::Closed(3))));
        assert!(matches!(q.pop(), Pop::Item(1)));
        assert!(matches!(q.pop_timeout(Duration::from_millis(5)), Pop::Item(2)));
        assert!(matches!(q.pop(), Pop::Closed));
        assert!(matches!(q.pop_timeout(Duration::from_millis(1)), Pop::Closed));
        q.close(); // idempotent
    }

    #[test]
    fn pop_timeout_expires_on_empty_queue() {
        let q: SharedQueue<u32> = SharedQueue::new(1);
        let t0 = Instant::now();
        assert!(matches!(q.pop_timeout(Duration::from_millis(20)), Pop::TimedOut));
        assert!(t0.elapsed() >= Duration::from_millis(20));
    }

    #[test]
    fn blocking_pop_wakes_on_push_and_on_close() {
        let q = Arc::new(SharedQueue::new(4));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            loop {
                match q2.pop() {
                    Pop::Item(v) => got.push(v),
                    Pop::Closed => break,
                    Pop::TimedOut => unreachable!("blocking pop cannot time out"),
                }
            }
            got
        });
        std::thread::sleep(Duration::from_millis(10));
        q.try_push(7).unwrap();
        q.try_push(8).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(h.join().unwrap(), vec![7, 8]);
    }

    #[test]
    fn multi_consumer_loses_and_duplicates_nothing() {
        let q = Arc::new(SharedQueue::new(1024));
        let consumers: Vec<_> = (0..4)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    loop {
                        match q.pop() {
                            Pop::Item(v) => got.push(v),
                            Pop::Closed => break,
                            Pop::TimedOut => unreachable!(),
                        }
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..4)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..100u32 {
                        let v = p * 1000 + i;
                        loop {
                            match q.try_push(v) {
                                Ok(()) => break,
                                Err(PushError::Full(_)) => std::thread::yield_now(),
                                Err(PushError::Closed(_)) => panic!("closed early"),
                            }
                        }
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        let mut want: Vec<u32> = (0..4).flat_map(|p| (0..100).map(move |i| p * 1000 + i)).collect();
        want.sort_unstable();
        assert_eq!(all, want);
    }
}
