//! Multi-model registry: the serving layer's model store and engine cache.
//!
//! A [`ModelRegistry`] holds every model one server process hosts and
//! routes requests to them by model id. Models come in two flavors:
//!
//! * **Pinned** — a prototype [`ModelEngine`] handed in at construction
//!   (the single-engine `Server::start` path). Always resident, never
//!   evicted, outside the cache budget.
//! * **Bundle-backed** — a decoded `.ttrv` [`ModelBundle`]. The engine is
//!   built lazily on first use via [`ModelBundle::build_engine`] (the
//!   warm-start path: packed cores + pre-seeded plans, no DSE), kept in a
//!   memory-budgeted LRU cache, and transparently rebuilt from the bundle
//!   after eviction. Rebuilds are deterministic, so an evict-then-reload
//!   cycle cannot move an output bit.
//!
//! Workers keep warm per-model engine views and re-clone only when the
//! registry's *epoch* for that model moved (i.e. a reload happened): the
//! [`lease`](ModelRegistry::lease) API returns the current epoch plus a
//! fresh [`ModelEngine::worker_clone`] only when the caller's epoch is
//! stale, so the steady-state hot path does zero cloning and takes one
//! short lock.
//!
//! Deadlock-freedom by construction: the registry has exactly one lock
//! and never calls back into the server while holding it. Engine builds
//! happen inside the lock — a reload briefly blocks other models'
//! leases, which is the accepted cost of correctness on a 1-engine
//! budget (the currently leased model is never evicted, so a too-small
//! budget degrades to reload-per-switch, never to deadlock).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::artifact::ModelBundle;
use crate::error::{Error, Result};
use crate::machine::MachineSpec;

use super::engine::ModelEngine;

/// Epoch stamped on every pinned-model lease; bundle loads start at 1.
const PINNED_EPOCH: u64 = 0;

enum ModelSource {
    Pinned(ModelEngine),
    Bundle {
        bundle: Box<ModelBundle>,
        machine: MachineSpec,
    },
}

/// Static facts about one registered model (immutable after registration).
struct ModelSlot {
    id: String,
    in_dim: usize,
    out_dim: usize,
    bytes: u64,
    source: ModelSource,
}

struct Resident {
    engine: ModelEngine,
    epoch: u64,
}

struct CacheState {
    /// Per-slot resident engine; always `None` for pinned slots (their
    /// prototype lives in the slot itself).
    resident: Vec<Option<Resident>>,
    /// Resident bundle-backed slots, least-recently-leased first.
    lru: Vec<usize>,
    /// Bytes of resident bundle-backed engines (pinned models excluded).
    resident_bytes: u64,
    next_epoch: u64,
}

/// Summary of one registered model, as reported by
/// [`ModelRegistry::models`] (the snapshot's `models` rows).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelInfo {
    /// Model id (routing key).
    pub id: String,
    /// Input width.
    pub in_dim: usize,
    /// Output width.
    pub out_dim: usize,
    /// Approximate engine bytes charged against the cache budget.
    pub bytes: u64,
    /// Whether an engine for this model is currently resident.
    pub resident: bool,
    /// Whether the model is pinned (never evicted).
    pub pinned: bool,
}

/// The multi-model store behind [`super::Server`]: id-routed lookup, lazy
/// warm-start loading, and a memory-budgeted LRU engine cache. See the
/// module docs for the design.
pub struct ModelRegistry {
    slots: Vec<ModelSlot>,
    index: HashMap<String, usize>,
    cache_bytes: u64,
    state: Mutex<CacheState>,
    loads: AtomicU64,
    evictions: AtomicU64,
}

impl ModelRegistry {
    /// An empty registry with an LRU budget of `cache_bytes` (0 =
    /// unlimited) over bundle-backed engines.
    pub fn new(cache_bytes: u64) -> Self {
        ModelRegistry {
            slots: Vec::new(),
            index: HashMap::new(),
            cache_bytes,
            state: Mutex::new(CacheState {
                resident: Vec::new(),
                lru: Vec::new(),
                resident_bytes: 0,
                next_epoch: PINNED_EPOCH + 1,
            }),
            loads: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn add_slot(&mut self, slot: ModelSlot) -> Result<usize> {
        if self.index.contains_key(&slot.id) {
            return Err(Error::serve(format!(
                "duplicate model id '{}' in registry",
                slot.id
            )));
        }
        let idx = self.slots.len();
        self.index.insert(slot.id.clone(), idx);
        self.slots.push(slot);
        self.state.lock().expect("registry lock").resident.push(None);
        Ok(idx)
    }

    /// Register a pinned prototype engine (always resident, never
    /// evicted). Returns the model's slot index.
    pub fn add_pinned(&mut self, engine: ModelEngine) -> Result<usize> {
        let slot = ModelSlot {
            id: engine.name().to_string(),
            in_dim: engine.in_dim(),
            out_dim: engine.out_dim(),
            bytes: engine.approx_bytes(),
            source: ModelSource::Pinned(engine),
        };
        self.add_slot(slot)
    }

    /// Register a decoded `.ttrv` bundle for lazy warm-start loading on
    /// `machine`. The bundle must target that machine — checked here so a
    /// mismatch fails at registration, not on the first request. Returns
    /// the model's slot index.
    pub fn add_bundle(&mut self, bundle: ModelBundle, machine: &MachineSpec) -> Result<usize> {
        if bundle.machine != machine.name {
            return Err(Error::artifact(format!(
                "bundle '{}' was compiled for machine '{}', registry serves '{}'",
                bundle.name, bundle.machine, machine.name
            )));
        }
        let slot = ModelSlot {
            id: bundle.name.clone(),
            in_dim: bundle.in_dim,
            out_dim: bundle.out_dim,
            bytes: bundle.engine_bytes(),
            source: ModelSource::Bundle { bundle: Box::new(bundle), machine: machine.clone() },
        };
        self.add_slot(slot)
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Whether the registry holds no models.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Resolve a request's model id to a slot index. `None` routes to the
    /// default model (slot 0, the first registered); an unknown id is a
    /// typed serve error naming the known models.
    pub fn resolve(&self, model: Option<&str>) -> Result<usize> {
        match model {
            None => {
                if self.slots.is_empty() {
                    return Err(Error::serve("registry has no models"));
                }
                Ok(0)
            }
            Some(id) => self.index.get(id).copied().ok_or_else(|| {
                let mut known: Vec<&str> =
                    self.slots.iter().map(|s| s.id.as_str()).collect();
                known.sort_unstable();
                Error::serve(format!(
                    "unknown model '{id}' (serving: {})",
                    known.join(", ")
                ))
            }),
        }
    }

    /// Model id for a slot index (panics on an out-of-range slot; slot
    /// indices come from [`resolve`](Self::resolve) or registration).
    pub fn id(&self, slot: usize) -> &str {
        &self.slots[slot].id
    }

    /// Input width of a slot's model.
    pub fn in_dim(&self, slot: usize) -> usize {
        self.slots[slot].in_dim
    }

    /// Output width of a slot's model.
    pub fn out_dim(&self, slot: usize) -> usize {
        self.slots[slot].out_dim
    }

    /// Lease a worker view of a slot's engine. `have_epoch` is the epoch
    /// of the view the caller already holds (`None` for "nothing yet").
    /// Returns the slot's current epoch plus `Some(fresh worker clone)`
    /// only when the caller's view is stale — the warm path returns
    /// `(epoch, None)` and the caller keeps its existing engine.
    ///
    /// For a bundle-backed slot this lazily (re)builds the engine from
    /// the stored bundle, touches the LRU, and evicts least-recently-used
    /// engines while the cache is over budget (never the slot being
    /// leased).
    pub fn lease(
        &self,
        slot: usize,
        have_epoch: Option<u64>,
    ) -> Result<(u64, Option<ModelEngine>)> {
        let s = &self.slots[slot];
        match &s.source {
            ModelSource::Pinned(proto) => {
                let clone = match have_epoch {
                    Some(e) if e == PINNED_EPOCH => None,
                    _ => Some(proto.worker_clone()),
                };
                Ok((PINNED_EPOCH, clone))
            }
            ModelSource::Bundle { bundle, machine } => {
                let mut st = self.state.lock().expect("registry lock");
                if st.resident[slot].is_none() {
                    let engine = bundle.build_engine(machine)?;
                    let epoch = st.next_epoch;
                    st.next_epoch += 1;
                    st.resident[slot] = Some(Resident { engine, epoch });
                    st.resident_bytes += s.bytes;
                    self.loads.fetch_add(1, Ordering::Relaxed);
                }
                st.lru.retain(|&x| x != slot);
                st.lru.push(slot);
                self.evict_over_budget(&mut st, slot);
                let r = st.resident[slot].as_ref().expect("leased slot is resident");
                let epoch = r.epoch;
                let clone = match have_epoch {
                    Some(e) if e == epoch => None,
                    _ => Some(r.engine.worker_clone()),
                };
                Ok((epoch, clone))
            }
        }
    }

    /// Evict LRU engines (never `keep`) until the budget is met. With
    /// only `keep` resident the cache may stay over budget — the model
    /// being served always stays loadable.
    fn evict_over_budget(&self, st: &mut CacheState, keep: usize) {
        if self.cache_bytes == 0 {
            return;
        }
        while st.resident_bytes > self.cache_bytes {
            let Some(victim) = st.lru.iter().copied().find(|&x| x != keep) else {
                break;
            };
            st.lru.retain(|&x| x != victim);
            if st.resident[victim].take().is_some() {
                st.resident_bytes -= self.slots[victim].bytes;
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Engines built from bundles so far (initial loads + reloads).
    pub fn loads(&self) -> u64 {
        self.loads.load(Ordering::Relaxed)
    }

    /// Engines evicted by the LRU budget so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// The configured cache budget in bytes (0 = unlimited).
    pub fn cache_bytes(&self) -> u64 {
        self.cache_bytes
    }

    /// Bytes of currently resident bundle-backed engines.
    pub fn resident_bytes(&self) -> u64 {
        self.state.lock().expect("registry lock").resident_bytes
    }

    /// Whether a slot's engine is currently resident (pinned slots always
    /// are).
    pub fn is_resident(&self, slot: usize) -> bool {
        match self.slots[slot].source {
            ModelSource::Pinned(_) => true,
            ModelSource::Bundle { .. } => {
                self.state.lock().expect("registry lock").resident[slot].is_some()
            }
        }
    }

    /// Per-model summaries in slot order (the snapshot's `models` rows).
    pub fn models(&self) -> Vec<ModelInfo> {
        let st = self.state.lock().expect("registry lock");
        self.slots
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let pinned = matches!(s.source, ModelSource::Pinned(_));
                ModelInfo {
                    id: s.id.clone(),
                    in_dim: s.in_dim,
                    out_dim: s.out_dim,
                    bytes: s.bytes,
                    resident: pinned || st.resident[i].is_some(),
                    pinned,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{BundleOp, DenseLayerBundle};
    use crate::baselines::dense::DenseFc;
    use crate::coordinator::LayerOp;
    use crate::tensor::Tensor;
    use crate::util::json::Json;
    use crate::util::prng::Rng;

    fn machine() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    fn pinned(name: &str) -> ModelEngine {
        let w = Tensor::from_vec(vec![2, 4], vec![1., 0., 0., 0., 0., 1., 0., 0.]).unwrap();
        let fc = DenseFc::new(&w, None).unwrap();
        ModelEngine::new(name, vec![LayerOp::Dense(fc)], 4, 2)
    }

    /// A hand-rolled dense-only bundle: exercises the full lazy
    /// build/evict/reload machinery without running DSE.
    fn dense_bundle(name: &str, seed: u64) -> ModelBundle {
        let mut rng = Rng::new(seed);
        let w = Tensor::randn(vec![2, 4], 0.5, &mut rng);
        ModelBundle {
            name: name.to_string(),
            machine: machine().name.to_string(),
            in_dim: 4,
            out_dim: 2,
            rank: 8,
            seed,
            shapes: vec![(4, 2)],
            ops: vec![BundleOp::Dense(DenseLayerBundle { w, bias: None })],
            report: Json::Arr(Vec::new()),
            tuned_kernel: None,
        }
    }

    #[test]
    fn resolve_routes_by_id_and_defaults_to_first() {
        let mut reg = ModelRegistry::new(0);
        reg.add_pinned(pinned("alpha")).unwrap();
        reg.add_bundle(dense_bundle("beta", 7), &machine()).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.resolve(None).unwrap(), 0);
        assert_eq!(reg.resolve(Some("alpha")).unwrap(), 0);
        assert_eq!(reg.resolve(Some("beta")).unwrap(), 1);
        let err = reg.resolve(Some("gamma")).unwrap_err().to_string();
        assert!(err.contains("gamma") && err.contains("alpha") && err.contains("beta"), "{err}");
    }

    #[test]
    fn duplicate_model_ids_are_rejected() {
        let mut reg = ModelRegistry::new(0);
        reg.add_pinned(pinned("m")).unwrap();
        assert!(reg.add_pinned(pinned("m")).is_err());
        assert!(reg.add_bundle(dense_bundle("m", 1), &machine()).is_err());
    }

    #[test]
    fn bundle_for_wrong_machine_is_rejected_at_registration() {
        let mut reg = ModelRegistry::new(0);
        let mut b = dense_bundle("m", 1);
        b.machine = "some-other-soc".to_string();
        let err = reg.add_bundle(b, &machine()).unwrap_err().to_string();
        assert!(err.contains("some-other-soc"), "{err}");
    }

    #[test]
    fn pinned_lease_is_epoch_stable_and_free_when_warm() {
        let mut reg = ModelRegistry::new(0);
        reg.add_pinned(pinned("m")).unwrap();
        let (e0, view) = reg.lease(0, None).unwrap();
        assert!(view.is_some(), "cold caller gets a clone");
        let (e1, view) = reg.lease(0, Some(e0)).unwrap();
        assert_eq!(e0, e1);
        assert!(view.is_none(), "warm caller keeps its engine");
        assert_eq!(reg.loads(), 0, "pinned models never count as loads");
        assert!(reg.is_resident(0));
    }

    #[test]
    fn bundle_lease_lazy_loads_once_and_reuses_epoch() {
        let mut reg = ModelRegistry::new(0);
        reg.add_bundle(dense_bundle("m", 3), &machine()).unwrap();
        assert!(!reg.is_resident(0), "bundles load lazily");
        let (e0, view) = reg.lease(0, None).unwrap();
        assert!(view.is_some());
        assert_eq!(reg.loads(), 1);
        let (e1, view) = reg.lease(0, Some(e0)).unwrap();
        assert_eq!(e0, e1);
        assert!(view.is_none());
        assert_eq!(reg.loads(), 1, "warm lease must not rebuild");
        assert!(reg.resident_bytes() > 0);
    }

    #[test]
    fn lru_evicts_least_recent_and_reload_bumps_epoch() {
        let mut reg = ModelRegistry::new(0);
        reg.add_bundle(dense_bundle("a", 1), &machine()).unwrap();
        reg.add_bundle(dense_bundle("b", 2), &machine()).unwrap();
        // budget fits exactly one engine
        let one = dense_bundle("x", 0).engine_bytes();
        let reg = ModelRegistry { cache_bytes: one, ..reg };
        let (ea, _) = reg.lease(0, None).unwrap();
        assert!(reg.is_resident(0));
        reg.lease(1, None).unwrap();
        assert!(!reg.is_resident(0), "leasing b must evict LRU a");
        assert!(reg.is_resident(1));
        assert_eq!(reg.evictions(), 1);
        // re-leasing a reloads it under a new epoch: stale workers re-clone
        let (ea2, view) = reg.lease(0, Some(ea)).unwrap();
        assert_ne!(ea, ea2);
        assert!(view.is_some(), "stale epoch must hand out a fresh engine");
        assert_eq!(reg.loads(), 3);
    }

    #[test]
    fn leased_model_survives_a_budget_smaller_than_itself() {
        let mut reg = ModelRegistry::new(0);
        reg.add_bundle(dense_bundle("a", 1), &machine()).unwrap();
        let reg = ModelRegistry { cache_bytes: 1, ..reg };
        let (_, view) = reg.lease(0, None).unwrap();
        assert!(view.is_some());
        assert!(reg.is_resident(0), "the requested model always stays resident");
        assert_eq!(reg.evictions(), 0);
    }

    #[test]
    fn evict_then_reload_is_bitwise_identical() {
        // unit-level twin of the .ttrv integration test: the rebuilt
        // engine must produce bit-identical outputs (builds are
        // deterministic functions of the stored bundle)
        let mut reg = ModelRegistry::new(0);
        reg.add_bundle(dense_bundle("a", 11), &machine()).unwrap();
        reg.add_bundle(dense_bundle("b", 12), &machine()).unwrap();
        let one = dense_bundle("x", 0).engine_bytes();
        let reg = ModelRegistry { cache_bytes: one, ..reg };
        let probe = Tensor::from_vec(vec![1, 4], vec![0.3, -0.7, 1.1, 0.05]).unwrap();
        let (_, view) = reg.lease(0, None).unwrap();
        let before: Vec<u32> = view
            .unwrap()
            .forward(&probe)
            .unwrap()
            .data()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        reg.lease(1, None).unwrap(); // evicts a
        assert!(!reg.is_resident(0));
        let (_, view) = reg.lease(0, None).unwrap(); // reloads a
        let after: Vec<u32> = view
            .unwrap()
            .forward(&probe)
            .unwrap()
            .data()
            .iter()
            .map(|v| v.to_bits())
            .collect();
        assert_eq!(before, after, "evict-then-reload moved an output bit");
    }

    #[test]
    fn models_summary_reports_residency() {
        let mut reg = ModelRegistry::new(0);
        reg.add_pinned(pinned("p")).unwrap();
        reg.add_bundle(dense_bundle("q", 4), &machine()).unwrap();
        let info = reg.models();
        assert_eq!(info.len(), 2);
        assert!(info[0].pinned && info[0].resident);
        assert!(!info[1].pinned && !info[1].resident);
        assert!(info[1].bytes > 0);
        reg.lease(1, None).unwrap();
        assert!(reg.models()[1].resident);
    }
}
