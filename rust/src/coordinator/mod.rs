//! L3 serving coordinator: a deployable inference runtime around the
//! compressed layers.
//!
//! The paper's contribution is compile-time (DSE + kernel plans); this
//! module is the system that *uses* those plans in production shape:
//!
//! * [`engine`] — executable models: TT FC layers driven by the optimized
//!   kernel engine, dense layers on the MMM baseline, composed into
//!   networks; built from DSE output by the [`router`]. The immutable
//!   compiled model (packed cores, weights) is `Arc`-shared; each worker
//!   holds its own executors (plan cache + scratch).
//! * [`batcher`] — dynamic batching: group requests up to (max_batch,
//!   max_wait) like a serving frontend.
//! * `queue` (crate-private) — a bounded MPMC admission queue:
//!   non-blocking `try_push` for fail-fast admission control, deadline-
//!   aware pops for the batch window, drain-then-exit close semantics.
//!   Also the work-unit queue of the parallel DSE engine
//!   ([`crate::dse::timed`]).
//! * [`server`] — the pool: `ServeConfig.workers` batching workers share
//!   the admission queue; replies fan out over channels; per-worker
//!   metrics shards merge on demand; no allocation on the per-request hot
//!   path beyond the reply buffers.
//! * [`metrics`] — latency histograms + throughput counters, sharded per
//!   worker and merged exactly on read.
//!
//! Invariants (property- and integration-tested): no request is lost or
//! duplicated, batches never exceed `max_batch`, admission never blocks
//! (full queue -> immediate error), responses are byte-identical across
//! pool sizes (`workers = 1` vs `workers = 4`), and graceful shutdown
//! answers everything admitted before joining the workers.

pub mod engine;
pub mod batcher;
pub(crate) mod queue;
pub mod server;
pub mod metrics;
pub mod router;

pub use engine::{LayerOp, ModelEngine, TtFcEngine};
pub use router::{route_model, Route};
pub use server::{InferenceRequest, InferenceResponse, Server};
