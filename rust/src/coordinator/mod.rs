//! L3 serving coordinator: a deployable inference runtime around the
//! compressed layers.
//!
//! The paper's contribution is compile-time (DSE + kernel plans); this
//! module is the system that *uses* those plans in production shape:
//!
//! * [`engine`] — executable models: TT FC layers driven by the optimized
//!   kernel engine, dense layers on the MMM baseline, composed into
//!   networks; built from DSE output by the [`router`].
//! * [`batcher`] — dynamic batching: group requests up to (max_batch,
//!   max_wait) like a serving frontend.
//! * [`server`] — the event loop: bounded queue, worker thread, replies
//!   over channels; no allocation on the per-request hot path beyond the
//!   reply buffers.
//! * [`metrics`] — latency histograms + throughput counters.
//!
//! Invariants (property-tested): no request is lost or duplicated, batches
//! never exceed `max_batch`, FIFO order within the queue, and batched
//! outputs are identical to single-request outputs.

pub mod engine;
pub mod batcher;
pub mod server;
pub mod metrics;
pub mod router;

pub use engine::{LayerOp, ModelEngine, TtFcEngine};
pub use router::{route_model, Route};
pub use server::{InferenceRequest, InferenceResponse, Server};
