//! L3 serving coordinator: a deployable multi-model inference runtime
//! around the compressed layers.
//!
//! The paper's contribution is compile-time (DSE + kernel plans); this
//! module is the system that *uses* those plans in production shape:
//!
//! * [`engine`] — executable models: TT FC layers driven by the optimized
//!   kernel engine, dense layers on the MMM baseline, composed into
//!   networks; built from DSE output by the [`router`]. The immutable
//!   compiled model (packed cores, weights) is `Arc`-shared; each worker
//!   holds its own executors (plan cache + scratch).
//! * [`registry`] — the multi-model store: several `.ttrv` artifacts (or
//!   pinned engines) co-hosted in one process, routed by model id, with a
//!   memory-budgeted LRU engine cache and lazy warm-start reload after
//!   eviction. Workers hold epoch-leased engine views, so the steady
//!   state does zero per-batch cloning.
//! * [`batcher`] — deadline-aware dynamic batching: group requests up to
//!   `max_batch`, dispatching when the *tightest* admitted latency budget
//!   (per-request SLO, capped by `max_wait`) is nearly spent.
//! * `queue` (crate-private) — bounded admission: the single MPMC
//!   primitive (still the work-unit queue of the parallel DSE engine,
//!   [`crate::dse::timed`]) and the sharded work-stealing front the
//!   server admits through — one shard per worker, round-robin placement,
//!   optional ring stealing, fail-fast `try_push`, drain-then-exit close
//!   semantics.
//! * [`server`] — the pool: `ServeConfig.workers` batching workers, each
//!   owning one queue shard and one open batch per model; replies fan out
//!   over channels; per-worker per-model metrics shards merge exactly on
//!   read; [`Server::snapshot`] emits the versioned machine-readable
//!   state document (`ttrv-serve-snapshot`).
//! * [`metrics`] — latency/batch-size histograms + throughput counters,
//!   sharded per worker and merged exactly on read, JSON-serializable.
//!
//! Invariants (property- and integration-tested): no request is lost or
//! duplicated, batches never exceed `max_batch` and never mix models,
//! admission never blocks (full queue -> immediate error), responses are
//! byte-identical across worker counts, shard counts, steal schedules,
//! and co-hosted-model counts, and graceful shutdown answers everything
//! admitted before joining the workers.

pub mod engine;
pub mod batcher;
pub(crate) mod queue;
pub mod registry;
pub mod server;
pub mod metrics;
pub mod router;

pub use engine::{LayerOp, ModelEngine, TtFcEngine};
pub use registry::{ModelInfo, ModelRegistry};
pub use router::{route_model, Route};
pub use server::{InferenceRequest, InferenceResponse, Server};
