//! Executable model engines: the serving-time realization of DSE output.

use std::collections::HashMap;

use crate::baselines::dense::DenseFc;
use crate::compiler::{compile, OptimizationPlan};
use crate::error::{Error, Result};
use crate::kernels::{self, PackedG};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost::{einsum_chain, EinsumDims};
use crate::ttd::decompose::TtCores;

/// A TT-decomposed FC layer compiled for serving: packed cores plus a
/// per-batch-size plan cache.
pub struct TtFcEngine {
    machine: MachineSpec,
    layout: crate::ttd::TtLayout,
    /// Packed core per chain step, in processing order (t = d-1 .. 0).
    packed: Vec<PackedG>,
    bias: Option<Vec<f32>>,
    /// batch -> plans per chain step.
    plan_cache: HashMap<usize, Vec<OptimizationPlan>>,
    /// Measured RB autotuning on plan-cache misses (kernels::tune_plan).
    tune: bool,
    /// Ping-pong buffers for the einsum chain (no per-request allocation).
    buf_a: Vec<f32>,
    buf_b: Vec<f32>,
}

impl TtFcEngine {
    /// Compile a decomposed layer for the target machine.
    pub fn new(tt: &TtCores, machine: &MachineSpec) -> Result<TtFcEngine> {
        // plans at batch 1 determine the (batch-independent) packing layout
        let chain = einsum_chain(&tt.layout, 1);
        let mut packed = Vec::with_capacity(chain.len());
        for (step, dims) in chain.iter().enumerate() {
            let core_idx = tt.layout.d() - 1 - step; // processing order
            let plan = compile(dims, machine)?;
            packed.push(kernels::pack(&tt.cores[core_idx], &plan)?);
        }
        Ok(TtFcEngine {
            machine: machine.clone(),
            layout: tt.layout.clone(),
            packed,
            bias: tt.bias.clone(),
            plan_cache: HashMap::new(),
            tune: false,
            buf_a: Vec::new(),
            buf_b: Vec::new(),
        })
    }

    /// Enable measured register-blocking autotuning: each plan-cache miss
    /// micro-benchmarks the solver's top candidates on this machine
    /// (EXPERIMENTS.md §Perf iteration 2). One-time cost per batch size.
    pub fn with_tuning(mut self) -> Self {
        self.tune = true;
        self
    }

    pub fn layout(&self) -> &crate::ttd::TtLayout {
        &self.layout
    }

    /// Input width N.
    pub fn n_total(&self) -> usize {
        self.layout.n_total() as usize
    }

    /// Output width M.
    pub fn m_total(&self) -> usize {
        self.layout.m_total() as usize
    }

    fn plans_for_batch(&mut self, batch: usize) -> Result<&[OptimizationPlan]> {
        if !self.plan_cache.contains_key(&batch) {
            let chain = einsum_chain(&self.layout, batch);
            let d = self.layout.d();
            let mut plans = Vec::with_capacity(chain.len());
            for (step, dims) in chain.iter().enumerate() {
                let mut plan = compile(dims, &self.machine)?;
                // packing layout must be batch-invariant for the cache to work
                debug_assert_eq!(
                    plan.vector_loop,
                    compile(&einsum_chain(&self.layout, 1)[step], &self.machine)?.vector_loop
                );
                if self.tune {
                    let core_shape = self.layout.core_shape(d - 1 - step);
                    let mut rng = crate::util::prng::Rng::new(0x7e57);
                    let g = Tensor::randn(core_shape.to_vec(), 0.5, &mut rng);
                    let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 0.5, &mut rng);
                    plan = kernels::tune_plan(&plan, &self.machine, &g, &x, 6)?;
                }
                plans.push(plan);
            }
            self.plan_cache.insert(batch, plans);
        }
        Ok(self.plan_cache.get(&batch).expect("just inserted"))
    }

    /// Forward `x (B, N) -> (B, M)` through the optimized kernel chain.
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        let dims = x.dims();
        if dims.len() != 2 || dims[1] != self.n_total() {
            return Err(Error::shape(format!(
                "engine expects (B, {}), got {:?}",
                self.n_total(),
                dims
            )));
        }
        let batch = dims[0];
        self.plans_for_batch(batch)?;
        let plans = self.plan_cache.get(&batch).expect("cached").clone();
        let m_total = self.m_total();

        // ping-pong between the two owned buffers; input of step 0 is x
        self.buf_a.clear();
        self.buf_a.extend_from_slice(x.data());
        for (step, plan) in plans.iter().enumerate() {
            let EinsumDims { b, n, k, .. } = plan.dims;
            debug_assert_eq!(self.buf_a.len(), b * n * k);
            kernels::execute_into(plan, &self.packed[step], &self.buf_a, &mut self.buf_b)?;
            std::mem::swap(&mut self.buf_a, &mut self.buf_b);
        }
        // final layout (M, B) row-major -> (B, M)
        let mut y = Tensor::from_vec(vec![m_total, batch], self.buf_a.clone())?
            .transpose(&[1, 0])?;
        if let Some(bias) = &self.bias {
            for row in y.data_mut().chunks_mut(m_total) {
                for (v, &bv) in row.iter_mut().zip(bias) {
                    *v += bv;
                }
            }
        }
        Ok(y)
    }
}

/// One step of a sequential model.
pub enum LayerOp {
    Tt(TtFcEngine),
    Dense(DenseFc),
    Relu,
}

/// A sequential model engine (the LeNet300-style MLP in the examples).
pub struct ModelEngine {
    pub name: String,
    ops: Vec<LayerOp>,
    in_dim: usize,
    out_dim: usize,
}

impl ModelEngine {
    pub fn new(name: impl Into<String>, ops: Vec<LayerOp>, in_dim: usize, out_dim: usize) -> Self {
        ModelEngine { name: name.into(), ops, in_dim, out_dim }
    }

    pub fn in_dim(&self) -> usize {
        self.in_dim
    }

    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Forward a batch `(B, in_dim) -> (B, out_dim)`.
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut cur = x.clone();
        for op in &mut self.ops {
            cur = match op {
                LayerOp::Tt(engine) => engine.forward(&cur)?,
                LayerOp::Dense(fc) => fc.forward(&cur)?,
                LayerOp::Relu => {
                    let mut t = cur;
                    for v in t.data_mut() {
                        *v = v.max(0.0);
                    }
                    t
                }
            };
        }
        if cur.dims()[1] != self.out_dim {
            return Err(Error::shape("model produced wrong output width"));
        }
        Ok(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::fc_batched_ref;
    use crate::ttd::decompose::random_cores;
    use crate::ttd::TtLayout;
    use crate::util::prng::Rng;

    fn engine_and_truth() -> (TtFcEngine, Tensor, Option<Vec<f32>>) {
        let mut rng = Rng::new(100);
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut tt = random_cores(&layout, &mut rng);
        tt.bias = Some((0..300).map(|i| (i % 7) as f32 * 0.1).collect());
        let w = tt.reconstruct().unwrap();
        let bias = tt.bias.clone();
        let engine = TtFcEngine::new(&tt, &MachineSpec::spacemit_k1()).unwrap();
        (engine, w, bias)
    }

    #[test]
    fn engine_matches_dense_reconstruction() {
        let (mut engine, w, bias) = engine_and_truth();
        let mut rng = Rng::new(101);
        for batch in [1usize, 3, 16] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let got = engine.forward(&x).unwrap();
            let want = fc_batched_ref(&w, &x, bias.as_deref()).unwrap();
            assert!(
                got.allclose(&want, 1e-3, 1e-3),
                "batch {batch}: {}",
                got.max_abs_diff(&want).unwrap()
            );
        }
    }

    #[test]
    fn plan_cache_reuses_batches() {
        let (mut engine, _, _) = engine_and_truth();
        let mut rng = Rng::new(102);
        let x = Tensor::randn(vec![4, 784], 1.0, &mut rng);
        engine.forward(&x).unwrap();
        engine.forward(&x).unwrap();
        assert_eq!(engine.plan_cache.len(), 1);
        let x2 = Tensor::randn(vec![8, 784], 1.0, &mut rng);
        engine.forward(&x2).unwrap();
        assert_eq!(engine.plan_cache.len(), 2);
    }

    #[test]
    fn rejects_wrong_width() {
        let (mut engine, _, _) = engine_and_truth();
        let x = Tensor::zeros(vec![2, 100]);
        assert!(engine.forward(&x).is_err());
    }

    #[test]
    fn model_engine_composes_layers() {
        let mut rng = Rng::new(103);
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![20, 15], 8).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let t_engine = TtFcEngine::new(&tt, &MachineSpec::spacemit_k1()).unwrap();
        let w2 = Tensor::randn(vec![10, 100], 0.2, &mut rng);
        let fc = DenseFc::new(&w2, None).unwrap();
        let mut model = ModelEngine::new(
            "toy",
            vec![LayerOp::Tt(t_engine), LayerOp::Relu, LayerOp::Dense(fc)],
            300,
            10,
        );
        let x = Tensor::randn(vec![5, 300], 1.0, &mut rng);
        let y = model.forward(&x).unwrap();
        assert_eq!(y.dims(), &[5, 10]);

        // reference: dense reconstruction + relu + dense
        let w1 = tt.reconstruct().unwrap();
        let mut h = fc_batched_ref(&w1, &x, None).unwrap();
        for v in h.data_mut() {
            *v = v.max(0.0);
        }
        let want = fc_batched_ref(&w2, &h, None).unwrap();
        assert!(y.allclose(&want, 1e-3, 1e-3));
    }
}
