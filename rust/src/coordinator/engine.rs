//! Executable model engines: the serving-time realization of DSE output.
//!
//! Split for the worker pool: everything expensive and immutable (packed
//! cores, dense weights, routing) lives behind an `Arc` and is **shared**
//! across workers; everything stateful (the [`Executor`]'s plan cache and
//! scratch buffers) is **per worker**, so the zero-allocation warm path
//! never crosses a lock. [`ModelEngine::worker_clone`] stamps out another
//! worker view over the same shared weights.

use std::sync::Arc;

use crate::baselines::dense::DenseFc;
use crate::error::{Error, Result};
use crate::kernels::{select_int8, Executor, PackedG, QuantizedG};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost::einsum_chain;
use crate::ttd::decompose::TtCores;

/// The resident core buffers of a compiled TT FC layer: the f32 packed
/// chain, or its int8-quantized shadow (same `G` layouts, ~4x fewer
/// bytes — [`crate::kernels::quantize`]).
enum CoreStore {
    /// F32 packed core per chain step, processing order (t = d-1 .. 0).
    F32(Vec<PackedG>),
    /// Int8 core + per-`m`-slice scales per chain step, same order.
    Int8(Vec<QuantizedG>),
}

/// The immutable, thread-shared half of a compiled TT FC layer: layout,
/// core buffers and bias. Workers share one instance behind an `Arc`;
/// each drives it with its own [`Executor`].
struct TtFcShared {
    layout: crate::ttd::TtLayout,
    cores: CoreStore,
    bias: Option<Vec<f32>>,
}

impl TtFcShared {
    /// Forward `x (B, N) -> (B, M)` through the optimized kernel chain,
    /// using the caller's executor for plans and scratch.
    fn forward_with(&self, executor: &mut Executor, x: &Tensor) -> Result<Tensor> {
        let n_total = self.layout.n_total() as usize;
        let m_total = self.layout.m_total() as usize;
        let dims = x.dims();
        if dims.len() != 2 || dims[1] != n_total || dims[0] == 0 {
            return Err(Error::shape(format!(
                "engine expects (B >= 1, {}), got {:?}",
                n_total, dims
            )));
        }
        let batch = dims[0];
        let final_slab = match &self.cores {
            CoreStore::F32(packed) => {
                executor.run_tt_chain(&self.layout, batch, packed, x.data())?
            }
            CoreStore::Int8(quant) => {
                executor.run_tt_chain_q(&self.layout, batch, quant, x.data())?
            }
        };
        // final layout (M, B) row-major -> (B, M)
        let mut y = Tensor::zeros(vec![batch, m_total]);
        {
            let yd = y.data_mut();
            for (mi, col) in final_slab.chunks_exact(batch).enumerate() {
                for (bi, &v) in col.iter().enumerate() {
                    yd[bi * m_total + mi] = v;
                }
            }
        }
        if let Some(bias) = &self.bias {
            for row in y.data_mut().chunks_mut(m_total) {
                for (v, &bv) in row.iter_mut().zip(bias) {
                    *v += bv;
                }
            }
        }
        Ok(y)
    }
}

/// A TT-decomposed FC layer compiled for serving: `Arc`-shared packed cores
/// plus a worker-local plan-driven [`Executor`] (plan cache + chain
/// scratch). Cloning a worker view ([`TtFcEngine::worker_clone`]) shares
/// the cores and copies the executor's plan cache into a fresh executor.
pub struct TtFcEngine {
    shared: Arc<TtFcShared>,
    executor: Executor,
}

impl TtFcEngine {
    /// Compile a decomposed layer for the target machine.
    ///
    /// Invariant: the cores are packed once with the batch-1 plans, which is
    /// sound because the vectorized-loop choice (and hence the packed `G`
    /// layout) depends only on `(r, n, k)`, never on the batch — pinned by
    /// the `packing_layout_is_batch_invariant` test below. A batch-dependent
    /// layout choice would surface as an `execute_plan_into` layout error at
    /// serving time. The same invariant makes worker executors safe: plans
    /// a worker compiles for shapes beyond the copied cache are produced
    /// deterministically and agree with the packed layout.
    pub fn new(tt: &TtCores, machine: &MachineSpec) -> Result<TtFcEngine> {
        let mut executor = Executor::new(machine);
        // plans at batch 1 determine the (batch-independent) packing layout
        let chain = einsum_chain(&tt.layout, 1);
        let mut packed = Vec::with_capacity(chain.len());
        for (step, dims) in chain.iter().enumerate() {
            let core_idx = tt.layout.d() - 1 - step; // processing order
            packed.push(executor.pack(&tt.cores[core_idx], dims)?);
        }
        Ok(TtFcEngine {
            shared: Arc::new(TtFcShared {
                layout: tt.layout.clone(),
                cores: CoreStore::F32(packed),
                bias: tt.bias.clone(),
            }),
            executor,
        })
    }

    /// Warm-start construction from artifact parts ([`crate::artifact`]):
    /// pre-packed cores and their compiled batch-1 plans, both in
    /// processing order (t = d-1 .. 0). No compiler invocation and no
    /// packing happens here — the executor's plan cache is pre-seeded with
    /// `plans`, so the first request runs straight on the warm path.
    ///
    /// The parts are validated against the layout's einsum chain (step
    /// count, per-step plan dims, per-step core dims, bias width); a
    /// mismatch is a typed [`Error::Artifact`]. Layout consistency between
    /// each packed buffer and its plan (e.g. Canonical data under a
    /// pack-requiring plan) is enforced at execution time by the kernel
    /// engine, exactly as for every other execution path.
    pub fn from_parts(
        layout: crate::ttd::TtLayout,
        packed: Vec<PackedG>,
        plans: &[crate::compiler::OptimizationPlan],
        bias: Option<Vec<f32>>,
        machine: &MachineSpec,
    ) -> Result<TtFcEngine> {
        let chain = einsum_chain(&layout, 1);
        if packed.len() != chain.len() || plans.len() != chain.len() {
            return Err(Error::artifact(format!(
                "TT layer {} needs {} chain steps, got {} cores / {} plans",
                layout.describe(),
                chain.len(),
                packed.len(),
                plans.len()
            )));
        }
        for (step, dims) in chain.iter().enumerate() {
            if plans[step].dims != *dims {
                return Err(Error::artifact(format!(
                    "step {step}: stored plan is for {:?}, chain expects {:?}",
                    plans[step].dims, dims
                )));
            }
            if packed[step].dims != (dims.r, dims.n, dims.m, dims.k) {
                return Err(Error::artifact(format!(
                    "step {step}: stored core dims {:?} do not match chain {:?}",
                    packed[step].dims, dims
                )));
            }
        }
        if let Some(b) = &bias {
            if b.len() != layout.m_total() as usize {
                return Err(Error::artifact(format!(
                    "bias length {} != layer width {}",
                    b.len(),
                    layout.m_total()
                )));
            }
        }
        let mut executor = Executor::new(machine);
        executor.preseed(plans)?;
        Ok(TtFcEngine {
            shared: Arc::new(TtFcShared { layout, cores: CoreStore::F32(packed), bias }),
            executor,
        })
    }

    /// [`TtFcEngine::from_parts`] for an int8-quantized layer: the chain's
    /// quantized cores (artifact QUANT section) replace the f32 packed
    /// cores as the resident buffers — ~4x fewer bytes — and the executor
    /// dispatches the int8 kernel family ([`select_int8`]: the best
    /// supported int8 microkernel, int8-portable under force-scalar).
    /// Same validation as the f32 path, plus one scale per `m` slice.
    pub fn from_quant_parts(
        layout: crate::ttd::TtLayout,
        quant: Vec<QuantizedG>,
        plans: &[crate::compiler::OptimizationPlan],
        bias: Option<Vec<f32>>,
        machine: &MachineSpec,
    ) -> Result<TtFcEngine> {
        let chain = einsum_chain(&layout, 1);
        if quant.len() != chain.len() || plans.len() != chain.len() {
            return Err(Error::artifact(format!(
                "TT layer {} needs {} chain steps, got {} quantized cores / {} plans",
                layout.describe(),
                chain.len(),
                quant.len(),
                plans.len()
            )));
        }
        for (step, dims) in chain.iter().enumerate() {
            if plans[step].dims != *dims {
                return Err(Error::artifact(format!(
                    "step {step}: stored plan is for {:?}, chain expects {:?}",
                    plans[step].dims, dims
                )));
            }
            if quant[step].dims != (dims.r, dims.n, dims.m, dims.k) {
                return Err(Error::artifact(format!(
                    "step {step}: quantized core dims {:?} do not match chain {:?}",
                    quant[step].dims, dims
                )));
            }
            if quant[step].scales.len() != dims.m {
                return Err(Error::artifact(format!(
                    "step {step}: quantized core has {} scales for m = {}",
                    quant[step].scales.len(),
                    dims.m
                )));
            }
        }
        if let Some(b) = &bias {
            if b.len() != layout.m_total() as usize {
                return Err(Error::artifact(format!(
                    "bias length {} != layer width {}",
                    b.len(),
                    layout.m_total()
                )));
            }
        }
        let mut executor = Executor::with_kernel(machine, select_int8())?;
        executor.preseed(plans)?;
        Ok(TtFcEngine {
            shared: Arc::new(TtFcShared { layout, cores: CoreStore::Int8(quant), bias }),
            executor,
        })
    }

    /// Enable measured register-blocking autotuning on plan-cache misses
    /// (EXPERIMENTS.md §Perf iteration 2). One-time cost per batch size.
    /// Worker clones inherit the tuning mode.
    pub fn with_tuning(mut self) -> Self {
        self.executor = self.executor.with_tuning();
        self
    }

    /// Another worker view of the same compiled layer: shared packed cores,
    /// own executor (plan cache copied so plans — tuned ones included —
    /// are not recompiled per worker; scratch cold; same tuning mode).
    pub fn worker_clone(&self) -> TtFcEngine {
        TtFcEngine {
            shared: Arc::clone(&self.shared),
            executor: self.executor.worker_clone(),
        }
    }

    /// The TT layout this layer was compiled from.
    pub fn layout(&self) -> &crate::ttd::TtLayout {
        &self.shared.layout
    }

    /// This worker's executor (plan cache + scratch) driving the layer.
    pub fn executor(&self) -> &Executor {
        &self.executor
    }

    /// Input width N.
    pub fn n_total(&self) -> usize {
        self.shared.layout.n_total() as usize
    }

    /// Output width M.
    pub fn m_total(&self) -> usize {
        self.shared.layout.m_total() as usize
    }

    /// Forward `x (B, N) -> (B, M)` through the optimized kernel chain.
    ///
    /// With single-threaded plans (the serving configuration measured in
    /// `rust/tests/alloc_free.rs`), per-request heap traffic is the response
    /// tensor only: plans are cached per shape and the chain ping-pongs
    /// inside the executor's scratch. Multi-threaded plans additionally
    /// allocate their fork/join scratch per request.
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        self.shared.forward_with(&mut self.executor, x)
    }
}

/// One step of a sequential model (construction-time description; the
/// engine converts it into shared weights + per-worker executor state).
pub enum LayerOp {
    /// A TT-compressed FC layer on the optimized kernel chain.
    Tt(TtFcEngine),
    /// A dense FC layer on the MMM baseline.
    Dense(DenseFc),
    /// Elementwise max(0, x).
    Relu,
}

/// The immutable, thread-shared half of a compiled model.
struct ModelShared {
    name: String,
    ops: Vec<SharedOp>,
    in_dim: usize,
    out_dim: usize,
}

/// Shared (read-only) form of one model step.
enum SharedOp {
    Tt(Arc<TtFcShared>),
    Dense(Arc<DenseFc>),
    Relu,
}

/// A sequential model engine (the LeNet300-style MLP in the examples).
///
/// One `ModelEngine` is one *worker view*: an `Arc` of the immutable
/// compiled model (weights, packed cores) plus this worker's executors
/// (plan caches + scratch, one per TT layer). [`ModelEngine::worker_clone`]
/// creates additional views for a pool; the shared half is never copied.
pub struct ModelEngine {
    shared: Arc<ModelShared>,
    /// Parallel to `shared.ops`: `Some(executor)` for TT ops, else `None`.
    execs: Vec<Option<Executor>>,
}

impl ModelEngine {
    /// Assemble a sequential model from compiled layers.
    pub fn new(name: impl Into<String>, ops: Vec<LayerOp>, in_dim: usize, out_dim: usize) -> Self {
        let mut shared_ops = Vec::with_capacity(ops.len());
        let mut execs = Vec::with_capacity(ops.len());
        for op in ops {
            match op {
                LayerOp::Tt(TtFcEngine { shared, executor }) => {
                    shared_ops.push(SharedOp::Tt(shared));
                    execs.push(Some(executor));
                }
                LayerOp::Dense(fc) => {
                    shared_ops.push(SharedOp::Dense(Arc::new(fc)));
                    execs.push(None);
                }
                LayerOp::Relu => {
                    shared_ops.push(SharedOp::Relu);
                    execs.push(None);
                }
            }
        }
        ModelEngine {
            shared: Arc::new(ModelShared { name: name.into(), ops: shared_ops, in_dim, out_dim }),
            execs,
        }
    }

    /// The model's display name.
    pub fn name(&self) -> &str {
        &self.shared.name
    }

    /// Input width.
    pub fn in_dim(&self) -> usize {
        self.shared.in_dim
    }

    /// Output width.
    pub fn out_dim(&self) -> usize {
        self.shared.out_dim
    }

    /// Another worker view over the same compiled model: the `Arc`-shared
    /// weights are reused, every TT layer gets its own [`Executor`] (same
    /// machine and tuning mode, plan cache copied, cold scratch). This is
    /// what [`super::Server`] calls once per extra worker.
    pub fn worker_clone(&self) -> ModelEngine {
        let execs = self
            .execs
            .iter()
            .map(|ex| ex.as_ref().map(Executor::worker_clone))
            .collect();
        ModelEngine { shared: Arc::clone(&self.shared), execs }
    }

    /// Approximate resident bytes of the `Arc`-shared compiled model
    /// (packed TT cores, dense weights, biases). Worker views and plan
    /// caches are excluded — this is the quantity the model registry's
    /// LRU budget accounts, and it matches
    /// [`crate::artifact::ModelBundle::engine_bytes`] for a bundle-built
    /// engine.
    pub fn approx_bytes(&self) -> u64 {
        self.shared
            .ops
            .iter()
            .map(|op| match op {
                SharedOp::Tt(tt) => {
                    let cores: usize = match &tt.cores {
                        CoreStore::F32(p) => p.iter().map(PackedG::bytes).sum(),
                        CoreStore::Int8(q) => q.iter().map(QuantizedG::bytes).sum(),
                    };
                    let bias = tt.bias.as_ref().map_or(0, |b| b.len() * 4);
                    (cores + bias) as u64
                }
                SharedOp::Dense(fc) => fc.weight_bytes(),
                SharedOp::Relu => 0,
            })
            .sum()
    }

    /// Name of the microkernel this worker's executors dispatch to
    /// (`"portable"` when the model has no TT layers — dense/ReLU ops
    /// never touch the microkernel layer). All executors in one engine
    /// share one construction-time selection, so the first is
    /// representative.
    pub fn kernel_name(&self) -> &'static str {
        self.execs
            .iter()
            .flatten()
            .map(Executor::kernel_name)
            .next()
            .unwrap_or(crate::kernels::PORTABLE_KERNEL_NAME)
    }

    /// Forward a batch `(B, in_dim) -> (B, out_dim)`.
    pub fn forward(&mut self, x: &Tensor) -> Result<Tensor> {
        let mut cur = x.clone();
        for (op, ex) in self.shared.ops.iter().zip(self.execs.iter_mut()) {
            cur = match op {
                SharedOp::Tt(tt) => {
                    let executor = ex.as_mut().expect("TT op carries an executor");
                    tt.forward_with(executor, &cur)?
                }
                SharedOp::Dense(fc) => fc.forward(&cur)?,
                SharedOp::Relu => {
                    let mut t = cur;
                    for v in t.data_mut() {
                        *v = v.max(0.0);
                    }
                    t
                }
            };
        }
        if cur.dims()[1] != self.shared.out_dim {
            return Err(Error::shape("model produced wrong output width"));
        }
        Ok(cur)
    }
}

// The pool moves worker views across threads and shares the compiled model
// between them; pin those bounds at compile time so a non-Send field can
// never sneak into the hot path.
#[allow(dead_code)]
fn assert_thread_safety() {
    fn is_send<T: Send>() {}
    fn is_send_sync<T: Send + Sync>() {}
    is_send::<ModelEngine>();
    is_send::<TtFcEngine>();
    is_send::<Executor>();
    is_send_sync::<ModelShared>();
    is_send_sync::<TtFcShared>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::fc_batched_ref;
    use crate::ttd::decompose::random_cores;
    use crate::ttd::TtLayout;
    use crate::util::prng::Rng;

    fn engine_and_truth() -> (TtFcEngine, Tensor, Option<Vec<f32>>) {
        let mut rng = Rng::new(100);
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut tt = random_cores(&layout, &mut rng);
        tt.bias = Some((0..300).map(|i| (i % 7) as f32 * 0.1).collect());
        let w = tt.reconstruct().unwrap();
        let bias = tt.bias.clone();
        let engine = TtFcEngine::new(&tt, &MachineSpec::spacemit_k1()).unwrap();
        (engine, w, bias)
    }

    #[test]
    fn engine_matches_dense_reconstruction() {
        let (mut engine, w, bias) = engine_and_truth();
        let mut rng = Rng::new(101);
        for batch in [1usize, 3, 16] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let got = engine.forward(&x).unwrap();
            let want = fc_batched_ref(&w, &x, bias.as_deref()).unwrap();
            assert!(
                got.allclose(&want, 1e-3, 1e-3),
                "batch {batch}: {}",
                got.max_abs_diff(&want).unwrap()
            );
        }
    }

    #[test]
    fn executor_plan_cache_reuses_batches() {
        let (mut engine, _, _) = engine_and_truth();
        let mut rng = Rng::new(102);
        // d = 2 chain: 2 plans per distinct batch size, cached in the
        // executor (construction already planned batch 1)
        let base = engine.executor().cached_plans();
        assert_eq!(base, 2);
        let x = Tensor::randn(vec![4, 784], 1.0, &mut rng);
        engine.forward(&x).unwrap();
        engine.forward(&x).unwrap();
        assert_eq!(engine.executor().cached_plans(), base + 2);
        let x2 = Tensor::randn(vec![8, 784], 1.0, &mut rng);
        engine.forward(&x2).unwrap();
        assert_eq!(engine.executor().cached_plans(), base + 4);
    }

    #[test]
    fn worker_clone_shares_cores_and_matches_bitwise() {
        let (mut engine, _, _) = engine_and_truth();
        let mut clone = engine.worker_clone();
        // own executor, but the already-compiled plans came along
        assert_eq!(clone.executor().cached_plans(), engine.executor().cached_plans());
        let mut rng = Rng::new(104);
        for batch in [1usize, 5, 16] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let a = engine.forward(&x).unwrap();
            let b = clone.forward(&x).unwrap();
            // same packed cores + deterministic plans => bit-identical
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "worker clone drifted");
            }
        }
    }

    #[test]
    fn packing_layout_is_batch_invariant() {
        // the engine packs cores once with batch-1 plans; the compiler's
        // vectorized-loop (and thus layout) choice must not depend on batch
        use crate::compiler::compile;
        let machine = MachineSpec::spacemit_k1();
        for layout in [
            TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap(),
            TtLayout::with_uniform_rank(vec![10, 10, 3], vec![4, 8, 16], 8).unwrap(),
        ] {
            let base = einsum_chain(&layout, 1);
            for batch in [2usize, 7, 64, 1024] {
                for (step, dims) in einsum_chain(&layout, batch).iter().enumerate() {
                    let p = compile(dims, &machine).unwrap();
                    let p1 = compile(&base[step], &machine).unwrap();
                    assert_eq!(
                        p.vector_loop, p1.vector_loop,
                        "batch {batch} step {step}: layout choice drifted"
                    );
                    assert_eq!(p.pack_g, p1.pack_g, "batch {batch} step {step}");
                }
            }
        }
    }

    #[test]
    fn from_parts_matches_new_bitwise_and_validates() {
        let mut rng = Rng::new(105);
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut tt = random_cores(&layout, &mut rng);
        tt.bias = Some(vec![0.25; 300]);
        let machine = MachineSpec::spacemit_k1();
        let mut engine = TtFcEngine::new(&tt, &machine).unwrap();
        // rebuild the parts exactly as a bundle stores them
        let mut ex = Executor::new(&machine);
        let chain = einsum_chain(&layout, 1);
        let mut plans = Vec::new();
        let mut packed = Vec::new();
        for (step, dims) in chain.iter().enumerate() {
            let plan = ex.plan(dims).unwrap();
            packed.push(crate::kernels::pack(&tt.cores[layout.d() - 1 - step], &plan).unwrap());
            plans.push(plan);
        }
        let mut warm =
            TtFcEngine::from_parts(layout.clone(), packed.clone(), &plans, tt.bias.clone(), &machine)
                .unwrap();
        // plan cache pre-seeded: no compile needed for the batch-1 chain
        assert_eq!(warm.executor().cached_plans(), 2);
        for batch in [1usize, 4] {
            let x = Tensor::randn(vec![batch, 784], 1.0, &mut rng);
            let a = engine.forward(&x).unwrap();
            let b = warm.forward(&x).unwrap();
            for (va, vb) in a.data().iter().zip(b.data()) {
                assert_eq!(va.to_bits(), vb.to_bits(), "warm-start engine drifted");
            }
        }
        // validation: wrong counts / bias width are typed artifact errors
        let err = TtFcEngine::from_parts(
            layout.clone(),
            packed[..1].to_vec(),
            &plans,
            None,
            &machine,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        let err =
            TtFcEngine::from_parts(layout, packed, &plans, Some(vec![0.0; 10]), &machine)
                .unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
    }

    #[test]
    fn from_quant_parts_tracks_f32_and_shrinks_resident_bytes() {
        let mut rng = Rng::new(106);
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut tt = random_cores(&layout, &mut rng);
        tt.bias = Some(vec![0.1; 300]);
        let machine = MachineSpec::spacemit_k1();
        let mut ex = Executor::new(&machine);
        let chain = einsum_chain(&layout, 1);
        let mut plans = Vec::new();
        let mut packed = Vec::new();
        for (step, dims) in chain.iter().enumerate() {
            let plan = ex.plan(dims).unwrap();
            packed.push(crate::kernels::pack(&tt.cores[layout.d() - 1 - step], &plan).unwrap());
            plans.push(plan);
        }
        let quant: Vec<_> = packed.iter().map(crate::kernels::quantize).collect();
        // a truncated scale vector is a typed artifact error up front
        let mut broken = quant.clone();
        broken[0].scales.pop();
        let err = TtFcEngine::from_quant_parts(
            layout.clone(),
            broken,
            &plans,
            None,
            &machine,
        )
        .unwrap_err();
        assert!(matches!(err, Error::Artifact(_)), "{err}");
        let mut f32_engine =
            TtFcEngine::from_parts(layout.clone(), packed, &plans, tt.bias.clone(), &machine)
                .unwrap();
        let mut q_engine =
            TtFcEngine::from_quant_parts(layout, quant, &plans, tt.bias.clone(), &machine)
                .unwrap();
        let x = Tensor::randn(vec![3, 784], 1.0, &mut rng);
        let a = f32_engine.forward(&x).unwrap();
        let b = q_engine.forward(&x).unwrap();
        // per-slice int8 quantization keeps the chain within a few percent
        // of the f32 output scale
        let scale = a.data().iter().fold(0f32, |acc, v| acc.max(v.abs()));
        for (va, vb) in a.data().iter().zip(b.data()) {
            assert!((va - vb).abs() <= 0.05 * scale, "{va} vs {vb} (scale {scale})");
        }
        // worker clones of the int8 engine stay bitwise with their parent
        let mut worker = q_engine.worker_clone();
        let bw = worker.forward(&x).unwrap();
        for (vb, vw) in b.data().iter().zip(bw.data()) {
            assert_eq!(vb.to_bits(), vw.to_bits());
        }
        // resident bytes shrink ~4x (per-slice scales are the only overhead)
        let f_bytes =
            ModelEngine::new("f", vec![LayerOp::Tt(f32_engine)], 784, 300).approx_bytes();
        let q_bytes =
            ModelEngine::new("q", vec![LayerOp::Tt(q_engine)], 784, 300).approx_bytes();
        assert!(
            f_bytes as f64 / q_bytes as f64 >= 3.5,
            "int8 engine must be >= 3.5x smaller: {f_bytes} vs {q_bytes}"
        );
    }

    #[test]
    fn rejects_wrong_width() {
        let (mut engine, _, _) = engine_and_truth();
        let x = Tensor::zeros(vec![2, 100]);
        assert!(engine.forward(&x).is_err());
    }

    #[test]
    fn model_engine_composes_layers() {
        let mut rng = Rng::new(103);
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![20, 15], 8).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let t_engine = TtFcEngine::new(&tt, &MachineSpec::spacemit_k1()).unwrap();
        let w2 = Tensor::randn(vec![10, 100], 0.2, &mut rng);
        let fc = DenseFc::new(&w2, None).unwrap();
        let mut model = ModelEngine::new(
            "toy",
            vec![LayerOp::Tt(t_engine), LayerOp::Relu, LayerOp::Dense(fc)],
            300,
            10,
        );
        let x = Tensor::randn(vec![5, 300], 1.0, &mut rng);
        let y = model.forward(&x).unwrap();
        assert_eq!(y.dims(), &[5, 10]);

        // reference: dense reconstruction + relu + dense
        let w1 = tt.reconstruct().unwrap();
        let mut h = fc_batched_ref(&w1, &x, None).unwrap();
        for v in h.data_mut() {
            *v = v.max(0.0);
        }
        let want = fc_batched_ref(&w2, &h, None).unwrap();
        assert!(y.allclose(&want, 1e-3, 1e-3));

        // a worker view over the same model produces bit-identical output
        let mut worker = model.worker_clone();
        let yw = worker.forward(&x).unwrap();
        for (a, b) in y.data().iter().zip(yw.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(worker.name(), "toy");
    }
}
