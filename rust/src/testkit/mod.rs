//! Mini property-based testing kit (proptest is unavailable offline).
//!
//! Properties draw random inputs from a [`Draw`] source and return
//! `Err(message)` on violation. The runner replays many seeded cases; on
//! failure it attempts *shrinking* by re-running the same seed with the
//! draw ranges progressively biased toward their minimum, and reports the
//! smallest failing case it found together with the reproducing seed.
//!
//! ```
//! use ttrv::testkit::{check, Draw};
//! check("addition commutes", 64, |d: &mut Draw| {
//!     let a = d.usize_in(0, 1000);
//!     let b = d.usize_in(0, 1000);
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```

use crate::util::prng::Rng;

/// A draw source handed to properties: seeded PRNG + shrink bias.
pub struct Draw {
    rng: Rng,
    /// 0.0 = no bias; 1.0 = always draw the range minimum.
    shrink: f64,
    /// Trace of draws for failure reports.
    trace: Vec<String>,
}

impl Draw {
    fn new(seed: u64, shrink: f64) -> Self {
        Draw { rng: Rng::new(seed), shrink, trace: Vec::new() }
    }

    /// Uniform usize in `[lo, hi]` (inclusive), biased toward `lo` when
    /// shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let raw = self.rng.gen_range(lo, hi + 1);
        let v = lo + (((raw - lo) as f64) * (1.0 - self.shrink)) as usize;
        self.trace.push(format!("{v}"));
        v
    }

    /// Uniform choice from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        let idx = if self.shrink >= 1.0 {
            0
        } else {
            self.rng.gen_range(0, xs.len())
        };
        self.trace.push(format!("#{idx}"));
        &xs[idx]
    }

    /// Uniform f64 in [lo, hi), shrinking toward lo.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo) * (1.0 - self.shrink);
        self.trace.push(format!("{v:.4}"));
        v
    }

    /// Standard-normal f32 vector of the given length.
    pub fn normal_vec(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        self.trace.push(format!("vec[{len}]"));
        self.rng.normal_vec(len, sigma)
    }

    /// Bernoulli draw.
    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1 && self.shrink < 1.0;
        self.trace.push(format!("{v}"));
        v
    }

    /// Access the underlying PRNG for custom draws.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Outcome of a failed property, with the shrunk witness.
#[derive(Debug)]
pub struct Failure {
    /// Property name.
    pub name: String,
    /// PRNG seed reproducing the failure.
    pub seed: u64,
    /// Case index within the run.
    pub case: usize,
    /// Shrink scale that still fails (1.0 = unshrunk).
    pub shrink: f64,
    /// The property's failure message.
    pub message: String,
    /// Draws recorded while generating the witness.
    pub trace: Vec<String>,
}

/// Run `cases` random cases of `prop`; panic with a reproducible report on
/// the first failure (after shrink attempts).
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Draw) -> Result<(), String>,
{
    if let Some(fail) = check_quiet(name, cases, prop) {
        panic!(
            "property '{}' failed (seed={}, case={}, shrink={}):\n  {}\n  draws: [{}]",
            fail.name,
            fail.seed,
            fail.case,
            fail.shrink,
            fail.message,
            fail.trace.join(", ")
        );
    }
}

/// Like [`check`] but returns the failure instead of panicking (used to test
/// the kit itself).
pub fn check_quiet<F>(name: &str, cases: usize, prop: F) -> Option<Failure>
where
    F: Fn(&mut Draw) -> Result<(), String>,
{
    // Base seed differs per property name so properties don't see identical
    // streams, but stays fixed across runs for reproducibility.
    let base = name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    });
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut d = Draw::new(seed, 0.0);
        if let Err(message) = prop(&mut d) {
            // try to shrink: same seed, increasing bias toward minimal draws
            let mut best = Failure {
                name: name.to_string(),
                seed,
                case,
                shrink: 0.0,
                message,
                trace: d.trace,
            };
            for &s in &[1.0, 0.9, 0.75, 0.5, 0.25] {
                let mut ds = Draw::new(seed, s);
                if let Err(msg) = prop(&mut ds) {
                    best.shrink = s;
                    best.message = msg;
                    best.trace = ds.trace;
                    break; // largest bias that still fails = smallest case
                }
            }
            return Some(best);
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_returns_none() {
        assert!(check_quiet("tautology", 50, |_| Ok(())).is_none());
    }

    #[test]
    fn failing_property_reports_seed_and_shrinks() {
        let fail = check_quiet("always-fails-on-big", 50, |d| {
            let v = d.usize_in(0, 100);
            if v >= 0 { Err(format!("v={v}")) } else { Ok(()) }
        })
        .expect("must fail");
        assert_eq!(fail.case, 0);
        // shrunk witness should be the minimal draw
        assert!(fail.shrink > 0.0);
        assert!(fail.message.contains("v=0"));
    }

    #[test]
    fn draws_are_deterministic_per_seed() {
        let mut a = Draw::new(99, 0.0);
        let mut b = Draw::new(99, 0.0);
        for _ in 0..20 {
            assert_eq!(a.usize_in(0, 1 << 20), b.usize_in(0, 1 << 20));
        }
    }

    #[test]
    #[should_panic(expected = "property 'boom' failed")]
    fn check_panics_with_report() {
        check("boom", 5, |_| Err("nope".into()));
    }
}
