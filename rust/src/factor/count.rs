//! Design-space size counting (paper Tables 1-2, "Number of solutions").
//!
//! Exact enumeration is infeasible (up to ~1e33 solutions), so sizes are
//! *counted*, never materialized. The counting model (documented in
//! EXPERIMENTS.md; the paper does not spell out its own) is:
//!
//! * a solution = (ordered m-shape, ordered n-shape, rank list), with shapes
//!   of equal length `d in 2..=d_max` and per-boundary ranks
//!   `r_t in 1..=min(max_rank_at(t), rank_cap)`;
//! * "All initial solutions" sums over all shape *permutations*;
//! * "Alignment strategy" sums over aligned shape pairs only (one multiset
//!   pair stands for `prop4_permutations` raw pairs, per Prop. 4);
//! * the vectorization constraint restricts each rank to multiples of `vl`.
//!
//! Counts are f64 (log-domain magnitudes like the paper's tables, which
//! report 2 significant digits); u128 exactness is impossible at 1e33 scale
//! with per-boundary rank products anyway.

use super::partitions::{factor_multisets, omega};
use super::{max_rank_at, prop4_permutations};

/// Counting-model parameters.
#[derive(Debug, Clone, Copy)]
pub struct CountCfg {
    /// Cap on any TT-rank (paper sweeps ranks up to 3064).
    pub rank_cap: u64,
    /// Vector length for the vectorization constraint (ranks must be
    /// multiples of `vl`).
    pub vl: u64,
    /// Maximum configuration length to explore.
    pub d_max: usize,
}

impl Default for CountCfg {
    fn default() -> Self {
        CountCfg { rank_cap: 3064, vl: 8, d_max: 6 }
    }
}

/// Number of rank lists for an aligned shape pair: product over boundaries
/// of the admissible rank count.
fn rank_list_count(m: &[u64], n: &[u64], cfg: &CountCfg, multiples_of_vl: bool) -> f64 {
    let d = m.len();
    let mut total = 1.0f64;
    for t in 1..d {
        let cap = max_rank_at(m, n, t).min(cfg.rank_cap);
        let choices = if multiples_of_vl {
            cap / cfg.vl // ranks vl, 2vl, ..., floor(cap/vl)*vl
        } else {
            cap
        };
        if choices == 0 {
            return 0.0;
        }
        total *= choices as f64;
    }
    total
}

/// Stage-by-stage design-space sizes for one FC layer `(M = out, N = in)`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SpaceSizes {
    /// All (permuted shapes x rank lists).
    pub all: f64,
    /// After keeping only aligned shape pairs.
    pub aligned: f64,
    /// After additionally constraining ranks to multiples of vl.
    pub vectorized: f64,
}

/// Count the design space for FC layer with `M` outputs, `N` inputs.
pub fn space_sizes(m_dim: u64, n_dim: u64, cfg: &CountCfg) -> SpaceSizes {
    let d_max = cfg.d_max.min(omega(m_dim)).min(omega(n_dim)).max(2);
    let mut sizes = SpaceSizes::default();
    for d in 2..=d_max {
        let m_sets = factor_multisets(m_dim, d);
        let n_sets = factor_multisets(n_dim, d);
        if m_sets.is_empty() || n_sets.is_empty() {
            continue;
        }
        for ms in &m_sets {
            // aligned m-shape is the descending ordering of the multiset
            let mut m_aligned = ms.clone();
            m_aligned.reverse();
            for ns in &n_sets {
                let n_aligned = ns.clone(); // multisets are ascending already
                let pair_perms = prop4_permutations(&m_aligned, &n_aligned) as f64;
                // rank bounds are permutation-dependent in general; the
                // aligned bound is used as the representative (the bound
                // depends only weakly on ordering: products telescope).
                let ranks_all = rank_list_count(&m_aligned, &n_aligned, cfg, false);
                let ranks_vec = rank_list_count(&m_aligned, &n_aligned, cfg, true);
                sizes.all += pair_perms * ranks_all;
                sizes.aligned += ranks_all;
                sizes.vectorized += ranks_vec;
            }
        }
    }
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_reduction_is_prop4_for_single_pair() {
        // M = 25 = 5*5, N = 6 = 2*3 (single d=2 multiset each)
        let cfg = CountCfg { rank_cap: 1_000_000, vl: 8, d_max: 2 };
        let s = space_sizes(25, 6, &cfg);
        // m perms = 1 (5,5 identical), n perms = 2 -> all = 2 * aligned
        assert!((s.all / s.aligned - 2.0).abs() < 1e-9);
    }

    #[test]
    fn vectorization_prunes_by_about_vl_per_boundary() {
        let cfg = CountCfg::default();
        let s = space_sizes(4096, 2048, &cfg);
        assert!(s.vectorized > 0.0);
        assert!(s.aligned / s.vectorized >= cfg.vl as f64 * 0.5);
        assert!(s.all > s.aligned);
    }

    #[test]
    fn monotone_in_layer_size() {
        let cfg = CountCfg::default();
        let small = space_sizes(120, 84, &cfg);
        let big = space_sizes(4096, 4096, &cfg);
        assert!(big.all > small.all);
    }

    #[test]
    fn paper_order_of_magnitude_sanity() {
        // Table 1 reports [400, 120] (N=400 in, M=120 out) at ~9.5E+08 raw.
        // Our counting model must land within a few orders of magnitude and
        // preserve the qualitative reduction chain all > aligned > vectorized.
        let cfg = CountCfg::default();
        let s = space_sizes(120, 400, &cfg);
        assert!(s.all > 1e6 && s.all < 1e12, "all = {:e}", s.all);
        assert!(s.aligned < s.all);
        assert!(s.vectorized < s.aligned);
    }

    #[test]
    fn prime_dims_have_empty_space() {
        let cfg = CountCfg::default();
        let s = space_sizes(13, 7, &cfg);
        assert_eq!(s.all, 0.0);
        assert_eq!(s.vectorized, 0.0);
    }

    #[test]
    fn rank_cap_reduces_counts() {
        let loose = CountCfg { rank_cap: 3064, vl: 8, d_max: 4 };
        let tight = CountCfg { rank_cap: 8, vl: 8, d_max: 4 };
        let a = space_sizes(512, 512, &loose);
        let b = space_sizes(512, 512, &tight);
        assert!(b.all < a.all);
        assert!(b.vectorized <= a.vectorized);
    }
}
