//! Multiset permutations: exact counts and enumeration.

/// Exact number of distinct permutations of a multiset: `d! / prod(k_i!)`.
pub fn permutation_count(multiset: &[u64]) -> u128 {
    let d = multiset.len();
    let mut numer: u128 = 1;
    for i in 1..=d {
        numer *= i as u128;
    }
    let mut sorted = multiset.to_vec();
    sorted.sort_unstable();
    let mut denom: u128 = 1;
    let mut run = 1u128;
    for w in sorted.windows(2) {
        if w[0] == w[1] {
            run += 1;
            denom *= run;
        } else {
            run = 1;
        }
    }
    numer / denom
}

/// All distinct permutations of a multiset, in lexicographic order.
/// Uses the classic next-permutation algorithm, so duplicates collapse.
pub fn multiset_permutations(multiset: &[u64]) -> Vec<Vec<u64>> {
    let mut cur = multiset.to_vec();
    cur.sort_unstable();
    let mut out = Vec::new();
    loop {
        out.push(cur.clone());
        if !next_permutation(&mut cur) {
            break;
        }
    }
    out
}

/// In-place lexicographic next permutation; false when `xs` was the last.
fn next_permutation(xs: &mut [u64]) -> bool {
    if xs.len() < 2 {
        return false;
    }
    // find longest non-increasing suffix
    let mut i = xs.len() - 1;
    while i > 0 && xs[i - 1] >= xs[i] {
        i -= 1;
    }
    if i == 0 {
        return false;
    }
    // pivot is xs[i-1]; find rightmost element greater than pivot
    let mut j = xs.len() - 1;
    while xs[j] <= xs[i - 1] {
        j -= 1;
    }
    xs.swap(i - 1, j);
    xs[i..].reverse();
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn count_distinct_elements() {
        assert_eq!(permutation_count(&[1, 2, 3]), 6);
        assert_eq!(permutation_count(&[2, 2]), 1);
        assert_eq!(permutation_count(&[5, 5, 3, 2, 2]), 30); // 5!/(2!2!)
        assert_eq!(permutation_count(&[2, 2, 2, 7, 14]), 20); // 5!/3!
        assert_eq!(permutation_count(&[]), 1);
    }

    #[test]
    fn enumeration_matches_count_and_is_unique() {
        for ms in [vec![2u64, 2, 3], vec![5, 5, 3, 2, 2], vec![4, 4, 4]] {
            let perms = multiset_permutations(&ms);
            assert_eq!(perms.len() as u128, permutation_count(&ms));
            let set: HashSet<Vec<u64>> = perms.iter().cloned().collect();
            assert_eq!(set.len(), perms.len(), "duplicates for {ms:?}");
            for p in &perms {
                let mut s = p.clone();
                s.sort_unstable();
                let mut orig = ms.clone();
                orig.sort_unstable();
                assert_eq!(s, orig);
            }
        }
    }

    #[test]
    fn lexicographic_order() {
        let perms = multiset_permutations(&[3, 1, 2]);
        assert_eq!(perms[0], vec![1, 2, 3]);
        assert_eq!(perms.last().unwrap(), &vec![3, 2, 1]);
        for w in perms.windows(2) {
            assert!(w[0] < w[1]);
        }
    }
}
