//! Multiplicative partitions (factorizations into ordered multisets).

/// All divisors of `x`, ascending.
pub fn divisors(x: u64) -> Vec<u64> {
    assert!(x >= 1);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut i = 1u64;
    while i * i <= x {
        if x % i == 0 {
            small.push(i);
            if i != x / i {
                large.push(x / i);
            }
        }
        i += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// All multisets of exactly `d` factors `>= 2` with product `x`, each
/// returned in non-decreasing order. Empty when impossible.
pub fn factor_multisets(x: u64, d: usize) -> Vec<Vec<u64>> {
    let mut out = Vec::new();
    let mut cur = Vec::with_capacity(d);
    rec(x, d, 2, &mut cur, &mut out);
    out
}

fn rec(x: u64, d: usize, min_factor: u64, cur: &mut Vec<u64>, out: &mut Vec<Vec<u64>>) {
    if d == 1 {
        if x >= min_factor {
            cur.push(x);
            out.push(cur.clone());
            cur.pop();
        }
        return;
    }
    // factor f must satisfy f^d <= x (non-decreasing order)
    let mut f = min_factor;
    while f.saturating_pow(d as u32) <= x {
        if x % f == 0 {
            cur.push(f);
            rec(x / f, d - 1, f, cur, out);
            cur.pop();
        }
        f += 1;
    }
}

/// Multisets for every length `2..=d_max` (the paper explores lengths up to
/// the number of prime factors; longer is impossible).
pub fn factor_multisets_all(x: u64, d_max: usize) -> Vec<(usize, Vec<Vec<u64>>)> {
    (2..=d_max)
        .map(|d| (d, factor_multisets(x, d)))
        .filter(|(_, v)| !v.is_empty())
        .collect()
}

/// Number of prime factors with multiplicity (upper bound on `d`).
pub fn omega(x: u64) -> usize {
    let mut x = x;
    let mut count = 0;
    let mut p = 2u64;
    while p * p <= x {
        while x % p == 0 {
            x /= p;
            count += 1;
        }
        p += 1;
    }
    if x > 1 {
        count += 1;
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(13), vec![1, 13]);
    }

    #[test]
    fn multisets_of_12() {
        assert_eq!(factor_multisets(12, 2), vec![vec![2, 6], vec![3, 4]]);
        assert_eq!(factor_multisets(12, 3), vec![vec![2, 2, 3]]);
        assert!(factor_multisets(12, 4).is_empty());
    }

    #[test]
    fn multisets_products_and_order() {
        for d in 2..=5 {
            for ms in factor_multisets(720, d) {
                assert_eq!(ms.iter().product::<u64>(), 720);
                assert!(ms.windows(2).all(|w| w[0] <= w[1]));
                assert!(ms.iter().all(|&f| f >= 2));
                assert_eq!(ms.len(), d);
            }
        }
    }

    #[test]
    fn multisets_of_primes_are_singular() {
        assert!(factor_multisets(13, 2).is_empty());
        assert_eq!(factor_multisets(4, 2), vec![vec![2, 2]]);
    }

    #[test]
    fn paper_running_example_shapes_present() {
        // 300 = 5*5*3*2*2 and 784 = 2*2*2*7*14 are valid d=5 multisets
        let m300 = factor_multisets(300, 5);
        assert!(m300.contains(&vec![2, 2, 3, 5, 5]));
        let n784 = factor_multisets(784, 5);
        assert!(n784.contains(&vec![2, 2, 2, 7, 14]));
    }

    #[test]
    fn omega_counts_prime_multiplicity() {
        assert_eq!(omega(12), 3); // 2*2*3
        assert_eq!(omega(784), 6); // 2^4 * 7^2
        assert_eq!(omega(13), 1);
        // no d=7 multiset of 784 can exist
        assert!(factor_multisets(784, 7).is_empty());
        assert_eq!(factor_multisets(784, 6).len(), 1); // [2,2,2,2,7,7]
    }

    #[test]
    fn all_lengths_enumeration() {
        let all = factor_multisets_all(64, 6);
        let lens: Vec<usize> = all.iter().map(|(d, _)| *d).collect();
        assert_eq!(lens, vec![2, 3, 4, 5, 6]); // 64 = 2^6
    }
}
