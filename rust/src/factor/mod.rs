//! Integer factorization combinatorics: the raw material of the TTD design
//! space (paper §4.1).
//!
//! A *combination shape* for dimension `X` and configuration length `d` is a
//! list of factors `[x_1..x_d]`, each `>= 2`, with product `X`. The design
//! space couples one shape for `M`, one for `N`, and a rank list. This module
//! enumerates shapes (as multisets and as permutations), counts permutations
//! exactly (Prop. 4), and provides the aligned ordering (Def. 1).

pub mod partitions;
mod perms;
pub mod count;

pub use partitions::{divisors, factor_multisets, factor_multisets_all};
pub use perms::{multiset_permutations, permutation_count};

/// Aligned output shape per Definition 1: `m_1 >= m_2 >= ... >= m_d`.
pub fn align_m(mut factors: Vec<u64>) -> Vec<u64> {
    factors.sort_unstable_by(|a, b| b.cmp(a));
    factors
}

/// Aligned input shape per Definition 1: `n_1 <= n_2 <= ... <= n_d`.
pub fn align_n(mut factors: Vec<u64>) -> Vec<u64> {
    factors.sort_unstable();
    factors
}

/// Is the (m, n) shape pair aligned per Definition 1?
pub fn is_aligned(m_shape: &[u64], n_shape: &[u64]) -> bool {
    m_shape.windows(2).all(|w| w[0] >= w[1])
        && n_shape.windows(2).all(|w| w[0] <= w[1])
}

/// Prop. 4: the number of (m, n) permutation pairs an aligned pair stands
/// for: `(d!)^2 / (k_1! k_2! ... k_j!)` with per-list multiplicities.
pub fn prop4_permutations(m_shape: &[u64], n_shape: &[u64]) -> u128 {
    permutation_count(m_shape) * permutation_count(n_shape)
}

/// Maximum admissible TT-rank at boundary `t` (between core t and t+1,
/// 1-based, `t in 1..d`): the rank of any TT unfolding is bounded by the
/// smaller of the two unfolding dimensions,
/// `r_t <= min(prod_{u<=t} m_u n_u, prod_{u>t} m_u n_u)`.
pub fn max_rank_at(m_shape: &[u64], n_shape: &[u64], t: usize) -> u64 {
    debug_assert!(t >= 1 && t < m_shape.len());
    let left: u128 = m_shape[..t]
        .iter()
        .zip(&n_shape[..t])
        .map(|(&m, &n)| m as u128 * n as u128)
        .product();
    let right: u128 = m_shape[t..]
        .iter()
        .zip(&n_shape[t..])
        .map(|(&m, &n)| m as u128 * n as u128)
        .product();
    left.min(right).min(u64::MAX as u128) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_orders() {
        assert_eq!(align_m(vec![2, 5, 3, 5, 2]), vec![5, 5, 3, 2, 2]);
        assert_eq!(align_n(vec![14, 2, 7, 2, 2]), vec![2, 2, 2, 7, 14]);
        assert!(is_aligned(&[5, 5, 3, 2, 2], &[2, 2, 2, 7, 14]));
        assert!(!is_aligned(&[5, 3, 5], &[2, 2, 2]));
        assert!(!is_aligned(&[5, 5, 5], &[2, 7, 2]));
    }

    #[test]
    fn prop4_paper_example() {
        // paper: m = [5,5,3,2,2], n = [2,2,2,7,14] -> (5!)^2/(2!2!3!) = 600
        let m = [5u64, 5, 3, 2, 2];
        let n = [2u64, 2, 2, 7, 14];
        assert_eq!(prop4_permutations(&m, &n), 600);
    }

    #[test]
    fn prop4_all_distinct_is_d_factorial_squared() {
        let m = [7u64, 5, 3, 2];
        let n = [11u64, 13, 17, 19];
        assert_eq!(prop4_permutations(&m, &n), (24 * 24) as u128);
    }

    #[test]
    fn max_rank_bounds() {
        // m=[4,4], n=[4,4]: boundary rank <= min(16, 16) = 16
        assert_eq!(max_rank_at(&[4, 4], &[4, 4], 1), 16);
        // strongly lopsided: min side governs
        assert_eq!(max_rank_at(&[2, 100], &[2, 100], 1), 4);
    }
}
