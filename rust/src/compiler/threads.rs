//! Thread-count heuristic (paper §4.2.3, calibrated by the Fig. 9 study).
//!
//! "For the Einsum loop kernels with FLOPs value lower than 2e6,
//! single-thread execution is optimal; between 2e6 and 4e6 two threads;
//! between 4e6 and 8e6 three; above 8e6 four."

use crate::machine::MachineSpec;
use crate::ttd::cost::EinsumDims;

/// FLOPs threshold of the paper's measured study: above this, two threads.
pub const T2: u64 = 2_000_000;
/// Above this many FLOPs: three threads.
pub const T3: u64 = 4_000_000;
/// Above this many FLOPs: four threads.
pub const T4: u64 = 8_000_000;

/// Threads to assign to one Einsum kernel, capped by the machine's cores.
pub fn threads_for(dims: &EinsumDims, machine: &MachineSpec) -> u32 {
    let f = dims.flops();
    let ideal: u32 = if f < T2 {
        1
    } else if f < T3 {
        2
    } else if f < T4 {
        3
    } else {
        4
    };
    ideal.min(machine.cores)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost::EinsumKind;

    fn dims_with_flops(target: u64) -> EinsumDims {
        // flops = 2*m*b*n*r*k; pick m to hit the target
        let m = (target / (2 * 64 * 8)).max(1) as usize;
        EinsumDims { kind: EinsumKind::Middle, m, b: 64, n: 1, r: 8, k: 1 }
    }

    #[test]
    fn paper_thresholds() {
        let k1 = MachineSpec::spacemit_k1();
        assert_eq!(threads_for(&dims_with_flops(1_000_000), &k1), 1);
        assert_eq!(threads_for(&dims_with_flops(3_000_000), &k1), 2);
        assert_eq!(threads_for(&dims_with_flops(6_000_000), &k1), 3);
        assert_eq!(threads_for(&dims_with_flops(20_000_000), &k1), 4);
    }

    #[test]
    fn capped_by_core_count() {
        let host = MachineSpec::host(); // 1 core
        assert_eq!(threads_for(&dims_with_flops(20_000_000), &host), 1);
    }

    #[test]
    fn table3_examples() {
        let k1 = MachineSpec::spacemit_k1();
        // middle CB5 (2.58E+05 FLOPs) -> single thread
        let cb5 = EinsumDims { kind: EinsumKind::Middle, m: 32, b: 9, n: 7, r: 8, k: 8 };
        assert_eq!(threads_for(&cb5, &k1), 1);
        // first CB3 (2.06E+08) -> four threads
        let cb3 = EinsumDims { kind: EinsumKind::First, m: 256, b: 64, n: 784, r: 8, k: 1 };
        assert_eq!(threads_for(&cb3, &k1), 4);
    }
}
