//! The optimization plan — the compiler's output artifact.
//!
//! Plans describe *how* to run the Listing-2 contraction; the core `G`
//! `(r, n, m, k)` and output `(m, b, r)` index conventions the loop bounds
//! refer to are documented once in [`crate::kernels`] (§ Data layout
//! conventions).

use crate::ttd::cost::EinsumDims;

/// Which loop the microkernel vectorizes (paper §4.3.3 analysis).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorLoop {
    /// Vectorize the `r` (output-rank) loop: contiguous vector stores, no
    /// horizontal adds. Requires `r > 1`; the packed `G` layout makes the
    /// loads contiguous. Chosen for first/middle Einsums.
    R,
    /// Vectorize the `k = n*r_t` contraction loop: needs a horizontal
    /// reduction per output element and scalar stores. Forced for the final
    /// Einsum (`r = 1`).
    K,
    /// No vectorization (baseline stages only).
    None,
}

/// Register-blocking factors (paper §4.3.4). `rm`/`rb` unroll the `m` and
/// `b` loops in scalar iterations; `rr`/`rk` unroll the vectorized `r`/`k`
/// loop in *vector registers* (each covering `vl` lanes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RbFactors {
    /// Unroll factor of the scalar `m` loop.
    pub rm: usize,
    /// Unroll factor of the scalar `b` loop.
    pub rb: usize,
    /// Vector-register unroll of the `r` loop.
    pub rr: usize,
    /// Vector-register unroll of the `k` loop.
    pub rk: usize,
}

impl RbFactors {
    /// No blocking: every factor 1.
    pub const NONE: RbFactors = RbFactors { rm: 1, rb: 1, rr: 1, rk: 1 };

    /// Vector registers the innermost body needs (paper Eq. 19):
    /// `Rm*Rb*Rr + min(Rb*Rk, Rm*Rr) + 1`.
    pub fn registers(&self) -> usize {
        self.rm * self.rb * self.rr + (self.rb * self.rk).min(self.rm * self.rr) + 1
    }
}

/// Loop order of the three data-parallel outer loops (paper §4.3.5 considers
/// these two of the 4! permutations).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LoopOrder {
    /// `{mt, bt, rt, nt*rt_1}` — parallelize `mt` (Eq. 26 / Eq. 28).
    Mbrk,
    /// `{bt, mt, rt, nt*rt_1}` — parallelize `bt` (Eq. 27).
    Bmrk,
}

/// L2 tiling decision (paper Eq. 26-28).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    /// Which of the two studied loop orders runs.
    pub order: LoopOrder,
    /// Tile length over `bt` when Eq. 26/27 fail and Eq. 28 must be applied;
    /// `None` = untiled.
    pub btl: Option<usize>,
}

/// Everything the kernel engine needs to execute one Einsum optimally.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OptimizationPlan {
    /// The Einsum instance this plan executes.
    pub dims: EinsumDims,
    /// Pack `G` into the access-ordered layout (always on in the full
    /// pipeline; off in ablation stages).
    pub pack_g: bool,
    /// Which loop the microkernel vectorizes.
    pub vector_loop: VectorLoop,
    /// f32 lanes per vector register on the target.
    pub vl: usize,
    /// Register-blocking factors (Eq. 19-25 solution).
    pub rb: RbFactors,
    /// L2 tiling decision (Eq. 26-28).
    pub tile: TilePlan,
    /// Threads assigned by the Fig. 9 heuristic.
    pub threads: u32,
    /// Predicted load/store instruction count (Eq. 20), the RB objective.
    pub ls_estimate: u64,
}

impl OptimizationPlan {
    /// An unoptimized plan (the GCC -O3 ablation baseline): no packing, no
    /// vectorization, no blocking, single thread.
    pub fn naive(dims: EinsumDims) -> Self {
        OptimizationPlan {
            dims,
            pack_g: false,
            vector_loop: VectorLoop::None,
            vl: 1,
            rb: RbFactors::NONE,
            tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
            threads: 1,
            ls_estimate: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost::{EinsumDims, EinsumKind};

    #[test]
    fn register_formula_matches_paper_example() {
        // paper Listing 6 context: Rm=2, Rb=3 -> 6 outputs + 2 G regs + 1
        let rb = RbFactors { rm: 2, rb: 3, rr: 1, rk: 1 };
        // Eq.19: 2*3*1 + min(3*1, 2*1) + 1 = 6 + 2 + 1 = 9
        assert_eq!(rb.registers(), 9);
        // paper Step-3 example solution {4,3,1,1} with 16 registers
        let rb = RbFactors { rm: 4, rb: 3, rr: 1, rk: 1 };
        assert_eq!(rb.registers(), 16);
    }

    #[test]
    fn naive_plan_is_fully_unoptimized() {
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 4, b: 4, n: 4, r: 8, k: 8 };
        let p = OptimizationPlan::naive(dims);
        assert_eq!(p.vector_loop, VectorLoop::None);
        assert_eq!(p.rb, RbFactors::NONE);
        assert_eq!(p.threads, 1);
        assert!(!p.pack_g);
    }
}
