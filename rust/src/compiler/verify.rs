//! Static plan/layout safety verification — the invariant checker behind
//! `ttrv lint` and the executor/artifact chokepoints.
//!
//! The unsafe vector microkernels ([`crate::kernels`]) trust a set of
//! packing and plan invariants with raw-pointer loads; historically those
//! were guarded only by fuzz tests and `debug_assert!`s that vanish in
//! release builds. This module proves them *statically* per plan — the
//! paper's own posture (decide at compile time, then run fast) applied to
//! our own artifacts:
//!
//! * **Safety tier** ([`check_plan`] / [`verify_plan`]) — machine-free
//!   invariants every plan must satisfy before it may reach a kernel
//!   region. Enforced at every [`crate::kernels::Executor`] plan-cache
//!   insert (`plan`, `set_plan`, `preseed`).
//! * **Strict tier** ([`check_plan_for`] / [`verify_plan_for`], plus the
//!   [`check_packed`] / [`check_quant`] cross-checks against a concrete
//!   core) — adds the machine register budget (paper Eq. 19) and the exact
//!   packed-buffer geometry formulas of [`crate::kernels::pack`].
//!   Enforced on every plan decoded from a `.ttrv` artifact and by
//!   `ttrv lint`.
//!
//! The register budget lives in the strict tier deliberately: exceeding it
//! causes register spills (a performance defect the solver never plans),
//! not out-of-bounds access — the region drivers clamp `rm`/`rb` into
//! `1..=8` — and the test suites sweep over-budget points on purpose for
//! remainder-tile coverage.
//!
//! Each failed check is a [`Violation`] naming the invariant by a stable
//! slug (the table in ARCHITECTURE.md "Static verification"); the
//! `verify_*` wrappers fold them into one typed [`Error::Plan`].
//!
//! [`Error::Plan`]: crate::error::Error::Plan

use std::fmt;

use crate::compiler::plan::{OptimizationPlan, VectorLoop};
use crate::error::{Error, Result};
use crate::kernels::{GLayout, PackedG, QuantizedG, VL};
use crate::machine::MachineSpec;
use crate::ttd::cost::EinsumKind;

/// Largest `rm`/`rb` unroll the region drivers dispatch (they clamp into
/// `1..=MAX_RB`; a plan outside that range would silently execute a
/// different unroll than it claims).
pub const MAX_RB: usize = 8;

/// One failed invariant: a stable slug naming it plus a human-readable
/// detail with the offending values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Stable invariant slug (e.g. `rpad-formula`, `rb-register-budget`) —
    /// the key diagnostics, mutant tests and the lint report agree on.
    pub invariant: &'static str,
    /// Human-readable detail naming the offending values.
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.invariant, self.detail)
    }
}

fn push(out: &mut Vec<Violation>, invariant: &'static str, detail: String) {
    out.push(Violation { invariant, detail });
}

/// The packed-core layout a plan requires — the single consistency table
/// the executor ([`crate::kernels`]) dispatches on.
pub fn expected_layout(plan: &OptimizationPlan) -> GLayout {
    match (plan.pack_g, plan.vector_loop) {
        (false, _) => GLayout::Canonical,
        (true, VectorLoop::R) => GLayout::PackedR,
        (true, _) => GLayout::PackedK,
    }
}

/// Safety tier: machine-free invariants every plan must satisfy before it
/// may reach a kernel region. Returns every violated invariant (empty =
/// safe).
pub fn check_plan(plan: &OptimizationPlan) -> Vec<Violation> {
    let mut out = Vec::new();
    let d = &plan.dims;
    if d.m == 0 || d.b == 0 || d.n == 0 || d.r == 0 || d.k == 0 {
        push(
            &mut out,
            "dims-positive",
            format!(
                "every Einsum extent must be >= 1, got m={} b={} n={} r={} k={}",
                d.m, d.b, d.n, d.r, d.k
            ),
        );
    }
    match d.kind {
        EinsumKind::First if d.k != 1 => push(
            &mut out,
            "dims-kind",
            format!("First Einsum contracts no rank, so k must be 1, got k={}", d.k),
        ),
        EinsumKind::Final if d.r != 1 => push(
            &mut out,
            "dims-kind",
            format!("Final Einsum produces no rank, so r must be 1, got r={}", d.r),
        ),
        _ => {}
    }
    let want_vl = if plan.vector_loop == VectorLoop::None { 1 } else { VL };
    if plan.vl != want_vl {
        push(
            &mut out,
            "vl-matches-packing",
            format!(
                "vector_loop {:?} executes at vl={want_vl}, plan claims vl={}",
                plan.vector_loop, plan.vl
            ),
        );
    }
    let rb = &plan.rb;
    if !(1..=MAX_RB).contains(&rb.rm)
        || !(1..=MAX_RB).contains(&rb.rb)
        || rb.rr == 0
        || rb.rk == 0
    {
        push(
            &mut out,
            "rb-range",
            format!(
                "rm/rb must be in 1..={MAX_RB} and rr/rk >= 1 (the range the region \
                 drivers dispatch), got rm={} rb={} rr={} rk={}",
                rb.rm, rb.rb, rb.rr, rb.rk
            ),
        );
    }
    if plan.threads == 0 {
        push(&mut out, "threads-positive", "threads must be >= 1, got 0".to_string());
    }
    if plan.tile.btl == Some(0) {
        push(
            &mut out,
            "btl-positive",
            "bt tile length must be >= 1 when tiled, got Some(0)".to_string(),
        );
    }
    out
}

/// Strict tier over a plan alone: the safety tier plus the machine
/// register budget (paper Eq. 19) — the solver's own feasibility
/// constraint, re-checked on externally-sourced plans.
pub fn check_plan_for(plan: &OptimizationPlan, machine: &MachineSpec) -> Vec<Violation> {
    let mut out = check_plan(plan);
    let need = plan.rb.registers();
    let budget = machine.vector_regs as usize;
    if need > budget {
        push(
            &mut out,
            "rb-register-budget",
            format!(
                "RB factors (rm={} rb={} rr={} rk={}) need {need} vector registers \
                 (Eq. 19) but {} has {budget}",
                plan.rb.rm, plan.rb.rb, plan.rb.rr, plan.rb.rk, machine.name
            ),
        );
    }
    out
}

/// Shared geometry checks for a packed core (f32 or int8): the layout
/// table, the canonical dims, the `r_pad` formula and the exact buffer
/// length formula of [`crate::kernels::pack`].
fn check_geometry(
    out: &mut Vec<Violation>,
    plan: &OptimizationPlan,
    layout: GLayout,
    dims: (usize, usize, usize, usize),
    r_pad: usize,
    len: usize,
) {
    let d = &plan.dims;
    let (r, n, m, k) = dims;
    if (d.r, d.n, d.m, d.k) != (r, n, m, k) {
        push(
            out,
            "core-dims-match",
            format!("plan dims {d:?} do not match core dims (r,n,m,k)={dims:?}"),
        );
    }
    let want_layout = expected_layout(plan);
    if layout != want_layout {
        push(
            out,
            "layout-consistent",
            format!(
                "core packed as {layout:?} but the plan (pack_g={}, vector_loop={:?}) \
                 requires {want_layout:?}",
                plan.pack_g, plan.vector_loop
            ),
        );
    }
    let want_rpad = match layout {
        GLayout::PackedR => r.div_ceil(VL) * VL,
        _ => r,
    };
    if r_pad != want_rpad {
        push(
            out,
            "rpad-formula",
            format!("r_pad={r_pad} but {layout:?} with r={r} requires r_pad={want_rpad}"),
        );
    }
    let want_len = match layout {
        GLayout::Canonical => r * n * m * k,
        GLayout::PackedR => m * want_rpad * n * k,
        GLayout::PackedK => m * r * n * k,
    };
    if len != want_len {
        push(
            out,
            "buffer-length",
            format!(
                "buffer holds {len} lanes but {layout:?} with (r,n,m,k)={dims:?} \
                 requires exactly {want_len}"
            ),
        );
    }
}

/// Find the first nonzero `PackedR` pad lane (`r <= lane_r < r_pad`) — the
/// lanes the r-kernels multiply-accumulate unconditionally, so any nonzero
/// value silently corrupts results. Only called once the geometry checks
/// passed (the index formula below assumes them).
fn pad_lane_violation(
    dims: (usize, usize, usize, usize),
    r_pad: usize,
    nonzero: impl Fn(usize) -> bool,
) -> Option<String> {
    let (r, n, m, k) = dims;
    let l = n * k;
    for mi in 0..m {
        for rv in 0..r_pad / VL {
            for kk in 0..l {
                let base = ((mi * (r_pad / VL) + rv) * l + kk) * VL;
                for lane in 0..VL {
                    if rv * VL + lane >= r && nonzero(base + lane) {
                        return Some(format!(
                            "pad lane (m={mi}, rv={rv}, nk={kk}, lane={lane}) is nonzero; \
                             r-kernels MAC pad lanes unconditionally so they must be 0"
                        ));
                    }
                }
            }
        }
    }
    None
}

/// Strict tier: prove a plan × f32 packed core pair safe for the unsafe
/// SIMD regions — layout table, dims, `r_pad` formula, exact buffer
/// length, and (for `PackedR`) provably-zero pad lanes.
pub fn check_packed(plan: &OptimizationPlan, g: &PackedG) -> Vec<Violation> {
    let mut out = Vec::new();
    check_geometry(&mut out, plan, g.layout, g.dims, g.r_pad, g.data.len());
    if out.is_empty() && g.layout == GLayout::PackedR {
        if let Some(detail) = pad_lane_violation(g.dims, g.r_pad, |i| g.data[i] != 0.0) {
            push(&mut out, "pad-lanes-zero", detail);
        }
    }
    out
}

/// Strict tier for an int8 core: the same geometry/pad-lane proofs as
/// [`check_packed`] plus the quantization contracts — one finite positive
/// scale per `m`-slice and no `-128` value (symmetric range, so negation
/// stays exact in the widening kernels).
pub fn check_quant(plan: &OptimizationPlan, q: &QuantizedG) -> Vec<Violation> {
    let mut out = Vec::new();
    check_geometry(&mut out, plan, q.layout, q.dims, q.r_pad, q.data.len());
    if out.is_empty() && q.layout == GLayout::PackedR {
        if let Some(detail) = pad_lane_violation(q.dims, q.r_pad, |i| q.data[i] != 0) {
            push(&mut out, "pad-lanes-zero", detail);
        }
    }
    let m = q.dims.2;
    if q.scales.len() != m {
        push(
            &mut out,
            "quant-scale-count",
            format!("quantized core has {} scales for m={m} (one per m-slice)", q.scales.len()),
        );
    }
    if let Some((mi, s)) =
        q.scales.iter().enumerate().find(|(_, s)| !s.is_finite() || **s <= 0.0)
    {
        push(
            &mut out,
            "quant-scale-finite",
            format!("scale[{mi}] = {s} must be finite and > 0"),
        );
    }
    if let Some(pos) = q.data.iter().position(|&v| v == i8::MIN) {
        push(
            &mut out,
            "quant-value-range",
            format!(
                "data[{pos}] = -128 is outside the symmetric int8 range [-127, 127] \
                 the quantizer guarantees"
            ),
        );
    }
    out
}

fn to_result(what: &str, violations: Vec<Violation>) -> Result<()> {
    if violations.is_empty() {
        return Ok(());
    }
    let msgs: Vec<String> = violations.iter().map(|v| v.to_string()).collect();
    Err(Error::plan(format!("{what}: {}", msgs.join("; "))))
}

/// [`check_plan`] as a typed error (the executor chokepoint).
pub fn verify_plan(plan: &OptimizationPlan) -> Result<()> {
    to_result("plan rejected", check_plan(plan))
}

/// [`check_plan_for`] as a typed error (externally-sourced plans).
pub fn verify_plan_for(plan: &OptimizationPlan, machine: &MachineSpec) -> Result<()> {
    to_result("plan rejected", check_plan_for(plan, machine))
}

/// [`check_packed`] as a typed error.
pub fn verify_packed(plan: &OptimizationPlan, g: &PackedG) -> Result<()> {
    to_result("plan/core pair rejected", check_packed(plan, g))
}

/// [`check_quant`] as a typed error.
pub fn verify_quant(plan: &OptimizationPlan, q: &QuantizedG) -> Result<()> {
    to_result("plan/quantized-core pair rejected", check_quant(plan, q))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{LoopOrder, RbFactors, TilePlan};
    use crate::compiler::{cb_suite, compile};
    use crate::kernels::{pack, quantize};
    use crate::tensor::Tensor;
    use crate::ttd::cost::EinsumDims;
    use crate::util::prng::Rng;

    fn names(vs: &[Violation]) -> Vec<&'static str> {
        vs.iter().map(|v| v.invariant).collect()
    }

    fn middle_plan() -> OptimizationPlan {
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 6, b: 4, n: 3, r: 8, k: 8 };
        OptimizationPlan {
            dims,
            pack_g: true,
            vector_loop: VectorLoop::R,
            vl: VL,
            rb: RbFactors { rm: 2, rb: 3, rr: 1, rk: 1 },
            tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
            threads: 1,
            ls_estimate: 0,
        }
    }

    #[test]
    fn compiled_plans_pass_both_tiers_on_both_machines() {
        for machine in [MachineSpec::spacemit_k1(), MachineSpec::host()] {
            for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
                for e in cb_suite(kind) {
                    let plan = compile(&e.dims, &machine).unwrap();
                    let vs = check_plan_for(&plan, &machine);
                    assert!(vs.is_empty(), "{} on {}: {:?}", e.id, machine.name, names(&vs));
                }
            }
        }
    }

    #[test]
    fn naive_plan_is_safe() {
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 4, b: 4, n: 4, r: 8, k: 8 };
        assert!(check_plan(&OptimizationPlan::naive(dims)).is_empty());
    }

    #[test]
    fn each_safety_invariant_fires_by_name() {
        let good = middle_plan();
        assert!(check_plan(&good).is_empty());

        let mut p = good;
        p.dims.n = 0;
        assert_eq!(names(&check_plan(&p)), ["dims-positive"]);

        let mut p = good;
        p.dims.kind = EinsumKind::First; // k = 8
        assert_eq!(names(&check_plan(&p)), ["dims-kind"]);
        let mut p = good;
        p.dims.kind = EinsumKind::Final; // r = 8
        assert_eq!(names(&check_plan(&p)), ["dims-kind"]);

        let mut p = good;
        p.vl = 4;
        assert_eq!(names(&check_plan(&p)), ["vl-matches-packing"]);
        let mut p = good;
        p.vector_loop = VectorLoop::None;
        p.vl = VL; // scalar loop must claim vl = 1
        assert_eq!(names(&check_plan(&p)), ["vl-matches-packing"]);

        for bad_rb in [
            RbFactors { rm: 0, rb: 1, rr: 1, rk: 1 },
            RbFactors { rm: 9, rb: 1, rr: 1, rk: 1 },
            RbFactors { rm: 1, rb: 0, rr: 1, rk: 1 },
            RbFactors { rm: 1, rb: 9, rr: 1, rk: 1 },
            RbFactors { rm: 1, rb: 1, rr: 0, rk: 1 },
            RbFactors { rm: 1, rb: 1, rr: 1, rk: 0 },
        ] {
            let mut p = good;
            p.rb = bad_rb;
            assert_eq!(names(&check_plan(&p)), ["rb-range"], "{bad_rb:?}");
        }

        let mut p = good;
        p.threads = 0;
        assert_eq!(names(&check_plan(&p)), ["threads-positive"]);

        let mut p = good;
        p.tile.btl = Some(0);
        assert_eq!(names(&check_plan(&p)), ["btl-positive"]);
    }

    #[test]
    fn register_budget_is_strict_tier_only() {
        // (8, 8) needs 73 registers — over every preset's budget, but the
        // region drivers clamp unrolls so it is *safe*; the test suites
        // sweep it deliberately for remainder-tile coverage.
        let mut p = middle_plan();
        p.rb = RbFactors { rm: 8, rb: 8, rr: 1, rk: 1 };
        assert!(check_plan(&p).is_empty(), "safety tier must accept over-budget RB");
        let vs = check_plan_for(&p, &MachineSpec::spacemit_k1());
        assert_eq!(names(&vs), ["rb-register-budget"]);
        // within budget on K1 (32 regs), over budget on the host (16)
        let mut p = middle_plan();
        p.rb = RbFactors { rm: 4, rb: 6, rr: 1, rk: 1 }; // 29 registers
        assert!(check_plan_for(&p, &MachineSpec::spacemit_k1()).is_empty());
        assert_eq!(names(&check_plan_for(&p, &MachineSpec::host())), ["rb-register-budget"]);
    }

    fn packed_pair() -> (OptimizationPlan, PackedG) {
        let plan = middle_plan();
        let d = plan.dims;
        let mut rng = Rng::new(90);
        let g = Tensor::randn(vec![d.r, d.n, d.m, d.k], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        (plan, pg)
    }

    #[test]
    fn packed_cross_checks_fire_by_name() {
        let (plan, pg) = packed_pair();
        assert!(check_packed(&plan, &pg).is_empty());

        let mut bad = pg.clone();
        bad.dims.1 += 1; // n
        assert!(names(&check_packed(&plan, &bad)).contains(&"core-dims-match"));

        let mut bad = pg.clone();
        bad.layout = GLayout::PackedK;
        assert!(names(&check_packed(&plan, &bad)).contains(&"layout-consistent"));

        let mut bad = pg.clone();
        bad.r_pad = pg.dims.0; // r, not div_ceil(r, VL) * VL... equal here (r = 8)
        bad.r_pad += VL; // force a mismatch regardless
        assert!(names(&check_packed(&plan, &bad)).contains(&"rpad-formula"));

        let mut bad = pg.clone();
        bad.data.pop(); // k-tail overrun: one lane short
        assert_eq!(names(&check_packed(&plan, &bad)), ["buffer-length"]);

        // nonzero pad lane: r = 3 pads to 8, poison lane 5
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 2, b: 2, n: 2, r: 3, k: 2 };
        let plan = OptimizationPlan { dims, ..middle_plan() };
        let mut rng = Rng::new(91);
        let g = Tensor::randn(vec![3, 2, 2, 2], 1.0, &mut rng);
        let mut pg = pack(&g, &plan).unwrap();
        assert!(check_packed(&plan, &pg).is_empty());
        pg.data[5] = 1.5; // lane 5 of the first vector: lane_r = 5 >= r = 3
        assert_eq!(names(&check_packed(&plan, &pg)), ["pad-lanes-zero"]);
    }

    #[test]
    fn quant_cross_checks_fire_by_name() {
        let (plan, pg) = packed_pair();
        let q = quantize(&pg);
        assert!(check_quant(&plan, &q).is_empty());

        let mut bad = q.clone();
        bad.scales.pop();
        assert_eq!(names(&check_quant(&plan, &bad)), ["quant-scale-count"]);

        let mut bad = q.clone();
        bad.scales[1] = f32::NAN;
        assert_eq!(names(&check_quant(&plan, &bad)), ["quant-scale-finite"]);
        let mut bad = q.clone();
        bad.scales[0] = 0.0;
        assert_eq!(names(&check_quant(&plan, &bad)), ["quant-scale-finite"]);

        let mut bad = q.clone();
        bad.data[0] = i8::MIN;
        assert_eq!(names(&check_quant(&plan, &bad)), ["quant-value-range"]);

        let mut bad = q.clone();
        bad.data.truncate(bad.data.len() - 3);
        assert_eq!(names(&check_quant(&plan, &bad)), ["buffer-length"]);
    }

    #[test]
    fn verify_wrappers_return_typed_plan_errors() {
        let mut p = middle_plan();
        p.threads = 0;
        let err = verify_plan(&p).unwrap_err();
        match err {
            Error::Plan(msg) => assert!(msg.contains("threads-positive"), "{msg}"),
            other => panic!("expected Error::Plan, got {other:?}"),
        }
        assert!(verify_plan(&middle_plan()).is_ok());
        let (plan, pg) = packed_pair();
        assert!(verify_packed(&plan, &pg).is_ok());
        assert!(verify_quant(&plan, &quantize(&pg)).is_ok());
    }
}
