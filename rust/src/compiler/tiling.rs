//! Loop order, parallelization and L2 tiling (paper §4.3.5, Eq. 26-28).
//!
//! The three candidate schedules, tried in order:
//! 1. order `{mt, bt, rt, nt*rt_1}`, parallelize `mt`, untiled — accept if
//!    the per-thread working set satisfies Eq. 26;
//! 2. order `{bt, mt, rt, nt*rt_1}`, parallelize `bt`, untiled — accept if
//!    Eq. 27 holds;
//! 3. order 1 with `bt` tiled by the largest `Btl` satisfying Eq. 28;
//!    if no `Btl >= 1` works the solution is discarded (Plan error).

use crate::error::{Error, Result};
use crate::machine::MachineSpec;
use crate::ttd::cost::EinsumDims;

use super::plan::{LoopOrder, TilePlan};

const F32: u64 = 4;

fn ways(bytes: u64, way_bytes: u64) -> u64 {
    bytes.div_ceil(way_bytes)
}

/// Eq. 26: `{mt, bt, rt, k}` order, `mt` parallelized over `t` threads.
/// Output slice (bt*rt), G slice (rt*nt*rt_1) per thread; Input shared.
pub fn eq26_holds(dims: &EinsumDims, machine: &MachineSpec, t: u32) -> bool {
    let (b, r) = (dims.b as u64, dims.r as u64);
    let l = (dims.n * dims.k) as u64;
    let way = machine.l2_way_bytes();
    let t = t as u64;
    let lhs = t * ways(b * r * F32, way) + t * ways(r * l * F32, way) + ways(b * l * F32, way);
    lhs <= machine.l2_assoc as u64
}

/// Eq. 27: `{bt, mt, rt, k}` order, `bt` parallelized. The whole `G`
/// (mt*rt*nt*rt_1) is shared; each thread streams one input row (nt*rt_1).
pub fn eq27_holds(dims: &EinsumDims, machine: &MachineSpec, t: u32) -> bool {
    let (m, r) = (dims.m as u64, dims.r as u64);
    let l = (dims.n * dims.k) as u64;
    let way = machine.l2_way_bytes();
    let lhs = 1 + ways(m * r * l * F32, way) + t as u64 * ways(l * F32, way);
    lhs <= machine.l2_assoc as u64
}

/// Eq. 28: order `{mt, bt, rt, k}` with `bt` tiled by `btl`.
pub fn eq28_holds(dims: &EinsumDims, machine: &MachineSpec, t: u32, btl: usize) -> bool {
    let r = dims.r as u64;
    let l = (dims.n * dims.k) as u64;
    let way = machine.l2_way_bytes();
    let t = t as u64;
    let btl = btl as u64;
    let lhs =
        t * ways(btl * r * F32, way) + t * ways(r * l * F32, way) + ways(btl * l * F32, way);
    lhs <= machine.l2_assoc as u64
}

/// Select loop order + tiling per the three-step method.
pub fn select(dims: &EinsumDims, machine: &MachineSpec, threads: u32) -> Result<TilePlan> {
    if eq26_holds(dims, machine, threads) {
        return Ok(TilePlan { order: LoopOrder::Mbrk, btl: None });
    }
    if eq27_holds(dims, machine, threads) {
        return Ok(TilePlan { order: LoopOrder::Bmrk, btl: None });
    }
    // Step 3: largest Btl (multiple of the vector length for clean ukernels)
    let mut btl = dims.b;
    while btl >= 1 {
        if eq28_holds(dims, machine, threads, btl) {
            return Ok(TilePlan { order: LoopOrder::Mbrk, btl: Some(btl) });
        }
        btl /= 2;
    }
    Err(Error::plan(format!(
        "no feasible L2 tiling for {dims:?} on {}",
        machine.name
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost::EinsumKind;

    fn dims(m: usize, b: usize, n: usize, r: usize, k: usize) -> EinsumDims {
        EinsumDims { kind: EinsumKind::Middle, m, b, n, r, k }
    }

    #[test]
    fn small_kernel_needs_no_tiling() {
        let k1 = MachineSpec::spacemit_k1();
        // CB5 middle: {32, 9, 7, 8, 8} — tiny working set
        let d = dims(32, 9, 7, 8, 8);
        let plan = select(&d, &k1, 1).unwrap();
        assert_eq!(plan.order, LoopOrder::Mbrk);
        assert_eq!(plan.btl, None);
    }

    #[test]
    fn huge_b_with_big_g_falls_through_to_eq27_or_tiling() {
        let k1 = MachineSpec::spacemit_k1();
        // CB6 middle: {4, 16383, 28, 8, 8}: b*l = 16383*224*4B = 14.7 MB >> L2
        let d = dims(4, 16383, 28, 8, 8);
        assert!(!eq26_holds(&d, &k1, 4));
        let plan = select(&d, &k1, 4).unwrap();
        // paper Sec. 6.3 (CB6): "we select the loop permutation {bt, mt, rt,
        // nt*rt_1} to fit data into L2-cache"
        assert_eq!(plan.order, LoopOrder::Bmrk);
    }

    #[test]
    fn giant_g_forces_bt_tiling() {
        let k1 = MachineSpec::spacemit_k1();
        // G = m*r*l*4 = 2048*8*1024*4 = 64 MB >> L2 -> Eq.27 fails too
        let d = dims(2048, 8192, 128, 8, 8);
        assert!(!eq26_holds(&d, &k1, 4));
        assert!(!eq27_holds(&d, &k1, 4));
        let plan = select(&d, &k1, 4).unwrap();
        assert_eq!(plan.order, LoopOrder::Mbrk);
        let btl = plan.btl.expect("must tile bt");
        assert!(btl < 8192);
        assert!(eq28_holds(&d, &k1, 4, btl));
    }

    #[test]
    fn eq26_monotone_in_threads() {
        let k1 = MachineSpec::spacemit_k1();
        let d = dims(256, 512, 16, 8, 8);
        // more threads -> more per-thread slices -> harder to satisfy
        let ok1 = eq26_holds(&d, &k1, 1);
        let ok4 = eq26_holds(&d, &k1, 4);
        assert!(ok1 || !ok4, "Eq.26 must not get easier with more threads");
    }

    #[test]
    fn tighter_cache_tiles_smaller() {
        let mut small = MachineSpec::spacemit_k1();
        small.l2_bytes = 256 * 1024; // 256 KB LLC
        let d = dims(512, 4096, 64, 8, 8);
        let plan_small = select(&d, &small, 4).unwrap();
        let plan_big = select(&d, &MachineSpec::spacemit_k1(), 4).unwrap();
        let btl_small = plan_small.btl.unwrap_or(d.b);
        let btl_big = plan_big.btl.unwrap_or(d.b);
        assert!(btl_small <= btl_big);
    }

    #[test]
    fn infeasible_tiling_is_discarded() {
        // paper: "if Eq. 28 is not satisfied, the solution is deemed
        // inefficient and discarded" — a tiny LLC makes even btl = 1 fail
        let mut tiny = MachineSpec::spacemit_k1();
        tiny.l2_bytes = 64 * 1024;
        let d = dims(512, 4096, 64, 8, 8);
        assert!(select(&d, &tiny, 4).is_err());
    }
}
