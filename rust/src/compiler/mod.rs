//! The analytical compiler for T3F Einsum kernels (paper §4.3).
//!
//! Given an Einsum instance ([`crate::ttd::cost::EinsumDims`]) and a target
//! ([`crate::machine::MachineSpec`]), the pass pipeline decides — entirely
//! analytically, no autotuning runs —
//!
//! 1. **array packing** of the constant core `G` (§4.3.1) and reshape-layer
//!    elimination (§4.3.2) — always on, encoded in the plan's layout;
//! 2. **vectorized loop** selection: `r`-loop where possible, `k`-loop for
//!    the final Einsum (§4.3.3);
//! 3. **register blocking** factors minimizing the load/store count under
//!    the register-file constraint (§4.3.4, Eq. 18-25);
//! 4. **loop order + L2 tiling** via the cache-occupancy inequalities
//!    (§4.3.5, Eq. 26-28);
//! 5. **thread count** from the workload heuristic (§4.2.3, Fig. 9).
//!
//! The output [`plan::OptimizationPlan`] is executed by [`crate::kernels`]
//! and priced by [`crate::machine::costmodel`].

pub mod ir;
pub mod regblock;
pub mod tiling;
pub mod threads;
pub mod plan;
pub mod pipeline;
pub mod verify;

pub use ir::{cb_suite, CbEntry};
pub use pipeline::compile;
pub use plan::{LoopOrder, OptimizationPlan, RbFactors, VectorLoop};
pub use verify::Violation;
