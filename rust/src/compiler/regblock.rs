//! Register-blocking solver (paper §4.3.4).
//!
//! Three steps, exactly as the paper:
//! 1. constrain factor tuples by the vector-register budget (Eq. 18/19);
//! 2. price each candidate with the load/store-count equations (Eq. 20-25),
//!    including the padding-ukernel terms (Eq. 22);
//! 3. pick the candidate minimizing the L/S count.
//!
//! `Rm` and `Rr` are restricted to powers of two: both shape the packed `G`
//! layout (`{m, r/(Rr*vl), n*k, Rr*vl}` chunks), which must tile evenly at
//! compile time. `Rb`/`Rk` are free. This restriction also reproduces the
//! paper's worked example ({128,32,8,8} @ 16 regs -> {4,3,1,1}).

use crate::machine::MachineSpec;
use crate::ttd::cost::EinsumDims;

use super::plan::{RbFactors, VectorLoop};

/// Kronecker delta of Eq. 23.
#[inline]
fn delta(x: usize) -> u64 {
    (x != 0) as u64
}

/// Load/store instruction counts per array (Eq. 20 decomposition).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LsCounts {
    /// Loads of the packed core `G`.
    pub g: u64,
    /// Loads of the input slab.
    pub input: u64,
    /// Loads + stores of the output.
    pub output: u64,
}

impl LsCounts {
    /// Total load/store count (the Eq. 20 objective).
    pub fn total(&self) -> u64 {
        self.g + self.input + self.output
    }
}

/// Evaluate Eq. 21/22/24/25 for a candidate factor tuple.
///
/// Loop extents in the paper's notation: `mt = dims.m`, `bt = dims.b`,
/// `rt = dims.r` (elements), and the merged contraction loop
/// `nt*rt_1 = dims.n * dims.k`.
pub fn ls_counts(dims: &EinsumDims, vl: usize, rb: &RbFactors, vloop: VectorLoop) -> LsCounts {
    let (m, b, r) = (dims.m as u64, dims.b as u64, dims.r as u64);
    let l = (dims.n * dims.k) as u64; // nt * rt_1
    let vl = vl as u64;
    let (rm, rbf, rr) = (rb.rm as u64, rb.rb as u64, rb.rr as u64);

    // Eq. 21 + Eq. 22: G is re-read once per b-block.
    let g_main = m * (b / rbf) * r * l / vl;
    let g_pad = (m * r * l / vl) * delta((b % rbf) as usize);

    // Eq. 24: Input is re-read once per (m-block, r-block).
    let in_main = (m / rm) * b * (r / rr) * l / vl;
    let in_pad = (b * (r / rr) * l / vl) * delta((m % rm) as usize);

    // Eq. 25: Output stores.
    let (out_main, out_pad) = match vloop {
        VectorLoop::K => {
            // k-vectorized microkernel stores scalars (paper: "the number of
            // stores for the Output array need to be amended").
            (m * (b / rbf) * (r / rr), (m * (r / rr)) * delta((b % rbf) as usize))
        }
        _ => (
            m * (b / rbf) * (r / rr) / vl,
            (m * (r / rr) / vl) * delta((b % rbf) as usize),
        ),
    };

    LsCounts { g: g_main + g_pad, input: in_main + in_pad, output: out_main + out_pad }
}

fn powers_of_two_upto(max: usize) -> impl Iterator<Item = usize> {
    (0..).map(|e| 1usize << e).take_while(move |&v| v <= max)
}

/// All feasible candidates sorted by predicted L/S count (ascending).
/// Used by the solver (first entry) and by the measured autotuner
/// (`kernels::tune_rb`), which re-ranks the top few on real hardware —
/// the L/S proxy cannot see register-spill/ILP effects (EXPERIMENTS.md
/// §Perf iteration 2).
pub fn candidates(
    dims: &EinsumDims,
    machine: &MachineSpec,
    vloop: VectorLoop,
    top_k: usize,
) -> Vec<(RbFactors, u64)> {
    let vl = machine.vl_f32();
    let regs = machine.vector_regs as usize;
    let rr_max = match vloop {
        VectorLoop::R => (dims.r / vl).max(1),
        _ => 1,
    };
    let mut all = Vec::new();
    for rm in powers_of_two_upto(dims.m.min(8).max(1)) {
        for rr in powers_of_two_upto(rr_max) {
            for rb in 1..=dims.b.min(8).max(1) {
                for rk in powers_of_two_upto((dims.n * dims.k).min(8).max(1)) {
                    let cand = RbFactors { rm, rb, rr, rk };
                    if cand.registers() > regs {
                        continue;
                    }
                    let ls = ls_counts(dims, vl, &cand, vloop).total();
                    all.push((cand, ls));
                }
            }
        }
    }
    all.sort_by_key(|(cand, ls)| (*ls, cand.registers()));
    // drop duplicates that differ only in rk (identical L/S and kernel)
    all.dedup_by_key(|(cand, ls)| (cand.rm, cand.rb, cand.rr, *ls));
    all.truncate(top_k);
    all
}

/// Solve for the L/S-minimizing register-blocking factors (paper Step 1-3).
/// Returns the factors and the predicted L/S count.
pub fn solve(dims: &EinsumDims, machine: &MachineSpec, vloop: VectorLoop) -> (RbFactors, u64) {
    let vl = machine.vl_f32();
    let regs = machine.vector_regs as usize;
    // r-loop unroll is in units of vector registers; at most r/vl of them.
    let rr_max = match vloop {
        VectorLoop::R => (dims.r / vl).max(1),
        _ => 1,
    };
    let mut best: Option<(RbFactors, u64)> = None;
    // Rm capped at 8 to match the kernel engine's 8x8 register tile.
    for rm in powers_of_two_upto(dims.m.min(8).max(1)) {
        for rr in powers_of_two_upto(rr_max) {
            // Rb capped at 8: beyond that the accumulator tile exceeds any
            // realistic register file, and the kernel engine's microkernel
            // register tile is sized 8x8.
            for rb in 1..=dims.b.min(8).max(1) {
                for rk in powers_of_two_upto((dims.n * dims.k).min(8).max(1)) {
                    let cand = RbFactors { rm, rb, rr, rk };
                    if cand.registers() > regs {
                        continue;
                    }
                    let ls = ls_counts(dims, vl, &cand, vloop).total();
                    let better = match &best {
                        None => true,
                        Some((prev, prev_ls)) => {
                            ls < *prev_ls
                                // tiebreak: fewer registers, then smaller factors
                                || (ls == *prev_ls && cand.registers() < prev.registers())
                        }
                    };
                    if better {
                        best = Some((cand, ls));
                    }
                }
            }
        }
    }
    best.unwrap_or((RbFactors::NONE, u64::MAX))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost::EinsumKind;

    fn dims(m: usize, b: usize, r: usize, l: usize) -> EinsumDims {
        // encode the merged contraction length l as n = l, k = 1
        EinsumDims { kind: EinsumKind::Middle, m, b, n: l, r, k: 1 }
    }

    #[test]
    fn paper_worked_example() {
        // paper Step 3: 16 registers, {mt, bt, rt, nt*rt_1} = {128, 32, 8, 8}
        // -> {Rm, Rb, Rr, Rk} = {4, 3, 1, 1}
        let mut machine = MachineSpec::spacemit_k1();
        machine.vector_regs = 16;
        let d = dims(128, 32, 8, 8);
        let (rb, _ls) = solve(&d, &machine, VectorLoop::R);
        assert_eq!((rb.rm, rb.rb, rb.rr, rb.rk), (4, 3, 1, 1));
    }

    #[test]
    fn ls_counts_worked_example_values() {
        let d = dims(128, 32, 8, 8);
        let rb = RbFactors { rm: 4, rb: 3, rr: 1, rk: 1 };
        let ls = ls_counts(&d, 8, &rb, VectorLoop::R);
        // Eq.21: 128*floor(32/3)*8*8/8 + 128*8*8/8 = 10240 + 1024
        assert_eq!(ls.g, 11_264);
        // Eq.24: floor(128/4)*32*8*8/8 + 0 = 8192
        assert_eq!(ls.input, 8_192);
        // Eq.25: 128*10*8/8 + 128*8/8 = 1280 + 128
        assert_eq!(ls.output, 1_408);
        assert_eq!(ls.total(), 20_864);
    }

    #[test]
    fn no_blocking_counts_every_access() {
        let d = dims(16, 16, 8, 4);
        let ls = ls_counts(&d, 8, &RbFactors::NONE, VectorLoop::R);
        // G: every (m, b, r-vec, l) -> 16*16*1*4 vec loads
        assert_eq!(ls.g, 16 * 16 * 8 * 4 / 8);
        assert_eq!(ls.input, 16 * 16 * 8 * 4 / 8);
        assert_eq!(ls.output, 16 * 16 * 8 / 8);
    }

    #[test]
    fn blocking_reduces_ls_vs_none() {
        let machine = MachineSpec::spacemit_k1();
        let d = dims(256, 128, 16, 32);
        let (rb, ls) = solve(&d, &machine, VectorLoop::R);
        let base = ls_counts(&d, 8, &RbFactors::NONE, VectorLoop::R).total();
        assert!(ls < base, "blocked {ls} !< naive {base}");
        assert!(rb.rm * rb.rb > 1);
        assert!(rb.registers() <= 32);
    }

    #[test]
    fn k_vectorized_stores_are_scalar() {
        let d = EinsumDims { kind: EinsumKind::Final, m: 32, b: 126, n: 4, r: 1, k: 8 };
        let r_like = ls_counts(&d, 8, &RbFactors::NONE, VectorLoop::R);
        let k_like = ls_counts(&d, 8, &RbFactors::NONE, VectorLoop::K);
        assert_eq!(k_like.output, r_like.output * 8);
        assert_eq!(k_like.g, r_like.g);
    }

    #[test]
    fn solver_respects_register_budget() {
        let mut machine = MachineSpec::spacemit_k1();
        for regs in [4u32, 8, 16, 32] {
            machine.vector_regs = regs;
            let d = dims(128, 64, 8, 16);
            let (rb, _) = solve(&d, &machine, VectorLoop::R);
            assert!(rb.registers() <= regs as usize, "{rb:?} over {regs}");
        }
    }

    #[test]
    fn tiny_kernels_get_unit_factors() {
        let machine = MachineSpec::spacemit_k1();
        let d = dims(1, 1, 8, 2);
        let (rb, _) = solve(&d, &machine, VectorLoop::R);
        assert_eq!((rb.rm, rb.rb), (1, 1));
    }
}
