//! The pass pipeline: Einsum instance + machine -> OptimizationPlan.

use crate::error::Result;
use crate::machine::MachineSpec;
use crate::ttd::cost::EinsumDims;

use super::plan::{OptimizationPlan, VectorLoop};
use super::{regblock, threads, tiling};

/// Vectorized-loop selection (paper §4.3.3): the r-loop, unless the kernel
/// is a final Einsum (r = 1) or r is too small to fill a vector register —
/// then the k-loop (horizontal-add microkernel). Kernels whose contraction
/// is also tiny stay scalar.
pub fn select_vector_loop(dims: &EinsumDims, vl: usize) -> VectorLoop {
    if dims.r >= vl && dims.r % vl == 0 {
        VectorLoop::R
    } else if dims.n * dims.k >= vl {
        VectorLoop::K
    } else {
        VectorLoop::None
    }
}

/// Run the full pipeline.
pub fn compile(dims: &EinsumDims, machine: &MachineSpec) -> Result<OptimizationPlan> {
    let vl = machine.vl_f32();
    let vector_loop = select_vector_loop(dims, vl);
    let eff_vl = if vector_loop == VectorLoop::None { 1 } else { vl };
    let (rb, ls_estimate) = regblock::solve(dims, machine, vector_loop);
    // the Fig. 9 heuristic gives the upper bound; the cost model then picks
    // the cheapest count at or below it, so "+parallelization" can never be
    // planned as a slowdown
    let t_max = threads::threads_for(dims, machine);
    let tile = tiling::select(dims, machine, t_max)?;
    let mut plan = OptimizationPlan {
        dims: *dims,
        pack_g: true,
        vector_loop,
        vl: eff_vl,
        rb,
        tile,
        threads: t_max,
        ls_estimate,
    };
    if t_max > 1 {
        let best = (1..=t_max)
            .min_by(|&a, &b| {
                let ta = crate::machine::costmodel::estimate(
                    &OptimizationPlan { threads: a, ..plan },
                    machine,
                )
                .seconds();
                let tb = crate::machine::costmodel::estimate(
                    &OptimizationPlan { threads: b, ..plan },
                    machine,
                )
                .seconds();
                ta.partial_cmp(&tb).expect("no NaN")
            })
            .unwrap_or(t_max);
        plan.threads = best;
    }
    Ok(plan)
}

/// Ablation stages for the Fig. 16 breakdown. Each stage adds one family of
/// optimizations on top of the previous.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptStage {
    /// Plain loop nest (the "GCC -O3" bar).
    Naive,
    /// + array packing and vectorization (§4.3.1-4.3.3).
    VecPack,
    /// + register blocking and L2 tiling (§4.3.4-4.3.5).
    RbTile,
    /// + parallelization (full pipeline).
    Parallel,
}

/// Compile at a given ablation stage.
pub fn compile_stage(
    dims: &EinsumDims,
    machine: &MachineSpec,
    stage: OptStage,
) -> Result<OptimizationPlan> {
    let full = compile(dims, machine)?;
    Ok(match stage {
        OptStage::Naive => OptimizationPlan::naive(*dims),
        OptStage::VecPack => OptimizationPlan {
            rb: super::plan::RbFactors::NONE,
            tile: super::plan::TilePlan { order: super::plan::LoopOrder::Mbrk, btl: None },
            threads: 1,
            ..full
        },
        OptStage::RbTile => OptimizationPlan { threads: 1, ..full },
        OptStage::Parallel => full,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ttd::cost::EinsumKind;

    fn middle(m: usize, b: usize, n: usize) -> EinsumDims {
        EinsumDims { kind: EinsumKind::Middle, m, b, n, r: 8, k: 8 }
    }

    #[test]
    fn vector_loop_selection_follows_paper() {
        // first/middle einsums (r = 8 = vl) vectorize r
        assert_eq!(select_vector_loop(&middle(64, 64, 8), 8), VectorLoop::R);
        // final einsum (r = 1) vectorizes k
        let fin = EinsumDims { kind: EinsumKind::Final, m: 32, b: 126, n: 256, r: 1, k: 8 };
        assert_eq!(select_vector_loop(&fin, 8), VectorLoop::K);
        // tiny everything stays scalar
        let tiny = EinsumDims { kind: EinsumKind::Final, m: 4, b: 4, n: 3, r: 1, k: 1 };
        assert_eq!(select_vector_loop(&tiny, 8), VectorLoop::None);
    }

    #[test]
    fn full_pipeline_produces_consistent_plan() {
        let k1 = MachineSpec::spacemit_k1();
        let d = middle(96, 128, 14); // CB2 middle
        let p = compile(&d, &k1).unwrap();
        assert!(p.pack_g);
        assert_eq!(p.vector_loop, VectorLoop::R);
        assert_eq!(p.vl, 8);
        assert!(p.rb.registers() <= 32);
        assert!(p.threads >= 1 && p.threads <= 4);
        assert!(p.ls_estimate > 0);
    }

    #[test]
    fn stages_are_monotone_in_capability() {
        let k1 = MachineSpec::spacemit_k1();
        let d = middle(64, 1020, 28); // CB7 middle, 2.3e8 FLOPs
        let naive = compile_stage(&d, &k1, OptStage::Naive).unwrap();
        let vec = compile_stage(&d, &k1, OptStage::VecPack).unwrap();
        let rbt = compile_stage(&d, &k1, OptStage::RbTile).unwrap();
        let par = compile_stage(&d, &k1, OptStage::Parallel).unwrap();
        assert_eq!(naive.vector_loop, VectorLoop::None);
        assert_eq!(vec.vector_loop, VectorLoop::R);
        assert_eq!(vec.rb, crate::compiler::plan::RbFactors::NONE);
        assert_ne!(rbt.rb, crate::compiler::plan::RbFactors::NONE);
        assert_eq!(rbt.threads, 1);
        assert_eq!(par.threads, 4);
    }
}
