//! Compiler-level IR: the Einsum instance plus the paper's Table 3
//! benchmark suite (CB0-CB7 for each kernel variant).

pub use crate::ttd::cost::{EinsumDims, EinsumKind};

/// One row of the paper's Table 3: the (mt, bt, nt, rank) sizes of a kernel
/// instance drawn from the studied models.
#[derive(Debug, Clone, Copy)]
pub struct CbEntry {
    /// Table 3 row label (e.g. `cb1`).
    pub id: &'static str,
    /// The kernel instance's loop bounds.
    pub dims: EinsumDims,
}

/// The paper's Table 3 suite for a given kernel variant. Rank value 8
/// throughout ("a rank value of eight was chosen"): first einsums have
/// `k = 1, r = 8`; middle have `r = k = 8`; final have `r = 1, k = 8`.
pub fn cb_suite(kind: EinsumKind) -> Vec<CbEntry> {
    const IDS: [&str; 8] = ["CB0", "CB1", "CB2", "CB3", "CB4", "CB5", "CB6", "CB7"];
    // (mt, bt, nt) triplets straight from Table 3.
    let (sizes, r, k): ([(usize, usize, usize); 8], usize, usize) = match kind {
        EinsumKind::First => (
            [
                (512, 32, 128),
                (64, 64, 64),
                (128, 1024, 4),
                (256, 64, 784),
                (32, 64, 392),
                (512, 896, 28),
                (100, 12, 64),
                (16, 4, 150),
            ],
            8,
            1,
        ),
        EinsumKind::Middle => (
            [
                (48, 224, 2),
                (64, 3582, 4),
                (96, 128, 14),
                (64, 64, 32),
                (256, 128, 4),
                (32, 9, 7),
                (4, 16383, 28),
                (64, 1020, 28),
            ],
            8,
            8,
        ),
        EinsumKind::Final => (
            [
                (32, 126, 256),
                (64, 64, 128),
                (32, 126, 4),
                (256, 16, 7),
                (8, 510, 896),
                (32, 250, 4),
                (124, 9, 16),
                (48, 21, 4),
            ],
            1,
            8,
        ),
    };
    sizes
        .iter()
        .zip(IDS)
        .map(|(&(m, b, n), id)| CbEntry {
            id,
            dims: EinsumDims { kind, m, b, n, r, k },
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_flops_match_paper() {
        // paper Table 3 prints the FLOPs column; spot-check entries
        let first = cb_suite(EinsumKind::First);
        assert_eq!(first[0].dims.flops(), 33_554_432); // CB0 3.36E+07
        assert_eq!(first[3].dims.flops(), 205_520_896); // CB3 2.06E+08
        let middle = cb_suite(EinsumKind::Middle);
        assert_eq!(middle[5].dims.flops(), 258_048); // CB5 2.58E+05
        assert_eq!(middle[6].dims.flops(), 234_866_688); // CB6 2.35E+08
        let fin = cb_suite(EinsumKind::Final);
        assert_eq!(fin[0].dims.flops(), 16_515_072); // CB0 1.65E+07
        assert_eq!(fin[7].dims.flops(), 64_512); // CB7 6.45E+04
    }

    #[test]
    fn variants_have_expected_rank_extents() {
        for e in cb_suite(EinsumKind::First) {
            assert_eq!(e.dims.k, 1);
            assert_eq!(e.dims.r, 8);
        }
        for e in cb_suite(EinsumKind::Final) {
            assert_eq!(e.dims.r, 1);
            assert_eq!(e.dims.k, 8);
        }
        for e in cb_suite(EinsumKind::Middle) {
            assert_eq!((e.dims.r, e.dims.k), (8, 8));
        }
    }

    #[test]
    fn suite_has_eight_entries_each() {
        for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
            assert_eq!(cb_suite(kind).len(), 8);
        }
    }
}
