//! The optimized Einsum kernel engine — executable realizations of every
//! optimization stage the compiler can plan (paper §4.3).
//!
//! # Data layout conventions
//!
//! This section is the single source of truth for the index conventions the
//! whole crate uses (referenced from [`crate::ttd`], [`crate::tensor::einsum`]
//! and [`crate::compiler::plan`] rather than restated there):
//!
//! * **Core `G`** is stored canonically as a rank-4 row-major tensor with
//!   shape `(r, n, m, k)` = `(r_{t-1}, n_t, m_t, r_t)` — the T3F convention
//!   of Novikov et al., *Tensorizing Neural Networks* (2015). `r` is the
//!   *output* rank extent, `k` the *contracted* rank extent.
//! * **Input slab** has shape `(b, n, k)` — the chain slab extent `b_t`,
//!   the layer's input factor `n_t`, and the contracted rank `r_t`.
//! * **Output** has shape `(m, b, r)` in row-major order:
//!   `Out[m, b, r] = sum over (n, k) of G[r, n, m, k] * In[b, n, k]`
//!   (the paper's Listing-2 hot-spot contraction).
//!
//! The RISC-V RVV intrinsics of the paper's listings are realized as
//! fixed-width `[f32; VL]` lane arrays that LLVM auto-vectorizes on the host
//! ISA (same lane count, same microkernel structure — DESIGN.md §3).
//!
//! # Execution model
//!
//! [`Executor`] is the **only** execution entry point: it owns the plan
//! cache (keyed by the full [`crate::ttd::cost::EinsumDims`], batch
//! included) and the scratch buffers of the serving hot loop, and it
//! executes exactly what an [`OptimizationPlan`] prescribes:
//!
//! * [`pack`] — array packing of the constant core (§4.3.1, Listing 3);
//! * vectorized r-loop / k-loop microkernels (§4.3.3, Listings 4-5);
//! * register-blocked tiles with padding ukernels (§4.3.4, Listing 6);
//! * bt tiling + loop order (§4.3.5) and thread parallelization (§4.2.3).
//!
//! Which *microkernel implementation* runs those plans is a
//! construction-time property of the executor: [`dispatch::select`] probes
//! the host once and picks the best supported [`Kernel`] (AVX2/FMA on
//! x86_64, NEON on aarch64, the portable `[f32; VL]` loop nests
//! everywhere), and `TTRV_FORCE_SCALAR` / [`set_force_scalar`] pins the
//! portable reference bits on any box. Kernel choice never affects packing
//! or plans — only the low-order bits of f32 reductions (FMA), which is
//! why bitwise pins run forced-scalar and vector kernels are verified by
//! the tolerance differential suite (ARCHITECTURE.md "Kernel dispatch").
//!
//! [`OptimizationPlan`]: crate::compiler::OptimizationPlan

#[cfg(target_arch = "x86_64")]
mod avx2;
pub mod dispatch;
mod exec;
mod executor;
mod int8;
mod micro;
mod naive;
#[cfg(target_arch = "aarch64")]
mod neon;
mod packed;
mod tune;

pub use dispatch::{
    all_kernels, default_kernel_name, force_scalar_active, portable, preferred_kernel,
    select_int8, set_force_scalar, set_preferred_kernel, Kernel, INT8_PORTABLE_KERNEL_NAME,
    PORTABLE_KERNEL_NAME,
};
pub use executor::Executor;
pub use naive::naive_einsum;
pub use packed::{dequantize, pack, quantize, GLayout, PackedG, QuantizedG};
pub use tune::{tune_plan, tune_plan_floored};

/// Microkernel lane width. Matches the paper's `vl` (256-bit RVV / f32) and
/// both MachineSpec presets; a different `MachineSpec::vl_f32` is planned
/// against but executed at this width.
pub const VL: usize = 8;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::{compile, pipeline::compile_stage, pipeline::OptStage};
    use crate::machine::MachineSpec;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::tensor::Tensor;
    use crate::ttd::cost::{EinsumDims, EinsumKind};
    use crate::util::prng::Rng;

    fn rand_case(dims: &EinsumDims, rng: &mut Rng) -> (Tensor, Tensor) {
        let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 1.0, rng);
        let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 1.0, rng);
        (g, x)
    }

    /// Every stage of every plan must equal the reference bit-for-bit shape
    /// and numerically close.
    #[test]
    fn all_stages_match_reference_on_cb_suite() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(40);
        for kind in [EinsumKind::First, EinsumKind::Middle, EinsumKind::Final] {
            // limit to 3 entries per kind to keep test time bounded;
            // integration tests sweep the full suite
            for e in crate::compiler::cb_suite(kind).into_iter().take(3) {
                // shrink huge b to keep the unit test fast
                let mut dims = e.dims;
                dims.b = dims.b.min(96);
                let (g, x) = rand_case(&dims, &mut rng);
                let want = tt_einsum_ref(&g, &x).unwrap();
                for stage in [
                    OptStage::Naive,
                    OptStage::VecPack,
                    OptStage::RbTile,
                    OptStage::Parallel,
                ] {
                    let plan = compile_stage(&dims, &machine, stage).unwrap();
                    let pg = pack(&g, &plan).unwrap();
                    let mut ex = Executor::new(&machine);
                    ex.set_plan(plan).unwrap();
                    let got = ex.execute(&dims, &pg, &x).unwrap();
                    assert!(
                        got.allclose(&want, 1e-4, 1e-4),
                        "{} {:?} stage {:?}: maxdiff {}",
                        e.id,
                        kind,
                        stage,
                        got.max_abs_diff(&want).unwrap()
                    );
                }
            }
        }
    }

    #[test]
    fn awkward_remainder_shapes() {
        // m, b deliberately prime / non-multiples of every blocking factor
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(41);
        let mut ex = Executor::new(&machine);
        for (m, b, n, r, k) in [
            (1usize, 1usize, 1usize, 8usize, 8usize),
            (7, 11, 3, 8, 8),
            (13, 5, 2, 8, 1),
            (3, 17, 5, 1, 8),
            (9, 1, 4, 16, 8),
            (2, 3, 1, 8, 16),
        ] {
            let kind = if k == 1 {
                EinsumKind::First
            } else if r == 1 {
                EinsumKind::Final
            } else {
                EinsumKind::Middle
            };
            let dims = EinsumDims { kind, m, b, n, r, k };
            let (g, x) = rand_case(&dims, &mut rng);
            let want = tt_einsum_ref(&g, &x).unwrap();
            let pg = ex.pack(&g, &dims).unwrap();
            let got = ex.execute(&dims, &pg, &x).unwrap();
            assert!(
                got.allclose(&want, 1e-4, 1e-4),
                "dims {dims:?}: maxdiff {}",
                got.max_abs_diff(&want).unwrap()
            );
        }
    }

    #[test]
    fn property_random_dims_match_reference() {
        let machine = MachineSpec::spacemit_k1();
        crate::testkit::check("kernel == reference", 30, |d| {
            let m = d.usize_in(1, 40);
            let b = d.usize_in(1, 40);
            let n = d.usize_in(1, 12);
            let (r, k) = *d.choose(&[(8usize, 8usize), (8, 1), (1, 8), (16, 8), (8, 16), (1, 1)]);
            let kind = if k == 1 && r > 1 {
                EinsumKind::First
            } else if r == 1 {
                EinsumKind::Final
            } else {
                EinsumKind::Middle
            };
            let dims = EinsumDims { kind, m, b, n, r, k };
            let mut rng = d.rng().fork();
            let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
            let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
            let want = tt_einsum_ref(&g, &x).map_err(|e| e.to_string())?;
            let mut ex = Executor::new(&machine);
            let pg = ex.pack(&g, &dims).map_err(|e| e.to_string())?;
            let got = ex.execute(&dims, &pg, &x).map_err(|e| e.to_string())?;
            if got.allclose(&want, 1e-3, 1e-3) {
                Ok(())
            } else {
                Err(format!(
                    "dims {dims:?} maxdiff {}",
                    got.max_abs_diff(&want).unwrap()
                ))
            }
        });
    }

    #[test]
    fn executor_plan_agrees_with_compiler() {
        // Executor::plan is a cached front-end over compiler::compile
        let machine = MachineSpec::spacemit_k1();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 96, b: 128, n: 14, r: 8, k: 8 };
        let mut ex = Executor::new(&machine);
        let p1 = ex.plan(&dims).unwrap();
        let p2 = compile(&dims, &machine).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(ex.cached_plans(), 1);
        let _ = ex.plan(&dims).unwrap();
        assert_eq!(ex.cached_plans(), 1, "repeat lookups must hit the cache");
    }
}
