//! aarch64 NEON microkernels.
//!
//! Same tiling structure as the portable kernels in [`super::micro`], with
//! each `[f32; VL]` lane array realized as a pair of `float32x4_t`
//! registers (NEON is 128-bit; `VL` = 8) and the per-lane multiply-then-add
//! replaced by fused multiply-add (`vfmaq_f32`). Like the AVX2 kernel this
//! changes low-order bits versus the portable reference, so it is verified
//! by the tolerance-based differential suite, never by bitwise pins.
//!
//! Memory safety: every load/store goes through a bounds-checked subslice
//! before the pointer is taken (see the safety note in [`super::avx2`]).

use core::arch::aarch64::{
    float32x4_t, vaddq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32,
};

use super::dispatch::Kernel;
use super::micro::dispatch_rb;
use super::packed::PackedG;
use super::VL;

/// NEON kernel set (2 × 4 f32 lanes = `VL`).
pub(crate) struct NeonKernel;

impl Kernel for NeonKernel {
    fn name(&self) -> &'static str {
        "neon"
    }

    fn supported(&self) -> bool {
        // NEON is architecturally mandatory on aarch64, but keep the probe
        // honest rather than hard-coding `true`.
        std::arch::is_aarch64_feature_detected!("neon")
    }

    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        debug_assert!(self.supported());
        // SAFETY: NEON probe passed (dispatch only selects supported
        // kernels); all accesses are through bounds-checked subslices.
        unsafe { r_region_neon(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base) }
    }

    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        debug_assert!(self.supported());
        // SAFETY: as above.
        unsafe { k_region_neon(g, xd, od, b_total, m0, m1, b0, b1, m_base) }
    }
}

/// A `VL`-wide f32 vector as two NEON quads.
#[derive(Clone, Copy)]
struct F32x8 {
    lo: float32x4_t,
    hi: float32x4_t,
}

#[inline(always)]
unsafe fn zero8() -> F32x8 {
    // SAFETY: register-only broadcast, no memory access; NEON availability
    // is the caller's contract (dispatch probes before selecting).
    unsafe { F32x8 { lo: vdupq_n_f32(0.0), hi: vdupq_n_f32(0.0) } }
}

/// Load `VL` lanes from a bounds-checked slice of length >= `VL`.
#[inline(always)]
unsafe fn load8(src: &[f32]) -> F32x8 {
    let s = &src[..VL];
    // SAFETY: `s` is a bounds-checked `VL`-long subslice, so the two
    // 4-lane loads (offsets 0 and 4) stay inside it.
    unsafe { F32x8 { lo: vld1q_f32(s.as_ptr()), hi: vld1q_f32(s[4..].as_ptr()) } }
}

#[inline(always)]
unsafe fn fma8(acc: F32x8, g: F32x8, xs: f32) -> F32x8 {
    // SAFETY: register-only broadcast + FMA; no memory access.
    unsafe {
        let xv = vdupq_n_f32(xs);
        F32x8 { lo: vfmaq_f32(acc.lo, g.lo, xv), hi: vfmaq_f32(acc.hi, g.hi, xv) }
    }
}

#[inline(always)]
unsafe fn store8(v: F32x8) -> [f32; VL] {
    let mut tmp = [0.0f32; VL];
    // SAFETY: `tmp` is exactly `VL` f32s on the stack; the two 4-lane
    // stores (offsets 0 and 4) write only within it.
    unsafe {
        vst1q_f32(tmp.as_mut_ptr(), v.lo);
        vst1q_f32(tmp[4..].as_mut_ptr(), v.hi);
    }
    tmp
}

/// Pairwise horizontal sum with the exact association of `micro::hsum`:
/// `lo + hi` gives `(v0+v4, v1+v5, v2+v6, v3+v7)`, then `(s0+s2)+(s1+s3)`.
#[inline(always)]
unsafe fn hsum8(v: F32x8) -> f32 {
    let mut tmp = [0.0f32; 4];
    // SAFETY: `tmp` is exactly 4 f32s on the stack and the single 4-lane
    // store writes only within it; the add is register-only.
    unsafe { vst1q_f32(tmp.as_mut_ptr(), vaddq_f32(v.lo, v.hi)) };
    (tmp[0] + tmp[2]) + (tmp[1] + tmp[3])
}

/// FMA register-tile block: the NEON twin of `micro::r_block`.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn r_block_fma<const RM: usize, const RB: usize>(
    gd: &[f32],
    xd: &[f32],
    od: &mut [f32],
    l: usize,
    r: usize,
    r_pad: usize,
    b_total: usize,
    m0: usize,
    b0: usize,
    m_base: usize,
) {
    let rv_count = r_pad / VL;
    for rv in 0..rv_count {
        // SAFETY: register-only helper; NEON availability is this
        // function's contract (see `r_region`/`k_region` above).
        let mut acc = [[unsafe { zero8() }; RB]; RM];
        let mut g_rows: [std::slice::ChunksExact<'_, f32>; RM] = std::array::from_fn(|im| {
            let off = ((m0 + im) * rv_count + rv) * l * VL;
            gd[off..off + l * VL].chunks_exact(VL)
        });
        let x_rows: [&[f32]; RB] =
            std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
        for kk in 0..l {
            // SAFETY: as above — register-only.
            let mut gvec = [unsafe { zero8() }; RM];
            for (im, row) in g_rows.iter_mut().enumerate() {
                // SAFETY: the chunk is a bounds-checked `VL`-long subslice
                // (`chunks_exact(VL)`), which is `load8`'s contract.
                gvec[im] = unsafe { load8(row.next().expect("length l by construction")) };
            }
            for ib in 0..RB {
                let xs = x_rows[ib][kk];
                for im in 0..RM {
                    // SAFETY: register-only FMA helper.
                    acc[im][ib] = unsafe { fma8(acc[im][ib], gvec[im], xs) };
                }
            }
        }
        let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
        for im in 0..RM {
            for ib in 0..RB {
                // SAFETY: `store8` only spills to its own `VL` stack array.
                let tmp = unsafe { store8(acc[im][ib]) };
                let out_base = ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                od[out_base..out_base + lanes].copy_from_slice(&tmp[..lanes]);
            }
        }
    }
}

/// NEON r-vectorized region driver: tiling identical to
/// `micro::r_region_based`, microkernel swapped for [`r_block_fma`].
#[allow(clippy::too_many_arguments)]
unsafe fn r_region_neon(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    rm: usize,
    rb: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let r_pad = g.r_pad;
    let rm = rm.clamp(1, 8);
    let rb = rb.clamp(1, 8);
    let m_main = m0 + (m1 - m0) / rm * rm;
    let b_main = b0 + (b1 - b0) / rb * rb;
    let mut mi = m0;
    while mi < m_main {
        let mut bi = b0;
        while bi < b_main {
            // SAFETY: `r_block_fma`'s contract (NEON available) is this
            // driver's own contract, discharged by the dispatch probe; its
            // slice accesses are bounds-checked against the packed-buffer
            // formulas that `compiler::verify` certifies per plan.
            unsafe {
                dispatch_rb!(rm, rb, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += rb;
        }
        while bi < b1 {
            // SAFETY: as above.
            unsafe {
                dispatch_rb!(rm, 1, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += 1;
        }
        mi += rm;
    }
    while mi < m1 {
        let mut bi = b0;
        while bi + rb <= b1 {
            // SAFETY: as above.
            unsafe {
                dispatch_rb!(1, rb, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += rb;
        }
        while bi < b1 {
            // SAFETY: as above.
            unsafe { r_block_fma::<1, 1>(&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base) };
            bi += 1;
        }
        mi += 1;
    }
}

/// NEON k-vectorized (dot-product) region: FMA accumulation over `VL`-wide
/// chunks, then the same pairwise horizontal-sum shape as `micro::hsum`
/// and the same scalar tail.
#[allow(clippy::too_many_arguments)]
unsafe fn k_region_neon(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let chunks = l / VL;
    let tail = chunks * VL;
    for mi in m0..m1 {
        for ri in 0..r {
            let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
            for bi in b0..b1 {
                let xrow = &xd[bi * l..(bi + 1) * l];
                // SAFETY: register-only helper; NEON availability is this
                // driver's contract, discharged by the dispatch probe.
                let mut acc = unsafe { zero8() };
                for (gc, xc) in grow[..tail]
                    .chunks_exact(VL)
                    .zip(xrow[..tail].chunks_exact(VL))
                {
                    // SAFETY: `gc` and `xc` are bounds-checked `VL`-long
                    // subslices (`chunks_exact(VL)`), which is `load8`'s
                    // contract; the FMAs are register-only.
                    unsafe {
                        let gv = load8(gc);
                        let xv = load8(xc);
                        acc = F32x8 {
                            lo: vfmaq_f32(acc.lo, gv.lo, xv.lo),
                            hi: vfmaq_f32(acc.hi, gv.hi, xv.hi),
                        };
                    }
                }
                // SAFETY: `hsum8` only spills to its own 4-lane stack array.
                let mut s = unsafe { hsum8(acc) };
                for i in tail..l {
                    s += grow[i] * xrow[i];
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = s;
            }
        }
    }
}
