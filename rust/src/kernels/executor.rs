//! The single plan-driven execution entry point.
//!
//! Every consumer of the optimized Einsum kernels — the serving engine
//! ([`crate::coordinator::engine::TtFcEngine`]), the coordinator's batch
//! dispatch, the comparator baselines and the figure benches — goes through
//! one [`Executor`], which owns:
//!
//! * a **plan cache** keyed by the full [`EinsumDims`] instance (batch
//!   included), so recurring shapes compile once;
//! * **scratch buffers** for single-kernel output and for the einsum-chain
//!   ping-pong, so a warm single-threaded plan (the serving hot-loop
//!   configuration) performs zero heap allocation per request on every `G`
//!   layout, Canonical included — see `rust/tests/alloc_free.rs`.
//!   Multi-threaded plans still allocate their fork/join scratch
//!   (per-thread output slices / merge buffers) each call.
//!
//! Plans come from [`crate::compiler::compile`] by default; staged ablations
//! and measured autotuning override them via [`Executor::set_plan`] /
//! [`Executor::with_tuning`].

use std::collections::HashMap;

use crate::compiler::{compile, verify, OptimizationPlan};
use crate::error::{Error, Result};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost::{self, EinsumDims};
use crate::ttd::TtLayout;

use super::dispatch::{self, Kernel};
use super::exec::{execute_plan_into, execute_plan_into_q};
use super::packed::{pack, PackedG, QuantizedG};

/// Reusable buffers for the serving hot loop (no allocation per request).
#[derive(Debug, Default)]
pub struct Scratch {
    /// Most recent kernel output (`m*b*r` floats, `(m, b, r)` order).
    out: Vec<f32>,
    /// Chain ping-pong partner / current slab.
    chain: Vec<f32>,
}

impl Scratch {
    /// The most recent kernel output (`m*b*r` floats, `(m, b, r)` order).
    pub fn out_slice(&self) -> &[f32] {
        &self.out
    }
}

/// Plan-driven kernel executor: one per engine / bench / baseline harness.
///
/// In the serving pool, one executor exists **per worker per TT layer** —
/// it is plain owned data (`Send`, asserted in `coordinator::engine`), so
/// a worker thread can carry it without locks, and the plan cache /
/// scratch never contend across workers. Plans are compiled
/// deterministically, so independently-built executors over the same
/// machine produce identical plans (and byte-identical kernel output).
pub struct Executor {
    machine: MachineSpec,
    plan_cache: HashMap<EinsumDims, OptimizationPlan>,
    scratch: Scratch,
    /// Reused per-request chain-dims buffer (allocation-free hot loop).
    chain_dims: Vec<EinsumDims>,
    /// Measured RB autotuning on plan-cache misses (see [`super::tune_plan`]).
    tune: bool,
    /// The microkernel set every packed-path execution uses. Selected once
    /// at construction ([`dispatch::select`]); `worker_clone` copies it so
    /// a whole serving pool runs one kernel (bitwise-stable outputs).
    kernel: &'static dyn Kernel,
    /// `true` when the kernel was chosen explicitly ([`Executor::with_kernel`]):
    /// autotuning then keeps it instead of re-ranking kernels.
    kernel_pinned: bool,
}

impl Executor {
    /// A fresh executor planning for `machine`, on the best supported
    /// kernel for this host (portable if `TTRV_FORCE_SCALAR` /
    /// [`dispatch::set_force_scalar`] is active).
    pub fn new(machine: &MachineSpec) -> Self {
        Executor {
            machine: machine.clone(),
            plan_cache: HashMap::new(),
            scratch: Scratch::default(),
            chain_dims: Vec::new(),
            tune: false,
            kernel: dispatch::select(),
            kernel_pinned: false,
        }
    }

    /// A fresh executor pinned to an explicit kernel. Returns
    /// [`Error::Kernel`](crate::error::Error::Kernel) if the kernel is not
    /// supported on this host. Pinned kernels are kept by autotuning
    /// (`tune_chain` ranks RB/thread candidates only).
    pub fn with_kernel(machine: &MachineSpec, kernel: &'static dyn Kernel) -> Result<Self> {
        dispatch::ensure_supported(kernel)?;
        Ok(Self::with_kernel_unchecked(machine, kernel))
    }

    /// [`Executor::with_kernel`] without the support probe — test hook for
    /// faking an unsupported kernel (`tune_chain` must then fail typed).
    pub(crate) fn with_kernel_unchecked(
        machine: &MachineSpec,
        kernel: &'static dyn Kernel,
    ) -> Self {
        let mut ex = Self::new(machine);
        ex.kernel = kernel;
        ex.kernel_pinned = true;
        ex
    }

    /// Enable measured register-blocking autotuning: each plan-cache miss
    /// micro-benchmarks the solver's top candidates on representative
    /// buffers of the planned shapes (EXPERIMENTS.md §Perf iteration 2).
    /// One-time cost per distinct `EinsumDims`. Plans cached before tuning
    /// was enabled (e.g. the batch-1 plans compiled while packing an
    /// engine's cores) are dropped so they get re-tuned on next use — safe,
    /// because tuning only changes RB factors, never the packed layout.
    pub fn with_tuning(mut self) -> Self {
        self.tune = true;
        self.plan_cache.clear();
        self
    }

    /// Whether measured autotuning is enabled (worker clones of a serving
    /// engine propagate this so every pool member tunes the same way).
    pub fn tuning_enabled(&self) -> bool {
        self.tune
    }

    /// A worker-view copy for pool fan-out: same machine and tuning mode,
    /// whatever the plan cache holds at clone time **copied** (plans are
    /// `Copy` and deterministic, so workers skip recompiling those
    /// shapes), scratch and chain buffers fresh. Note that
    /// [`Executor::with_tuning`] clears the cache, so clones of a freshly
    /// tuned engine start cold and tune independently per worker; RB
    /// factors never change result bits, so outputs stay byte-identical
    /// across the pool either way.
    pub fn worker_clone(&self) -> Executor {
        Executor {
            machine: self.machine.clone(),
            plan_cache: self.plan_cache.clone(),
            scratch: Scratch::default(),
            chain_dims: Vec::new(),
            tune: self.tune,
            // same microkernels pool-wide: outputs stay byte-identical
            // across workers even when autotune switched the kernel
            kernel: self.kernel,
            kernel_pinned: self.kernel_pinned,
        }
    }

    /// The machine this executor plans for.
    pub fn machine(&self) -> &MachineSpec {
        &self.machine
    }

    /// Name of the microkernel set this executor dispatches to
    /// (observability: TUNE sections, serving snapshots, BENCH rows).
    pub fn kernel_name(&self) -> &'static str {
        self.kernel.name()
    }

    /// The dispatched kernel object (crate-internal: tune ranking).
    pub(crate) fn kernel(&self) -> &'static dyn Kernel {
        self.kernel
    }

    /// Whether the kernel was explicitly pinned (crate-internal).
    pub(crate) fn kernel_pinned(&self) -> bool {
        self.kernel_pinned
    }

    /// Switch the dispatched kernel (crate-internal: `tune_chain` installs
    /// the measured winner; plans are kernel-independent so the cache and
    /// packed cores stay valid).
    pub(crate) fn set_kernel(&mut self, kernel: &'static dyn Kernel) {
        self.kernel = kernel;
    }

    /// Number of cached plans (one per distinct `EinsumDims`).
    pub fn cached_plans(&self) -> usize {
        self.plan_cache.len()
    }

    /// The compiled (and possibly tuned) plan for an Einsum instance,
    /// computing and caching it on first use.
    pub fn plan(&mut self, dims: &EinsumDims) -> Result<OptimizationPlan> {
        if let Some(p) = self.plan_cache.get(dims) {
            return Ok(*p);
        }
        let mut plan = compile(dims, &self.machine)?;
        if self.tune {
            // representative random buffers of the planned shapes; fixed
            // seed so tuning inputs are reproducible
            let mut rng = crate::util::prng::Rng::new(0x7e57);
            let g = Tensor::randn(vec![dims.r, dims.n, dims.m, dims.k], 0.5, &mut rng);
            let x = Tensor::randn(vec![dims.b, dims.n, dims.k], 0.5, &mut rng);
            plan = super::tune::tune_plan_with_kernel(
                &plan,
                &self.machine,
                &g,
                &x,
                6,
                self.kernel,
            )?;
        }
        verify::verify_plan(&plan)?;
        self.plan_cache.insert(*dims, plan);
        Ok(plan)
    }

    /// Override the cached plan for `plan.dims` (ablation stages, forced
    /// thread counts, externally tuned plans). Subsequent `execute*` calls
    /// for those dims use it verbatim.
    ///
    /// The plan must pass the safety tier of [`verify`] — every cache
    /// insert is a verification chokepoint, so no unverified plan can
    /// reach a kernel region. Rejection is a typed
    /// [`Error::Plan`](crate::error::Error::Plan) naming the violated
    /// invariant.
    pub fn set_plan(&mut self, plan: OptimizationPlan) -> Result<()> {
        verify::verify_plan(&plan)?;
        self.plan_cache.insert(plan.dims, plan);
        Ok(())
    }

    /// Pre-seed the plan cache with previously compiled plans — the
    /// artifact warm-start path ([`crate::artifact`]): a bundle stores the
    /// chain's plans next to its packed cores, so an engine built from it
    /// serves its first request without invoking the compiler at all.
    /// Later cache misses (new batch sizes) still compile normally.
    ///
    /// Every plan is verified ([`verify::verify_plan`]) before insertion —
    /// the one-time cost that keeps the warm-start hot path free of any
    /// per-request checking. A rejected plan aborts the preseed with a
    /// typed error; earlier plans in the slice stay cached.
    pub fn preseed(&mut self, plans: &[OptimizationPlan]) -> Result<()> {
        for plan in plans {
            verify::verify_plan(plan)?;
            self.plan_cache.insert(plan.dims, *plan);
        }
        Ok(())
    }

    /// Pack a canonical core as the (cached) plan for `dims` requires.
    pub fn pack(&mut self, g: &Tensor, dims: &EinsumDims) -> Result<PackedG> {
        let plan = self.plan(dims)?;
        pack(g, &plan)
    }

    /// Execute one planned Einsum, allocating the `(m, b, r)` output tensor.
    pub fn execute(&mut self, dims: &EinsumDims, g: &PackedG, x: &Tensor) -> Result<Tensor> {
        let plan = self.plan(dims)?;
        let mut out = Vec::new();
        execute_plan_into(&plan, self.kernel, g, x.data(), &mut out)?;
        Tensor::from_vec(vec![plan.dims.m, plan.dims.b, plan.dims.r], out)
    }

    /// Execute one planned Einsum over an int8 core (f32 accumulation,
    /// per-`m`-slice dequantization at store — [`super::quantize`]),
    /// allocating the `(m, b, r)` output tensor. Same plan cache as
    /// [`Executor::execute`]: plans are layout properties, not dtype
    /// properties.
    pub fn execute_q(
        &mut self,
        dims: &EinsumDims,
        g: &QuantizedG,
        x: &Tensor,
    ) -> Result<Tensor> {
        let plan = self.plan(dims)?;
        let mut out = Vec::new();
        execute_plan_into_q(&plan, self.kernel, g, x.data(), &mut out)?;
        Tensor::from_vec(vec![plan.dims.m, plan.dims.b, plan.dims.r], out)
    }

    /// Execute into a caller-owned buffer (resized to `m*b*r`). On error the
    /// buffer is left untouched.
    pub fn execute_into(
        &mut self,
        dims: &EinsumDims,
        g: &PackedG,
        xd: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let plan = self.plan(dims)?;
        execute_plan_into(&plan, self.kernel, g, xd, out)
    }

    /// Allocation-free variant: output lands in the executor's scratch and
    /// is returned as a slice (`m*b*r` floats, `(m, b, r)` order).
    pub fn execute_with_scratch(
        &mut self,
        dims: &EinsumDims,
        g: &PackedG,
        xd: &[f32],
    ) -> Result<&[f32]> {
        let plan = self.plan(dims)?;
        execute_plan_into(&plan, self.kernel, g, xd, &mut self.scratch.out)?;
        Ok(&self.scratch.out)
    }

    /// The serving hot path: run a TT layout's whole einsum chain over the
    /// pre-packed cores (processing order, t = d-1 .. 0), ping-ponging
    /// between the two scratch buffers. Returns the final `(M, B)` row-major
    /// slab. Once the caches and buffers are warm this performs zero heap
    /// allocation per call when every step's plan is single-threaded;
    /// multi-threaded steps allocate their fork/join scratch.
    pub fn run_tt_chain(
        &mut self,
        layout: &TtLayout,
        batch: usize,
        packed: &[PackedG],
        x: &[f32],
    ) -> Result<&[f32]> {
        // temporarily move the dims buffer out of self so `self.plan` can be
        // called while iterating it (both need &mut self); restored below so
        // its capacity is reused by the next request
        let mut chain_dims = std::mem::take(&mut self.chain_dims);
        cost::einsum_chain_into(layout, batch, &mut chain_dims);
        let run = self.run_chain_steps(&chain_dims, packed, x);
        self.chain_dims = chain_dims;
        run?;
        Ok(&self.scratch.chain)
    }

    fn run_chain_steps(
        &mut self,
        chain_dims: &[EinsumDims],
        packed: &[PackedG],
        x: &[f32],
    ) -> Result<()> {
        if chain_dims.len() != packed.len() {
            return Err(Error::shape(format!(
                "chain has {} steps but {} packed cores",
                chain_dims.len(),
                packed.len()
            )));
        }
        self.scratch.chain.clear();
        self.scratch.chain.extend_from_slice(x);
        for (dims, g) in chain_dims.iter().zip(packed) {
            let plan = self.plan(dims)?;
            execute_plan_into(&plan, self.kernel, g, &self.scratch.chain, &mut self.scratch.out)?;
            std::mem::swap(&mut self.scratch.chain, &mut self.scratch.out);
        }
        Ok(())
    }

    /// Int8 twin of [`Executor::run_tt_chain`]: the serving hot path over
    /// quantized cores. Same plans, same scratch ping-pong, same zero
    /// warm-path allocation for single-threaded plans — the per-step
    /// execution routes to the kernel's `*_q` regions (f32 accumulation,
    /// per-slice scale at the store).
    pub fn run_tt_chain_q(
        &mut self,
        layout: &TtLayout,
        batch: usize,
        quant: &[QuantizedG],
        x: &[f32],
    ) -> Result<&[f32]> {
        let mut chain_dims = std::mem::take(&mut self.chain_dims);
        cost::einsum_chain_into(layout, batch, &mut chain_dims);
        let run = self.run_chain_steps_q(&chain_dims, quant, x);
        self.chain_dims = chain_dims;
        run?;
        Ok(&self.scratch.chain)
    }

    fn run_chain_steps_q(
        &mut self,
        chain_dims: &[EinsumDims],
        quant: &[QuantizedG],
        x: &[f32],
    ) -> Result<()> {
        if chain_dims.len() != quant.len() {
            return Err(Error::shape(format!(
                "chain has {} steps but {} quantized cores",
                chain_dims.len(),
                quant.len()
            )));
        }
        self.scratch.chain.clear();
        self.scratch.chain.extend_from_slice(x);
        for (dims, g) in chain_dims.iter().zip(quant) {
            let plan = self.plan(dims)?;
            execute_plan_into_q(&plan, self.kernel, g, &self.scratch.chain, &mut self.scratch.out)?;
            std::mem::swap(&mut self.scratch.chain, &mut self.scratch.out);
        }
        Ok(())
    }

    // --- comparator baselines through the same entry point ----------------
    //
    // The baselines keep their own code shape (that is what they measure),
    // but all call sites drive them through the Executor so benches and
    // integration tests have exactly one execution API.

    /// IREE-like baseline (paper Appendix Listing 8), end to end. Shared
    /// (`&self`) because the baselines keep their own code shape — that is
    /// exactly what they measure — and touch no executor state.
    pub fn execute_iree_like(&self, g: &Tensor, x: &Tensor) -> Result<Tensor> {
        crate::baselines::iree_like::einsum(g, x)
    }

    /// IREE-like runtime half over a const-folded `(r*m, n*k)` matrix
    /// (prepare with [`crate::baselines::iree_like::prepare_g`]).
    pub fn execute_iree_prepared(&self, g_mat: &Tensor, r: usize, x: &Tensor) -> Result<Tensor> {
        crate::baselines::iree_like::run(g_mat, x, r)
    }

    /// Pluto-like baseline (polyhedral tiling, scalar, canonical layout).
    pub fn execute_pluto_like(&self, g: &Tensor, x: &Tensor) -> Result<Tensor> {
        crate::baselines::pluto_like::einsum_default(g, x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::compiler::plan::LoopOrder;
    use crate::kernels::pack;
    use crate::machine::MachineSpec;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::ttd::cost::EinsumKind;
    use crate::util::prng::Rng;

    #[test]
    fn scratch_reuse_produces_identical_results() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(70);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 24, b: 17, n: 5, r: 8, k: 8 };
        let mut ex = Executor::new(&machine);
        let g = Tensor::randn(vec![8, 5, 24, 8], 1.0, &mut rng);
        let pg = ex.pack(&g, &dims).unwrap();
        let x1 = Tensor::randn(vec![17, 5, 8], 1.0, &mut rng);
        let x2 = Tensor::randn(vec![17, 5, 8], 1.0, &mut rng);
        let out1 = ex.execute_with_scratch(&dims, &pg, x1.data()).unwrap().to_vec();
        let want1 = tt_einsum_ref(&g, &x1).unwrap();
        let want2 = tt_einsum_ref(&g, &x2).unwrap();
        assert_eq!(out1.len(), want1.numel());
        for (a, b) in out1.iter().zip(want1.data()) {
            assert!((a - b).abs() < 1e-4);
        }
        let out2 = ex.execute_with_scratch(&dims, &pg, x2.data()).unwrap();
        for (a, b) in out2.iter().zip(want2.data()) {
            assert!((a - b).abs() < 1e-4);
        }
        // exactly one plan was compiled for the repeated shape
        assert_eq!(ex.cached_plans(), 1);
    }

    #[test]
    fn forced_multithread_mbrk_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(71);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 37, b: 29, n: 6, r: 8, k: 8 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.threads = 4;
        plan.tile.order = LoopOrder::Mbrk;
        let g = Tensor::randn(vec![8, 6, 37, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![29, 6, 8], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let mut ex = Executor::new(&machine);
        ex.set_plan(plan).unwrap();
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn forced_multithread_bmrk_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(72);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 8, b: 61, n: 6, r: 8, k: 8 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.threads = 3;
        plan.tile.order = LoopOrder::Bmrk;
        let g = Tensor::randn(vec![8, 6, 8, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![61, 6, 8], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let mut ex = Executor::new(&machine);
        ex.set_plan(plan).unwrap();
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn forced_bt_tiling_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(73);
        let dims = EinsumDims { kind: EinsumKind::First, m: 16, b: 53, n: 9, r: 8, k: 1 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.tile.btl = Some(7); // deliberately non-dividing tile
        let g = Tensor::randn(vec![8, 9, 16, 1], 1.0, &mut rng);
        let x = Tensor::randn(vec![53, 9, 1], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let mut ex = Executor::new(&machine);
        ex.set_plan(plan).unwrap();
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn mismatched_layout_is_rejected() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(74);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 4, b: 4, n: 4, r: 8, k: 8 };
        let naive = OptimizationPlan::naive(dims);
        let g = Tensor::randn(vec![8, 4, 4, 8], 1.0, &mut rng);
        let pg_naive = pack(&g, &naive).unwrap();
        let x = Tensor::randn(vec![4, 4, 8], 1.0, &mut rng);
        let mut ex = Executor::new(&machine);
        assert!(ex.execute(&dims, &pg_naive, &x).is_err());
        // bad input length
        let pg = ex.pack(&g, &dims).unwrap();
        let x_bad = Tensor::randn(vec![4, 4, 4], 1.0, &mut rng);
        assert!(ex.execute(&dims, &pg, &x_bad).is_err());
    }

    #[test]
    fn failed_call_leaves_scratch_untouched() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(75);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 6, b: 5, n: 3, r: 8, k: 8 };
        let mut ex = Executor::new(&machine);
        let g = Tensor::randn(vec![8, 3, 6, 8], 1.0, &mut rng);
        let pg = ex.pack(&g, &dims).unwrap();
        let x = Tensor::randn(vec![5, 3, 8], 1.0, &mut rng);
        let good = ex.execute_with_scratch(&dims, &pg, x.data()).unwrap().to_vec();
        // wrong input length: must fail *before* clearing the scratch
        let err = ex.execute_with_scratch(&dims, &pg, &x.data()[..10]);
        assert!(err.is_err());
        assert_eq!(ex.scratch.out_slice(), &good[..], "scratch clobbered by failed call");
    }

    #[test]
    fn explicit_portable_kernel_is_pinned_and_propagates_to_workers() {
        let machine = MachineSpec::spacemit_k1();
        let ex = Executor::with_kernel(&machine, crate::kernels::portable()).unwrap();
        assert_eq!(ex.kernel_name(), "portable");
        assert!(ex.kernel_pinned());
        let w = ex.worker_clone();
        assert_eq!(w.kernel_name(), "portable");
        assert!(w.kernel_pinned());
        // default construction picks *some* supported kernel
        let d = Executor::new(&machine);
        assert!(crate::kernels::all_kernels().iter().any(|k| k.name() == d.kernel_name()));
    }

    #[test]
    fn preseed_fills_the_cache_without_compiling() {
        let machine = MachineSpec::spacemit_k1();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 24, b: 1, n: 5, r: 8, k: 8 };
        let mut source = Executor::new(&machine);
        let plan = source.plan(&dims).unwrap();
        let mut warm = Executor::new(&machine);
        assert_eq!(warm.cached_plans(), 0);
        warm.preseed(&[plan]).unwrap();
        assert_eq!(warm.cached_plans(), 1);
        // the cached plan is returned verbatim
        assert_eq!(warm.plan(&dims).unwrap(), plan);
        assert_eq!(warm.cached_plans(), 1);
    }

    #[test]
    fn unsafe_plans_are_rejected_at_every_cache_insert() {
        // the chokepoint contract: set_plan and preseed refuse a plan that
        // fails the safety tier with a typed Error::Plan naming the
        // invariant, and the cache stays untouched
        let machine = MachineSpec::spacemit_k1();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 8, b: 4, n: 3, r: 8, k: 8 };
        let good = compile(&dims, &machine).unwrap();
        let mut bad = good;
        bad.rb.rm = 0;
        let mut ex = Executor::new(&machine);
        match ex.set_plan(bad) {
            Err(Error::Plan(msg)) => assert!(msg.contains("rb-range"), "{msg}"),
            other => panic!("set_plan must reject rm=0, got {other:?}"),
        }
        assert_eq!(ex.cached_plans(), 0);
        let mut bad = good;
        bad.threads = 0;
        match ex.preseed(&[good, bad]) {
            Err(Error::Plan(msg)) => assert!(msg.contains("threads-positive"), "{msg}"),
            other => panic!("preseed must reject threads=0, got {other:?}"),
        }
        // the good plan before the bad one stays cached (documented order)
        assert_eq!(ex.cached_plans(), 1);
        let mut bad = good;
        bad.vl = 4;
        match ex.set_plan(bad) {
            Err(Error::Plan(msg)) => assert!(msg.contains("vl-matches-packing"), "{msg}"),
            other => panic!("set_plan must reject vl=4, got {other:?}"),
        }
    }

    #[test]
    fn run_tt_chain_q_tracks_the_f32_chain_within_quantization_error() {
        use crate::kernels::packed::quantize;
        use crate::ttd::decompose::random_cores;
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(77);
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let chain1 = cost::einsum_chain(&layout, 1);
        let packed: Vec<PackedG> = chain1
            .iter()
            .enumerate()
            .map(|(step, d)| ex.pack(&tt.cores[layout.d() - 1 - step], d).unwrap())
            .collect();
        let quant: Vec<QuantizedG> = packed.iter().map(quantize).collect();
        let x = Tensor::randn(vec![3, 180], 1.0, &mut rng);
        let want = ex.run_tt_chain(&layout, 3, &packed, x.data()).unwrap().to_vec();
        let got = ex.run_tt_chain_q(&layout, 3, &quant, x.data()).unwrap();
        assert_eq!(got.len(), want.len());
        // int8 per-slice quantization perturbs each core by <= scale/2 per
        // element (~0.4% of the slice max); two chained layers stay well
        // inside a few percent of the output scale
        let scale = want.iter().fold(0.0f32, |a, v| a.max(v.abs())).max(1e-6);
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 0.05 * scale,
                "idx {i}: int8 {a} vs f32 {b} (out scale {scale})"
            );
        }
    }

    #[test]
    fn run_tt_chain_matches_reference_forward() {
        use crate::ttd::decompose::random_cores;
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(76);
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        // pack in processing order with the batch-1 plans
        let chain1 = cost::einsum_chain(&layout, 1);
        let packed: Vec<PackedG> = chain1
            .iter()
            .enumerate()
            .map(|(step, d)| ex.pack(&tt.cores[layout.d() - 1 - step], d).unwrap())
            .collect();
        for batch in [1usize, 3] {
            let x = Tensor::randn(vec![batch, 180], 1.0, &mut rng);
            let out = ex.run_tt_chain(&layout, batch, &packed, x.data()).unwrap();
            let want = crate::ttd::apply::tt_forward(&tt.cores, &x, None).unwrap();
            // out is (M, B); want is (B, M)
            for b in 0..batch {
                for m in 0..100 {
                    let a = out[m * batch + b];
                    let w = want.at(&[b, m]).unwrap();
                    assert!((a - w).abs() < 1e-3, "batch {batch} ({b},{m}): {a} vs {w}");
                }
            }
        }
    }
}
