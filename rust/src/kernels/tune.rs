//! Measured register-blocking / threading autotuner (§Perf iteration 2).
//!
//! The paper's Eq. 18-25 L/S model ranks candidates analytically; on hosts
//! we can *measure*, the top candidates are micro-benchmarked on the real
//! buffers and the fastest wins. Packing depends only on the vectorized
//! loop, not the RB factors or the thread count, so one packed core serves
//! every candidate — which is also why tuned plans are always safe to
//! persist next to analytically-planned packed cores
//! ([`crate::artifact`]'s TUNE section) and why tuning never changes
//! result bits (per-element reduction order is RB/thread-invariant,
//! pinned by `tuned_chain_output_is_bitwise_identical` below).
//!
//! Every timing comparison here runs under a [`MeasureFloor`]: a candidate
//! is measured for at least a minimum wall-clock **and** iteration count
//! (`min-of-samples` over batched runs, see [`timer::min_secs`]). The old
//! best-of-3 `Instant` loop read 0 ns for several candidates on
//! coarse-clock hosts, making the winner arbitrary run to run.
//!
//! The analytic path ([`crate::compiler::compile`]) stays paper-faithful;
//! benches and deployments opt in via [`tune_plan`] /
//! [`Executor::tune_chain`], and `ttrv compress --tune` persists the
//! chain winners into the bundle.

use crate::compiler::plan::OptimizationPlan;
use crate::compiler::regblock;
use crate::error::{Error, Result};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost;
use crate::ttd::TtLayout;
use crate::util::prng::Rng;
use crate::util::timer::{self, MeasureFloor};

use super::exec::execute_plan_into;
use super::executor::Executor;
use super::packed::{pack, PackedG};

/// How many of the solver's top RB candidates each tuning pass measures.
const TUNE_TOP_K: usize = 6;

/// Floored min-of-samples seconds for one candidate plan on fixed buffers
/// ([`timer::try_min_secs`]: warm + validate once, typed error instead of
/// panic or a non-finite result).
fn measure_candidate(
    plan: &OptimizationPlan,
    g: &PackedG,
    xd: &[f32],
    out: &mut Vec<f32>,
    floor: &MeasureFloor,
) -> Result<f64> {
    timer::try_min_secs("autotune candidate", || execute_plan_into(plan, g, xd, out), floor)
}

/// Re-rank the solver's top-`k` RB candidates by measurement under `floor`
/// and return the plan updated with the winner. `g`/`x` are representative
/// buffers of the planned shapes. Strictly-faster wins, so ties keep the
/// analytically-best (first) candidate deterministically.
pub fn tune_plan_floored(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
    floor: &MeasureFloor,
) -> Result<OptimizationPlan> {
    let cands = regblock::candidates(&plan.dims, machine, plan.vector_loop, top_k);
    if cands.len() <= 1 {
        return Ok(*plan);
    }
    let pg = pack(g, plan)?; // layout is RB-invariant
    let mut out = Vec::new();
    let mut best = (*plan, f64::INFINITY);
    for (rb, _ls) in cands {
        let cand_plan = OptimizationPlan { rb, ..*plan };
        let secs = measure_candidate(&cand_plan, &pg, x.data(), &mut out, floor)?;
        if secs < best.1 {
            best = (cand_plan, secs);
        }
    }
    Ok(best.0)
}

/// [`tune_plan_floored`] under the environment floor
/// ([`MeasureFloor::from_env`]): the signature every existing caller
/// (notably [`Executor::plan`] with tuning enabled) uses.
pub fn tune_plan(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
) -> Result<OptimizationPlan> {
    tune_plan_floored(plan, machine, g, x, top_k, &MeasureFloor::from_env())
}

impl Executor {
    /// Measured autotuning of a whole TT einsum chain: for every step of
    /// `layout`'s chain at `batch`, measure the solver's top RB candidates
    /// crossed with thread-count candidates (`{analytic, 1}`) on the
    /// **actual packed cores** (`packed`, processing order), cache each
    /// winner via [`Executor::set_plan`], and return the winners in chain
    /// order.
    ///
    /// Tuning only ever changes RB factors and the thread count — never
    /// the vectorized loop or the `G` layout — so the caller's packed
    /// cores stay valid and result bits are unchanged (reduction order is
    /// RB/thread-invariant). The returned plans are exactly what
    /// `ttrv compress --tune` persists in the artifact TUNE section.
    pub fn tune_chain(
        &mut self,
        layout: &TtLayout,
        batch: usize,
        packed: &[PackedG],
        floor: &MeasureFloor,
    ) -> Result<Vec<OptimizationPlan>> {
        let chain = cost::einsum_chain(layout, batch);
        if chain.len() != packed.len() {
            return Err(Error::shape(format!(
                "tune_chain: chain has {} steps but {} packed cores",
                chain.len(),
                packed.len()
            )));
        }
        // fixed seed: representative inputs are reproducible run to run
        let mut rng = Rng::new(0x7e57_c4a1);
        let mut out = Vec::new();
        let mut winners = Vec::with_capacity(chain.len());
        for (step, dims) in chain.iter().enumerate() {
            let base = self.plan(dims)?;
            let x = rng.normal_vec(dims.b * dims.n * dims.k, 0.5);
            let mut cands: Vec<OptimizationPlan> =
                regblock::candidates(dims, self.machine(), base.vector_loop, TUNE_TOP_K)
                    .into_iter()
                    .map(|(rb, _ls)| OptimizationPlan { rb, ..base })
                    .collect();
            if cands.is_empty() {
                cands.push(base);
            }
            let thread_opts = [base.threads, 1];
            let threads = if base.threads > 1 { &thread_opts[..] } else { &thread_opts[1..] };
            let mut best: Option<(OptimizationPlan, f64)> = None;
            for cand in &cands {
                for &t in threads {
                    let plan = OptimizationPlan { threads: t, ..*cand };
                    let secs = measure_candidate(&plan, &packed[step], &x, &mut out, floor)?;
                    let better = match &best {
                        Some((_, b)) => secs < *b,
                        None => true,
                    };
                    if better {
                        best = Some((plan, secs));
                    }
                }
            }
            let (winner, _) = best.expect("candidate list is non-empty");
            self.set_plan(winner);
            winners.push(winner);
        }
        Ok(winners)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::ttd::cost::{einsum_chain, EinsumDims, EinsumKind};
    use crate::ttd::decompose::random_cores;

    #[test]
    fn tuned_plan_is_valid_and_not_slower_class() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 32, b: 48, n: 8, r: 8, k: 8 };
        let mut rng = Rng::new(123);
        let g = Tensor::randn(vec![8, 8, 32, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![48, 8, 8], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned =
            tune_plan_floored(&plan, &machine, &g, &x, 6, &MeasureFloor::quick()).unwrap();
        // same structure, possibly different RB; must stay within budget
        assert_eq!(tuned.vector_loop, plan.vector_loop);
        assert!(tuned.rb.registers() <= machine.vector_regs as usize);
        // and must still compute the right answer
        let pg = pack(&g, &tuned).unwrap();
        let mut ex = crate::kernels::Executor::new(&machine);
        ex.set_plan(tuned);
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn degenerate_spaces_return_original() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Final, m: 1, b: 1, n: 1, r: 1, k: 1 };
        let mut rng = Rng::new(124);
        let g = Tensor::randn(vec![1, 1, 1, 1], 1.0, &mut rng);
        let x = Tensor::randn(vec![1, 1, 1], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned = tune_plan(&plan, &machine, &g, &x, 4).unwrap();
        assert_eq!(tuned.dims, plan.dims);
    }

    fn packed_chain(
        layout: &TtLayout,
        tt: &crate::ttd::decompose::TtCores,
        ex: &mut Executor,
        batch: usize,
    ) -> Vec<PackedG> {
        einsum_chain(layout, batch)
            .iter()
            .enumerate()
            .map(|(step, dims)| ex.pack(&tt.cores[layout.d() - 1 - step], dims).unwrap())
            .collect()
    }

    #[test]
    fn tune_chain_preserves_structure_and_caches_winners() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut rng = Rng::new(125);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut ex, 1);
        let analytic: Vec<OptimizationPlan> =
            einsum_chain(&layout, 1).iter().map(|d| ex.plan(d).unwrap()).collect();
        let tuned = ex.tune_chain(&layout, 1, &packed, &MeasureFloor::quick()).unwrap();
        assert_eq!(tuned.len(), analytic.len());
        for (t, a) in tuned.iter().zip(&analytic) {
            // dims, vectorized loop and packing layout never change —
            // only RB factors / thread count may
            assert_eq!(t.dims, a.dims);
            assert_eq!(t.vector_loop, a.vector_loop);
            assert_eq!(t.pack_g, a.pack_g);
            assert!(t.rb.registers() <= machine.vector_regs as usize);
            assert!(t.threads >= 1);
            // the winner is what the executor now serves for those dims
            assert_eq!(ex.plan(&t.dims).unwrap(), *t);
        }
    }

    #[test]
    fn tune_chain_rejects_mismatched_cores() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let mut rng = Rng::new(126);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut ex, 1);
        let err = ex.tune_chain(&layout, 1, &packed[..1], &MeasureFloor::quick());
        assert!(err.is_err());
    }

    #[test]
    fn tuned_chain_output_is_bitwise_identical() {
        // tuning may pick any RB/thread winner; the serving output must not
        // move by a single bit (the invariant the artifact TUNE section
        // and the whole pool design lean on)
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![12, 10], vec![10, 18], 8).unwrap();
        let mut rng = Rng::new(127);
        let tt = random_cores(&layout, &mut rng);
        let mut plain = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut plain, 1);
        let x = Tensor::randn(vec![1, layout.n_total() as usize], 1.0, &mut rng);
        let want = plain.run_tt_chain(&layout, 1, &packed, x.data()).unwrap().to_vec();
        let mut tuned_ex = Executor::new(&machine);
        // independent pack (same deterministic plans -> same layout)
        let packed2 = packed_chain(&layout, &tt, &mut tuned_ex, 1);
        tuned_ex.tune_chain(&layout, 1, &packed2, &MeasureFloor::quick()).unwrap();
        let got = tuned_ex.run_tt_chain(&layout, 1, &packed2, x.data()).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "element {i}: {a} vs {b}");
        }
    }
}
