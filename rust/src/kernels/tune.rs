//! Measured register-blocking / threading autotuner (§Perf iteration 2).
//!
//! The paper's Eq. 18-25 L/S model ranks candidates analytically; on hosts
//! we can *measure*, the top candidates are micro-benchmarked on the real
//! buffers and the fastest wins. Packing depends only on the vectorized
//! loop, not the RB factors, the thread count or the kernel, so one packed
//! core serves every candidate — which is also why tuned plans are always
//! safe to persist next to analytically-planned packed cores
//! ([`crate::artifact`]'s TUNE section). For a **fixed kernel**, tuning
//! never changes result bits (per-element reduction order is
//! RB/thread-invariant, pinned by `tuned_chain_output_is_bitwise_identical`
//! below on the portable kernel). [`Executor::tune_chain`] additionally
//! ranks the supported **kernels** (`dispatch::candidate_kernels`) unless
//! the executor's kernel is pinned; switching to a vector kernel does move
//! low-order bits, which is exactly why the bitwise suites pin the portable
//! path (ARCHITECTURE.md "Kernel dispatch").
//!
//! Every timing comparison here runs under a [`MeasureFloor`]: a candidate
//! is measured for at least a minimum wall-clock **and** iteration count
//! (`min-of-samples` over batched runs, see [`timer::min_secs`]). The old
//! best-of-3 `Instant` loop read 0 ns for several candidates on
//! coarse-clock hosts, making the winner arbitrary run to run.
//!
//! The analytic path ([`crate::compiler::compile`]) stays paper-faithful;
//! benches and deployments opt in via [`tune_plan`] /
//! [`Executor::tune_chain`], and `ttrv compress --tune` persists the
//! chain winners into the bundle.

use crate::compiler::plan::OptimizationPlan;
use crate::compiler::regblock;
use crate::error::{Error, Result};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost;
use crate::ttd::TtLayout;
use crate::util::prng::Rng;
use crate::util::timer::{self, MeasureFloor};

use super::dispatch::{self, Kernel};
use super::exec::{execute_plan_into, execute_plan_into_q};
use super::executor::Executor;
use super::packed::{pack, PackedG, QuantizedG};

/// How many of the solver's top RB candidates each tuning pass measures.
const TUNE_TOP_K: usize = 6;

/// Floored min-of-samples seconds for one candidate plan on fixed buffers
/// ([`timer::try_min_secs`]: warm + validate once, typed error instead of
/// panic or a non-finite result).
fn measure_candidate(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &PackedG,
    xd: &[f32],
    out: &mut Vec<f32>,
    floor: &MeasureFloor,
) -> Result<f64> {
    timer::try_min_secs(
        "autotune candidate",
        || execute_plan_into(plan, kernel, g, xd, out),
        floor,
    )
}

/// [`measure_candidate`] for a quantized core: same floored min-of-samples
/// timing, running the int8 execution path.
fn measure_candidate_q(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &QuantizedG,
    xd: &[f32],
    out: &mut Vec<f32>,
    floor: &MeasureFloor,
) -> Result<f64> {
    timer::try_min_secs(
        "autotune int8 candidate",
        || execute_plan_into_q(plan, kernel, g, xd, out),
        floor,
    )
}

/// [`tune_plan_floored`] measuring on an explicit kernel — what
/// [`Executor::plan`] with tuning enabled uses so measurement and serving
/// run the same microkernels.
pub(crate) fn tune_plan_floored_with(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
    floor: &MeasureFloor,
    kernel: &'static dyn Kernel,
) -> Result<OptimizationPlan> {
    dispatch::ensure_supported(kernel)?;
    let cands = regblock::candidates(&plan.dims, machine, plan.vector_loop, top_k);
    if cands.len() <= 1 {
        return Ok(*plan);
    }
    let pg = pack(g, plan)?; // layout is RB- and kernel-invariant
    let mut out = Vec::new();
    let mut best = (*plan, f64::INFINITY);
    for (rb, _ls) in cands {
        let cand_plan = OptimizationPlan { rb, ..*plan };
        let secs = measure_candidate(&cand_plan, kernel, &pg, x.data(), &mut out, floor)?;
        if secs < best.1 {
            best = (cand_plan, secs);
        }
    }
    Ok(best.0)
}

/// Re-rank the solver's top-`k` RB candidates by measurement under `floor`
/// and return the plan updated with the winner. `g`/`x` are representative
/// buffers of the planned shapes. Strictly-faster wins, so ties keep the
/// analytically-best (first) candidate deterministically. Measures on the
/// host's dispatched kernel ([`dispatch::select`]).
pub fn tune_plan_floored(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
    floor: &MeasureFloor,
) -> Result<OptimizationPlan> {
    tune_plan_floored_with(plan, machine, g, x, top_k, floor, dispatch::select())
}

/// [`tune_plan_floored`] under the environment floor
/// ([`MeasureFloor::from_env`]).
pub fn tune_plan(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
) -> Result<OptimizationPlan> {
    tune_plan_floored(plan, machine, g, x, top_k, &MeasureFloor::from_env())
}

/// [`tune_plan_floored_with`] under the environment floor.
pub(crate) fn tune_plan_with_kernel(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
    kernel: &'static dyn Kernel,
) -> Result<OptimizationPlan> {
    tune_plan_floored_with(plan, machine, g, x, top_k, &MeasureFloor::from_env(), kernel)
}

impl Executor {
    /// Measured autotuning of a whole TT einsum chain: for every step of
    /// `layout`'s chain at `batch`, measure the solver's top RB candidates
    /// crossed with thread-count candidates (`{analytic, 1}`) on the
    /// **actual packed cores** (`packed`, processing order), cache each
    /// winner via [`Executor::set_plan`], and return the winners in chain
    /// order.
    ///
    /// Plan tuning only ever changes RB factors and the thread count —
    /// never the vectorized loop or the `G` layout — so the caller's packed
    /// cores stay valid. Unless this executor's kernel was pinned
    /// ([`Executor::with_kernel`]) or force-scalar is active, the supported
    /// **kernels** are ranked alongside: each candidate kernel's per-step
    /// bests are summed over the chain and the kernel with the smallest
    /// total becomes this executor's dispatch (strictly-faster wins, so
    /// ties keep the portable reference). Note a kernel switch — unlike
    /// RB/thread tuning — does move low-order result bits; bitwise suites
    /// therefore pin the portable kernel. The chosen kernel's name is what
    /// `ttrv compress --tune` persists next to the plans in the artifact
    /// TUNE section ([`Executor::kernel_name`]).
    ///
    /// An unsupported executor kernel (possible only via the unchecked
    /// test hook or a stale pin) is a typed [`Error::Kernel`] up front —
    /// never a panic, never an illegal instruction mid-measurement.
    pub fn tune_chain(
        &mut self,
        layout: &TtLayout,
        batch: usize,
        packed: &[PackedG],
        floor: &MeasureFloor,
    ) -> Result<Vec<OptimizationPlan>> {
        dispatch::ensure_supported(self.kernel())?;
        let chain = cost::einsum_chain(layout, batch);
        if chain.len() != packed.len() {
            return Err(Error::shape(format!(
                "tune_chain: chain has {} steps but {} packed cores",
                chain.len(),
                packed.len()
            )));
        }
        let kernels: Vec<&'static dyn Kernel> = if self.kernel_pinned() {
            vec![self.kernel()]
        } else {
            dispatch::candidate_kernels()
        };
        // every candidate kernel must pass the runtime probe before we
        // execute a single instruction of it (the typed-error contract)
        for k in &kernels {
            dispatch::ensure_supported(*k)?;
        }
        // fixed seed: representative inputs are reproducible run to run
        let mut rng = Rng::new(0x7e57_c4a1);
        let mut out = Vec::new();
        // per-kernel chain totals + per-kernel winning plans per step
        let mut totals = vec![0.0f64; kernels.len()];
        let mut winners: Vec<Vec<OptimizationPlan>> =
            kernels.iter().map(|_| Vec::with_capacity(chain.len())).collect();
        for (step, dims) in chain.iter().enumerate() {
            let base = self.plan(dims)?;
            let x = rng.normal_vec(dims.b * dims.n * dims.k, 0.5);
            let mut cands: Vec<OptimizationPlan> =
                regblock::candidates(dims, self.machine(), base.vector_loop, TUNE_TOP_K)
                    .into_iter()
                    .map(|(rb, _ls)| OptimizationPlan { rb, ..base })
                    .collect();
            if cands.is_empty() {
                cands.push(base);
            }
            let thread_opts = [base.threads, 1];
            let threads = if base.threads > 1 { &thread_opts[..] } else { &thread_opts[1..] };
            for (ki, kernel) in kernels.iter().enumerate() {
                let mut best: Option<(OptimizationPlan, f64)> = None;
                for cand in &cands {
                    for &t in threads {
                        let plan = OptimizationPlan { threads: t, ..*cand };
                        let secs =
                            measure_candidate(&plan, *kernel, &packed[step], &x, &mut out, floor)?;
                        let better = match &best {
                            Some((_, b)) => secs < *b,
                            None => true,
                        };
                        if better {
                            best = Some((plan, secs));
                        }
                    }
                }
                let (winner, secs) = best.expect("candidate list is non-empty");
                totals[ki] += secs;
                winners[ki].push(winner);
            }
        }
        // smallest chain total wins; strict inequality keeps the earlier
        // candidate on ties (kernels[0] is the portable reference)
        let mut best_ki = 0;
        for ki in 1..kernels.len() {
            if totals[ki] < totals[best_ki] {
                best_ki = ki;
            }
        }
        self.set_kernel(kernels[best_ki]);
        let plans = winners.swap_remove(best_ki);
        for winner in &plans {
            self.set_plan(*winner)?;
        }
        Ok(plans)
    }

    /// [`Executor::tune_chain`] over **quantized** cores: identical
    /// candidate space (top-K RB × thread counts per step, fixed-seed
    /// representative inputs) measured through the int8 execution path,
    /// and the kernel roster is the int8 family
    /// ([`dispatch::candidate_kernels_q`], int8-portable first) unless
    /// this executor's kernel was pinned. The winning int8 kernel becomes
    /// this executor's dispatch so its name flows into the artifact TUNE
    /// section exactly like the f32 path's.
    pub fn tune_chain_q(
        &mut self,
        layout: &TtLayout,
        batch: usize,
        quant: &[QuantizedG],
        floor: &MeasureFloor,
    ) -> Result<Vec<OptimizationPlan>> {
        dispatch::ensure_supported(self.kernel())?;
        let chain = cost::einsum_chain(layout, batch);
        if chain.len() != quant.len() {
            return Err(Error::shape(format!(
                "tune_chain_q: chain has {} steps but {} quantized cores",
                chain.len(),
                quant.len()
            )));
        }
        let kernels: Vec<&'static dyn Kernel> = if self.kernel_pinned() {
            vec![self.kernel()]
        } else {
            dispatch::candidate_kernels_q()
        };
        for k in &kernels {
            dispatch::ensure_supported(*k)?;
        }
        // same fixed seed as the f32 tuner: comparable representative inputs
        let mut rng = Rng::new(0x7e57_c4a1);
        let mut out = Vec::new();
        let mut totals = vec![0.0f64; kernels.len()];
        let mut winners: Vec<Vec<OptimizationPlan>> =
            kernels.iter().map(|_| Vec::with_capacity(chain.len())).collect();
        for (step, dims) in chain.iter().enumerate() {
            let base = self.plan(dims)?;
            let x = rng.normal_vec(dims.b * dims.n * dims.k, 0.5);
            let mut cands: Vec<OptimizationPlan> =
                regblock::candidates(dims, self.machine(), base.vector_loop, TUNE_TOP_K)
                    .into_iter()
                    .map(|(rb, _ls)| OptimizationPlan { rb, ..base })
                    .collect();
            if cands.is_empty() {
                cands.push(base);
            }
            let thread_opts = [base.threads, 1];
            let threads = if base.threads > 1 { &thread_opts[..] } else { &thread_opts[1..] };
            for (ki, kernel) in kernels.iter().enumerate() {
                let mut best: Option<(OptimizationPlan, f64)> = None;
                for cand in &cands {
                    for &t in threads {
                        let plan = OptimizationPlan { threads: t, ..*cand };
                        let secs =
                            measure_candidate_q(&plan, *kernel, &quant[step], &x, &mut out, floor)?;
                        let better = match &best {
                            Some((_, b)) => secs < *b,
                            None => true,
                        };
                        if better {
                            best = Some((plan, secs));
                        }
                    }
                }
                let (winner, secs) = best.expect("candidate list is non-empty");
                totals[ki] += secs;
                winners[ki].push(winner);
            }
        }
        // smallest chain total wins; strict inequality keeps the earlier
        // candidate on ties (kernels[0] is the int8-portable reference)
        let mut best_ki = 0;
        for ki in 1..kernels.len() {
            if totals[ki] < totals[best_ki] {
                best_ki = ki;
            }
        }
        self.set_kernel(kernels[best_ki]);
        let plans = winners.swap_remove(best_ki);
        for winner in &plans {
            self.set_plan(*winner)?;
        }
        Ok(plans)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::ttd::cost::{einsum_chain, EinsumDims, EinsumKind};
    use crate::ttd::decompose::random_cores;

    #[test]
    fn tuned_plan_is_valid_and_not_slower_class() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 32, b: 48, n: 8, r: 8, k: 8 };
        let mut rng = Rng::new(123);
        let g = Tensor::randn(vec![8, 8, 32, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![48, 8, 8], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned =
            tune_plan_floored(&plan, &machine, &g, &x, 6, &MeasureFloor::quick()).unwrap();
        // same structure, possibly different RB; must stay within budget
        assert_eq!(tuned.vector_loop, plan.vector_loop);
        assert!(tuned.rb.registers() <= machine.vector_regs as usize);
        // and must still compute the right answer
        let pg = pack(&g, &tuned).unwrap();
        let mut ex = crate::kernels::Executor::new(&machine);
        ex.set_plan(tuned).unwrap();
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn degenerate_spaces_return_original() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Final, m: 1, b: 1, n: 1, r: 1, k: 1 };
        let mut rng = Rng::new(124);
        let g = Tensor::randn(vec![1, 1, 1, 1], 1.0, &mut rng);
        let x = Tensor::randn(vec![1, 1, 1], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned = tune_plan(&plan, &machine, &g, &x, 4).unwrap();
        assert_eq!(tuned.dims, plan.dims);
    }

    fn packed_chain(
        layout: &TtLayout,
        tt: &crate::ttd::decompose::TtCores,
        ex: &mut Executor,
        batch: usize,
    ) -> Vec<PackedG> {
        einsum_chain(layout, batch)
            .iter()
            .enumerate()
            .map(|(step, dims)| ex.pack(&tt.cores[layout.d() - 1 - step], dims).unwrap())
            .collect()
    }

    #[test]
    fn tune_chain_preserves_structure_and_caches_winners() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut rng = Rng::new(125);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut ex, 1);
        let analytic: Vec<OptimizationPlan> =
            einsum_chain(&layout, 1).iter().map(|d| ex.plan(d).unwrap()).collect();
        let tuned = ex.tune_chain(&layout, 1, &packed, &MeasureFloor::quick()).unwrap();
        assert_eq!(tuned.len(), analytic.len());
        for (t, a) in tuned.iter().zip(&analytic) {
            // dims, vectorized loop and packing layout never change —
            // only RB factors / thread count may
            assert_eq!(t.dims, a.dims);
            assert_eq!(t.vector_loop, a.vector_loop);
            assert_eq!(t.pack_g, a.pack_g);
            assert!(t.rb.registers() <= machine.vector_regs as usize);
            assert!(t.threads >= 1);
            // the winner is what the executor now serves for those dims
            assert_eq!(ex.plan(&t.dims).unwrap(), *t);
        }
    }

    #[test]
    fn tune_chain_q_preserves_structure_and_selects_an_int8_kernel() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap();
        let mut rng = Rng::new(129);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let quant: Vec<QuantizedG> = packed_chain(&layout, &tt, &mut ex, 1)
            .iter()
            .map(crate::kernels::quantize)
            .collect();
        let analytic: Vec<OptimizationPlan> =
            einsum_chain(&layout, 1).iter().map(|d| ex.plan(d).unwrap()).collect();
        let tuned = ex.tune_chain_q(&layout, 1, &quant, &MeasureFloor::quick()).unwrap();
        assert_eq!(tuned.len(), analytic.len());
        for (t, a) in tuned.iter().zip(&analytic) {
            assert_eq!(t.dims, a.dims);
            assert_eq!(t.vector_loop, a.vector_loop);
            assert_eq!(t.pack_g, a.pack_g);
            assert!(t.rb.registers() <= machine.vector_regs as usize);
            assert!(t.threads >= 1);
            assert_eq!(ex.plan(&t.dims).unwrap(), *t);
        }
        // the roster is the int8 family, so the installed winner must be int8
        let winner = dispatch::by_name(ex.kernel_name())
            .expect("tuned kernel is registered");
        assert!(winner.int8(), "tune_chain_q winner {} must be int8", ex.kernel_name());
    }

    #[test]
    fn tune_chain_q_rejects_mismatched_cores() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let mut rng = Rng::new(130);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let quant: Vec<QuantizedG> = packed_chain(&layout, &tt, &mut ex, 1)
            .iter()
            .map(crate::kernels::quantize)
            .collect();
        assert!(ex.tune_chain_q(&layout, 1, &quant[..1], &MeasureFloor::quick()).is_err());
    }

    #[test]
    fn tune_chain_rejects_mismatched_cores() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let mut rng = Rng::new(126);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut ex, 1);
        let err = ex.tune_chain(&layout, 1, &packed[..1], &MeasureFloor::quick());
        assert!(err.is_err());
    }

    #[test]
    fn tuned_chain_output_is_bitwise_identical() {
        // for a FIXED kernel, tuning may pick any RB/thread winner and the
        // serving output must not move by a single bit (the invariant the
        // artifact TUNE section and the whole pool design lean on). Both
        // executors pin the portable reference kernel so autotune ranks
        // only RB/thread candidates — kernel switches legitimately move
        // bits and are covered by the tolerance suite instead.
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![12, 10], vec![10, 18], 8).unwrap();
        let mut rng = Rng::new(127);
        let tt = random_cores(&layout, &mut rng);
        let mut plain = Executor::with_kernel(&machine, dispatch::portable()).unwrap();
        let packed = packed_chain(&layout, &tt, &mut plain, 1);
        let x = Tensor::randn(vec![1, layout.n_total() as usize], 1.0, &mut rng);
        let want = plain.run_tt_chain(&layout, 1, &packed, x.data()).unwrap().to_vec();
        let mut tuned_ex = Executor::with_kernel(&machine, dispatch::portable()).unwrap();
        // independent pack (same deterministic plans -> same layout)
        let packed2 = packed_chain(&layout, &tt, &mut tuned_ex, 1);
        tuned_ex.tune_chain(&layout, 1, &packed2, &MeasureFloor::quick()).unwrap();
        assert_eq!(tuned_ex.kernel_name(), dispatch::PORTABLE_KERNEL_NAME);
        let got = tuned_ex.run_tt_chain(&layout, 1, &packed2, x.data()).unwrap();
        assert_eq!(got.len(), want.len());
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "element {i}: {a} vs {b}");
        }
    }

    /// A kernel whose runtime probe always fails: `tune_chain` must refuse
    /// it with a typed error before executing a single region.
    struct NeverSupportedKernel;

    impl Kernel for NeverSupportedKernel {
        fn name(&self) -> &'static str {
            "never-supported"
        }
        fn supported(&self) -> bool {
            false
        }
        #[allow(clippy::too_many_arguments)]
        fn r_region(
            &self,
            _g: &PackedG,
            _xd: &[f32],
            _od: &mut [f32],
            _b_total: usize,
            _rm: usize,
            _rb: usize,
            _m0: usize,
            _m1: usize,
            _b0: usize,
            _b1: usize,
            _m_base: usize,
        ) {
            unreachable!("unsupported kernel must never execute");
        }
        #[allow(clippy::too_many_arguments)]
        fn k_region(
            &self,
            _g: &PackedG,
            _xd: &[f32],
            _od: &mut [f32],
            _b_total: usize,
            _m0: usize,
            _m1: usize,
            _b0: usize,
            _b1: usize,
            _m_base: usize,
        ) {
            unreachable!("unsupported kernel must never execute");
        }
    }

    static NEVER: NeverSupportedKernel = NeverSupportedKernel;

    #[test]
    fn tune_chain_rejects_unsupported_kernel_with_typed_error() {
        let machine = MachineSpec::spacemit_k1();
        let layout = TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let mut rng = Rng::new(128);
        let tt = random_cores(&layout, &mut rng);
        // the checked constructor refuses outright...
        let err = Executor::with_kernel(&machine, &NEVER)
            .err()
            .expect("with_kernel must refuse an unsupported kernel");
        match err {
            crate::error::Error::Kernel(msg) => {
                assert!(msg.contains("never-supported"), "message names the kernel: {msg}")
            }
            other => panic!("expected Error::Kernel, got {other:?}"),
        }
        // ...and an executor smuggled past the probe fails typed in
        // tune_chain rather than panicking or executing the kernel
        let mut ex = Executor::with_kernel_unchecked(&machine, &NEVER);
        let mut packer = Executor::new(&machine);
        let packed = packed_chain(&layout, &tt, &mut packer, 1);
        let err = ex
            .tune_chain(&layout, 1, &packed, &MeasureFloor::quick())
            .err()
            .expect("tune_chain must refuse an unsupported kernel");
        match err {
            crate::error::Error::Kernel(_) => {}
            other => panic!("expected Error::Kernel, got {other:?}"),
        }
    }
}
