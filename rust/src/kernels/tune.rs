//! Measured register-blocking autotuner (§Perf iteration 2).
//!
//! The paper's Eq. 18-25 L/S model ranks candidates analytically; on hosts
//! we can *measure*, the top candidates are micro-benchmarked on the real
//! buffers and the fastest wins. Packing depends only on the vectorized
//! loop, not the RB factors, so one packed core serves every candidate.
//!
//! The analytic path ([`crate::compiler::compile`]) stays paper-faithful;
//! benches and deployments opt in via [`tune_plan`].

use std::time::Instant;

use crate::compiler::plan::OptimizationPlan;
use crate::compiler::regblock;
use crate::error::Result;
use crate::machine::MachineSpec;
use crate::tensor::Tensor;

use super::exec::execute_plan_into;
use super::packed::pack;

/// Re-rank the solver's top-`k` RB candidates by measurement and return the
/// plan updated with the winner. `g`/`x` are representative buffers of the
/// planned shapes.
pub fn tune_plan(
    plan: &OptimizationPlan,
    machine: &MachineSpec,
    g: &Tensor,
    x: &Tensor,
    top_k: usize,
) -> Result<OptimizationPlan> {
    let cands = regblock::candidates(&plan.dims, machine, plan.vector_loop, top_k);
    if cands.len() <= 1 {
        return Ok(*plan);
    }
    let pg = pack(g, plan)?; // layout is RB-invariant
    let mut out = Vec::new();
    let mut best = (*plan, f64::INFINITY);
    for (rb, _ls) in cands {
        let cand_plan = OptimizationPlan { rb, ..*plan };
        // warm once, then take the best of 3 (min is the right statistic
        // for short deterministic kernels)
        execute_plan_into(&cand_plan, &pg, x.data(), &mut out)?;
        let mut t_best = f64::INFINITY;
        for _ in 0..3 {
            let t0 = Instant::now();
            execute_plan_into(&cand_plan, &pg, x.data(), &mut out)?;
            t_best = t_best.min(t0.elapsed().as_secs_f64());
        }
        if t_best < best.1 {
            best = (cand_plan, t_best);
        }
    }
    Ok(best.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::ttd::cost::{EinsumDims, EinsumKind};
    use crate::util::prng::Rng;

    #[test]
    fn tuned_plan_is_valid_and_not_slower_class() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 32, b: 48, n: 8, r: 8, k: 8 };
        let mut rng = Rng::new(123);
        let g = Tensor::randn(vec![8, 8, 32, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![48, 8, 8], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned = tune_plan(&plan, &machine, &g, &x, 6).unwrap();
        // same structure, possibly different RB; must stay within budget
        assert_eq!(tuned.vector_loop, plan.vector_loop);
        assert!(tuned.rb.registers() <= machine.vector_regs as usize);
        // and must still compute the right answer
        let pg = pack(&g, &tuned).unwrap();
        let mut ex = crate::kernels::Executor::new(&machine);
        ex.set_plan(tuned);
        let got = ex.execute(&dims, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn degenerate_spaces_return_original() {
        let machine = MachineSpec::host();
        let dims = EinsumDims { kind: EinsumKind::Final, m: 1, b: 1, n: 1, r: 1, k: 1 };
        let mut rng = Rng::new(124);
        let g = Tensor::randn(vec![1, 1, 1, 1], 1.0, &mut rng);
        let x = Tensor::randn(vec![1, 1, 1], 1.0, &mut rng);
        let plan = compile(&dims, &machine).unwrap();
        let tuned = tune_plan(&plan, &machine, &g, &x, 4).unwrap();
        assert_eq!(tuned.dims, plan.dims);
    }
}
