//! x86_64 AVX2/FMA microkernels.
//!
//! Same tiling structure as the portable kernels in [`super::micro`] —
//! identical region drivers, identical `(rm, rb)` register-tile dispatch,
//! identical remainder handling — with the `[f32; VL]` lane arrays replaced
//! by `__m256` registers and the per-lane multiply-then-add replaced by
//! fused multiply-add (`_mm256_fmadd_ps`). FMA skips the intermediate
//! rounding of the product, so results differ from the portable reference
//! by a few ULPs; this kernel is therefore verified by the tolerance-based
//! differential suite (`rust/tests/kernel_reference.rs`), never by bitwise
//! pins (ARCHITECTURE.md "Kernel dispatch").
//!
//! Memory safety: every load/store goes through a bounds-checked subslice
//! (`chunks_exact`, range indexing) before the pointer is taken, and each
//! pointer is read/written for exactly `VL` lanes of that subslice — the
//! sanitizer CI leg runs the packing fuzz + differential suites with these
//! kernels selected to enforce it.

use core::arch::x86_64::{
    __m256, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
    _mm256_storeu_ps,
};

use super::dispatch::Kernel;
use super::micro::dispatch_rb;
use super::packed::PackedG;
use super::VL;

/// AVX2 + FMA kernel set (8 f32 lanes — exactly `VL`).
pub(crate) struct Avx2Kernel;

impl Kernel for Avx2Kernel {
    fn name(&self) -> &'static str {
        "avx2-fma"
    }

    fn supported(&self) -> bool {
        is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
    }

    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        debug_assert!(self.supported());
        // SAFETY: dispatch only hands out this kernel when `supported()`
        // (runtime AVX2+FMA probe) is true — enforced at Executor
        // construction and by `ensure_supported` in tune_chain.
        unsafe { r_region_avx2(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base) }
    }

    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        debug_assert!(self.supported());
        // SAFETY: as above — only reachable when the host probe passed.
        unsafe { k_region_avx2(g, xd, od, b_total, m0, m1, b0, b1, m_base) }
    }
}

/// FMA register-tile block: the AVX2 twin of `micro::r_block`. Kept free of
/// `#[target_feature]` so it can stay generic; `#[inline(always)]` makes it
/// inline into the target-feature region drivers below, which is what
/// enables AVX2 codegen for the intrinsics.
///
/// # Safety
///
/// The caller must guarantee AVX2+FMA are available (all call sites live
/// inside the `target_feature(avx2,fma)` region drivers below).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
unsafe fn r_block_fma<const RM: usize, const RB: usize>(
    gd: &[f32],
    xd: &[f32],
    od: &mut [f32],
    l: usize,
    r: usize,
    r_pad: usize,
    b_total: usize,
    m0: usize,
    b0: usize,
    m_base: usize,
) {
    let rv_count = r_pad / VL;
    // SAFETY: register-only intrinsic, no memory access; AVX2 availability
    // is this function's contract.
    let zero = unsafe { _mm256_setzero_ps() };
    for rv in 0..rv_count {
        let mut acc = [[zero; RB]; RM];
        let mut g_rows: [std::slice::ChunksExact<'_, f32>; RM] = std::array::from_fn(|im| {
            let off = ((m0 + im) * rv_count + rv) * l * VL;
            gd[off..off + l * VL].chunks_exact(VL)
        });
        let x_rows: [&[f32]; RB] =
            std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
        for kk in 0..l {
            let mut gvec = [zero; RM];
            for (im, row) in g_rows.iter_mut().enumerate() {
                let chunk = row.next().expect("length l by construction");
                // SAFETY: `chunk` is a bounds-checked `VL`-long subslice
                // (`chunks_exact(VL)` over a range-indexed row), so the
                // 8-lane unaligned load stays inside it.
                gvec[im] = unsafe { _mm256_loadu_ps(chunk.as_ptr()) };
            }
            for ib in 0..RB {
                // SAFETY: register-only broadcast; no memory access.
                let xs = unsafe { _mm256_set1_ps(x_rows[ib][kk]) };
                for im in 0..RM {
                    // SAFETY: register-only FMA; no memory access.
                    acc[im][ib] = unsafe { _mm256_fmadd_ps(gvec[im], xs, acc[im][ib]) };
                }
            }
        }
        let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
        for im in 0..RM {
            for ib in 0..RB {
                let mut tmp = [0.0f32; VL];
                // SAFETY: `tmp` is exactly `VL` f32s on the stack; the
                // unaligned 8-lane store writes only within it.
                unsafe { _mm256_storeu_ps(tmp.as_mut_ptr(), acc[im][ib]) };
                let out_base = ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                od[out_base..out_base + lanes].copy_from_slice(&tmp[..lanes]);
            }
        }
    }
}

/// AVX2 r-vectorized region driver: tiling identical to
/// `micro::r_region_based`, microkernel swapped for [`r_block_fma`].
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn r_region_avx2(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    rm: usize,
    rb: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let r_pad = g.r_pad;
    let rm = rm.clamp(1, 8);
    let rb = rb.clamp(1, 8);
    let m_main = m0 + (m1 - m0) / rm * rm;
    let b_main = b0 + (b1 - b0) / rb * rb;
    let mut mi = m0;
    while mi < m_main {
        let mut bi = b0;
        while bi < b_main {
            // SAFETY: `r_block_fma`'s contract (AVX2+FMA available) is met
            // inside this `target_feature` region; its slice accesses are
            // bounds-checked against the packed-buffer formulas that
            // `compiler::verify` certifies for every accepted plan.
            unsafe {
                dispatch_rb!(rm, rb, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += rb;
        }
        while bi < b1 {
            // SAFETY: as above.
            unsafe {
                dispatch_rb!(rm, 1, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += 1;
        }
        mi += rm;
    }
    while mi < m1 {
        let mut bi = b0;
        while bi + rb <= b1 {
            // SAFETY: as above.
            unsafe {
                dispatch_rb!(1, rb, r_block_fma,
                    (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
            };
            bi += rb;
        }
        while bi < b1 {
            // SAFETY: as above.
            unsafe { r_block_fma::<1, 1>(&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base) };
            bi += 1;
        }
        mi += 1;
    }
}

/// AVX2 k-vectorized (dot-product) region: FMA accumulation over `VL`-wide
/// chunks, then the same pairwise horizontal-sum shape as `micro::hsum`
/// and the same scalar tail.
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn k_region_avx2(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let chunks = l / VL;
    let tail = chunks * VL;
    for mi in m0..m1 {
        for ri in 0..r {
            let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
            for bi in b0..b1 {
                let xrow = &xd[bi * l..(bi + 1) * l];
                // SAFETY: register-only intrinsic; no memory access.
                let mut acc = unsafe { _mm256_setzero_ps() };
                for (gc, xc) in grow[..tail]
                    .chunks_exact(VL)
                    .zip(xrow[..tail].chunks_exact(VL))
                {
                    // SAFETY: `gc` and `xc` are bounds-checked `VL`-long
                    // subslices (`chunks_exact(VL)`), so both unaligned
                    // 8-lane loads stay inside them; the FMA itself is
                    // register-only.
                    acc = unsafe {
                        _mm256_fmadd_ps(
                            _mm256_loadu_ps(gc.as_ptr()),
                            _mm256_loadu_ps(xc.as_ptr()),
                            acc,
                        )
                    };
                }
                // SAFETY: `hsum_m256` only spills the register to a
                // `VL`-long stack array.
                let mut s = unsafe { hsum_m256(acc) };
                for i in tail..l {
                    s += grow[i] * xrow[i];
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = s;
            }
        }
    }
}

/// Pairwise horizontal sum with the exact association of `micro::hsum`:
/// `(v0+v4 + v2+v6) + (v1+v5 + v3+v7)`.
#[inline(always)]
unsafe fn hsum_m256(v: __m256) -> f32 {
    let mut tmp = [0.0f32; VL];
    // SAFETY: `tmp` is exactly `VL` f32s on the stack; the unaligned
    // 8-lane store writes only within it.
    unsafe { _mm256_storeu_ps(tmp.as_mut_ptr(), v) };
    let s0 = tmp[0] + tmp[4];
    let s1 = tmp[1] + tmp[5];
    let s2 = tmp[2] + tmp[6];
    let s3 = tmp[3] + tmp[7];
    (s0 + s2) + (s1 + s3)
}
