//! Array packing of the constant core `G` (paper §4.3.1, Listing 3).
//!
//! The canonical T3F layout is `G[r][n][m][k]`. The Einsum loop nest reads
//! it as `(m, r-vector-step, n*k, lane)` — so packing rewrites it, at
//! compile time (G is a constant weight), into exactly that order:
//!
//! * `PackedR`: `G_t[m][r/vl][n*k][vl]` — unit-stride vector loads for the
//!   r-vectorized microkernel (Listing 5's layout change);
//! * `PackedK`: `G_t[m][r][n*k]` — unit-stride along the contraction for
//!   the k-vectorized microkernel (Listing 4) and the scalar kernels
//!   (Listing 3's merged `k = n*rt_1` loop).

use crate::compiler::plan::{OptimizationPlan, VectorLoop};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::VL;

/// Which packed layout a [`PackedG`] buffer holds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GLayout {
    /// Canonical `[r][n][m][k]` (naive stage — no packing).
    Canonical,
    /// `[m][r/VL][n*k][VL]` (+ zero padding of r up to a VL multiple).
    PackedR,
    /// `[m][r][n*k]`.
    PackedK,
}

/// A core repacked for the kernel engine.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedG {
    /// Which packed layout `data` holds.
    pub layout: GLayout,
    /// (r, n, m, k) of the canonical core.
    pub dims: (usize, usize, usize, usize),
    /// r rounded up to a VL multiple (PackedR only).
    pub r_pad: usize,
    /// The packed buffer.
    pub data: Vec<f32>,
}

impl PackedG {
    /// Bytes of the packed buffer.
    pub fn bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// An int8-quantized core in one of the [`PackedG`] layouts.
///
/// Quantization is symmetric per `m`-slice: every value belonging to
/// output row `mi` shares one positive scale, `data = round(g / scale)`
/// clamped to `[-127, 127]` (the symmetric int8 range — -128 is never
/// produced so negation stays exact). Indexing of `data` is identical to
/// the f32 buffer of the same layout, including `PackedR` zero pad lanes
/// (a zero quantizes to zero under every scale), so the int8 kernels walk
/// the exact same offsets as their f32 twins.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantizedG {
    /// Which packed layout `data` holds.
    pub layout: GLayout,
    /// (r, n, m, k) of the canonical core.
    pub dims: (usize, usize, usize, usize),
    /// r rounded up to a VL multiple (PackedR only).
    pub r_pad: usize,
    /// Per-`m`-slice dequantization scales, length `m`, all finite and > 0.
    pub scales: Vec<f32>,
    /// The quantized buffer — same length and index formula as the f32
    /// buffer of `layout`.
    pub data: Vec<i8>,
}

impl QuantizedG {
    /// Resident bytes: one byte per lane plus the f32 scale vector.
    pub fn bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Iterate the indices of `data` that belong to `m`-slice `mi`.
    /// `PackedR`/`PackedK` keep each slice contiguous; `Canonical` strides.
    fn slice_indices(
        layout: GLayout,
        dims: (usize, usize, usize, usize),
        r_pad: usize,
        mi: usize,
    ) -> Box<dyn Iterator<Item = usize>> {
        let (r, n, m, k) = dims;
        let l = n * k;
        match layout {
            GLayout::PackedR => Box::new(mi * r_pad * l..(mi + 1) * r_pad * l),
            GLayout::PackedK => Box::new(mi * r * l..(mi + 1) * r * l),
            GLayout::Canonical => {
                // `[r][n][m][k]`: row `mi` owns a k-run every m*k elements
                Box::new((0..r * n).map(move |rn| (rn * m + mi) * k).flat_map(|base| base..base + k))
            }
        }
    }
}

/// Quantize a packed core to int8 with one symmetric scale per `m`-slice.
///
/// The scale is `max|g| / 127` over the slice (1.0 for an all-zero slice so
/// dequantization never divides by zero); pad lanes are zero in the input
/// and stay zero in the output, preserving the `PackedR` contract the
/// vector kernels rely on.
pub fn quantize(p: &PackedG) -> QuantizedG {
    let m = p.dims.2;
    let mut scales = vec![1.0f32; m];
    let mut data = vec![0i8; p.data.len()];
    for (mi, scale) in scales.iter_mut().enumerate() {
        let mut amax = 0.0f32;
        for i in QuantizedG::slice_indices(p.layout, p.dims, p.r_pad, mi) {
            amax = amax.max(p.data[i].abs());
        }
        if amax > 0.0 {
            *scale = amax / 127.0;
        }
        for i in QuantizedG::slice_indices(p.layout, p.dims, p.r_pad, mi) {
            data[i] = (p.data[i] / *scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    QuantizedG { layout: p.layout, dims: p.dims, r_pad: p.r_pad, scales, data }
}

/// Reconstruct the f32 packed buffer a [`QuantizedG`] approximates —
/// the reference the roundtrip property tests bound error against.
pub fn dequantize(q: &QuantizedG) -> PackedG {
    let m = q.dims.2;
    let mut data = vec![0.0f32; q.data.len()];
    for (mi, &scale) in q.scales.iter().enumerate() {
        for i in QuantizedG::slice_indices(q.layout, q.dims, q.r_pad, mi) {
            data[i] = q.data[i] as f32 * scale;
        }
    }
    PackedG { layout: q.layout, dims: q.dims, r_pad: q.r_pad, data }
}

/// Pack `g` as the plan requires.
pub fn pack(g: &Tensor, plan: &OptimizationPlan) -> Result<PackedG> {
    let d = g.dims();
    if d.len() != 4 {
        return Err(Error::shape(format!("core must be rank 4, got {:?}", d)));
    }
    let (r, n, m, k) = (d[0], d[1], d[2], d[3]);
    let dm = &plan.dims;
    if (dm.r, dm.n, dm.m, dm.k) != (r, n, m, k) {
        return Err(Error::shape(format!(
            "plan dims {:?} do not match core {:?}",
            dm, d
        )));
    }
    let gd = g.data();
    let at = |ri: usize, ni: usize, mi: usize, ki: usize| gd[((ri * n + ni) * m + mi) * k + ki];

    if !plan.pack_g {
        return Ok(PackedG {
            layout: GLayout::Canonical,
            dims: (r, n, m, k),
            r_pad: r,
            data: gd.to_vec(),
        });
    }
    match plan.vector_loop {
        VectorLoop::R => {
            let r_pad = r.div_ceil(VL) * VL;
            let l = n * k;
            let mut data = vec![0.0f32; m * r_pad * l];
            for mi in 0..m {
                for rv in 0..r_pad / VL {
                    for ni in 0..n {
                        for ki in 0..k {
                            let kk = ni * k + ki;
                            let base = ((mi * (r_pad / VL) + rv) * l + kk) * VL;
                            for lane in 0..VL {
                                let ri = rv * VL + lane;
                                if ri < r {
                                    data[base + lane] = at(ri, ni, mi, ki);
                                }
                            }
                        }
                    }
                }
            }
            Ok(PackedG { layout: GLayout::PackedR, dims: (r, n, m, k), r_pad, data })
        }
        VectorLoop::K | VectorLoop::None => {
            let l = n * k;
            let mut data = vec![0.0f32; m * r * l];
            for mi in 0..m {
                for ri in 0..r {
                    for ni in 0..n {
                        for ki in 0..k {
                            data[(mi * r + ri) * l + ni * k + ki] = at(ri, ni, mi, ki);
                        }
                    }
                }
            }
            Ok(PackedG { layout: GLayout::PackedK, dims: (r, n, m, k), r_pad: r, data })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{LoopOrder, RbFactors, TilePlan};
    use crate::ttd::cost::{EinsumDims, EinsumKind};
    use crate::util::prng::Rng;

    fn plan_for(g_dims: (usize, usize, usize, usize), vloop: VectorLoop, pack_g: bool) -> OptimizationPlan {
        let (r, n, m, k) = g_dims;
        OptimizationPlan {
            dims: EinsumDims { kind: EinsumKind::Middle, m, b: 4, n, r, k },
            pack_g,
            vector_loop: vloop,
            vl: VL,
            rb: RbFactors::NONE,
            tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
            threads: 1,
            ls_estimate: 0,
        }
    }

    #[test]
    fn packed_r_layout_roundtrip() {
        let mut rng = Rng::new(50);
        let g = Tensor::randn(vec![8, 3, 5, 2], 1.0, &mut rng);
        let p = pack(&g, &plan_for((8, 3, 5, 2), VectorLoop::R, true)).unwrap();
        assert_eq!(p.layout, GLayout::PackedR);
        assert_eq!(p.r_pad, 8);
        // check a handful of entries
        let l = 3 * 2;
        for (ri, ni, mi, ki) in [(0, 0, 0, 0), (7, 2, 4, 1), (3, 1, 2, 0)] {
            let kk = ni * 2 + ki;
            let packed = p.data[((mi * 1 + 0) * l + kk) * VL + ri];
            assert_eq!(packed, g.at(&[ri, ni, mi, ki]).unwrap());
        }
    }

    #[test]
    fn packed_r_pads_odd_r_with_zeros() {
        let mut rng = Rng::new(51);
        let g = Tensor::randn(vec![3, 2, 2, 1], 1.0, &mut rng);
        let p = pack(&g, &plan_for((3, 2, 2, 1), VectorLoop::R, true)).unwrap();
        assert_eq!(p.r_pad, 8);
        // lanes 3..8 must be zero
        for mi in 0..2 {
            for kk in 0..2 {
                for lane in 3..8 {
                    assert_eq!(p.data[(mi * 2 + kk) * VL + lane], 0.0);
                }
            }
        }
    }

    #[test]
    fn packed_k_layout_roundtrip() {
        let mut rng = Rng::new(52);
        let g = Tensor::randn(vec![2, 3, 4, 8], 1.0, &mut rng);
        let p = pack(&g, &plan_for((2, 3, 4, 8), VectorLoop::K, true)).unwrap();
        assert_eq!(p.layout, GLayout::PackedK);
        let l = 3 * 8;
        for (ri, ni, mi, ki) in [(0, 0, 0, 0), (1, 2, 3, 7), (1, 1, 2, 4)] {
            assert_eq!(
                p.data[(mi * 2 + ri) * l + ni * 8 + ki],
                g.at(&[ri, ni, mi, ki]).unwrap()
            );
        }
    }

    #[test]
    fn canonical_when_packing_disabled() {
        let mut rng = Rng::new(53);
        let g = Tensor::randn(vec![2, 2, 2, 2], 1.0, &mut rng);
        let p = pack(&g, &plan_for((2, 2, 2, 2), VectorLoop::None, false)).unwrap();
        assert_eq!(p.layout, GLayout::Canonical);
        assert_eq!(p.data, g.data());
    }

    #[test]
    fn quantize_roundtrip_error_is_within_half_a_step_per_slice() {
        let mut rng = Rng::new(54);
        let g = Tensor::randn(vec![5, 3, 4, 2], 1.0, &mut rng);
        for vloop in [VectorLoop::R, VectorLoop::K, VectorLoop::None] {
            let p = pack(&g, &plan_for((5, 3, 4, 2), vloop, vloop != VectorLoop::None)).unwrap();
            let q = quantize(&p);
            assert_eq!(q.layout, p.layout);
            assert_eq!(q.data.len(), p.data.len());
            assert_eq!(q.scales.len(), 4);
            let back = dequantize(&q);
            for mi in 0..4 {
                let step = q.scales[mi];
                assert!(step > 0.0 && step.is_finite());
                for i in QuantizedG::slice_indices(p.layout, p.dims, p.r_pad, mi) {
                    let err = (back.data[i] - p.data[i]).abs();
                    assert!(err <= step / 2.0 + 1e-7, "slice {mi} idx {i}: err {err} > {step}/2");
                }
            }
        }
    }

    #[test]
    fn quantize_keeps_packed_r_pad_lanes_zero() {
        let mut rng = Rng::new(55);
        let g = Tensor::randn(vec![3, 2, 2, 1], 1.0, &mut rng);
        let p = pack(&g, &plan_for((3, 2, 2, 1), VectorLoop::R, true)).unwrap();
        let q = quantize(&p);
        assert_eq!(q.r_pad, 8);
        for mi in 0..2 {
            for kk in 0..2 {
                for lane in 3..8 {
                    assert_eq!(q.data[(mi * 2 + kk) * VL + lane], 0);
                }
            }
        }
    }

    #[test]
    fn quantize_all_zero_slice_uses_unit_scale() {
        let g = Tensor::zeros(vec![2, 2, 3, 2]);
        let p = pack(&g, &plan_for((2, 2, 3, 2), VectorLoop::K, true)).unwrap();
        let q = quantize(&p);
        assert_eq!(q.scales, vec![1.0; 3]);
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(dequantize(&q).data, p.data);
    }

    #[test]
    fn quantized_bytes_are_a_quarter_of_f32_plus_scales() {
        let mut rng = Rng::new(56);
        let g = Tensor::randn(vec![8, 3, 5, 2], 1.0, &mut rng);
        let p = pack(&g, &plan_for((8, 3, 5, 2), VectorLoop::R, true)).unwrap();
        let q = quantize(&p);
        assert_eq!(q.bytes(), p.bytes() / 4 + 5 * 4);
    }

    #[test]
    fn dims_mismatch_rejected() {
        let g = Tensor::zeros(vec![2, 2, 2, 2]);
        let p = plan_for((2, 2, 3, 2), VectorLoop::R, true);
        assert!(pack(&g, &p).is_err());
        assert!(pack(&Tensor::zeros(vec![2, 2, 2]), &p).is_err());
    }
}
