//! Plan execution internals: loop order, bt tiling, thread parallelization
//! around the microkernels (paper §4.3.5 + §4.2.3).
//!
//! This module is crate-private; the single public entry point is
//! [`super::Executor`]. All validation happens *before* the output buffer is
//! touched, so a failed call leaves caller scratch exactly as it was.

use crate::compiler::plan::{LoopOrder, OptimizationPlan, VectorLoop};
use crate::error::{Error, Result};

use super::dispatch::Kernel;
use super::naive::{naive_region, naive_region_q};
use super::packed::{GLayout, PackedG, QuantizedG};

/// Execute a planned Einsum into a caller-owned buffer (resized to `m*b*r`)
/// using `kernel`'s microkernels for the packed paths (the Canonical/naive
/// stage is layout-bound and kernel-independent).
///
/// Validation order matters: every precondition (plan/core dims, input
/// length, packing layout) is checked before `out` is cleared or resized, so
/// an `Err` return cannot expose a half-initialized buffer.
pub(crate) fn execute_plan_into(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &PackedG,
    xd: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    let d = &plan.dims;
    let (r, n, m, k) = g.dims;
    if (d.r, d.n, d.m, d.k) != (r, n, m, k) {
        return Err(Error::shape(format!("plan dims {d:?} vs core {:?}", g.dims)));
    }
    if xd.len() != d.b * n * k {
        return Err(Error::shape(format!(
            "input len {} != b*n*k = {}",
            xd.len(),
            d.b * n * k
        )));
    }
    // layout/vector-loop consistency
    let expected_layout = match (plan.pack_g, plan.vector_loop) {
        (false, _) => GLayout::Canonical,
        (true, VectorLoop::R) => GLayout::PackedR,
        (true, _) => GLayout::PackedK,
    };
    if g.layout != expected_layout {
        return Err(Error::plan(format!(
            "core packed as {:?} but plan requires {:?}",
            g.layout, expected_layout
        )));
    }

    out.clear();
    out.resize(m * d.b * r, 0.0);

    if g.layout == GLayout::Canonical {
        // naive stage: the Listing-2 loop nest straight into the caller's
        // buffer — no Tensor round-trip, no per-call allocation
        naive_region(&g.data, xd, &mut out[..], r, n, m, k, d.b);
        return Ok(());
    }

    let threads = plan.threads.max(1) as usize;
    let b_total = d.b;
    // bt tile bound (Eq. 28); full extent when untiled
    let btl = plan.tile.btl.unwrap_or(b_total).max(1);

    if threads == 1 {
        let od = &mut out[..];
        let mut b0 = 0;
        while b0 < b_total {
            let b1 = (b0 + btl).min(b_total);
            run_region(plan, kernel, g, xd, od, b_total, 0, m, b0, b1);
            b0 = b1;
        }
        return Ok(());
    }

    match plan.tile.order {
        LoopOrder::Mbrk => {
            // parallelize mt: output is m-major, so thread slices are
            // contiguous and can be split safely
            let rows_per = m.div_ceil(threads);
            let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = &mut out[..];
            let mut m0 = 0;
            while m0 < m {
                let m1 = (m0 + rows_per).min(m);
                let (head, tail) = rest.split_at_mut((m1 - m0) * b_total * r);
                slices.push((m0, m1, head));
                rest = tail;
                m0 = m1;
            }
            std::thread::scope(|s| {
                for (m0, m1, out_slice) in slices {
                    s.spawn(move || {
                        let mut b0 = 0;
                        while b0 < b_total {
                            let b1 = (b0 + btl).min(b_total);
                            // out_slice starts at row m0: shift base by -m0
                            run_region_offset(
                                plan, kernel, g, xd, out_slice, b_total, m0, m1, b0, b1, m0,
                            );
                            b0 = b1;
                        }
                    });
                }
            });
            Ok(())
        }
        LoopOrder::Bmrk => {
            // parallelize bt: output is b-strided; compute into per-thread
            // temps and merge (safe; the host measurement path is
            // single-threaded anyway — DESIGN.md §3)
            let cols_per = b_total.div_ceil(threads);
            let mut ranges = Vec::new();
            let mut b0 = 0;
            while b0 < b_total {
                let b1 = (b0 + cols_per).min(b_total);
                ranges.push((b0, b1));
                b0 = b1;
            }
            let chunks: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
                let handles: Vec<_> = ranges
                    .into_iter()
                    .map(|(b0, b1)| {
                        s.spawn(move || {
                            let width = b1 - b0;
                            let mut local = vec![0.0f32; m * width * r];
                            // local is (m, width, r) with b rebased to 0
                            let xl: Vec<f32> = xd[b0 * n * k..b1 * n * k].to_vec();
                            let mut plan_local = *plan;
                            plan_local.dims.b = width;
                            run_region(
                                &plan_local, kernel, g, &xl, &mut local, width, 0, m, 0, width,
                            );
                            (b0, b1, local)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            });
            for (b0, b1, local) in chunks {
                let width = b1 - b0;
                for mi in 0..m {
                    for bi in 0..width {
                        let src = (mi * width + bi) * r;
                        let dst = (mi * b_total + b0 + bi) * r;
                        out[dst..dst + r].copy_from_slice(&local[src..src + r]);
                    }
                }
            }
            Ok(())
        }
    }
}

/// Int8 twin of [`execute_plan_into`]: the same validation order, the same
/// bt tiling and thread parallelization, with the region dispatch routed to
/// the kernel's `*_q` methods over a [`QuantizedG`]. Kept as a mirror
/// rather than a generic driver so the f32 hot path stays monomorphic and
/// byte-identical to what every bitwise pin was recorded against.
pub(crate) fn execute_plan_into_q(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &QuantizedG,
    xd: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    let d = &plan.dims;
    let (r, n, m, k) = g.dims;
    if (d.r, d.n, d.m, d.k) != (r, n, m, k) {
        return Err(Error::shape(format!("plan dims {d:?} vs core {:?}", g.dims)));
    }
    if g.scales.len() != m {
        return Err(Error::shape(format!(
            "quantized core has {} scales for m = {m}",
            g.scales.len()
        )));
    }
    if xd.len() != d.b * n * k {
        return Err(Error::shape(format!(
            "input len {} != b*n*k = {}",
            xd.len(),
            d.b * n * k
        )));
    }
    let expected_layout = match (plan.pack_g, plan.vector_loop) {
        (false, _) => GLayout::Canonical,
        (true, VectorLoop::R) => GLayout::PackedR,
        (true, _) => GLayout::PackedK,
    };
    if g.layout != expected_layout {
        return Err(Error::plan(format!(
            "core packed as {:?} but plan requires {:?}",
            g.layout, expected_layout
        )));
    }

    out.clear();
    out.resize(m * d.b * r, 0.0);

    if g.layout == GLayout::Canonical {
        naive_region_q(&g.data, &g.scales, xd, &mut out[..], r, n, m, k, d.b);
        return Ok(());
    }

    let threads = plan.threads.max(1) as usize;
    let b_total = d.b;
    let btl = plan.tile.btl.unwrap_or(b_total).max(1);

    if threads == 1 {
        let od = &mut out[..];
        let mut b0 = 0;
        while b0 < b_total {
            let b1 = (b0 + btl).min(b_total);
            run_region_offset_q(plan, kernel, g, xd, od, b_total, 0, m, b0, b1, 0);
            b0 = b1;
        }
        return Ok(());
    }

    match plan.tile.order {
        LoopOrder::Mbrk => {
            let rows_per = m.div_ceil(threads);
            let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = &mut out[..];
            let mut m0 = 0;
            while m0 < m {
                let m1 = (m0 + rows_per).min(m);
                let (head, tail) = rest.split_at_mut((m1 - m0) * b_total * r);
                slices.push((m0, m1, head));
                rest = tail;
                m0 = m1;
            }
            std::thread::scope(|s| {
                for (m0, m1, out_slice) in slices {
                    s.spawn(move || {
                        let mut b0 = 0;
                        while b0 < b_total {
                            let b1 = (b0 + btl).min(b_total);
                            run_region_offset_q(
                                plan, kernel, g, xd, out_slice, b_total, m0, m1, b0, b1, m0,
                            );
                            b0 = b1;
                        }
                    });
                }
            });
            Ok(())
        }
        LoopOrder::Bmrk => {
            let cols_per = b_total.div_ceil(threads);
            let mut ranges = Vec::new();
            let mut b0 = 0;
            while b0 < b_total {
                let b1 = (b0 + cols_per).min(b_total);
                ranges.push((b0, b1));
                b0 = b1;
            }
            let chunks: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
                let handles: Vec<_> = ranges
                    .into_iter()
                    .map(|(b0, b1)| {
                        s.spawn(move || {
                            let width = b1 - b0;
                            let mut local = vec![0.0f32; m * width * r];
                            let xl: Vec<f32> = xd[b0 * n * k..b1 * n * k].to_vec();
                            let mut plan_local = *plan;
                            plan_local.dims.b = width;
                            run_region_offset_q(
                                &plan_local, kernel, g, &xl, &mut local, width, 0, m, 0, width, 0,
                            );
                            (b0, b1, local)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            });
            for (b0, b1, local) in chunks {
                let width = b1 - b0;
                for mi in 0..m {
                    for bi in 0..width {
                        let src = (mi * width + bi) * r;
                        let dst = (mi * b_total + b0 + bi) * r;
                        out[dst..dst + r].copy_from_slice(&local[src..src + r]);
                    }
                }
            }
            Ok(())
        }
    }
}

/// Dispatch a rectangular region to the plan's microkernel on `kernel`.
#[allow(clippy::too_many_arguments)]
fn run_region(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
) {
    run_region_offset(plan, kernel, g, xd, od, b_total, m0, m1, b0, b1, 0)
}

/// Same as [`run_region`] but with the output buffer starting at row
/// `m_base` (for contiguous per-thread slices).
#[allow(clippy::too_many_arguments)]
fn run_region_offset(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    // microkernels index output by absolute m; rebase via a shifted slice
    // trick: when m_base > 0, we conceptually pass od starting at negative
    // offset. Implemented by adjusting m bounds and core offsets instead:
    // the packed-G reads use absolute m, output uses (m - m_base).
    match plan.vector_loop {
        VectorLoop::R => kernel.r_region(
            g, xd, od, b_total, plan.rb.rm, plan.rb.rb, m0, m1, b0, b1, m_base,
        ),
        VectorLoop::K => kernel.k_region(g, xd, od, b_total, m0, m1, b0, b1, m_base),
        VectorLoop::None => kernel.scalar_region(g, xd, od, b_total, m0, m1, b0, b1, m_base),
    }
}

/// Int8 twin of [`run_region_offset`]: routes to the `*_q` region methods.
#[allow(clippy::too_many_arguments)]
fn run_region_offset_q(
    plan: &OptimizationPlan,
    kernel: &'static dyn Kernel,
    g: &QuantizedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    match plan.vector_loop {
        VectorLoop::R => kernel.r_region_q(
            g, xd, od, b_total, plan.rb.rm, plan.rb.rb, m0, m1, b0, b1, m_base,
        ),
        VectorLoop::K => kernel.k_region_q(g, xd, od, b_total, m0, m1, b0, b1, m_base),
        VectorLoop::None => kernel.scalar_region_q(g, xd, od, b_total, m0, m1, b0, b1, m_base),
    }
}
