//! Plan executor: loop order, bt tiling, thread parallelization around the
//! microkernels (paper §4.3.5 + §4.2.3).

use crate::compiler::plan::{LoopOrder, OptimizationPlan, VectorLoop};
use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::micro;
use super::naive::naive_einsum;
use super::packed::{GLayout, PackedG};

/// Reusable buffers for the serving hot loop (no allocation per request).
#[derive(Debug, Default)]
pub struct Scratch {
    out: Vec<f32>,
}

impl Scratch {
    /// The most recent kernel output (`m*b*r` floats, `(m, b, r)` order).
    pub fn out_slice(&self) -> &[f32] {
        &self.out
    }
}

/// Execute a planned Einsum: `x (b, n, k)` against the packed core,
/// producing `(m, b, r)`.
pub fn execute(plan: &OptimizationPlan, g: &PackedG, x: &Tensor) -> Result<Tensor> {
    let mut out = Vec::new();
    let d = &plan.dims;
    execute_into(plan, g, x.data(), &mut out)?;
    Tensor::from_vec(vec![d.m, d.b, d.r], out)
}

/// Allocation-free variant: output lands in `scratch.out` (`m*b*r` floats).
pub fn execute_with_scratch(
    plan: &OptimizationPlan,
    g: &PackedG,
    xd: &[f32],
    scratch: &mut Scratch,
) -> Result<()> {
    execute_into(plan, g, xd, &mut scratch.out)
}

/// Core executor writing into a caller-owned buffer (resized to `m*b*r`).
pub fn execute_into(
    plan: &OptimizationPlan,
    g: &PackedG,
    xd: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    let d = &plan.dims;
    let (r, n, m, k) = g.dims;
    if (d.r, d.n, d.m, d.k) != (r, n, m, k) {
        return Err(Error::shape(format!("plan dims {d:?} vs core {:?}", g.dims)));
    }
    if xd.len() != d.b * n * k {
        return Err(Error::shape(format!(
            "input len {} != b*n*k = {}",
            xd.len(),
            d.b * n * k
        )));
    }
    // layout/vector-loop consistency
    let expected_layout = match (plan.pack_g, plan.vector_loop) {
        (false, _) => GLayout::Canonical,
        (true, VectorLoop::R) => GLayout::PackedR,
        (true, _) => GLayout::PackedK,
    };
    if g.layout != expected_layout {
        return Err(Error::plan(format!(
            "core packed as {:?} but plan requires {:?}",
            g.layout, expected_layout
        )));
    }

    out.clear();
    out.resize(m * d.b * r, 0.0);

    if g.layout == GLayout::Canonical {
        // naive stage: run the Listing-2 loop nest
        let gt = Tensor::from_vec(vec![r, n, m, k], g.data.clone())?;
        let xt = Tensor::from_vec(vec![d.b, n, k], xd.to_vec())?;
        let naive = naive_einsum(&gt, &xt)?;
        out.copy_from_slice(naive.data());
        return Ok(());
    }

    let threads = plan.threads.max(1) as usize;
    let b_total = d.b;
    // bt tile bound (Eq. 28); full extent when untiled
    let btl = plan.tile.btl.unwrap_or(b_total).max(1);

    if threads == 1 {
        let od = &mut out[..];
        let mut b0 = 0;
        while b0 < b_total {
            let b1 = (b0 + btl).min(b_total);
            run_region(plan, g, xd, od, b_total, 0, m, b0, b1);
            b0 = b1;
        }
        return Ok(());
    }

    match plan.tile.order {
        LoopOrder::Mbrk => {
            // parallelize mt: output is m-major, so thread slices are
            // contiguous and can be split safely
            let rows_per = m.div_ceil(threads);
            let mut slices: Vec<(usize, usize, &mut [f32])> = Vec::new();
            let mut rest: &mut [f32] = &mut out[..];
            let mut m0 = 0;
            while m0 < m {
                let m1 = (m0 + rows_per).min(m);
                let (head, tail) = rest.split_at_mut((m1 - m0) * b_total * r);
                slices.push((m0, m1, head));
                rest = tail;
                m0 = m1;
            }
            std::thread::scope(|s| {
                for (m0, m1, out_slice) in slices {
                    s.spawn(move || {
                        let mut b0 = 0;
                        while b0 < b_total {
                            let b1 = (b0 + btl).min(b_total);
                            // out_slice starts at row m0: shift base by -m0
                            run_region_offset(
                                plan, g, xd, out_slice, b_total, m0, m1, b0, b1, m0,
                            );
                            b0 = b1;
                        }
                    });
                }
            });
            Ok(())
        }
        LoopOrder::Bmrk => {
            // parallelize bt: output is b-strided; compute into per-thread
            // temps and merge (safe; the host measurement path is
            // single-threaded anyway — DESIGN.md §3)
            let cols_per = b_total.div_ceil(threads);
            let mut ranges = Vec::new();
            let mut b0 = 0;
            while b0 < b_total {
                let b1 = (b0 + cols_per).min(b_total);
                ranges.push((b0, b1));
                b0 = b1;
            }
            let chunks: Vec<(usize, usize, Vec<f32>)> = std::thread::scope(|s| {
                let handles: Vec<_> = ranges
                    .into_iter()
                    .map(|(b0, b1)| {
                        s.spawn(move || {
                            let width = b1 - b0;
                            let mut local = vec![0.0f32; m * width * r];
                            // local is (m, width, r) with b rebased to 0
                            let xl: Vec<f32> = xd[b0 * n * k..b1 * n * k].to_vec();
                            let mut plan_local = *plan;
                            plan_local.dims.b = width;
                            run_region(&plan_local, g, &xl, &mut local, width, 0, m, 0, width);
                            (b0, b1, local)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
            });
            for (b0, b1, local) in chunks {
                let width = b1 - b0;
                for mi in 0..m {
                    for bi in 0..width {
                        let src = (mi * width + bi) * r;
                        let dst = (mi * b_total + b0 + bi) * r;
                        out[dst..dst + r].copy_from_slice(&local[src..src + r]);
                    }
                }
            }
            Ok(())
        }
    }
}

/// Dispatch a rectangular region to the plan's microkernel.
#[allow(clippy::too_many_arguments)]
fn run_region(
    plan: &OptimizationPlan,
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
) {
    run_region_offset(plan, g, xd, od, b_total, m0, m1, b0, b1, 0)
}

/// Same as [`run_region`] but with the output buffer starting at row
/// `m_base` (for contiguous per-thread slices).
#[allow(clippy::too_many_arguments)]
fn run_region_offset(
    plan: &OptimizationPlan,
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    // microkernels index output by absolute m; rebase via a shifted slice
    // trick: when m_base > 0, we conceptually pass od starting at negative
    // offset. Implemented by adjusting m bounds and core offsets instead:
    // the packed-G reads use absolute m, output uses (m - m_base).
    match plan.vector_loop {
        VectorLoop::R => micro::r_region_based(
            g, xd, od, b_total, plan.rb.rm, plan.rb.rb, m0, m1, b0, b1, m_base,
        ),
        VectorLoop::K => micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base),
        VectorLoop::None => {
            micro::scalar_packed_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::compile;
    use crate::kernels::pack;
    use crate::machine::MachineSpec;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::ttd::cost::{EinsumDims, EinsumKind};
    use crate::util::prng::Rng;

    #[test]
    fn scratch_reuse_produces_identical_results() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(70);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 24, b: 17, n: 5, r: 8, k: 8 };
        let plan = compile(&dims, &machine).unwrap();
        let g = Tensor::randn(vec![8, 5, 24, 8], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let mut scratch = Scratch::default();
        let x1 = Tensor::randn(vec![17, 5, 8], 1.0, &mut rng);
        let x2 = Tensor::randn(vec![17, 5, 8], 1.0, &mut rng);
        execute_with_scratch(&plan, &pg, x1.data(), &mut scratch).unwrap();
        let out1 = scratch.out_slice().to_vec();
        execute_with_scratch(&plan, &pg, x2.data(), &mut scratch).unwrap();
        let want1 = tt_einsum_ref(&g, &x1).unwrap();
        let want2 = tt_einsum_ref(&g, &x2).unwrap();
        assert_eq!(out1.len(), want1.numel());
        for (a, b) in out1.iter().zip(want1.data()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in scratch.out_slice().iter().zip(want2.data()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn forced_multithread_mbrk_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(71);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 37, b: 29, n: 6, r: 8, k: 8 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.threads = 4;
        plan.tile.order = LoopOrder::Mbrk;
        let g = Tensor::randn(vec![8, 6, 37, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![29, 6, 8], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let got = execute(&plan, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn forced_multithread_bmrk_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(72);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 8, b: 61, n: 6, r: 8, k: 8 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.threads = 3;
        plan.tile.order = LoopOrder::Bmrk;
        let g = Tensor::randn(vec![8, 6, 8, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![61, 6, 8], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let got = execute(&plan, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn forced_bt_tiling_matches_reference() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(73);
        let dims = EinsumDims { kind: EinsumKind::First, m: 16, b: 53, n: 9, r: 8, k: 1 };
        let mut plan = compile(&dims, &machine).unwrap();
        plan.tile.btl = Some(7); // deliberately non-dividing tile
        let g = Tensor::randn(vec![8, 9, 16, 1], 1.0, &mut rng);
        let x = Tensor::randn(vec![53, 9, 1], 1.0, &mut rng);
        let pg = pack(&g, &plan).unwrap();
        let got = execute(&plan, &pg, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn mismatched_layout_is_rejected() {
        let machine = MachineSpec::spacemit_k1();
        let mut rng = Rng::new(74);
        let dims = EinsumDims { kind: EinsumKind::Middle, m: 4, b: 4, n: 4, r: 8, k: 8 };
        let plan = compile(&dims, &machine).unwrap();
        let naive = OptimizationPlan::naive(dims);
        let g = Tensor::randn(vec![8, 4, 4, 8], 1.0, &mut rng);
        let pg_naive = pack(&g, &naive).unwrap();
        let x = Tensor::randn(vec![4, 4, 8], 1.0, &mut rng);
        assert!(execute(&plan, &pg_naive, &x).is_err());
        // bad input length
        let pg = pack(&g, &plan).unwrap();
        let x_bad = Tensor::randn(vec![4, 4, 4], 1.0, &mut rng);
        assert!(execute(&plan, &pg, &x_bad).is_err());
    }
}
