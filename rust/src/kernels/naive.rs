//! The unoptimized kernel (paper Listing 2 / "GCC -O3" ablation bar):
//! canonical layouts, no vectorization structure, no blocking.

use crate::error::Result;
use crate::tensor::einsum::{core_dims, slab_dims};
use crate::tensor::Tensor;

/// Plain five-deep loop nest over the canonical `G[r][n][m][k]`.
pub fn naive_einsum(g: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let b = slab_dims(x, n, k)?;
    let (gd, xd) = (g.data(), x.data());
    let mut out = Tensor::zeros(vec![m, b, r]);
    let od = out.data_mut();
    for mi in 0..m {
        for bi in 0..b {
            for ri in 0..r {
                let mut acc = 0.0f32;
                for ni in 0..n {
                    for ki in 0..k {
                        acc += gd[((ri * n + ni) * m + mi) * k + ki]
                            * xd[(bi * n + ni) * k + ki];
                    }
                }
                od[(mi * b + bi) * r + ri] = acc;
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::util::prng::Rng;

    #[test]
    fn equals_reference() {
        let mut rng = Rng::new(60);
        let g = Tensor::randn(vec![8, 5, 7, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![9, 5, 8], 1.0, &mut rng);
        let a = naive_einsum(&g, &x).unwrap();
        let b = tt_einsum_ref(&g, &x).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }
}
