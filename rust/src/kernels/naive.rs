//! The unoptimized kernel (paper Listing 2 / "GCC -O3" ablation bar):
//! canonical layouts, no vectorization structure, no blocking.

use crate::error::Result;
use crate::tensor::einsum::{core_dims, slab_dims};
use crate::tensor::Tensor;

/// Listing-2 loop nest over the canonical `G[r][n][m][k]`, writing straight
/// into a caller-owned `(m, b, r)` buffer — the allocation-free body shared
/// by [`naive_einsum`] and the executor's Canonical path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn naive_region(
    gd: &[f32],
    xd: &[f32],
    od: &mut [f32],
    r: usize,
    n: usize,
    m: usize,
    k: usize,
    b: usize,
) {
    for mi in 0..m {
        for bi in 0..b {
            for ri in 0..r {
                let mut acc = 0.0f32;
                for ni in 0..n {
                    for ki in 0..k {
                        acc += gd[((ri * n + ni) * m + mi) * k + ki]
                            * xd[(bi * n + ni) * k + ki];
                    }
                }
                od[(mi * b + bi) * r + ri] = acc;
            }
        }
    }
}

/// Int8 twin of [`naive_region`]: the same Listing-2 loop nest over a
/// quantized canonical core, f32 accumulation, per-`m`-slice scale applied
/// once at the store.
#[allow(clippy::too_many_arguments)]
pub(crate) fn naive_region_q(
    gd: &[i8],
    scales: &[f32],
    xd: &[f32],
    od: &mut [f32],
    r: usize,
    n: usize,
    m: usize,
    k: usize,
    b: usize,
) {
    for mi in 0..m {
        let scale = scales[mi];
        for bi in 0..b {
            for ri in 0..r {
                let mut acc = 0.0f32;
                for ni in 0..n {
                    for ki in 0..k {
                        acc += gd[((ri * n + ni) * m + mi) * k + ki] as f32
                            * xd[(bi * n + ni) * k + ki];
                    }
                }
                od[(mi * b + bi) * r + ri] = acc * scale;
            }
        }
    }
}

/// Plain five-deep loop nest over the canonical `G[r][n][m][k]`.
pub fn naive_einsum(g: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let b = slab_dims(x, n, k)?;
    let mut out = Tensor::zeros(vec![m, b, r]);
    naive_region(g.data(), x.data(), out.data_mut(), r, n, m, k, b);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::util::prng::Rng;

    #[test]
    fn equals_reference() {
        let mut rng = Rng::new(60);
        let g = Tensor::randn(vec![8, 5, 7, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![9, 5, 8], 1.0, &mut rng);
        let a = naive_einsum(&g, &x).unwrap();
        let b = tt_einsum_ref(&g, &x).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
    }
}
