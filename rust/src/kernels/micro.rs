//! Microkernels: the innermost loop bodies for each vectorization choice.
//!
//! RVV intrinsics from the paper's Listings 4-6 map to `[f32; VL]` lane
//! arrays (`vle32` = lane copy, `vfmv_v_f` = broadcast, `vfmacc` = per-lane
//! fma, `vfredosum` = horizontal sum). Register blocking is monomorphized
//! over (Rm, Rb) so accumulator tiles live in registers, exactly like the
//! unroll-and-jam the paper performs in source.

use super::packed::PackedG;
use super::VL;

type Lane = [f32; VL];

#[inline(always)]
fn fma(acc: &mut Lane, a: &Lane, scalar: f32) {
    for i in 0..VL {
        acc[i] += a[i] * scalar;
    }
}

#[inline(always)]
fn load(src: &[f32]) -> Lane {
    let mut v = [0.0f32; VL];
    v.copy_from_slice(&src[..VL]);
    v
}

#[inline(always)]
fn hsum(v: &Lane) -> f32 {
    // pairwise for a short dependency chain (the ordered vfredosum is the
    // slow part the paper calls out; pairwise is the faster legal shape)
    let s0 = v[0] + v[4];
    let s1 = v[1] + v[5];
    let s2 = v[2] + v[6];
    let s3 = v[3] + v[7];
    (s0 + s2) + (s1 + s3)
}

/// r-vectorized, register-blocked block: computes the output tile
/// `m0..m0+RM` x `b0..b0+RB` for all r-vector steps (paper Listing 6).
///
/// `gd` is PackedR `[m][r_pad/VL][L][VL]`, `xd` is `[b][L]`,
/// `od` is `[m][b][r]` whose first row corresponds to absolute row
/// `m_base` (per-thread contiguous output slices).
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn r_block<const RM: usize, const RB: usize>(
    gd: &[f32],
    xd: &[f32],
    od: &mut [f32],
    l: usize,
    r: usize,
    r_pad: usize,
    b_total: usize,
    m0: usize,
    b0: usize,
    m_base: usize,
) {
    let rv_count = r_pad / VL;
    for rv in 0..rv_count {
        let mut acc = [[[0.0f32; VL]; RB]; RM];
        // Per-row packed-G slices + chunks_exact iterators: the bounds
        // checks hoist out of the k loop entirely (§Perf iteration 1).
        let mut g_rows: [std::slice::ChunksExact<'_, f32>; RM] =
            std::array::from_fn(|im| {
                let off = ((m0 + im) * rv_count + rv) * l * VL;
                gd[off..off + l * VL].chunks_exact(VL)
            });
        let x_rows: [&[f32]; RB] =
            std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
        for kk in 0..l {
            // G vector loads: one per m-row, reused across the RB b-columns
            let mut gvec = [[0.0f32; VL]; RM];
            for im in 0..RM {
                gvec[im] = load(g_rows[im].next().expect("length l by construction"));
            }
            for ib in 0..RB {
                let xs = x_rows[ib][kk]; // vfmv_v_f broadcast
                for im in 0..RM {
                    fma(&mut acc[im][ib], &gvec[im], xs);
                }
            }
        }
        // stores: vl elements per (m, b) pair; clip the final partial vector
        let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
        for im in 0..RM {
            for ib in 0..RB {
                let out_base =
                    ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                od[out_base..out_base + lanes].copy_from_slice(&acc[im][ib][..lanes]);
            }
        }
    }
}

macro_rules! dispatch_rb {
    ($rm:expr, $rb:expr, $call:ident, ($($args:tt)*)) => {
        match ($rm, $rb) {
            (1, 1) => $call::<1, 1>($($args)*),
            (1, 2) => $call::<1, 2>($($args)*),
            (1, 3) => $call::<1, 3>($($args)*),
            (1, 4) => $call::<1, 4>($($args)*),
            (1, 5) => $call::<1, 5>($($args)*),
            (1, 6) => $call::<1, 6>($($args)*),
            (1, 7) => $call::<1, 7>($($args)*),
            (1, 8) => $call::<1, 8>($($args)*),
            (2, 1) => $call::<2, 1>($($args)*),
            (2, 2) => $call::<2, 2>($($args)*),
            (2, 3) => $call::<2, 3>($($args)*),
            (2, 4) => $call::<2, 4>($($args)*),
            (2, 5) => $call::<2, 5>($($args)*),
            (2, 6) => $call::<2, 6>($($args)*),
            (2, 7) => $call::<2, 7>($($args)*),
            (2, 8) => $call::<2, 8>($($args)*),
            (4, 1) => $call::<4, 1>($($args)*),
            (4, 2) => $call::<4, 2>($($args)*),
            (4, 3) => $call::<4, 3>($($args)*),
            (4, 4) => $call::<4, 4>($($args)*),
            (4, 5) => $call::<4, 5>($($args)*),
            (4, 6) => $call::<4, 6>($($args)*),
            (4, 7) => $call::<4, 7>($($args)*),
            (4, 8) => $call::<4, 8>($($args)*),
            (8, 1) => $call::<8, 1>($($args)*),
            (8, 2) => $call::<8, 2>($($args)*),
            (8, 3) => $call::<8, 3>($($args)*),
            (8, 4) => $call::<8, 4>($($args)*),
            (8, 5) => $call::<8, 5>($($args)*),
            (8, 6) => $call::<8, 6>($($args)*),
            (8, 7) => $call::<8, 7>($($args)*),
            (8, 8) => $call::<8, 8>($($args)*),
            _ => $call::<1, 1>($($args)*),
        }
    };
}
// The arch-specific kernels (avx2/neon) reuse the same (rm, rb) -> const
// monomorphization table for their own microkernel blocks.
pub(crate) use dispatch_rb;

/// r-vectorized region kernel over `m0..m1` x `b0..b1` with register
/// blocking (rm, rb); remainders run as (1, 1) padding ukernels
/// (paper Listing 6 lines 42/44). `od`'s first row is absolute row `m_base`.
#[allow(clippy::too_many_arguments)]
pub fn r_region_based(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    rm: usize,
    rb: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let r_pad = g.r_pad;
    let rm = rm.clamp(1, 8);
    let rb = rb.clamp(1, 8);
    let m_main = m0 + (m1 - m0) / rm * rm;
    let b_main = b0 + (b1 - b0) / rb * rb;
    let mut mi = m0;
    while mi < m_main {
        let mut bi = b0;
        while bi < b_main {
            dispatch_rb!(rm, rb, r_block,
                (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += rb;
        }
        // padding ukernel: b remainder
        while bi < b1 {
            dispatch_rb!(rm, 1, r_block,
                (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += 1;
        }
        mi += rm;
    }
    // padding ukernel: m remainder
    while mi < m1 {
        let mut bi = b0;
        while bi + rb <= b1 {
            dispatch_rb!(1, rb, r_block,
                (&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += rb;
        }
        while bi < b1 {
            r_block::<1, 1>(&g.data, xd, od, l, r, r_pad, b_total, mi, bi, m_base);
            bi += 1;
        }
        mi += 1;
    }
}

/// k-vectorized region kernel (paper Listing 4): dot-product microkernel
/// with horizontal reduction and scalar stores. `g` is PackedK `[m][r][L]`;
/// `od`'s first row is absolute row `m_base`.
#[allow(clippy::too_many_arguments)]
pub fn k_region_based(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let chunks = l / VL;
    let tail = chunks * VL;
    for mi in m0..m1 {
        for ri in 0..r {
            let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
            for bi in b0..b1 {
                let xrow = &xd[bi * l..(bi + 1) * l];
                let mut acc = [0.0f32; VL];
                for c in 0..chunks {
                    let gv = load(&grow[c * VL..]);
                    let xv = load(&xrow[c * VL..]);
                    for i in 0..VL {
                        acc[i] += gv[i] * xv[i];
                    }
                }
                let mut s = hsum(&acc);
                for i in tail..l {
                    s += grow[i] * xrow[i];
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = s; // scalar store
            }
        }
    }
}

/// Packed-but-scalar region kernel (paper Listing 3: packing applied, merged
/// `k = n*rt_1` loop, no vector structure). `g` is PackedK `[m][r][L]`;
/// `od`'s first row is absolute row `m_base`.
#[allow(clippy::too_many_arguments)]
pub fn scalar_packed_region_based(
    g: &PackedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    for mi in m0..m1 {
        for bi in b0..b1 {
            let xrow = &xd[bi * l..(bi + 1) * l];
            for ri in 0..r {
                let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
                let mut acc = 0.0f32;
                for (gv, xv) in grow.iter().zip(xrow) {
                    acc += gv * xv;
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hsum_matches_scalar_sum() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        assert_eq!(hsum(&v), 36.0);
    }

    #[test]
    fn fma_accumulates_lanes() {
        let mut acc = [1.0f32; VL];
        let a = [2.0f32; VL];
        fma(&mut acc, &a, 3.0);
        assert!(acc.iter().all(|&x| x == 7.0));
    }
}
