//! Runtime microkernel dispatch: arch-specific SIMD behind the portable
//! reference kernels.
//!
//! Modeled on rten's `gemm/kernels.rs`: a [`Kernel`] object bundles the
//! register-tile microkernels for one ISA, `supported()` probes the host at
//! runtime, and [`select`] picks the best supported implementation once at
//! [`Executor`](super::Executor) construction. The portable `[f32; VL]`
//! lane-array kernels ([`super::micro`]) stay the **reference semantics**:
//! every bitwise pin in the repo runs against them, and vector kernels are
//! held to a reduction-depth-derived tolerance instead
//! (`rust/tests/kernel_reference.rs` — see ARCHITECTURE.md "Kernel
//! dispatch" for the verify-tier policy).
//!
//! Forcing the reference bits on any box: `TTRV_FORCE_SCALAR=1` in the
//! environment, or [`set_force_scalar`] in-process (used by the bitwise
//! test suites so they pin the portable path regardless of host ISA).
//!
//! Kernel choice never affects packing: all kernels consume the same
//! Canonical / PackedR / PackedK layouts, so a tuned artifact's packed
//! cores stay valid whichever kernel the serving host selects.

use std::sync::atomic::{AtomicBool, Ordering};

use crate::error::{Error, Result};

use super::micro;
use super::packed::PackedG;

/// One ISA's microkernel set. Region signatures mirror the portable
/// entry points in [`super::micro`] exactly; `od`'s first row is absolute
/// row `m_base` (per-thread contiguous output slices).
pub trait Kernel: Send + Sync {
    /// Stable identifier persisted in TUNE sections / snapshots / BENCH
    /// rows for observability (`"portable"`, `"avx2-fma"`, `"neon"`).
    fn name(&self) -> &'static str;

    /// Whether this host can execute the kernel (runtime CPUID-style
    /// probe). The portable kernel always returns `true`.
    fn supported(&self) -> bool;

    /// r-vectorized region over `m0..m1` x `b0..b1` with register blocking
    /// `(rm, rb)`. `g` is PackedR.
    #[allow(clippy::too_many_arguments)]
    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    );

    /// k-vectorized (dot-product) region. `g` is PackedK.
    #[allow(clippy::too_many_arguments)]
    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    );

    /// Packed-but-scalar region (`VectorLoop::None` plans). Default: the
    /// portable implementation — this path is part of the bitwise
    /// reference surface, so vector kernels inherit it unchanged.
    #[allow(clippy::too_many_arguments)]
    fn scalar_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::scalar_packed_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }
}

/// Name of the portable reference kernel.
pub const PORTABLE_KERNEL_NAME: &str = "portable";

/// The portable reference kernel: the `[f32; VL]` lane-array loop nests of
/// [`super::micro`], compiled for whatever the target baseline is. Always
/// supported; the semantics every bitwise pin is defined against.
struct PortableKernel;

impl Kernel for PortableKernel {
    fn name(&self) -> &'static str {
        PORTABLE_KERNEL_NAME
    }
    fn supported(&self) -> bool {
        true
    }
    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::r_region_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
    }
    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }
}

static PORTABLE: PortableKernel = PortableKernel;

#[cfg(target_arch = "x86_64")]
static VECTOR: super::avx2::Avx2Kernel = super::avx2::Avx2Kernel;
#[cfg(target_arch = "aarch64")]
static VECTOR: super::neon::NeonKernel = super::neon::NeonKernel;

// Preference order: vector kernels first, portable fallback last.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
static ALL: [&dyn Kernel; 2] = [&VECTOR, &PORTABLE];
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
static ALL: [&dyn Kernel; 1] = [&PORTABLE];

/// Every kernel compiled into this binary, in preference order (vector
/// implementations first, portable last). Entries may be unsupported on
/// this host — filter by [`Kernel::supported`].
pub fn all_kernels() -> &'static [&'static dyn Kernel] {
    &ALL
}

/// The portable reference kernel.
pub fn portable() -> &'static dyn Kernel {
    &PORTABLE
}

/// In-process force-scalar override (the `TTRV_FORCE_SCALAR` env knob,
/// settable from code for test binaries).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force every subsequently constructed [`Executor`](super::Executor) onto
/// the portable reference kernel (equivalent to `TTRV_FORCE_SCALAR=1`).
/// Bitwise-pinned test binaries call this first thing in every test so the
/// flag is set before any executor exists, regardless of test order.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether force-scalar dispatch is active (in-process flag **or**
/// `TTRV_FORCE_SCALAR=1|true|yes` in the environment).
pub fn force_scalar_active() -> bool {
    if FORCE_SCALAR.load(Ordering::SeqCst) {
        return true;
    }
    matches!(
        std::env::var("TTRV_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// The kernel a fresh [`Executor`](super::Executor) uses on this host: the
/// first supported entry of [`all_kernels`] (portable if forced scalar).
pub fn select() -> &'static dyn Kernel {
    if force_scalar_active() {
        return &PORTABLE;
    }
    for &k in ALL.iter() {
        if k.supported() {
            return k;
        }
    }
    &PORTABLE
}

/// The name [`select`] would return right now (CLI / bench observability).
pub fn default_kernel_name() -> &'static str {
    select().name()
}

/// Look up a compiled-in kernel by its persisted name (TUNE sections store
/// the tuning host's kernel). `None` if this binary has no such kernel.
pub fn by_name(name: &str) -> Option<&'static dyn Kernel> {
    ALL.iter().copied().find(|k| k.name() == name)
}

/// Typed guard: `Err(Error::Kernel)` if `k` cannot run on this host.
/// `tune_chain` and [`Executor::with_kernel`](super::Executor::with_kernel)
/// call this so an unsupported kernel is a clean error, never a panic or an
/// illegal instruction.
pub fn ensure_supported(k: &dyn Kernel) -> Result<()> {
    if k.supported() {
        Ok(())
    } else {
        Err(Error::kernel(format!(
            "kernel '{}' is not supported on this host",
            k.name()
        )))
    }
}

/// The kernels autotuning should rank: the portable reference first (so
/// measurement ties deterministically keep the reference), then every
/// supported vector kernel — unless force-scalar is active, in which case
/// only portable.
pub(crate) fn candidate_kernels() -> Vec<&'static dyn Kernel> {
    let mut v: Vec<&'static dyn Kernel> = vec![&PORTABLE];
    if !force_scalar_active() {
        for &k in ALL.iter() {
            if k.name() != PORTABLE_KERNEL_NAME && k.supported() {
                v.push(k);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_is_always_present_and_supported() {
        assert!(all_kernels()
            .iter()
            .any(|k| k.name() == PORTABLE_KERNEL_NAME && k.supported()));
        // portable is the preference-order fallback: last entry
        assert_eq!(
            all_kernels().last().unwrap().name(),
            PORTABLE_KERNEL_NAME
        );
        assert!(ensure_supported(portable()).is_ok());
    }

    #[test]
    fn selected_kernel_is_supported() {
        let k = select();
        assert!(k.supported(), "select() returned unsupported '{}'", k.name());
        assert!(by_name(k.name()).is_some());
        assert!(by_name("no-such-kernel").is_none());
    }

    #[test]
    fn candidate_kernels_lead_with_portable() {
        let cands = candidate_kernels();
        assert!(!cands.is_empty());
        assert_eq!(cands[0].name(), PORTABLE_KERNEL_NAME);
        for k in cands {
            assert!(k.supported());
        }
    }

    #[test]
    fn force_scalar_pins_selection_to_portable() {
        // set -> observe -> restore; concurrent tests only ever see a
        // *portable* selection while the flag is up, which every tolerance
        // suite accepts (no lib test asserts a vector kernel was picked)
        set_force_scalar(true);
        assert!(force_scalar_active());
        assert_eq!(select().name(), PORTABLE_KERNEL_NAME);
        assert_eq!(candidate_kernels().len(), 1);
        set_force_scalar(false);
    }
}
