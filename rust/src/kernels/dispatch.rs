//! Runtime microkernel dispatch: arch-specific SIMD behind the portable
//! reference kernels.
//!
//! Modeled on rten's `gemm/kernels.rs`: a [`Kernel`] object bundles the
//! register-tile microkernels for one ISA, `supported()` probes the host at
//! runtime, and [`select`] picks the best supported implementation once at
//! [`Executor`](super::Executor) construction. The portable `[f32; VL]`
//! lane-array kernels ([`super::micro`]) stay the **reference semantics**:
//! every bitwise pin in the repo runs against them, and vector kernels are
//! held to a reduction-depth-derived tolerance instead
//! (`rust/tests/kernel_reference.rs` — see ARCHITECTURE.md "Kernel
//! dispatch" for the verify-tier policy).
//!
//! Forcing the reference bits on any box: `TTRV_FORCE_SCALAR=1` in the
//! environment, or [`set_force_scalar`] in-process (used by the bitwise
//! test suites so they pin the portable path regardless of host ISA).
//!
//! Kernel choice never affects packing: all kernels consume the same
//! Canonical / PackedR / PackedK layouts, so a tuned artifact's packed
//! cores stay valid whichever kernel the serving host selects.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use crate::error::{Error, Result};

use super::micro;
use super::packed::{PackedG, QuantizedG};

/// One ISA's microkernel set. Region signatures mirror the portable
/// entry points in [`super::micro`] exactly; `od`'s first row is absolute
/// row `m_base` (per-thread contiguous output slices).
///
/// The `*_q` twins take an int8 [`QuantizedG`] instead of the f32
/// [`PackedG`] and default to the portable int8 reference regions in
/// [`super::int8`], so f32-only kernels execute quantized cores correctly
/// (just not fast) and int8 kernels override them with widening SIMD.
pub trait Kernel: Send + Sync {
    /// Stable identifier persisted in TUNE sections / snapshots / BENCH
    /// rows for observability (`"portable"`, `"avx2-fma"`, `"neon"`,
    /// `"int8-portable"`, `"int8-avx2"`, `"int8-neon"`).
    fn name(&self) -> &'static str;

    /// Whether this host can execute the kernel (runtime CPUID-style
    /// probe). The portable kernels always return `true`.
    fn supported(&self) -> bool;

    /// Whether the int8 `*_q` regions are this kernel's *fast path* (the
    /// kernel was built for quantized cores). [`select`] skips such
    /// kernels for f32 execution and [`select_int8`] prefers them.
    fn int8(&self) -> bool {
        false
    }

    /// r-vectorized region over `m0..m1` x `b0..b1` with register blocking
    /// `(rm, rb)`. `g` is PackedR.
    #[allow(clippy::too_many_arguments)]
    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    );

    /// k-vectorized (dot-product) region. `g` is PackedK.
    #[allow(clippy::too_many_arguments)]
    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    );

    /// Packed-but-scalar region (`VectorLoop::None` plans). Default: the
    /// portable implementation — this path is part of the bitwise
    /// reference surface, so vector kernels inherit it unchanged.
    #[allow(clippy::too_many_arguments)]
    fn scalar_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::scalar_packed_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }

    /// r-vectorized region over an int8 core. `g` is quantized PackedR.
    /// Default: the portable int8 reference.
    #[allow(clippy::too_many_arguments)]
    fn r_region_q(
        &self,
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        super::int8::r_region_q_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
    }

    /// k-vectorized (dot-product) region over an int8 core. `g` is
    /// quantized PackedK. Default: the portable int8 reference.
    #[allow(clippy::too_many_arguments)]
    fn k_region_q(
        &self,
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        super::int8::k_region_q_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }

    /// Packed-but-scalar region over an int8 core (`VectorLoop::None`
    /// plans). Default: the portable int8 reference — like
    /// [`Kernel::scalar_region`], part of the reference surface that
    /// vector kernels inherit unchanged.
    #[allow(clippy::too_many_arguments)]
    fn scalar_region_q(
        &self,
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        super::int8::scalar_region_q_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }
}

/// Name of the portable reference kernel.
pub const PORTABLE_KERNEL_NAME: &str = "portable";

/// Name of the portable int8 reference kernel.
pub const INT8_PORTABLE_KERNEL_NAME: &str = "int8-portable";

/// The portable reference kernel: the `[f32; VL]` lane-array loop nests of
/// [`super::micro`], compiled for whatever the target baseline is. Always
/// supported; the semantics every bitwise pin is defined against.
struct PortableKernel;

impl Kernel for PortableKernel {
    fn name(&self) -> &'static str {
        PORTABLE_KERNEL_NAME
    }
    fn supported(&self) -> bool {
        true
    }
    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::r_region_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
    }
    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }
}

static PORTABLE: PortableKernel = PortableKernel;
static INT8_PORTABLE: super::int8::Int8PortableKernel = super::int8::Int8PortableKernel;

#[cfg(target_arch = "x86_64")]
static VECTOR: super::avx2::Avx2Kernel = super::avx2::Avx2Kernel;
#[cfg(target_arch = "aarch64")]
static VECTOR: super::neon::NeonKernel = super::neon::NeonKernel;

#[cfg(target_arch = "x86_64")]
static INT8_VECTOR: super::int8::Int8Avx2Kernel = super::int8::Int8Avx2Kernel;
#[cfg(target_arch = "aarch64")]
static INT8_VECTOR: super::int8::Int8NeonKernel = super::int8::Int8NeonKernel;

// Preference order: vector kernels first (f32, then int8), portable
// references last (f32 portable is the overall fallback).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
static ALL: [&dyn Kernel; 4] = [&VECTOR, &INT8_VECTOR, &INT8_PORTABLE, &PORTABLE];
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
static ALL: [&dyn Kernel; 2] = [&INT8_PORTABLE, &PORTABLE];

/// Every kernel compiled into this binary, in preference order (vector
/// implementations first, portable last). Entries may be unsupported on
/// this host — filter by [`Kernel::supported`].
pub fn all_kernels() -> &'static [&'static dyn Kernel] {
    &ALL
}

/// The portable reference kernel.
pub fn portable() -> &'static dyn Kernel {
    &PORTABLE
}

/// In-process force-scalar override (the `TTRV_FORCE_SCALAR` env knob,
/// settable from code for test binaries).
static FORCE_SCALAR: AtomicBool = AtomicBool::new(false);

/// Force every subsequently constructed [`Executor`](super::Executor) onto
/// the portable reference kernel (equivalent to `TTRV_FORCE_SCALAR=1`).
/// Bitwise-pinned test binaries call this first thing in every test so the
/// flag is set before any executor exists, regardless of test order.
pub fn set_force_scalar(on: bool) {
    FORCE_SCALAR.store(on, Ordering::SeqCst);
}

/// Whether force-scalar dispatch is active (in-process flag **or**
/// `TTRV_FORCE_SCALAR=1|true|yes` in the environment).
pub fn force_scalar_active() -> bool {
    if FORCE_SCALAR.load(Ordering::SeqCst) {
        return true;
    }
    matches!(
        std::env::var("TTRV_FORCE_SCALAR").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// In-process preferred-kernel pin (the CLI `--kernel NAME` flag on
/// `ttrv bench` / `ttrv serve-demo`): index+1 into [`ALL`], 0 = unset, so
/// the hot-path read stays one relaxed-free atomic load.
static PREFERRED: AtomicUsize = AtomicUsize::new(0);

/// Pin dispatch to the named kernel for the rest of the process (or clear
/// the pin with `None`). The pin is *family-respecting*: an f32 kernel pin
/// steers [`select`] and an int8 kernel pin steers [`select_int8`], while
/// the other family keeps its default selection — pinning `avx2-fma` must
/// never push quantized engines off their int8 fast path, and vice versa.
/// Unknown names and kernels this host cannot run are a typed
/// [`Error::Kernel`] up front — the pin either takes effect or the caller
/// hears why, never a silent fallback.
pub fn set_preferred_kernel(name: Option<&str>) -> Result<()> {
    let Some(name) = name else {
        PREFERRED.store(0, Ordering::SeqCst);
        return Ok(());
    };
    let idx = ALL.iter().position(|k| k.name() == name).ok_or_else(|| {
        Error::kernel(format!(
            "unknown kernel '{name}' (compiled in: {})",
            ALL.iter().map(|k| k.name()).collect::<Vec<_>>().join(", ")
        ))
    })?;
    ensure_supported(ALL[idx])?;
    PREFERRED.store(idx + 1, Ordering::SeqCst);
    Ok(())
}

/// The pinned kernel, if [`set_preferred_kernel`] is active.
pub fn preferred_kernel() -> Option<&'static dyn Kernel> {
    match PREFERRED.load(Ordering::SeqCst) {
        0 => None,
        i => Some(ALL[i - 1]),
    }
}

/// The kernel a fresh [`Executor`](super::Executor) uses on this host for
/// f32 cores: the [`set_preferred_kernel`] pin when it names an f32
/// kernel, else the first supported non-int8 entry of [`all_kernels`]
/// (portable if forced scalar).
pub fn select() -> &'static dyn Kernel {
    if let Some(k) = preferred_kernel() {
        if !k.int8() {
            return k;
        }
    }
    if force_scalar_active() {
        return &PORTABLE;
    }
    for &k in ALL.iter() {
        if !k.int8() && k.supported() {
            return k;
        }
    }
    &PORTABLE
}

/// The kernel a quantized engine uses on this host: the
/// [`set_preferred_kernel`] pin when it names an int8 kernel, else the
/// first supported int8 entry of [`all_kernels`] (the portable int8
/// reference if forced scalar). Int8 kernels are always available — the
/// portable reference backs every arch — so unlike f32 [`select`] there
/// is no cross-family fallback.
pub fn select_int8() -> &'static dyn Kernel {
    if let Some(k) = preferred_kernel() {
        if k.int8() {
            return k;
        }
    }
    if force_scalar_active() {
        return &INT8_PORTABLE;
    }
    for &k in ALL.iter() {
        if k.int8() && k.supported() {
            return k;
        }
    }
    &INT8_PORTABLE
}

/// The name [`select`] would return right now (CLI / bench observability).
pub fn default_kernel_name() -> &'static str {
    select().name()
}

/// Look up a compiled-in kernel by its persisted name (TUNE sections store
/// the tuning host's kernel). `None` if this binary has no such kernel.
pub fn by_name(name: &str) -> Option<&'static dyn Kernel> {
    ALL.iter().copied().find(|k| k.name() == name)
}

/// Typed guard: `Err(Error::Kernel)` if `k` cannot run on this host.
/// `tune_chain` and [`Executor::with_kernel`](super::Executor::with_kernel)
/// call this so an unsupported kernel is a clean error, never a panic or an
/// illegal instruction.
pub fn ensure_supported(k: &dyn Kernel) -> Result<()> {
    if k.supported() {
        Ok(())
    } else {
        Err(Error::kernel(format!(
            "kernel '{}' is not supported on this host",
            k.name()
        )))
    }
}

/// The f32 kernels autotuning should rank: the portable reference first
/// (so measurement ties deterministically keep the reference), then every
/// supported f32 vector kernel — unless force-scalar is active, in which
/// case only portable. Int8 kernels are excluded: an f32 chain never
/// touches their fast path, so ranking them would just re-measure the
/// portable fallback under another name ([`candidate_kernels_q`] is their
/// roster).
pub(crate) fn candidate_kernels() -> Vec<&'static dyn Kernel> {
    let mut v: Vec<&'static dyn Kernel> = vec![&PORTABLE];
    if !force_scalar_active() {
        for &k in ALL.iter() {
            if k.name() != PORTABLE_KERNEL_NAME && !k.int8() && k.supported() {
                v.push(k);
            }
        }
    }
    v
}

/// The int8 kernels quantized autotuning should rank: the portable int8
/// reference first, then every supported int8 vector kernel — unless
/// force-scalar is active, in which case only the int8 reference.
pub(crate) fn candidate_kernels_q() -> Vec<&'static dyn Kernel> {
    let mut v: Vec<&'static dyn Kernel> = vec![&INT8_PORTABLE];
    if !force_scalar_active() {
        for &k in ALL.iter() {
            if k.name() != INT8_PORTABLE_KERNEL_NAME && k.int8() && k.supported() {
                v.push(k);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn portable_is_always_present_and_supported() {
        assert!(all_kernels()
            .iter()
            .any(|k| k.name() == PORTABLE_KERNEL_NAME && k.supported()));
        // portable is the preference-order fallback: last entry
        assert_eq!(
            all_kernels().last().unwrap().name(),
            PORTABLE_KERNEL_NAME
        );
        assert!(ensure_supported(portable()).is_ok());
    }

    #[test]
    fn selected_kernel_is_supported() {
        let k = select();
        assert!(k.supported(), "select() returned unsupported '{}'", k.name());
        assert!(!k.int8(), "select() must stay on the f32 family, got '{}'", k.name());
        assert!(by_name(k.name()).is_some());
        assert!(by_name("no-such-kernel").is_none());
    }

    #[test]
    fn int8_selection_is_supported_and_int8() {
        let k = select_int8();
        assert!(k.supported(), "select_int8() returned unsupported '{}'", k.name());
        assert!(k.int8(), "select_int8() returned f32 kernel '{}'", k.name());
        // the int8 reference is always registered and findable by name
        let p = by_name(INT8_PORTABLE_KERNEL_NAME).expect("int8-portable registered");
        assert!(p.supported() && p.int8());
    }

    #[test]
    fn candidate_kernels_lead_with_portable() {
        let cands = candidate_kernels();
        assert!(!cands.is_empty());
        assert_eq!(cands[0].name(), PORTABLE_KERNEL_NAME);
        for k in cands {
            assert!(k.supported());
            // the f32 tuning roster never contains int8 kernels
            assert!(!k.int8(), "f32 candidate roster contains '{}'", k.name());
        }
    }

    #[test]
    fn candidate_kernels_q_lead_with_int8_portable() {
        let cands = candidate_kernels_q();
        assert!(!cands.is_empty());
        assert_eq!(cands[0].name(), INT8_PORTABLE_KERNEL_NAME);
        for k in cands {
            assert!(k.supported());
            assert!(k.int8(), "int8 candidate roster contains '{}'", k.name());
        }
    }

    #[test]
    fn preferred_kernel_pin_is_validated_and_family_respecting() {
        // unknown names are a typed error and leave the pin untouched
        let err = set_preferred_kernel(Some("no-such-kernel")).unwrap_err();
        assert!(err.to_string().contains("no-such-kernel"), "{err}");
        assert!(preferred_kernel().is_none());
        // pinning the int8 reference steers select_int8 only; select()
        // stays on the f32 family (concurrent tests observing select()
        // are therefore unaffected, like the force-scalar test below)
        set_preferred_kernel(Some(INT8_PORTABLE_KERNEL_NAME)).unwrap();
        assert_eq!(preferred_kernel().unwrap().name(), INT8_PORTABLE_KERNEL_NAME);
        assert_eq!(select_int8().name(), INT8_PORTABLE_KERNEL_NAME);
        assert!(!select().int8());
        set_preferred_kernel(None).unwrap();
        assert!(preferred_kernel().is_none());
    }

    #[test]
    fn force_scalar_pins_selection_to_portable() {
        // set -> observe -> restore; concurrent tests only ever see a
        // *portable* selection while the flag is up, which every tolerance
        // suite accepts (no lib test asserts a vector kernel was picked)
        set_force_scalar(true);
        assert!(force_scalar_active());
        assert_eq!(select().name(), PORTABLE_KERNEL_NAME);
        assert_eq!(select_int8().name(), INT8_PORTABLE_KERNEL_NAME);
        assert_eq!(candidate_kernels().len(), 1);
        assert_eq!(candidate_kernels_q().len(), 1);
        set_force_scalar(false);
    }
}
