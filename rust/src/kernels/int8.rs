//! Int8 microkernels: quantized-core einsum regions with f32 accumulation.
//!
//! Mirrors [`super::micro`] loop-for-loop — identical tiling, identical
//! `(rm, rb)` register-tile dispatch, identical remainder handling — with
//! the f32 `G` loads replaced by int8 loads widened in-register
//! (`vsext`/`vfcvt` on the paper's RVV target, `cvtepi8` on AVX2,
//! `vmovl_s8` on NEON) and the per-`m`-slice dequantization scale applied
//! exactly once, at the store:
//!
//! ```text
//! Out[m,b,r] = scales[m] * sum_{n,k} (q[r,n,m,k] as f32) * In[b,n,k]
//! ```
//!
//! Accumulation is f32 throughout, so the only deviation from the f32
//! reference on the same core is the quantization step itself — which is
//! what the tier-2 tolerance suite bounds (γ_L forward error plus half a
//! quantization step per reduction term). Int8 kernels are never part of
//! the bitwise-pinned surface.
//!
//! The portable region functions below are the **reference semantics** for
//! every int8 kernel; `"int8-portable"` runs them directly and is the
//! default-implementation target of the `*_q` methods on
//! [`Kernel`](super::dispatch::Kernel), so f32-only kernels transparently
//! fall back to them when handed a quantized core.

use super::micro;
use super::packed::{PackedG, QuantizedG};
use super::VL;

type Lane = [f32; VL];

/// Widen `VL` int8 lanes to f32 (the portable stand-in for
/// `vsext.vf4` + `vfcvt.f.x.v`).
#[inline(always)]
fn load_q(src: &[i8]) -> Lane {
    let mut v = [0.0f32; VL];
    for (d, &s) in v.iter_mut().zip(&src[..VL]) {
        *d = s as f32;
    }
    v
}

#[inline(always)]
fn fma(acc: &mut Lane, a: &Lane, scalar: f32) {
    for i in 0..VL {
        acc[i] += a[i] * scalar;
    }
}

#[inline(always)]
fn hsum(v: &Lane) -> f32 {
    // same pairwise association as `micro::hsum`
    let s0 = v[0] + v[4];
    let s1 = v[1] + v[5];
    let s2 = v[2] + v[6];
    let s3 = v[3] + v[7];
    (s0 + s2) + (s1 + s3)
}

/// Int8 twin of `micro::r_block`: r-vectorized register-tile block over
/// quantized PackedR data. Accumulators are unscaled f32; each output row's
/// scale multiplies in at the store.
#[allow(clippy::too_many_arguments)]
#[inline(always)]
fn r_block_q<const RM: usize, const RB: usize>(
    gd: &[i8],
    scales: &[f32],
    xd: &[f32],
    od: &mut [f32],
    l: usize,
    r: usize,
    r_pad: usize,
    b_total: usize,
    m0: usize,
    b0: usize,
    m_base: usize,
) {
    let rv_count = r_pad / VL;
    for rv in 0..rv_count {
        let mut acc = [[[0.0f32; VL]; RB]; RM];
        let mut g_rows: [std::slice::ChunksExact<'_, i8>; RM] = std::array::from_fn(|im| {
            let off = ((m0 + im) * rv_count + rv) * l * VL;
            gd[off..off + l * VL].chunks_exact(VL)
        });
        let x_rows: [&[f32]; RB] =
            std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
        for kk in 0..l {
            let mut gvec = [[0.0f32; VL]; RM];
            for (im, row) in g_rows.iter_mut().enumerate() {
                gvec[im] = load_q(row.next().expect("length l by construction"));
            }
            for ib in 0..RB {
                let xs = x_rows[ib][kk];
                for im in 0..RM {
                    fma(&mut acc[im][ib], &gvec[im], xs);
                }
            }
        }
        let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
        for im in 0..RM {
            let scale = scales[m0 + im];
            for ib in 0..RB {
                let out_base = ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                for (o, a) in od[out_base..out_base + lanes].iter_mut().zip(&acc[im][ib][..lanes])
                {
                    *o = a * scale;
                }
            }
        }
    }
}

/// Portable int8 r-vectorized region: tiling identical to
/// `micro::r_region_based`, microkernel swapped for [`r_block_q`].
/// `g` is quantized PackedR.
#[allow(clippy::too_many_arguments)]
pub(crate) fn r_region_q_based(
    g: &QuantizedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    rm: usize,
    rb: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let r_pad = g.r_pad;
    let rm = rm.clamp(1, 8);
    let rb = rb.clamp(1, 8);
    let m_main = m0 + (m1 - m0) / rm * rm;
    let b_main = b0 + (b1 - b0) / rb * rb;
    let mut mi = m0;
    while mi < m_main {
        let mut bi = b0;
        while bi < b_main {
            micro::dispatch_rb!(rm, rb, r_block_q,
                (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += rb;
        }
        while bi < b1 {
            micro::dispatch_rb!(rm, 1, r_block_q,
                (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += 1;
        }
        mi += rm;
    }
    while mi < m1 {
        let mut bi = b0;
        while bi + rb <= b1 {
            micro::dispatch_rb!(1, rb, r_block_q,
                (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base));
            bi += rb;
        }
        while bi < b1 {
            r_block_q::<1, 1>(&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base);
            bi += 1;
        }
        mi += 1;
    }
}

/// Portable int8 k-vectorized (dot-product) region. `g` is quantized
/// PackedK; the scale multiplies the reduced sum at the scalar store.
#[allow(clippy::too_many_arguments)]
pub(crate) fn k_region_q_based(
    g: &QuantizedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    let chunks = l / VL;
    let tail = chunks * VL;
    for mi in m0..m1 {
        let scale = g.scales[mi];
        for ri in 0..r {
            let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
            for bi in b0..b1 {
                let xrow = &xd[bi * l..(bi + 1) * l];
                let mut acc = [0.0f32; VL];
                for c in 0..chunks {
                    let gv = load_q(&grow[c * VL..]);
                    for i in 0..VL {
                        acc[i] += gv[i] * xrow[c * VL + i];
                    }
                }
                let mut s = hsum(&acc);
                for i in tail..l {
                    s += grow[i] as f32 * xrow[i];
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = s * scale;
            }
        }
    }
}

/// Portable int8 packed-but-scalar region (`VectorLoop::None` plans).
/// `g` is quantized PackedK.
#[allow(clippy::too_many_arguments)]
pub(crate) fn scalar_region_q_based(
    g: &QuantizedG,
    xd: &[f32],
    od: &mut [f32],
    b_total: usize,
    m0: usize,
    m1: usize,
    b0: usize,
    b1: usize,
    m_base: usize,
) {
    let (r, n, _m, k) = g.dims;
    let l = n * k;
    for mi in m0..m1 {
        let scale = g.scales[mi];
        for bi in b0..b1 {
            let xrow = &xd[bi * l..(bi + 1) * l];
            for ri in 0..r {
                let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
                let mut acc = 0.0f32;
                for (gv, xv) in grow.iter().zip(xrow) {
                    acc += *gv as f32 * xv;
                }
                od[((mi - m_base) * b_total + bi) * r + ri] = acc * scale;
            }
        }
    }
}

use super::dispatch::Kernel;

/// The portable int8 reference kernel: runs the region functions above for
/// quantized cores and the portable f32 microkernels for f32 cores. Always
/// supported; the semantics every int8 SIMD kernel is tolerance-checked
/// against.
pub(crate) struct Int8PortableKernel;

impl Kernel for Int8PortableKernel {
    fn name(&self) -> &'static str {
        super::dispatch::INT8_PORTABLE_KERNEL_NAME
    }
    fn supported(&self) -> bool {
        true
    }
    fn int8(&self) -> bool {
        true
    }
    // f32 regions: the portable reference, unchanged — an int8 kernel
    // asked to run an f32 core computes exactly the portable bits.
    fn r_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::r_region_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
    }
    fn k_region(
        &self,
        g: &PackedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
    }
    // *_q regions: the trait defaults already run this module's portable
    // reference implementations.
}

#[cfg(target_arch = "x86_64")]
pub(crate) use x86::Int8Avx2Kernel;

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! AVX2 int8 kernels: 8 int8 lanes sign-extended to i32
    //! (`_mm256_cvtepi8_epi32`), converted to f32, then the same FMA
    //! register tiles as [`super::super::avx2`]. Memory safety follows the
    //! same rule: every pointer comes from a bounds-checked subslice.

    use core::arch::x86_64::{
        __m128i, __m256, _mm256_cvtepi8_epi32, _mm256_cvtepi32_ps, _mm256_fmadd_ps,
        _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, _mm_loadl_epi64,
    };

    use super::super::dispatch::Kernel;
    use super::super::micro::{self, dispatch_rb};
    use super::super::packed::{PackedG, QuantizedG};
    use super::super::VL;

    /// AVX2 + FMA int8 kernel set (widen-multiply-accumulate in f32).
    pub(crate) struct Int8Avx2Kernel;

    impl Kernel for Int8Avx2Kernel {
        fn name(&self) -> &'static str {
            "int8-avx2"
        }
        fn supported(&self) -> bool {
            is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
        }
        fn int8(&self) -> bool {
            true
        }
        fn r_region(
            &self,
            g: &PackedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            rm: usize,
            rb: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            micro::r_region_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
        }
        fn k_region(
            &self,
            g: &PackedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
        }
        fn r_region_q(
            &self,
            g: &QuantizedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            rm: usize,
            rb: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            debug_assert!(self.supported());
            // SAFETY: dispatch only hands out this kernel when the runtime
            // AVX2+FMA probe passed (Executor construction / tune_chain).
            unsafe { r_region_q_avx2(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base) }
        }
        fn k_region_q(
            &self,
            g: &QuantizedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            debug_assert!(self.supported());
            // SAFETY: as above — only reachable when the host probe passed.
            unsafe { k_region_q_avx2(g, xd, od, b_total, m0, m1, b0, b1, m_base) }
        }
    }

    /// Widen `VL` int8 lanes to a f32 vector from a bounds-checked slice of
    /// length >= `VL` (load 8 bytes, sign-extend to i32, convert).
    #[inline(always)]
    unsafe fn load_q8(src: &[i8]) -> __m256 {
        let s = &src[..VL];
        // SAFETY: `s` is a bounds-checked `VL`-byte subslice, so the 8-byte
        // low-half load stays inside it (`loadl` has no alignment
        // requirement); sign-extend and convert are register-only.
        unsafe {
            _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(_mm_loadl_epi64(
                s.as_ptr() as *const __m128i,
            )))
        }
    }

    /// Int8 FMA register-tile block: the AVX2 twin of [`super::r_block_q`].
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    unsafe fn r_block_q_fma<const RM: usize, const RB: usize>(
        gd: &[i8],
        scales: &[f32],
        xd: &[f32],
        od: &mut [f32],
        l: usize,
        r: usize,
        r_pad: usize,
        b_total: usize,
        m0: usize,
        b0: usize,
        m_base: usize,
    ) {
        let rv_count = r_pad / VL;
        // SAFETY: register-only intrinsic, no memory access; AVX2
        // availability is this block's contract (called only from the
        // `target_feature` drivers below).
        let zero = unsafe { _mm256_setzero_ps() };
        for rv in 0..rv_count {
            let mut acc = [[zero; RB]; RM];
            let mut g_rows: [std::slice::ChunksExact<'_, i8>; RM] = std::array::from_fn(|im| {
                let off = ((m0 + im) * rv_count + rv) * l * VL;
                gd[off..off + l * VL].chunks_exact(VL)
            });
            let x_rows: [&[f32]; RB] =
                std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
            for kk in 0..l {
                let mut gvec = [zero; RM];
                for (im, row) in g_rows.iter_mut().enumerate() {
                    // SAFETY: the chunk is a bounds-checked `VL`-byte
                    // subslice (`chunks_exact(VL)`), `load_q8`'s contract.
                    gvec[im] =
                        unsafe { load_q8(row.next().expect("length l by construction")) };
                }
                for ib in 0..RB {
                    // SAFETY: register-only broadcast.
                    let xs = unsafe { _mm256_set1_ps(x_rows[ib][kk]) };
                    for im in 0..RM {
                        // SAFETY: register-only FMA.
                        acc[im][ib] = unsafe { _mm256_fmadd_ps(gvec[im], xs, acc[im][ib]) };
                    }
                }
            }
            let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
            for im in 0..RM {
                // SAFETY: register-only broadcast (`scales[m0 + im]` is a
                // bounds-checked slice read).
                let sv = unsafe { _mm256_set1_ps(scales[m0 + im]) };
                for ib in 0..RB {
                    let mut tmp = [0.0f32; VL];
                    // SAFETY: `tmp` is exactly `VL` f32s on the stack; the
                    // unaligned 8-lane store writes only within it (the
                    // multiply is register-only).
                    unsafe {
                        _mm256_storeu_ps(tmp.as_mut_ptr(), _mm256_mul_ps(acc[im][ib], sv))
                    };
                    let out_base = ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                    od[out_base..out_base + lanes].copy_from_slice(&tmp[..lanes]);
                }
            }
        }
    }

    /// AVX2 int8 r-vectorized region driver: tiling identical to
    /// [`super::r_region_q_based`], microkernel swapped for
    /// [`r_block_q_fma`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn r_region_q_avx2(
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        let (r, n, _m, k) = g.dims;
        let l = n * k;
        let r_pad = g.r_pad;
        let rm = rm.clamp(1, 8);
        let rb = rb.clamp(1, 8);
        let m_main = m0 + (m1 - m0) / rm * rm;
        let b_main = b0 + (b1 - b0) / rb * rb;
        let mut mi = m0;
        while mi < m_main {
            let mut bi = b0;
            while bi < b_main {
                // SAFETY: `r_block_q_fma`'s contract — the SIMD feature
                // this module's kernels probe at dispatch (`supported()`)
                // — holds inside this driver; its slice accesses are
                // bounds-checked against the quantized-buffer formulas
                // that `compiler::verify` certifies per plan.
                unsafe {
                    dispatch_rb!(rm, rb, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += rb;
            }
            while bi < b1 {
                // SAFETY: as above.
                unsafe {
                    dispatch_rb!(rm, 1, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += 1;
            }
            mi += rm;
        }
        while mi < m1 {
            let mut bi = b0;
            while bi + rb <= b1 {
                // SAFETY: as above.
                unsafe {
                    dispatch_rb!(1, rb, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += rb;
            }
            while bi < b1 {
                // SAFETY: as above.
                unsafe {
                    r_block_q_fma::<1, 1>(
                        &g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base,
                    )
                };
                bi += 1;
            }
            mi += 1;
        }
    }

    /// AVX2 int8 k-vectorized (dot-product) region: widen, FMA, then the
    /// same pairwise horizontal-sum shape as `micro::hsum` and the same
    /// scalar tail; the slice scale multiplies the reduced sum.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn k_region_q_avx2(
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        use core::arch::x86_64::_mm256_loadu_ps;
        let (r, n, _m, k) = g.dims;
        let l = n * k;
        let chunks = l / VL;
        let tail = chunks * VL;
        for mi in m0..m1 {
            let scale = g.scales[mi];
            for ri in 0..r {
                let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
                for bi in b0..b1 {
                    let xrow = &xd[bi * l..(bi + 1) * l];
                    // SAFETY: register-only intrinsic; no memory access.
                    let mut acc = unsafe { _mm256_setzero_ps() };
                    for (gc, xc) in grow[..tail]
                        .chunks_exact(VL)
                        .zip(xrow[..tail].chunks_exact(VL))
                    {
                        // SAFETY: `gc` and `xc` are bounds-checked
                        // `VL`-long subslices (`chunks_exact(VL)`), which
                        // is the contract of `load_q8` and of the 8-lane
                        // unaligned f32 load; the FMA is register-only.
                        acc = unsafe {
                            _mm256_fmadd_ps(load_q8(gc), _mm256_loadu_ps(xc.as_ptr()), acc)
                        };
                    }
                    // SAFETY: `hsum_m256` only spills the register to a
                    // `VL`-long stack array.
                    let mut s = unsafe { hsum_m256(acc) };
                    for i in tail..l {
                        s += grow[i] as f32 * xrow[i];
                    }
                    od[((mi - m_base) * b_total + bi) * r + ri] = s * scale;
                }
            }
        }
    }

    /// Pairwise horizontal sum with the exact association of `micro::hsum`.
    #[inline(always)]
    unsafe fn hsum_m256(v: __m256) -> f32 {
        let mut tmp = [0.0f32; VL];
        // SAFETY: `tmp` is exactly `VL` f32s on the stack; the unaligned
        // 8-lane store writes only within it.
        unsafe { _mm256_storeu_ps(tmp.as_mut_ptr(), v) };
        let s0 = tmp[0] + tmp[4];
        let s1 = tmp[1] + tmp[5];
        let s2 = tmp[2] + tmp[6];
        let s3 = tmp[3] + tmp[7];
        (s0 + s2) + (s1 + s3)
    }
}

#[cfg(target_arch = "aarch64")]
pub(crate) use arm::Int8NeonKernel;

#[cfg(target_arch = "aarch64")]
mod arm {
    //! NEON int8 kernels: 8 int8 lanes widened via `vmovl_s8`/`vmovl_s16`
    //! to two i32 quads, converted to f32, then the same FMA register tiles
    //! as [`super::super::neon`]. Memory safety follows the same
    //! bounds-checked-subslice rule.

    use core::arch::aarch64::{
        float32x4_t, vaddq_f32, vcvtq_f32_s32, vdupq_n_f32, vfmaq_f32, vget_high_s16,
        vget_low_s16, vld1_s8, vld1q_f32, vmovl_s16, vmovl_s8, vmulq_n_f32, vst1q_f32,
    };

    use super::super::dispatch::Kernel;
    use super::super::micro::{self, dispatch_rb};
    use super::super::packed::{PackedG, QuantizedG};
    use super::super::VL;

    /// NEON int8 kernel set (widen-multiply-accumulate in f32).
    pub(crate) struct Int8NeonKernel;

    impl Kernel for Int8NeonKernel {
        fn name(&self) -> &'static str {
            "int8-neon"
        }
        fn supported(&self) -> bool {
            std::arch::is_aarch64_feature_detected!("neon")
        }
        fn int8(&self) -> bool {
            true
        }
        fn r_region(
            &self,
            g: &PackedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            rm: usize,
            rb: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            micro::r_region_based(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base)
        }
        fn k_region(
            &self,
            g: &PackedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            micro::k_region_based(g, xd, od, b_total, m0, m1, b0, b1, m_base)
        }
        fn r_region_q(
            &self,
            g: &QuantizedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            rm: usize,
            rb: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            debug_assert!(self.supported());
            // SAFETY: NEON probe passed (dispatch only selects supported
            // kernels); all accesses go through bounds-checked subslices.
            unsafe { r_region_q_neon(g, xd, od, b_total, rm, rb, m0, m1, b0, b1, m_base) }
        }
        fn k_region_q(
            &self,
            g: &QuantizedG,
            xd: &[f32],
            od: &mut [f32],
            b_total: usize,
            m0: usize,
            m1: usize,
            b0: usize,
            b1: usize,
            m_base: usize,
        ) {
            debug_assert!(self.supported());
            // SAFETY: as above.
            unsafe { k_region_q_neon(g, xd, od, b_total, m0, m1, b0, b1, m_base) }
        }
    }

    /// A `VL`-wide f32 vector as two NEON quads.
    #[derive(Clone, Copy)]
    struct F32x8 {
        lo: float32x4_t,
        hi: float32x4_t,
    }

    #[inline(always)]
    unsafe fn zero8() -> F32x8 {
        // SAFETY: register-only broadcast, no memory access; NEON
        // availability is the caller's contract (dispatch probes first).
        unsafe { F32x8 { lo: vdupq_n_f32(0.0), hi: vdupq_n_f32(0.0) } }
    }

    /// Widen `VL` int8 lanes from a bounds-checked slice of length >= `VL`.
    #[inline(always)]
    unsafe fn load_q8(src: &[i8]) -> F32x8 {
        let s = &src[..VL];
        // SAFETY: `s` is a bounds-checked `VL`-byte subslice, so the
        // 8-byte load stays inside it; widen/convert are register-only.
        unsafe {
            let w = vmovl_s8(vld1_s8(s.as_ptr()));
            F32x8 {
                lo: vcvtq_f32_s32(vmovl_s16(vget_low_s16(w))),
                hi: vcvtq_f32_s32(vmovl_s16(vget_high_s16(w))),
            }
        }
    }

    #[inline(always)]
    unsafe fn fma8(acc: F32x8, g: F32x8, xs: f32) -> F32x8 {
        // SAFETY: register-only broadcast + FMA; no memory access.
        unsafe {
            let xv = vdupq_n_f32(xs);
            F32x8 { lo: vfmaq_f32(acc.lo, g.lo, xv), hi: vfmaq_f32(acc.hi, g.hi, xv) }
        }
    }

    /// Pairwise horizontal sum with the exact association of `micro::hsum`.
    #[inline(always)]
    unsafe fn hsum8(v: F32x8) -> f32 {
        let mut tmp = [0.0f32; 4];
        // SAFETY: `tmp` is exactly 4 f32s on the stack and the single
        // 4-lane store writes only within it; the add is register-only.
        unsafe { vst1q_f32(tmp.as_mut_ptr(), vaddq_f32(v.lo, v.hi)) };
        (tmp[0] + tmp[2]) + (tmp[1] + tmp[3])
    }

    /// Int8 FMA register-tile block: the NEON twin of [`super::r_block_q`].
    #[allow(clippy::too_many_arguments)]
    #[inline(always)]
    unsafe fn r_block_q_fma<const RM: usize, const RB: usize>(
        gd: &[i8],
        scales: &[f32],
        xd: &[f32],
        od: &mut [f32],
        l: usize,
        r: usize,
        r_pad: usize,
        b_total: usize,
        m0: usize,
        b0: usize,
        m_base: usize,
    ) {
        let rv_count = r_pad / VL;
        for rv in 0..rv_count {
            // SAFETY: register-only helper; NEON availability is this
            // block's contract (`supported()` probed at dispatch).
            let mut acc = [[unsafe { zero8() }; RB]; RM];
            let mut g_rows: [std::slice::ChunksExact<'_, i8>; RM] = std::array::from_fn(|im| {
                let off = ((m0 + im) * rv_count + rv) * l * VL;
                gd[off..off + l * VL].chunks_exact(VL)
            });
            let x_rows: [&[f32]; RB] =
                std::array::from_fn(|ib| &xd[(b0 + ib) * l..(b0 + ib) * l + l]);
            for kk in 0..l {
                // SAFETY: as above — register-only.
                let mut gvec = [unsafe { zero8() }; RM];
                for (im, row) in g_rows.iter_mut().enumerate() {
                    // SAFETY: the chunk is a bounds-checked `VL`-byte
                    // subslice (`chunks_exact(VL)`), `load_q8`'s contract.
                    gvec[im] =
                        unsafe { load_q8(row.next().expect("length l by construction")) };
                }
                for ib in 0..RB {
                    let xs = x_rows[ib][kk];
                    for im in 0..RM {
                        // SAFETY: register-only FMA helper.
                        acc[im][ib] = unsafe { fma8(acc[im][ib], gvec[im], xs) };
                    }
                }
            }
            let lanes = if (rv + 1) * VL <= r { VL } else { r - rv * VL };
            for im in 0..RM {
                let scale = scales[m0 + im];
                for ib in 0..RB {
                    let v = acc[im][ib];
                    let mut tmp = [0.0f32; VL];
                    // SAFETY: `tmp` is exactly `VL` f32s on the stack; the
                    // two 4-lane stores (offsets 0 and 4) write only
                    // within it (the multiplies are register-only).
                    unsafe {
                        vst1q_f32(tmp.as_mut_ptr(), vmulq_n_f32(v.lo, scale));
                        vst1q_f32(tmp[4..].as_mut_ptr(), vmulq_n_f32(v.hi, scale));
                    }
                    let out_base = ((m0 + im - m_base) * b_total + (b0 + ib)) * r + rv * VL;
                    od[out_base..out_base + lanes].copy_from_slice(&tmp[..lanes]);
                }
            }
        }
    }

    /// NEON int8 r-vectorized region driver: tiling identical to
    /// [`super::r_region_q_based`], microkernel swapped for
    /// [`r_block_q_fma`].
    #[allow(clippy::too_many_arguments)]
    unsafe fn r_region_q_neon(
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        rm: usize,
        rb: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        let (r, n, _m, k) = g.dims;
        let l = n * k;
        let r_pad = g.r_pad;
        let rm = rm.clamp(1, 8);
        let rb = rb.clamp(1, 8);
        let m_main = m0 + (m1 - m0) / rm * rm;
        let b_main = b0 + (b1 - b0) / rb * rb;
        let mut mi = m0;
        while mi < m_main {
            let mut bi = b0;
            while bi < b_main {
                // SAFETY: `r_block_q_fma`'s contract — the SIMD feature
                // this module's kernels probe at dispatch (`supported()`)
                // — holds inside this driver; its slice accesses are
                // bounds-checked against the quantized-buffer formulas
                // that `compiler::verify` certifies per plan.
                unsafe {
                    dispatch_rb!(rm, rb, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += rb;
            }
            while bi < b1 {
                // SAFETY: as above.
                unsafe {
                    dispatch_rb!(rm, 1, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += 1;
            }
            mi += rm;
        }
        while mi < m1 {
            let mut bi = b0;
            while bi + rb <= b1 {
                // SAFETY: as above.
                unsafe {
                    dispatch_rb!(1, rb, r_block_q_fma,
                        (&g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base))
                };
                bi += rb;
            }
            while bi < b1 {
                // SAFETY: as above.
                unsafe {
                    r_block_q_fma::<1, 1>(
                        &g.data, &g.scales, xd, od, l, r, r_pad, b_total, mi, bi, m_base,
                    )
                };
                bi += 1;
            }
            mi += 1;
        }
    }

    /// NEON int8 k-vectorized (dot-product) region: widen, FMA, the same
    /// pairwise horizontal-sum shape as `micro::hsum`, the same scalar
    /// tail, scale at the store.
    #[allow(clippy::too_many_arguments)]
    unsafe fn k_region_q_neon(
        g: &QuantizedG,
        xd: &[f32],
        od: &mut [f32],
        b_total: usize,
        m0: usize,
        m1: usize,
        b0: usize,
        b1: usize,
        m_base: usize,
    ) {
        let (r, n, _m, k) = g.dims;
        let l = n * k;
        let chunks = l / VL;
        let tail = chunks * VL;
        for mi in m0..m1 {
            let scale = g.scales[mi];
            for ri in 0..r {
                let grow = &g.data[(mi * r + ri) * l..(mi * r + ri + 1) * l];
                for bi in b0..b1 {
                    let xrow = &xd[bi * l..(bi + 1) * l];
                    // SAFETY: register-only helper; NEON availability is
                    // this driver's contract (`supported()` probed).
                    let mut acc = unsafe { zero8() };
                    for (gc, xc) in grow[..tail]
                        .chunks_exact(VL)
                        .zip(xrow[..tail].chunks_exact(VL))
                    {
                        // SAFETY: `gc` and `xc` are bounds-checked
                        // `VL`-long subslices (`chunks_exact(VL)`), so the
                        // int8 widen-load and the two 4-lane f32 loads
                        // (offsets 0 and 4) stay inside them; the FMAs are
                        // register-only.
                        unsafe {
                            let gv = load_q8(gc);
                            let xv = F32x8 {
                                lo: vld1q_f32(xc[..VL].as_ptr()),
                                hi: vld1q_f32(xc[4..].as_ptr()),
                            };
                            acc = F32x8 {
                                lo: vfmaq_f32(acc.lo, gv.lo, xv.lo),
                                hi: vfmaq_f32(acc.hi, gv.hi, xv.hi),
                            };
                        }
                    }
                    // SAFETY: `hsum8` only spills to its own 4-lane stack
                    // array.
                    let mut s = unsafe { hsum8(acc) };
                    for i in tail..l {
                        s += grow[i] as f32 * xrow[i];
                    }
                    od[((mi - m_base) * b_total + bi) * r + ri] = s * scale;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::plan::{LoopOrder, OptimizationPlan, RbFactors, TilePlan, VectorLoop};
    use crate::kernels::packed::{dequantize, pack, quantize};
    use crate::tensor::Tensor;
    use crate::ttd::cost::{EinsumDims, EinsumKind};
    use crate::util::prng::Rng;

    fn plan_for(dims: EinsumDims, vloop: VectorLoop) -> OptimizationPlan {
        OptimizationPlan {
            dims,
            pack_g: true,
            vector_loop: vloop,
            vl: VL,
            rb: RbFactors::NONE,
            tile: TilePlan { order: LoopOrder::Mbrk, btl: None },
            threads: 1,
            ls_estimate: 0,
        }
    }

    /// The portable int8 regions must agree bitwise with the portable f32
    /// regions run over the *dequantized* core — same loop order, same
    /// accumulation order, the scale folded in is the only difference and
    /// `scale * (q * x)` vs `(scale * q) * x` differ only when the fold
    /// itself rounds; an exactly-representable core sidesteps that, so the
    /// comparison below is exact.
    #[test]
    fn portable_int8_regions_match_f32_reference_on_dequantized_core() {
        let (r, n, m, k, b) = (11, 2, 5, 3, 4);
        let dims = EinsumDims { kind: EinsumKind::Middle, m, b, n, r, k };
        let mut rng = Rng::new(60);
        // integer-valued core in [-127, 127]: quantizes losslessly with
        // scale 1.0, so int8-vs-f32 comparisons are exact
        let gd: Vec<f32> = (0..r * n * m * k)
            .map(|_| (rng.normal() * 40.0).round().clamp(-126.0, 126.0) as f32)
            .collect();
        let mut g = Tensor::zeros(vec![r, n, m, k]);
        g.data_mut().copy_from_slice(&gd);
        // force scale 1.0 per slice: plant a +/-127 in every m-slice
        for mi in 0..m {
            g.data_mut()[(mi) * k] = 127.0;
        }
        let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);

        for vloop in [VectorLoop::R, VectorLoop::K, VectorLoop::None] {
            let p = pack(&g, &plan_for(dims, vloop)).unwrap();
            let q = quantize(&p);
            assert!(q.scales.iter().all(|&s| s == 1.0), "{:?}", q.scales);
            assert_eq!(dequantize(&q).data, p.data);
            let mut out_q = vec![0.0f32; m * b * r];
            let mut out_f = vec![0.0f32; m * b * r];
            match vloop {
                VectorLoop::R => {
                    r_region_q_based(&q, x.data(), &mut out_q, b, 2, 3, 0, m, 0, b, 0);
                    micro::r_region_based(&p, x.data(), &mut out_f, b, 2, 3, 0, m, 0, b, 0);
                }
                VectorLoop::K => {
                    k_region_q_based(&q, x.data(), &mut out_q, b, 0, m, 0, b, 0);
                    micro::k_region_based(&p, x.data(), &mut out_f, b, 0, m, 0, b, 0);
                }
                VectorLoop::None => {
                    scalar_region_q_based(&q, x.data(), &mut out_q, b, 0, m, 0, b, 0);
                    micro::scalar_packed_region_based(&p, x.data(), &mut out_f, b, 0, m, 0, b, 0);
                }
            }
            assert_eq!(out_q, out_f, "{vloop:?}");
        }
    }

    #[test]
    fn scales_rescale_the_output_rows() {
        // one m-slice with magnitude 254 -> scale 2.0; output must be the
        // scaled product, not the raw int accumulation
        let (r, n, m, k, b) = (1, 1, 1, 2, 1);
        let dims = EinsumDims { kind: EinsumKind::Final, m, b, n, r, k };
        let mut g = Tensor::zeros(vec![r, n, m, k]);
        g.data_mut().copy_from_slice(&[254.0, -2.0]);
        let mut x = Tensor::zeros(vec![b, n, k]);
        x.data_mut().copy_from_slice(&[0.5, 3.0]);
        let p = pack(&g, &plan_for(dims, VectorLoop::K)).unwrap();
        let q = quantize(&p);
        assert_eq!(q.scales, vec![2.0]);
        assert_eq!(q.data, vec![127, -1]);
        let mut out = vec![0.0f32; 1];
        k_region_q_based(&q, x.data(), &mut out, b, 0, m, 0, b, 0);
        // 2.0 * (127*0.5 + (-1)*3.0) = 2.0 * 60.5 = 121.0
        assert_eq!(out, vec![121.0]);
        // exact value with the true core: 254*0.5 - 2*3 = 121 (quantization
        // is lossless here, so they agree)
        let mut out_f = vec![0.0f32; 1];
        micro::k_region_based(&p, x.data(), &mut out_f, b, 0, m, 0, b, 0);
        assert_eq!(out, out_f);
    }
}
