//! Stage 6 of the DSE engine: price every stage-5 survivor through the
//! compiler + machine cost model, cut solutions that fail the configured
//! speedup-vs-dense threshold, and expose the Pareto frontier over
//! (modeled time, params, FLOPs) — the paper's "predicted inference
//! performance" selection step that the analytic stages 1-5 feed.
//!
//! Exploration is parallelized across [`WorkUnit`]s (one `(d, m-shape)`
//! slice each) by a worker pool over the coordinator's bounded MPMC queue.
//! Every unit is a pure function of its inputs and results merge in unit
//! order before a canonical sort, so `dse_workers = N` produces output
//! byte-identical to `dse_workers = 1` (pinned by
//! `rust/tests/dse_engine.rs`).

use std::sync::Mutex;

use crate::config::DseConfig;
use crate::coordinator::queue::{Pop, SharedQueue};
use crate::machine::{costmodel, MachineSpec};
use crate::ttd::cost::{self, EinsumDims, EinsumKind};

use super::pareto::pareto_frontier;
use super::pipeline::{Explored, InitialLayer, Scalability, StageCounts, StageCtx};
use super::space::{self, Solution, WorkUnit};

/// A stage-5 survivor priced by the analytical machine model.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedSolution {
    /// The underlying factorization.
    pub solution: Solution,
    /// Modeled wall-clock seconds of the full einsum chain at the
    /// configured batch on the target machine.
    pub time_s: f64,
    /// Modeled speedup over the unfactorized dense layer (dense modeled
    /// time / `time_s`; infinite when the dense layer itself is
    /// unschedulable).
    pub speedup: f64,
}

impl TimedSolution {
    /// The factorized layout (shorthand for `solution.layout`).
    pub fn layout(&self) -> &crate::ttd::TtLayout {
        &self.solution.layout
    }
}

/// Result of the full six-stage exploration of one FC layer.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedExplored {
    /// Stages 1-5: counts and survivors, byte-identical to
    /// [`super::pipeline::explore`] on the same inputs.
    pub explored: Explored,
    /// Modeled time of the unfactorized dense layer (the stage-6 baseline);
    /// infinite when the dense layer cannot be scheduled.
    pub dense_time_s: f64,
    /// Stage 6 survivors: every stage-5 survivor that compiles and meets
    /// `cfg.time_speedup_min`, in canonical order.
    pub timed: Vec<TimedSolution>,
    /// The Pareto frontier of `timed` over (modeled time, params, FLOPs),
    /// in canonical order — the selection substrate
    /// ([`super::select::select_solution`]).
    pub frontier: Vec<TimedSolution>,
}

/// Modeled seconds of one solution's full einsum chain at `batch`, or
/// `None` when any kernel in the chain has no feasible schedule (paper
/// §4.3.5: such solutions are "deemed inefficient and discarded").
pub fn price_solution(s: &Solution, machine: &MachineSpec, batch: usize) -> Option<f64> {
    let mut total = 0.0;
    for dims in cost::einsum_chain(&s.layout, batch) {
        let plan = crate::compiler::compile(&dims, machine).ok()?;
        total += costmodel::estimate(&plan, machine).seconds();
    }
    Some(total)
}

/// Modeled seconds of the unfactorized dense layer (an `r = k = 1` final
/// einsum, the same framing the Fig. 15 comparison uses), or infinity when
/// it cannot be scheduled.
pub fn dense_time(m_dim: u64, n_dim: u64, machine: &MachineSpec, batch: usize) -> f64 {
    let dims = EinsumDims {
        kind: EinsumKind::Final,
        m: m_dim as usize,
        b: batch,
        n: n_dim as usize,
        r: 1,
        k: 1,
    };
    match crate::compiler::compile(&dims, machine) {
        Ok(plan) => costmodel::estimate(&plan, machine).seconds(),
        Err(_) => f64::INFINITY,
    }
}

/// Per-work-unit exploration output, merged in unit order.
struct UnitResult {
    vectorized: usize,
    initial: usize,
    scalability: usize,
    survivors: Vec<Solution>,
    timed: Vec<TimedSolution>,
}

/// Stages 3-6 for one work unit (pure: no shared state).
fn process_unit(
    unit: &WorkUnit,
    ctx: &StageCtx<'_>,
    machine: &MachineSpec,
    dense_time_s: f64,
) -> UnitResult {
    let sols = space::enumerate_unit(unit, ctx.cfg);
    let vectorized = sols.len();
    let mut survivors: Vec<Solution> =
        sols.into_iter().filter(|s| InitialLayer.keep(ctx, s)).collect();
    let initial = survivors.len();
    survivors.retain(|s| Scalability.keep(ctx, s));
    let scalability = survivors.len();
    let mut timed = Vec::with_capacity(scalability);
    for s in &survivors {
        if let Some(time_s) = price_solution(s, machine, ctx.cfg.batch) {
            let speedup = dense_time_s / time_s;
            if speedup >= ctx.cfg.time_speedup_min {
                timed.push(TimedSolution { solution: s.clone(), time_s, speedup });
            }
        }
    }
    UnitResult { vectorized, initial, scalability, survivors, timed }
}

/// Run the full six-stage engine for one FC layer (M outputs, N inputs) on
/// the target machine, using `cfg.dse_workers` worker threads over the
/// `(d, m-shape)` work-unit queue. Output is byte-identical for every
/// worker count.
pub fn explore_timed(
    m_dim: u64,
    n_dim: u64,
    machine: &MachineSpec,
    cfg: &DseConfig,
) -> TimedExplored {
    let ctx = StageCtx::new(m_dim, n_dim, cfg);
    let units = space::work_units(m_dim, n_dim, cfg);
    let dense_time_s = dense_time(m_dim, n_dim, machine, cfg.batch);

    let workers = cfg.dse_workers.max(1).min(units.len().max(1));
    let results: Vec<UnitResult> = if workers <= 1 {
        units
            .iter()
            .map(|u| process_unit(u, &ctx, machine, dense_time_s))
            .collect()
    } else {
        // Fill the MPMC queue with unit indices up front and close it;
        // workers drain it and park each unit's result in its own slot, so
        // the merge below observes units in their deterministic order no
        // matter which worker ran them.
        let queue = SharedQueue::new(units.len());
        for i in 0..units.len() {
            queue
                .try_push(i)
                .unwrap_or_else(|_| unreachable!("queue sized to hold every unit"));
        }
        queue.close();
        let slots: Vec<Mutex<Option<UnitResult>>> =
            units.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    match queue.pop() {
                        Pop::Item(i) => {
                            let r = process_unit(&units[i], &ctx, machine, dense_time_s);
                            *slots[i].lock().expect("unit slot lock") = Some(r);
                        }
                        Pop::Closed => break,
                        Pop::TimedOut => unreachable!("blocking pop cannot time out"),
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|s| {
                s.into_inner()
                    .expect("unit slot lock")
                    .expect("every queued unit was processed")
            })
            .collect()
    };

    let mut vectorized = 0;
    let mut initial = 0;
    let mut scalability = 0;
    let mut survivors = Vec::new();
    let mut timed = Vec::new();
    for r in results {
        vectorized += r.vectorized;
        initial += r.initial;
        scalability += r.scalability;
        survivors.extend(r.survivors);
        timed.extend(r.timed);
    }
    survivors.sort_by(Solution::canonical_cmp);
    timed.sort_by(|a, b| a.solution.canonical_cmp(&b.solution));
    let frontier = pareto_frontier(&timed);

    TimedExplored {
        explored: Explored {
            m_dim,
            n_dim,
            counts: StageCounts {
                all: ctx.sizes.all,
                aligned: ctx.sizes.aligned,
                vectorized,
                initial,
                scalability,
            },
            survivors,
        },
        dense_time_s,
        timed,
        frontier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::pareto::dominates;
    use crate::dse::pipeline::explore;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    #[test]
    fn stages_1_to_5_identical_to_untimed_pipeline() {
        let cfg = DseConfig::default();
        for (m, n) in [(300u64, 784u64), (120, 400), (13, 17)] {
            let te = explore_timed(m, n, &k1(), &cfg);
            assert_eq!(te.explored, explore(m, n, &cfg), "[{n},{m}]");
        }
    }

    #[test]
    fn timed_survivors_meet_the_threshold_and_sit_in_canonical_order() {
        let cfg = DseConfig::default();
        let te = explore_timed(300, 784, &k1(), &cfg);
        assert!(!te.timed.is_empty());
        assert!(te.timed.len() <= te.explored.counts.scalability);
        for t in &te.timed {
            assert!(t.time_s > 0.0);
            assert!(t.speedup >= cfg.time_speedup_min, "{}", t.layout().describe());
            assert!((t.speedup - te.dense_time_s / t.time_s).abs() < 1e-12);
        }
        for w in te.timed.windows(2) {
            assert_eq!(
                w[0].solution.canonical_cmp(&w[1].solution),
                std::cmp::Ordering::Less
            );
        }
    }

    #[test]
    fn frontier_is_nonempty_subset_of_timed() {
        let te = explore_timed(512, 512, &k1(), &DseConfig::default());
        assert!(!te.frontier.is_empty());
        assert!(te.frontier.len() <= te.timed.len());
        for f in &te.frontier {
            assert!(te.timed.contains(f));
            assert!(!te.timed.iter().any(|o| dominates(o, f)));
        }
    }

    #[test]
    fn raising_the_threshold_prunes_more() {
        let mut cfg = DseConfig::default();
        let loose = explore_timed(300, 784, &k1(), &cfg);
        cfg.time_speedup_min = 10.0;
        let tight = explore_timed(300, 784, &k1(), &cfg);
        assert!(tight.timed.len() < loose.timed.len());
        assert!(tight.timed.iter().all(|t| t.speedup >= 10.0));
        // stage 1-5 accounting is untouched by the stage-6 knob
        assert_eq!(tight.explored, loose.explored);
    }

    #[test]
    fn worker_count_does_not_change_results() {
        let mut cfg = DseConfig::default();
        let serial = explore_timed(120, 400, &k1(), &cfg);
        for workers in [2usize, 3, 8] {
            cfg.dse_workers = workers;
            assert_eq!(explore_timed(120, 400, &k1(), &cfg), serial, "workers={workers}");
        }
    }

    #[test]
    fn prime_layer_yields_empty_engine_output() {
        let te = explore_timed(13, 17, &k1(), &DseConfig::default());
        assert!(te.timed.is_empty());
        assert!(te.frontier.is_empty());
        assert_eq!(te.explored.counts.scalability, 0);
    }

    #[test]
    fn dense_time_is_finite_and_positive_for_real_layers() {
        let d = dense_time(300, 784, &k1(), 1);
        assert!(d.is_finite() && d > 0.0);
    }
}
