//! Permutation sweeps for the alignment-strategy evaluation
//! (paper Figs. 5-8 and Eq. 16-17 ratios) — the measurement backing the
//! pipeline's [`super::pipeline::Alignment`] stage.

use crate::factor::{self, multiset_permutations};
use crate::ttd::{cost, TtLayout};

/// FLOPs + memory of every (m, n) shape-permutation pair for one aligned
/// configuration at uniform rank `r`, with the aligned pair flagged.
#[derive(Debug, Clone)]
pub struct PermutationSweep {
    /// (flops, memory, is_aligned) per permutation pair.
    pub points: Vec<(u64, u64, bool)>,
    /// FLOPs of the aligned permutation pair.
    pub aligned_flops: u64,
    /// Parameter memory of the aligned permutation pair.
    pub aligned_memory: u64,
}

/// Sweep all permutations of the given shape multisets (paper Figs. 5-6).
/// Skips rank-infeasible permutations (the paper's rank caps apply to all).
pub fn sweep_permutations(m_multiset: &[u64], n_multiset: &[u64], rank: u64) -> PermutationSweep {
    let m_aligned = factor::align_m(m_multiset.to_vec());
    let n_aligned = factor::align_n(n_multiset.to_vec());
    let mut points = Vec::new();
    let mut aligned_flops = u64::MAX;
    let mut aligned_memory = u64::MAX;
    for mp in multiset_permutations(m_multiset) {
        for np in multiset_permutations(n_multiset) {
            let layout = match TtLayout::with_uniform_rank(mp.clone(), np.clone(), rank) {
                Ok(l) => l,
                Err(_) => continue,
            };
            let f = cost::flops(&layout);
            let mem = cost::params(&layout);
            let is_aligned = mp == m_aligned && np == n_aligned;
            if is_aligned {
                aligned_flops = f;
                aligned_memory = mem;
            }
            points.push((f, mem, is_aligned));
        }
    }
    PermutationSweep { points, aligned_flops, aligned_memory }
}

/// Eq. 16/17 normalized ratios for one sweep: 1.0 = aligned achieves the
/// minimum, 0.0 = the maximum.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlignmentRatios {
    /// Normalized FLOPs ratio (Eq. 16).
    pub flops: f64,
    /// Normalized memory ratio (Eq. 17).
    pub memory: f64,
}

/// Compute the Eq. 16/17 ratios for one permutation sweep.
pub fn ratios(sweep: &PermutationSweep) -> AlignmentRatios {
    let fmax = sweep.points.iter().map(|p| p.0).max().unwrap_or(0) as f64;
    let fmin = sweep.points.iter().map(|p| p.0).min().unwrap_or(0) as f64;
    let mmax = sweep.points.iter().map(|p| p.1).max().unwrap_or(0) as f64;
    let mmin = sweep.points.iter().map(|p| p.1).min().unwrap_or(0) as f64;
    let ratio = |max: f64, min: f64, aligned: f64| {
        if max > min {
            (max - aligned) / (max - min)
        } else {
            1.0
        }
    };
    AlignmentRatios {
        flops: ratio(fmax, fmin, sweep.aligned_flops as f64),
        memory: ratio(mmax, mmin, sweep.aligned_memory as f64),
    }
}

/// Fig. 7/8 benchmark: ratios over many (shape, rank) configurations of a
/// layer. Returns one `AlignmentRatios` per aligned configuration.
pub fn layer_ratio_study(
    m_dim: u64,
    n_dim: u64,
    d: usize,
    ranks: &[u64],
    max_configs: usize,
) -> Vec<AlignmentRatios> {
    let m_sets = factor::factor_multisets(m_dim, d);
    let n_sets = factor::factor_multisets(n_dim, d);
    let mut out = Vec::new();
    'outer: for ms in &m_sets {
        for ns in &n_sets {
            for &r in ranks {
                let sweep = sweep_permutations(ms, ns, r);
                if sweep.aligned_flops == u64::MAX {
                    continue; // aligned pair infeasible at this rank
                }
                out.push(ratios(&sweep));
                if out.len() >= max_configs {
                    break 'outer;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_is_always_flops_optimal() {
        // the paper's central claim (Fig. 7: FLOPs ratio boxplot collapses
        // to 1.0); exhaustively verify on several configurations
        for (ms, ns, r) in [
            (vec![5u64, 5, 3, 2, 2], vec![2u64, 2, 2, 7, 14], 4),
            (vec![10, 10, 5, 2], vec![2, 8, 8, 32], 8),
            (vec![16, 32], vec![64, 64], 8),
            (vec![4, 8, 16], vec![2, 4, 8], 2),
        ] {
            let sweep = sweep_permutations(&ms, &ns, r);
            let rt = ratios(&sweep);
            assert!(
                (rt.flops - 1.0).abs() < 1e-12,
                "aligned not FLOPs-minimal for {ms:?} x {ns:?}: {rt:?}"
            );
            let min = sweep.points.iter().map(|p| p.0).min().unwrap();
            assert_eq!(sweep.aligned_flops, min);
        }
    }

    #[test]
    fn memory_ratio_close_to_one_but_not_always_one() {
        // Fig. 7: memory is near-optimal; Fig. 8 example values
        let rts = layer_ratio_study(1000, 2048, 3, &[8, 16], 64);
        assert!(!rts.is_empty());
        let avg_mem = rts.iter().map(|r| r.memory).sum::<f64>() / rts.len() as f64;
        assert!(avg_mem > 0.8, "avg memory ratio {avg_mem}");
    }

    #[test]
    fn paper_fig8_example_memory_values() {
        // paper: m=[10,10,5,2], n=[2,8,8,32], r=[1,8,8,8,1] -> memory 9352,
        // max over permutations 26952, min 5224
        let sweep = sweep_permutations(&[10, 10, 5, 2], &[2, 8, 8, 32], 8);
        assert_eq!(sweep.aligned_memory, 9352);
        let mmax = sweep.points.iter().map(|p| p.1).max().unwrap();
        let mmin = sweep.points.iter().map(|p| p.1).min().unwrap();
        assert_eq!(mmax, 26952);
        assert_eq!(mmin, 5224);
    }

    #[test]
    fn sweep_point_count_matches_prop4() {
        let ms = [5u64, 5, 3, 2, 2];
        let ns = [2u64, 2, 2, 7, 14];
        let sweep = sweep_permutations(&ms, &ns, 1); // rank 1 always feasible
        assert_eq!(sweep.points.len() as u128, factor::prop4_permutations(&ms, &ns));
        assert_eq!(sweep.points.len(), 600); // the paper's example value
        assert_eq!(sweep.points.iter().filter(|p| p.2).count(), 1);
    }
}
