//! The design-space exploration engine (paper §4.1-4.2) — the paper's
//! primary contribution.
//!
//! Pipeline stages, exactly the paper's Figure 4 / Tables 1-2 columns:
//!
//! 1. **All initial solutions** — counted, never materialized
//!    ([`crate::factor::count`]).
//! 2. **Alignment strategy** (§4.1) — keep only aligned shape pairs
//!    (Def. 1); reduction factor per Prop. 4.
//! 3. **Vectorization constraint** (§4.2.1) — ranks must be multiples of
//!    `vl`; from here the space is small enough to *enumerate*.
//! 4. **Initial-layer constraint** (§4.2.2) — FLOPs *and* params must beat
//!    the dense layer.
//! 5. **Scalability constraint** (§4.2.3) — discard long configurations
//!    whose heaviest Einsum cannot keep threads busy.
//!
//! The enumerated stages sweep *uniform* rank values (the paper's `R`
//! notation; its experiments fix R per solution), which keeps stage-3+
//! spaces at the table's reported magnitudes.

pub mod space;
pub mod prune;
pub mod report;
pub mod select;
pub mod alignment_stats;

pub use prune::{explore, StageCounts};
pub use select::select_solution;
pub use space::Solution;
