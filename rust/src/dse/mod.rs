//! The design-space exploration engine (paper §4.1-4.2) — the paper's
//! primary contribution, grown into a staged, parallel, time-aware
//! exploration engine.
//!
//! Pipeline stages — the paper's Figure 4 / Tables 1-2 columns plus the
//! modeled-performance step its text describes but the tables stop short
//! of:
//!
//! 1. **All initial solutions** — counted, never materialized
//!    ([`crate::factor::count`]).
//! 2. **Alignment strategy** (§4.1) — keep only aligned shape pairs
//!    (Def. 1); reduction factor per Prop. 4.
//! 3. **Vectorization constraint** (§4.2.1) — ranks must be multiples of
//!    `vl`; from here the space is small enough to *enumerate*.
//! 4. **Initial-layer constraint** (§4.2.2) — FLOPs *and* params must beat
//!    the dense layer.
//! 5. **Scalability constraint** (§4.2.3) — discard long configurations
//!    whose heaviest Einsum cannot keep threads busy.
//! 6. **Modeled-time cut** ([`timed`]) — price every survivor through
//!    [`crate::compiler::compile`] + [`crate::machine::costmodel`]; cut
//!    solutions whose modeled speedup over the dense layer falls below
//!    `DseConfig::time_speedup_min`; expose the Pareto frontier over
//!    (modeled time, params, FLOPs) as the selection substrate.
//! 7. **Rank sweep** ([`ranksweep`], weight-aware) — re-decompose each
//!    stage-6 survivor shape at the configurable rank ladder
//!    (`DseConfig::rank_candidates`) against the layer's weight matrix,
//!    annotate every priced, time-qualified candidate with its measured
//!    TT-SVD relative reconstruction error, and expose the composed-error
//!    frontier (reconstruction + quantization axes on top of the three
//!    classic objectives); [`select::select_within_accuracy_budget`]
//!    turns an accuracy budget into a rank choice.
//!
//! Stages 1-5 are the composable [`pipeline`] (one named [`pipeline::Stage`]
//! per cut); stage 6 plus the `(d, m-shape)` work-unit worker pool is
//! [`timed::explore_timed`]; stage 7 is [`ranksweep::sweep_ranks`], a pure
//! function of the stage-6 output (so parallel enumeration stays
//! bit-identical to serial); [`select`] turns the frontier + qualified set
//! into a single choice per policy. The enumerated stages sweep *uniform*
//! rank values (the paper's `R` notation; its experiments fix R per
//! solution), which keeps stage-3+ spaces at the table's reported
//! magnitudes — the rank sweep is where non-enumerated low ranks enter,
//! justified by measured accuracy instead of the vectorization heuristic.

pub mod space;
pub mod pipeline;
pub mod timed;
pub mod pareto;
pub mod ranksweep;
pub mod report;
pub mod select;
pub mod alignment_stats;

pub use pareto::{
    dominates, dominates_with_error, dominates_with_errors, pareto_frontier,
    pareto_frontier_with_error, pareto_frontier_with_errors,
};
pub use pipeline::{explore, Explored, StageCounts};
pub use ranksweep::{sweep_ranks, RankSweep, SweptSolution};
pub use report::{measured_quant_error, quant_error_estimate};
pub use select::{
    select_solution, select_solution_within_error_budget, select_within_accuracy_budget,
};
pub use space::Solution;
pub use timed::{explore_timed, TimedExplored, TimedSolution};
