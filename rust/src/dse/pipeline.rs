//! The composable staged pruning pipeline and its stage-size accounting
//! (paper Tables 1-2, Figure 4).
//!
//! Each paper cut is a named [`Stage`]. The first two stages operate on
//! *counted* design spaces (the raw space reaches ~1e33 and is never
//! materialized); the vectorization stage is where the space becomes small
//! enough to enumerate, and later stages filter the enumerated
//! [`Solution`] set per-solution. The per-solution predicates
//! ([`InitialLayer::keep`], [`Scalability::keep`]) are shared with the
//! parallel timed engine ([`super::timed`]), which applies them inside each
//! work unit instead of over the whole set — same cuts, same counts.
//!
//! After the modeled-time cut, the weight-aware rank sweep
//! ([`super::ranksweep`]) runs as a post-stage-6 step over the survivor
//! shapes. It is not a [`Stage`] (stages are pure shape/cost predicates
//! with no access to weights) and does not appear in [`StageCounts`] — the
//! stage-size accounting stays pinned to the paper's Tables 1-2 columns.

use crate::config::DseConfig;
use crate::factor::count::{space_sizes, CountCfg, SpaceSizes};
use crate::ttd::cost;

use super::space::{enumerate_aligned, Solution};

/// Immutable context every stage sees: the layer under exploration, the
/// engine knobs, and the (precomputed) combinatorial space sizes.
#[derive(Debug, Clone)]
pub struct StageCtx<'a> {
    /// Output dimension M of the explored layer.
    pub m_dim: u64,
    /// Input dimension N of the explored layer.
    pub n_dim: u64,
    /// Engine configuration.
    pub cfg: &'a DseConfig,
    /// Counted sizes of the raw / aligned / vectorized spaces.
    pub sizes: SpaceSizes,
}

impl<'a> StageCtx<'a> {
    /// Build the context for one layer, counting the combinatorial stages
    /// once up front.
    pub fn new(m_dim: u64, n_dim: u64, cfg: &'a DseConfig) -> Self {
        let ccfg = CountCfg { vl: cfg.vl, d_max: cfg.d_max, ..CountCfg::default() };
        StageCtx { m_dim, n_dim, cfg, sizes: space_sizes(m_dim, n_dim, &ccfg) }
    }
}

/// The design space as it flows through the pipeline: a counted magnitude
/// while enumeration is infeasible, a concrete solution list afterwards.
#[derive(Debug, Clone, PartialEq)]
pub enum SpaceState {
    /// A counted (never materialized) space of this many solutions.
    Counted(f64),
    /// An enumerated solution set.
    Enumerated(Vec<Solution>),
}

impl SpaceState {
    /// The magnitude of this state (list length for enumerated states).
    pub fn magnitude(&self) -> f64 {
        match self {
            SpaceState::Counted(v) => *v,
            SpaceState::Enumerated(v) => v.len() as f64,
        }
    }
}

/// One named pipeline stage: a pure transformation of the design space.
pub trait Stage {
    /// Short stage name (the Tables-1/2 column header).
    fn name(&self) -> &'static str;
    /// Apply the stage.
    fn run(&self, ctx: &StageCtx<'_>, state: SpaceState) -> SpaceState;
}

/// Stage 1 — *all initial solutions*: seeds the pipeline with the counted
/// raw space (every shape-permutation pair x rank list;
/// [`crate::factor::count`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllSolutions;

impl Stage for AllSolutions {
    fn name(&self) -> &'static str {
        "all"
    }
    fn run(&self, ctx: &StageCtx<'_>, _state: SpaceState) -> SpaceState {
        SpaceState::Counted(ctx.sizes.all)
    }
}

/// Stage 2 — *alignment strategy* (§4.1): keep only aligned shape pairs
/// (Def. 1); reduction factor per Prop. 4. Still counted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Alignment;

impl Stage for Alignment {
    fn name(&self) -> &'static str {
        "aligned"
    }
    fn run(&self, ctx: &StageCtx<'_>, _state: SpaceState) -> SpaceState {
        SpaceState::Counted(ctx.sizes.aligned)
    }
}

/// Stage 3 — *vectorization constraint* (§4.2.1): ranks must be multiples
/// of `vl`. From here the space is small enough to enumerate, so this stage
/// turns the counted space into the concrete aligned-solution list.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Vectorization;

impl Stage for Vectorization {
    fn name(&self) -> &'static str {
        "vectorized"
    }
    fn run(&self, ctx: &StageCtx<'_>, _state: SpaceState) -> SpaceState {
        SpaceState::Enumerated(enumerate_aligned(ctx.m_dim, ctx.n_dim, ctx.cfg))
    }
}

/// Stage 4 — *initial-layer constraint* (§4.2.2): FLOPs *and* params must
/// beat the dense layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InitialLayer;

impl InitialLayer {
    /// The per-solution predicate (shared with the parallel engine).
    pub fn keep(&self, ctx: &StageCtx<'_>, s: &Solution) -> bool {
        initial_layer_ok(s, ctx.m_dim, ctx.n_dim)
    }
}

impl Stage for InitialLayer {
    fn name(&self) -> &'static str {
        "initial"
    }
    fn run(&self, ctx: &StageCtx<'_>, state: SpaceState) -> SpaceState {
        filter_stage(state, |s| self.keep(ctx, s))
    }
}

/// Stage 5 — *scalability constraint* (§4.2.3): discard long configurations
/// whose heaviest Einsum cannot keep threads busy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Scalability;

impl Scalability {
    /// The per-solution predicate (shared with the parallel engine).
    pub fn keep(&self, ctx: &StageCtx<'_>, s: &Solution) -> bool {
        scalability_ok(s, ctx.cfg)
    }
}

impl Stage for Scalability {
    fn name(&self) -> &'static str {
        "scalability"
    }
    fn run(&self, ctx: &StageCtx<'_>, state: SpaceState) -> SpaceState {
        filter_stage(state, |s| self.keep(ctx, s))
    }
}

fn filter_stage(state: SpaceState, keep: impl Fn(&Solution) -> bool) -> SpaceState {
    match state {
        SpaceState::Enumerated(mut sols) => {
            sols.retain(keep);
            SpaceState::Enumerated(sols)
        }
        counted => counted,
    }
}

/// An ordered stage list with per-stage size accounting.
pub struct Pipeline {
    stages: Vec<Box<dyn Stage>>,
}

impl Pipeline {
    /// The paper's five-stage funnel (Tables 1-2 columns in order).
    pub fn standard() -> Self {
        Pipeline {
            stages: vec![
                Box::new(AllSolutions),
                Box::new(Alignment),
                Box::new(Vectorization),
                Box::new(InitialLayer),
                Box::new(Scalability),
            ],
        }
    }

    /// A pipeline from an explicit stage list (composability hook: ablation
    /// studies drop or reorder cuts without touching the engine).
    pub fn from_stages(stages: Vec<Box<dyn Stage>>) -> Self {
        Pipeline { stages }
    }

    /// Stage names in execution order.
    pub fn stage_names(&self) -> Vec<&'static str> {
        self.stages.iter().map(|s| s.name()).collect()
    }

    /// Run every stage in order, recording each stage's output magnitude.
    /// Returns the per-stage `(name, magnitude)` trace and the final
    /// enumerated survivor set (empty when no stage enumerates).
    pub fn run(&self, ctx: &StageCtx<'_>) -> (Vec<(&'static str, f64)>, Vec<Solution>) {
        let mut state = SpaceState::Counted(0.0);
        let mut trace = Vec::with_capacity(self.stages.len());
        for stage in &self.stages {
            state = stage.run(ctx, state);
            trace.push((stage.name(), state.magnitude()));
        }
        let survivors = match state {
            SpaceState::Enumerated(v) => v,
            SpaceState::Counted(_) => Vec::new(),
        };
        (trace, survivors)
    }
}

/// Design-space size after each pipeline stage (one Tables-1/2 row).
///
/// Stages 1-2 are counted combinatorially (f64 magnitudes; the raw space
/// reaches ~1e33). Stages 3-5 are exact enumeration counts. The modeled-
/// time cut (stage 6) lives in [`super::timed::TimedExplored`], which keeps
/// these five counts byte-for-byte identical to the untimed pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct StageCounts {
    /// Stage 1: every (shape, permutation, rank) combination.
    pub all: f64,
    /// Stage 2: after shape alignment.
    pub aligned: f64,
    /// Stage 3: after the vectorization (rank multiple of vl) cut.
    pub vectorized: usize,
    /// Stage 4: after the initial-configuration cut.
    pub initial: usize,
    /// Stage 5: after the scalability cut.
    pub scalability: usize,
}

/// Result of exploring one FC layer through stages 1-5.
#[derive(Debug, Clone, PartialEq)]
pub struct Explored {
    /// Output dimension M of the explored layer.
    pub m_dim: u64,
    /// Input dimension N of the explored layer.
    pub n_dim: u64,
    /// Per-stage design-space sizes.
    pub counts: StageCounts,
    /// Solutions surviving all five stages, in canonical order
    /// ([`Solution::canonical_cmp`]).
    pub survivors: Vec<Solution>,
}

/// Stage 4 as a free predicate: keep solutions whose FLOPs *and* parameters
/// beat the unfactorized layer (§4.2.2).
pub fn initial_layer_ok(s: &Solution, m_dim: u64, n_dim: u64) -> bool {
    s.flops < cost::dense_flops(m_dim, n_dim) && s.params < cost::dense_params(m_dim, n_dim)
}

/// Stage 5 as a free predicate: discard configuration lengths over
/// `cfg.d_scal_limit` whose heaviest Einsum has fewer than `cfg.scal_flops`
/// FLOPs (poor workload per thread, §4.2.3).
pub fn scalability_ok(s: &Solution, cfg: &DseConfig) -> bool {
    if s.layout.d() <= cfg.d_scal_limit {
        return true;
    }
    let max_flops = cost::einsum_chain(&s.layout, cfg.batch)
        .iter()
        .map(|e| e.flops())
        .max()
        .unwrap_or(0);
    max_flops >= cfg.scal_flops
}

/// Run the standard five-stage pipeline for one FC layer (M outputs,
/// N inputs). For the full six-stage engine (modeled-time cut + Pareto
/// frontier + parallel enumeration) use [`super::timed::explore_timed`].
pub fn explore(m_dim: u64, n_dim: u64, cfg: &DseConfig) -> Explored {
    let ctx = StageCtx::new(m_dim, n_dim, cfg);
    let (trace, mut survivors) = Pipeline::standard().run(&ctx);
    survivors.sort_by(Solution::canonical_cmp);
    Explored {
        m_dim,
        n_dim,
        counts: counts_from_trace(&trace),
        survivors,
    }
}

/// Assemble [`StageCounts`] from a standard-pipeline trace.
fn counts_from_trace(trace: &[(&'static str, f64)]) -> StageCounts {
    let get = |name: &str| {
        trace
            .iter()
            .find(|(n, _)| *n == name)
            .unwrap_or_else(|| panic!("stage '{name}' missing from pipeline trace"))
            .1
    };
    StageCounts {
        all: get("all"),
        aligned: get("aligned"),
        vectorized: get("vectorized") as usize,
        initial: get("initial") as usize,
        scalability: get("scalability") as usize,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn cfg() -> DseConfig {
        DseConfig::default()
    }

    #[test]
    fn stage_counts_monotone_nonincreasing() {
        for (m, n) in [(120u64, 400u64), (300, 784), (512, 512), (2048, 2048)] {
            let e = explore(m, n, &cfg());
            let c = &e.counts;
            assert!(c.all >= c.aligned, "{m}x{n}");
            assert!(c.aligned >= c.vectorized as f64, "{m}x{n}");
            assert!(c.vectorized >= c.initial, "{m}x{n}");
            assert!(c.initial >= c.scalability, "{m}x{n}");
            assert_eq!(e.survivors.len(), c.scalability);
        }
    }

    #[test]
    fn survivors_canonically_ordered_and_all_beat_dense() {
        let e = explore(300, 784, &cfg());
        assert!(!e.survivors.is_empty());
        for w in e.survivors.windows(2) {
            assert_eq!(
                w[0].canonical_cmp(&w[1]),
                std::cmp::Ordering::Less,
                "canonical order violated: {} !< {}",
                w[0].layout.describe(),
                w[1].layout.describe()
            );
            assert!(w[0].flops <= w[1].flops);
        }
        for s in &e.survivors {
            assert!(s.flops < cost::dense_flops(300, 784));
            assert!(s.params < cost::dense_params(300, 784));
        }
    }

    #[test]
    fn initial_layer_constraint_bites_at_high_rank() {
        // with a huge uniform rank the factorized layer is more expensive
        let mut c = cfg();
        c.ranks = vec![512];
        let e = explore(512, 512, &c);
        // everything enumerable at rank 512 must fail the initial constraint
        assert_eq!(e.counts.initial, 0);
    }

    #[test]
    fn scalability_prunes_only_long_light_configs() {
        let e = explore(4096, 4096, &cfg());
        // pruned = initial - scalability; every pruned solution must have
        // d > 4, i.e. every survivor with d > 4 is heavy
        for s in &e.survivors {
            if s.layout.d() > 4 {
                let max_f = cost::einsum_chain(&s.layout, 1)
                    .iter()
                    .map(|x| x.flops())
                    .max()
                    .unwrap();
                assert!(max_f >= cfg().scal_flops);
            }
        }
        assert!(e.counts.initial > e.counts.scalability, "constraint should bite");
    }

    #[test]
    fn property_survivors_always_satisfy_all_constraints() {
        testkit::check("dse invariants", 12, |d| {
            // random composite dims
            let m = 8 * d.usize_in(2, 64) as u64;
            let n = 8 * d.usize_in(2, 64) as u64;
            let e = explore(m, n, &cfg());
            for s in &e.survivors {
                if !s.layout.is_aligned() {
                    return Err(format!("misaligned survivor {}", s.layout.describe()));
                }
                if s.rank % 8 != 0 {
                    return Err("non-vectorizable rank".into());
                }
                if !initial_layer_ok(s, m, n) {
                    return Err("initial-layer violation".into());
                }
                if !scalability_ok(s, &cfg()) {
                    return Err("scalability violation".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn standard_pipeline_names_match_table_columns() {
        assert_eq!(
            Pipeline::standard().stage_names(),
            vec!["all", "aligned", "vectorized", "initial", "scalability"]
        );
    }

    #[test]
    fn pipeline_trace_matches_stage_counts() {
        let c = cfg();
        let ctx = StageCtx::new(300, 784, &c);
        let (trace, survivors) = Pipeline::standard().run(&ctx);
        let counts = counts_from_trace(&trace);
        assert_eq!(counts.all, ctx.sizes.all);
        assert_eq!(counts.aligned, ctx.sizes.aligned);
        assert_eq!(counts.scalability, survivors.len());
        // the trace is monotone non-increasing past the seed
        for w in trace.windows(2) {
            assert!(w[0].1 >= w[1].1, "{:?} -> {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn composed_pipeline_can_skip_cuts() {
        // dropping the scalability stage keeps stage-4 survivors intact
        let c = cfg();
        let ctx = StageCtx::new(300, 784, &c);
        let partial = Pipeline::from_stages(vec![
            Box::new(AllSolutions),
            Box::new(Alignment),
            Box::new(Vectorization),
            Box::new(InitialLayer),
        ]);
        let (trace, survivors) = partial.run(&ctx);
        let full = explore(300, 784, &c);
        assert_eq!(survivors.len(), full.counts.initial);
        assert_eq!(trace.len(), 4);
    }
}
