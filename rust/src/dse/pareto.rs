//! Dominance-checked Pareto frontier over (modeled time, params, FLOPs).
//!
//! The multi-objective view follows "Comprehensive Design Space Exploration
//! for Tensorized Neural Network Hardware Accelerators" (PAPERS.md): rather
//! than collapsing the survivor set to one scalar score, the engine keeps
//! every non-dominated trade-off point so downstream policies (latency-
//! first deployment, memory-first embedding, accuracy-driven fallback) can
//! pick without re-exploring.

use super::timed::TimedSolution;

/// Does `a` dominate `b`: no worse on every objective (modeled time,
/// params, FLOPs) and strictly better on at least one?
pub fn dominates(a: &TimedSolution, b: &TimedSolution) -> bool {
    let no_worse = a.time_s <= b.time_s
        && a.solution.params <= b.solution.params
        && a.solution.flops <= b.solution.flops;
    let strictly_better = a.time_s < b.time_s
        || a.solution.params < b.solution.params
        || a.solution.flops < b.solution.flops;
    no_worse && strictly_better
}

/// Multi-error dominance: the pinned three-objective relation
/// ([`dominates`]) extended with any number of *paired* error axes
/// (`ea[i]` against `eb[i]`; the slices must have equal length). This is
/// how the quantization axis and the rank sweep's reconstruction axis
/// compose rather than fork: pass `[rel_error, quant_error]` and `a`
/// dominates `b` iff it is no worse on every axis — classic and error
/// alike — and strictly better on at least one. With an empty error
/// vector this is exactly [`dominates`].
pub fn dominates_with_errors(a: &TimedSolution, ea: &[f64], b: &TimedSolution, eb: &[f64]) -> bool {
    assert_eq!(ea.len(), eb.len(), "error vectors must pair up axis-for-axis");
    let no_worse = a.time_s <= b.time_s
        && a.solution.params <= b.solution.params
        && a.solution.flops <= b.solution.flops
        && ea.iter().zip(eb).all(|(x, y)| x <= y);
    let strictly_better = a.time_s < b.time_s
        || a.solution.params < b.solution.params
        || a.solution.flops < b.solution.flops
        || ea.iter().zip(eb).any(|(x, y)| x < y);
    no_worse && strictly_better
}

/// Four-axis dominance: [`dominates_with_errors`] with a single error axis
/// (each solution's `err` is its modeled or measured int8 output error,
/// [`super::report::quant_error_estimate`] /
/// [`super::report::measured_quant_error`]). The three-axis relation
/// itself is untouched — this is a wrapper, so every existing frontier
/// stays byte-identical when the error axis is ignored.
pub fn dominates_with_error(a: &TimedSolution, ea: f64, b: &TimedSolution, eb: f64) -> bool {
    dominates_with_errors(a, &[ea], b, &[eb])
}

/// The non-dominated subset of error-vector-annotated solutions under
/// [`dominates_with_errors`], input order preserved. All-pairs — the
/// composed-error view is only ever computed over a frontier head, an
/// annotated selection, or a rank sweep, never the raw stage-5 survivor
/// sets, so the `O(n^2)` cost is irrelevant here.
pub fn pareto_frontier_with_errors(
    annotated: &[(TimedSolution, Vec<f64>)],
) -> Vec<(TimedSolution, Vec<f64>)> {
    annotated
        .iter()
        .filter(|(s, e)| {
            !annotated
                .iter()
                .any(|(o, oe)| dominates_with_errors(o, oe, s, e))
        })
        .cloned()
        .collect()
}

/// [`pareto_frontier_with_errors`] specialized to the single
/// quantization-error axis.
pub fn pareto_frontier_with_error(
    annotated: &[(TimedSolution, f64)],
) -> Vec<(TimedSolution, f64)> {
    annotated
        .iter()
        .filter(|(s, e)| {
            !annotated
                .iter()
                .any(|(o, oe)| dominates_with_error(o, *oe, s, *e))
        })
        .cloned()
        .collect()
}

/// The non-dominated subset of `timed`, returned in canonical order
/// ([`Solution::canonical_cmp`]). Input in any order is accepted; the
/// already-canonical lists the engine produces skip the internal re-sort
/// in all but name.
///
/// The sweep runs in `O(n log n + n * frontier)` rather than the naive
/// all-pairs `O(n^2)`, which matters for the large layers that motivate
/// the engine (stage 5 leaves ~14k survivors on the 9216x4096 AlexNet
/// layer):
///
/// * In canonical order, any dominator of `s` precedes `s` — except a
///   solution tying `s` on both FLOPs and params while beating it on
///   time. A pre-pass over each equal-`(flops, params)` run (contiguous
///   once sorted) discards everything slower than the run's fastest
///   member, eliminating that case.
/// * After the pre-pass, checking each survivor against the *kept*
///   frontier members alone is sound: a dominated `s` has a non-dominated
///   dominator (follow dominators to a maximal one — dominance is a
///   strict partial order), which precedes `s` and was therefore kept.
///
/// Equivalence with the naive all-pairs definition is pinned by the
/// property tests in `rust/tests/dse_engine.rs` and the crafted-set test
/// below.
///
/// [`Solution::canonical_cmp`]: super::space::Solution::canonical_cmp
pub fn pareto_frontier(timed: &[TimedSolution]) -> Vec<TimedSolution> {
    let mut sorted: Vec<&TimedSolution> = timed.iter().collect();
    sorted.sort_by(|a, b| a.solution.canonical_cmp(&b.solution));
    // pre-pass: within an equal-(flops, params) run only the fastest
    // member(s) can be non-dominated (the rest lose on time alone)
    let mut alive = vec![true; sorted.len()];
    let mut start = 0;
    while start < sorted.len() {
        let key = |s: &TimedSolution| (s.solution.flops, s.solution.params);
        let mut end = start + 1;
        while end < sorted.len() && key(sorted[end]) == key(sorted[start]) {
            end += 1;
        }
        let fastest = sorted[start..end]
            .iter()
            .map(|s| s.time_s)
            .fold(f64::INFINITY, f64::min);
        for i in start..end {
            alive[i] = sorted[i].time_s <= fastest;
        }
        start = end;
    }
    // sweep: every surviving candidate needs checking only against the
    // frontier members already kept ahead of it
    let mut frontier: Vec<TimedSolution> = Vec::new();
    for (i, s) in sorted.into_iter().enumerate() {
        if alive[i] && !frontier.iter().any(|f| dominates(f, s)) {
            frontier.push(s.clone());
        }
    }
    frontier
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::space::Solution;
    use crate::ttd::TtLayout;

    fn sol(m: Vec<u64>, n: Vec<u64>, rank: u64, time_s: f64) -> TimedSolution {
        let mut s = Solution::new(
            TtLayout::with_uniform_rank(m, n, rank).unwrap(),
            rank,
        );
        // decouple the objectives from the layout so tests can shape the
        // dominance structure freely
        s.params = (time_s * 1e7) as u64;
        s.flops = s.params * 2;
        TimedSolution { solution: s, time_s, speedup: 1.0 / time_s }
    }

    /// The naive all-pairs definition, kept as the oracle the sweep in
    /// [`pareto_frontier`] must match.
    fn naive_frontier(timed: &[TimedSolution]) -> Vec<TimedSolution> {
        timed
            .iter()
            .filter(|s| !timed.iter().any(|o| dominates(o, s)))
            .cloned()
            .collect()
    }

    #[test]
    fn strict_domination_removes_the_worse_point() {
        let better = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let worse = sol(vec![8, 2], vec![2, 8], 8, 2e-5);
        assert!(dominates(&better, &worse));
        assert!(!dominates(&worse, &better));
        // canonical order puts the lower-(flops, params) point first
        let f = pareto_frontier(&[better.clone(), worse.clone()]);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0], better);
    }

    #[test]
    fn incomparable_points_both_survive() {
        let mut fast_big = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let mut slow_small = sol(vec![8, 2], vec![2, 8], 8, 2e-5);
        fast_big.solution.params = 100;
        fast_big.solution.flops = 100;
        slow_small.solution.params = 50;
        slow_small.solution.flops = 50;
        assert!(!dominates(&fast_big, &slow_small));
        assert!(!dominates(&slow_small, &fast_big));
        let f = pareto_frontier(&[slow_small, fast_big]);
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn sweep_matches_the_naive_definition_on_a_crafted_set() {
        // exercises the equal-(flops, params) pre-pass: the run's slower
        // member must fall to its faster twin, and a later-group member
        // dominated only through a chain must still be cut
        let mut pts = vec![
            sol(vec![4, 4], vec![4, 4], 8, 1.0e-5),
            sol(vec![8, 2], vec![2, 8], 8, 3.0e-5),
            sol(vec![16, 1], vec![1, 16], 8, 2.0e-5),
            sol(vec![2, 8], vec![8, 2], 8, 4.0e-5),
        ];
        // group 0/1: same (flops, params), different times
        pts[1].solution.params = pts[0].solution.params;
        pts[1].solution.flops = pts[0].solution.flops;
        // group 2: more params/flops, faster (incomparable with group 0)
        pts[2].solution.params = pts[0].solution.params + 1;
        pts[2].solution.flops = pts[0].solution.flops + 1;
        pts[2].time_s = 0.5e-5;
        // point 3: dominated by pts[2] (and only by it)
        pts[3].solution.params = pts[2].solution.params + 1;
        pts[3].solution.flops = pts[2].solution.flops + 1;
        pts[3].time_s = 0.6e-5;
        let swept = pareto_frontier(&pts);
        assert_eq!(swept, naive_frontier(&pts));
        assert_eq!(swept.len(), 2); // pts[0] and pts[2]
        assert_eq!(swept[0], pts[0]);
        assert_eq!(swept[1], pts[2]);
    }

    #[test]
    fn identical_objectives_do_not_dominate_each_other() {
        let a = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let mut b = sol(vec![8, 2], vec![2, 8], 8, 1e-5);
        b.solution.params = a.solution.params;
        b.solution.flops = a.solution.flops;
        assert!(!dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert_eq!(pareto_frontier(&[a, b]).len(), 2);
    }

    #[test]
    fn empty_input_gives_empty_frontier() {
        assert!(pareto_frontier(&[]).is_empty());
        assert!(pareto_frontier_with_error(&[]).is_empty());
    }

    #[test]
    fn error_axis_rescues_an_otherwise_dominated_point() {
        // b loses on all three classic axes but quantizes better: under
        // the four-axis relation both survive
        let a = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let b = sol(vec![8, 2], vec![2, 8], 8, 2e-5);
        assert!(dominates(&a, &b));
        assert!(!dominates_with_error(&a, 0.02, &b, 0.01));
        let f = pareto_frontier_with_error(&[(a.clone(), 0.02), (b.clone(), 0.01)]);
        assert_eq!(f.len(), 2);
        // equal errors reduce to the pinned three-axis relation
        assert!(dominates_with_error(&a, 0.01, &b, 0.01));
        let f = pareto_frontier_with_error(&[(a, 0.01), (b, 0.01)]);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn composed_error_axes_require_winning_every_axis() {
        let a = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let b = sol(vec![8, 2], vec![2, 8], 8, 2e-5);
        assert!(dominates(&a, &b));
        // the single-error wrapper and the general relation agree
        assert_eq!(
            dominates_with_error(&a, 0.01, &b, 0.02),
            dominates_with_errors(&a, &[0.01], &b, &[0.02])
        );
        // a wins quantization but loses reconstruction: neither dominates,
        // so both survive the composed frontier — the axes compose instead
        // of forking into two separate frontiers
        assert!(!dominates_with_errors(&a, &[0.5, 0.01], &b, &[0.1, 0.02]));
        assert!(!dominates_with_errors(&b, &[0.1, 0.02], &a, &[0.5, 0.01]));
        let f = pareto_frontier_with_errors(&[
            (a.clone(), vec![0.5, 0.01]),
            (b.clone(), vec![0.1, 0.02]),
        ]);
        assert_eq!(f.len(), 2);
        // equal error vectors reduce to the pinned three-axis relation
        assert!(dominates_with_errors(&a, &[0.1, 0.1], &b, &[0.1, 0.1]));
        let f = pareto_frontier_with_errors(&[(a, vec![0.1, 0.1]), (b, vec![0.1, 0.1])]);
        assert_eq!(f.len(), 1);
        assert!(pareto_frontier_with_errors(&[]).is_empty());
    }

    #[test]
    fn unsorted_input_is_handled_and_output_is_canonical() {
        let better = sol(vec![4, 4], vec![4, 4], 8, 1e-5);
        let worse = sol(vec![8, 2], vec![2, 8], 8, 2e-5);
        let reversed = [worse.clone(), better.clone()];
        let f = pareto_frontier(&reversed);
        assert_eq!(f, vec![better.clone()]);
        assert_eq!(f, pareto_frontier(&[better, worse]));
    }
}
