//! Tables 1-2 row generation: design-space reduction per FC layer of the
//! model zoo. Rows report the paper's five analytic stages
//! ([`super::pipeline`]); selection itself goes through the six-stage
//! engine ([`super::timed`]) and never reads raw survivor lists here.

use crate::config::DseConfig;
use crate::models::ModelArch;
use crate::util::json::Json;
use crate::util::sci;

use super::pipeline::{explore, StageCounts};
use super::timed::TimedSolution;

/// One table row.
#[derive(Debug, Clone)]
pub struct DsRow {
    /// Model name.
    pub model: String,
    /// Dataset tag as the paper's tables print it.
    pub dataset: String,
    /// `[N, M]` as the paper prints FC shapes.
    pub shape: (u64, u64),
    /// How many identical layers share this shape.
    pub count: u64,
    /// Per-stage design-space sizes for this shape.
    pub counts: StageCounts,
}

/// The paper factorizes layers above a size floor only ("extremely small
/// layers are not factorized"): Table 1 keeps [120, 84] and [256, 100] but
/// drops the 10-/100-class heads whose output width is tiny.
pub const MIN_FC_DIM: u64 = 64;

/// Generate the DS-reduction rows for one model.
pub fn rows_for_model(model: &ModelArch, cfg: &DseConfig) -> Vec<DsRow> {
    model
        .fc_shapes()
        .into_iter()
        .filter(|s| s.n >= MIN_FC_DIM && s.m >= MIN_FC_DIM)
        .map(|s| DsRow {
            model: model.name.to_string(),
            dataset: model.dataset.to_string(),
            shape: (s.n, s.m),
            count: s.count,
            counts: explore(s.m, s.n, cfg).counts,
        })
        .collect()
}

/// JSON form of one [`TimedSolution`] — the shared vocabulary of the CLI's
/// `dse --json` report and the DSE section embedded in `.ttrv` bundles
/// ([`crate::artifact`]).
pub fn timed_solution_json(s: &TimedSolution) -> Json {
    let shape = |vals: &[u64]| Json::Arr(vals.iter().map(|&v| Json::from(v as usize)).collect());
    Json::obj(vec![
        ("m_shape", shape(s.layout().m_shape())),
        ("n_shape", shape(s.layout().n_shape())),
        ("rank", Json::from(s.solution.rank as usize)),
        ("d", Json::from(s.layout().d())),
        ("params", Json::from(s.solution.params as usize)),
        ("flops", Json::from(s.solution.flops as usize)),
        ("modeled_time_s", Json::from(s.time_s)),
        ("speedup_vs_dense", Json::from(s.speedup)),
    ])
}

/// Render rows in the paper's table format.
pub fn format_rows(title: &str, rows: &[DsRow]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<22} {:<10} {:>6} {:>16} {:>12} {:>12} {:>12} {:>12}\n",
        "model", "dataset", "count", "FC shape [N,M]", "all", "aligned", "vector", "final"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<22} {:<10} {:>6} {:>16} {:>12} {:>12} {:>12} {:>12}\n",
            r.model,
            r.dataset,
            r.count,
            format!("[{}, {}]", r.shape.0, r.shape.1),
            sci(r.counts.all),
            sci(r.counts.aligned),
            sci(r.counts.vectorized as f64),
            sci(r.counts.scalability as f64),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::model_by_name;

    #[test]
    fn lenet5_rows_match_table1_structure() {
        let m = model_by_name("LeNet5").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        // [400,120] and [120,84] qualify; [84,10] is below the floor
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].shape, (400, 120));
        assert_eq!(rows[1].shape, (120, 84));
        for r in &rows {
            assert!(r.counts.all > r.counts.scalability as f64);
        }
    }

    #[test]
    fn tiny_fc_layers_are_skipped() {
        let m = model_by_name("LeNet300").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        // [784,300] and [300,100]; [100,10] skipped (m = 10 < 100)
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn timed_solution_json_carries_every_field() {
        use crate::machine::MachineSpec;
        let e = crate::dse::explore_timed(300, 784, &MachineSpec::spacemit_k1(), &DseConfig::default());
        let j = timed_solution_json(&e.frontier[0]);
        for key in [
            "m_shape", "n_shape", "rank", "d", "params", "flops",
            "modeled_time_s", "speedup_vs_dense",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // round-trips through the writer/parser
        let text = crate::util::json::to_string(&j);
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn formatting_contains_sci_notation() {
        let m = model_by_name("LeNet5").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        let s = format_rows("Table 1", &rows);
        assert!(s.contains("E+"));
        assert!(s.contains("LeNet5"));
    }
}
