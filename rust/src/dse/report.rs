//! Tables 1-2 row generation: design-space reduction per FC layer of the
//! model zoo. Rows report the paper's five analytic stages
//! ([`super::pipeline`]); selection itself goes through the six-stage
//! engine ([`super::timed`]) and never reads raw survivor lists here.

use crate::config::DseConfig;
use crate::error::Result;
use crate::kernels::{dispatch, Executor, PackedG, QuantizedG, INT8_PORTABLE_KERNEL_NAME};
use crate::machine::MachineSpec;
use crate::models::ModelArch;
use crate::ttd::TtLayout;
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::sci;

use super::pipeline::{explore, StageCounts};
use super::timed::TimedSolution;

/// One table row.
#[derive(Debug, Clone)]
pub struct DsRow {
    /// Model name.
    pub model: String,
    /// Dataset tag as the paper's tables print it.
    pub dataset: String,
    /// `[N, M]` as the paper prints FC shapes.
    pub shape: (u64, u64),
    /// How many identical layers share this shape.
    pub count: u64,
    /// Per-stage design-space sizes for this shape.
    pub counts: StageCounts,
}

/// The paper factorizes layers above a size floor only ("extremely small
/// layers are not factorized"): Table 1 keeps [120, 84] and [256, 100] but
/// drops the 10-/100-class heads whose output width is tiny.
pub const MIN_FC_DIM: u64 = 64;

/// Generate the DS-reduction rows for one model.
pub fn rows_for_model(model: &ModelArch, cfg: &DseConfig) -> Vec<DsRow> {
    model
        .fc_shapes()
        .into_iter()
        .filter(|s| s.n >= MIN_FC_DIM && s.m >= MIN_FC_DIM)
        .map(|s| DsRow {
            model: model.name.to_string(),
            dataset: model.dataset.to_string(),
            shape: (s.n, s.m),
            count: s.count,
            counts: explore(s.m, s.n, cfg).counts,
        })
        .collect()
}

/// JSON form of one [`TimedSolution`] — the shared vocabulary of the CLI's
/// `dse --json` report and the DSE section embedded in `.ttrv` bundles
/// ([`crate::artifact`]).
pub fn timed_solution_json(s: &TimedSolution) -> Json {
    let shape = |vals: &[u64]| Json::Arr(vals.iter().map(|&v| Json::from(v as usize)).collect());
    Json::obj(vec![
        ("m_shape", shape(s.layout().m_shape())),
        ("n_shape", shape(s.layout().n_shape())),
        ("rank", Json::from(s.solution.rank as usize)),
        ("d", Json::from(s.layout().d())),
        ("params", Json::from(s.solution.params as usize)),
        ("flops", Json::from(s.solution.flops as usize)),
        ("modeled_time_s", Json::from(s.time_s)),
        ("speedup_vs_dense", Json::from(s.speedup)),
    ])
}

/// JSON form of one [`SweptSolution`](super::ranksweep::SweptSolution) —
/// a [`timed_solution_json`] object extended with the two accuracy axes the
/// rank sweep attaches: the measured TT-SVD relative reconstruction error
/// and the analytic quantization-error estimate for the chain depth.
pub fn swept_solution_json(s: &super::ranksweep::SweptSolution) -> Json {
    let mut j = timed_solution_json(&s.timed);
    if let Json::Obj(map) = &mut j {
        map.insert("rel_error".to_string(), Json::from(s.rel_error));
        map.insert(
            "quant_error".to_string(),
            Json::from(quant_error_estimate(s.timed.layout().d())),
        );
    }
    j
}

/// Modeled relative output error of int8 per-`m`-slice quantization for a
/// depth-`d` TT chain — the analytic quantization-error axis attached to
/// DSE candidates before any weights exist. Symmetric int8 rounds each
/// core element to within half a quantization step, i.e. at most
/// `1/254` of its slice maximum ([`crate::kernels::quantize`]); the chain
/// multiplies `d` cores, so first-order relative error accumulates
/// additively across depth. A crude bound by design: it exists to *order*
/// candidates (deeper chains quantize worse) and to gate budgets cheaply;
/// [`measured_quant_error`] is the ground truth once cores exist.
pub fn quant_error_estimate(d: usize) -> f64 {
    d as f64 / 254.0
}

/// Measured max-relative-output-error of an int8 chain against its f32
/// chain on seeded calibration inputs: both chains run the portable
/// reference kernels (f32 portable vs int8-portable), so the measurement
/// is deterministic on every host — `verify` replays it byte for byte.
/// The metric is `max_i |q_i - f_i| / max_j |f_j|` over a `batch` of
/// standard-normal calibration rows drawn from `seed`.
pub fn measured_quant_error(
    layout: &TtLayout,
    packed: &[PackedG],
    quant: &[QuantizedG],
    machine: &MachineSpec,
    batch: usize,
    seed: u64,
) -> Result<f64> {
    let int8_kernel = dispatch::by_name(INT8_PORTABLE_KERNEL_NAME)
        .expect("int8-portable is always registered");
    let mut ex_f = Executor::with_kernel(machine, crate::kernels::portable())?;
    let mut ex_q = Executor::with_kernel(machine, int8_kernel)?;
    let mut rng = Rng::new(seed);
    let x = rng.normal_vec(batch * layout.n_total() as usize, 1.0);
    let f = ex_f.run_tt_chain(layout, batch, packed, &x)?.to_vec();
    let q = ex_q.run_tt_chain_q(layout, batch, quant, &x)?;
    let denom = f
        .iter()
        .fold(0f32, |acc, v| acc.max(v.abs()))
        .max(f32::MIN_POSITIVE);
    let max_abs = f
        .iter()
        .zip(q)
        .fold(0f32, |acc, (a, b)| acc.max((a - b).abs()));
    Ok((max_abs / denom) as f64)
}

/// Render rows in the paper's table format.
pub fn format_rows(title: &str, rows: &[DsRow]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<22} {:<10} {:>6} {:>16} {:>12} {:>12} {:>12} {:>12}\n",
        "model", "dataset", "count", "FC shape [N,M]", "all", "aligned", "vector", "final"
    ));
    for r in rows {
        out.push_str(&format!(
            "{:<22} {:<10} {:>6} {:>16} {:>12} {:>12} {:>12} {:>12}\n",
            r.model,
            r.dataset,
            r.count,
            format!("[{}, {}]", r.shape.0, r.shape.1),
            sci(r.counts.all),
            sci(r.counts.aligned),
            sci(r.counts.vectorized as f64),
            sci(r.counts.scalability as f64),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::model_by_name;

    #[test]
    fn lenet5_rows_match_table1_structure() {
        let m = model_by_name("LeNet5").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        // [400,120] and [120,84] qualify; [84,10] is below the floor
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].shape, (400, 120));
        assert_eq!(rows[1].shape, (120, 84));
        for r in &rows {
            assert!(r.counts.all > r.counts.scalability as f64);
        }
    }

    #[test]
    fn tiny_fc_layers_are_skipped() {
        let m = model_by_name("LeNet300").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        // [784,300] and [300,100]; [100,10] skipped (m = 10 < 100)
        assert_eq!(rows.len(), 2);
    }

    #[test]
    fn timed_solution_json_carries_every_field() {
        use crate::machine::MachineSpec;
        let e = crate::dse::explore_timed(300, 784, &MachineSpec::spacemit_k1(), &DseConfig::default());
        let j = timed_solution_json(&e.frontier[0]);
        for key in [
            "m_shape", "n_shape", "rank", "d", "params", "flops",
            "modeled_time_s", "speedup_vs_dense",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // round-trips through the writer/parser
        let text = crate::util::json::to_string(&j);
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn swept_solution_json_extends_the_timed_vocabulary() {
        use crate::dse::ranksweep::SweptSolution;
        use crate::machine::MachineSpec;
        let e =
            crate::dse::explore_timed(300, 784, &MachineSpec::spacemit_k1(), &DseConfig::default());
        let s = SweptSolution { timed: e.frontier[0].clone(), rel_error: 0.125 };
        let j = swept_solution_json(&s);
        // every timed field plus the two accuracy axes
        for key in [
            "m_shape", "n_shape", "rank", "d", "params", "flops",
            "modeled_time_s", "speedup_vs_dense", "rel_error", "quant_error",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        assert_eq!(j.get("rel_error"), Some(&Json::from(0.125)));
        assert_eq!(
            j.get("quant_error"),
            Some(&Json::from(quant_error_estimate(s.timed.layout().d())))
        );
        let text = crate::util::json::to_string(&j);
        assert_eq!(crate::util::json::parse(&text).unwrap(), j);
    }

    #[test]
    fn quant_error_estimate_grows_with_depth() {
        assert!(quant_error_estimate(2) < quant_error_estimate(3));
        assert!(quant_error_estimate(3) < quant_error_estimate(6));
        // d = 2 models under 1% relative error — comfortably inside any
        // practical budget, matching what the measured axis reports
        assert!(quant_error_estimate(2) < 0.01);
    }

    #[test]
    fn measured_quant_error_is_small_and_deterministic() {
        use crate::kernels::quantize;
        use crate::machine::MachineSpec;
        use crate::ttd::cost::einsum_chain;
        use crate::ttd::decompose::random_cores;
        use crate::util::prng::Rng;
        let machine = MachineSpec::spacemit_k1();
        let layout =
            crate::ttd::TtLayout::with_uniform_rank(vec![10, 10], vec![12, 15], 8).unwrap();
        let mut rng = Rng::new(314);
        let tt = random_cores(&layout, &mut rng);
        let mut ex = crate::kernels::Executor::new(&machine);
        let packed: Vec<_> = einsum_chain(&layout, 1)
            .iter()
            .enumerate()
            .map(|(step, dims)| ex.pack(&tt.cores[layout.d() - 1 - step], dims).unwrap())
            .collect();
        let quant: Vec<_> = packed.iter().map(quantize).collect();
        let e1 = measured_quant_error(&layout, &packed, &quant, &machine, 4, 99).unwrap();
        let e2 = measured_quant_error(&layout, &packed, &quant, &machine, 4, 99).unwrap();
        assert_eq!(e1, e2, "fixed seed and portable kernels => deterministic");
        assert!(e1 > 0.0, "quantization moves the output");
        assert!(e1 < 0.05, "per-slice int8 stays within a few percent: {e1}");
    }

    #[test]
    fn formatting_contains_sci_notation() {
        let m = model_by_name("LeNet5").unwrap();
        let rows = rows_for_model(&m, &DseConfig::default());
        let s = format_rows("Table 1", &rows);
        assert!(s.contains("E+"));
        assert!(s.contains("LeNet5"));
    }
}
