//! Solution selection (paper §6.4): configuration length two at the
//! requested rank, preferring *balanced* factor pairs.
//!
//! The paper's text says "minimum FLOPs and a configuration length of two",
//! but every §6.4 selection it reports is a near-square factorization
//! ([4096, 2048] -> [64x64, 64x32]; [1024, 1000] -> [16x64, 40x25]; ...)
//! which is far from the FLOPs minimum of Eq. 11 (degenerate shapes like
//! n = [2, N/2] minimize FLOPs but destroy the TT-rank structure of real
//! weight matrices, so they are useless for accuracy). We therefore select
//! by (balance, FLOPs): the most balanced surviving d=2 pair, FLOPs as the
//! tie-break — which reproduces the paper's reported shape family.
//! [`select_min_flops`] provides the literal-text policy for comparison.
//!
//! The DSE keeps the whole survivor list, so callers can walk alternates if
//! an accuracy constraint fails downstream (paper §4).

use crate::error::{Error, Result};

use super::prune::Explored;
use super::space::Solution;

/// Imbalance score of a shape: `max(factor) / min(factor)` (1.0 = square).
fn imbalance(shape: &[u64]) -> f64 {
    let max = *shape.iter().max().expect("non-empty") as f64;
    let min = *shape.iter().min().expect("non-empty") as f64;
    max / min
}

/// Combined imbalance of a solution's (m, n) shapes.
pub fn solution_imbalance(s: &Solution) -> f64 {
    imbalance(s.layout.m_shape()) * imbalance(s.layout.n_shape())
}

/// §6.4 policy: the most balanced d=2 solution at the requested rank
/// (FLOPs tie-break); falls back to any-d / any-rank survivors.
pub fn select_solution(e: &Explored, rank: u64) -> Result<Solution> {
    let candidates = |d2_only: bool, rank_only: bool| {
        e.survivors
            .iter()
            .filter(move |s| !d2_only || s.layout.d() == 2)
            .filter(move |s| !rank_only || s.rank == rank)
    };
    for (d2, rk) in [(true, true), (true, false), (false, true), (false, false)] {
        let best = candidates(d2, rk).min_by(|a, b| {
            (solution_imbalance(a), a.flops)
                .partial_cmp(&(solution_imbalance(b), b.flops))
                .expect("no NaN")
        });
        if let Some(s) = best {
            return Ok(s.clone());
        }
    }
    Err(Error::NoSolution(format!(
        "no TT solution for {}x{} at rank {rank}",
        e.m_dim, e.n_dim
    )))
}

/// The literal §6.4 text policy: minimum FLOPs among d=2 at the rank.
pub fn select_min_flops(e: &Explored, rank: u64) -> Result<Solution> {
    e.survivors
        .iter()
        .filter(|s| s.layout.d() == 2 && s.rank == rank)
        .min_by_key(|s| s.flops)
        .or_else(|| e.survivors.iter().min_by_key(|s| s.flops))
        .cloned()
        .ok_or_else(|| {
            Error::NoSolution(format!(
                "no TT solution for {}x{} at rank {rank}",
                e.m_dim, e.n_dim
            ))
        })
}

/// The ranked alternates list for accuracy-driven fallback, ordered by the
/// selection score.
pub fn alternates(e: &Explored, limit: usize) -> Vec<Solution> {
    let mut sols = e.survivors.clone();
    sols.sort_by(|a, b| {
        (solution_imbalance(a), a.flops)
            .partial_cmp(&(solution_imbalance(b), b.flops))
            .expect("no NaN")
    });
    sols.truncate(limit);
    sols
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DseConfig;
    use crate::dse::prune::explore;

    #[test]
    fn selects_balanced_d2_at_rank8() {
        let e = explore(300, 784, &DseConfig::default());
        let s = select_solution(&e, 8).unwrap();
        assert_eq!(s.layout.d(), 2);
        assert_eq!(s.rank, 8);
        // the balanced pick for 784 is [28, 28]; for 300 it is [20, 15] —
        // exactly the layout the AOT artifacts use
        assert_eq!(s.layout.n_shape(), &[28, 28]);
        assert_eq!(s.layout.m_shape(), &[20, 15]);
    }

    #[test]
    fn paper_fig15_alexnet_selection() {
        // paper §6.4: [4096, 2048] factorized into [64x64, 64x32]
        let e = explore(2048, 4096, &DseConfig::default());
        let s = select_solution(&e, 8).unwrap();
        assert_eq!(s.layout.n_shape(), &[64, 64]);
        assert_eq!(s.layout.m_shape(), &[64, 32]);
    }

    #[test]
    fn min_flops_policy_is_cheaper_but_less_balanced() {
        let e = explore(300, 784, &DseConfig::default());
        let bal = select_solution(&e, 8).unwrap();
        let min = select_min_flops(&e, 8).unwrap();
        assert!(min.flops <= bal.flops);
        assert!(solution_imbalance(&min) >= solution_imbalance(&bal));
    }

    #[test]
    fn fig15_selection_is_aligned_and_compressive() {
        let e = explore(1000, 2048, &DseConfig::default());
        let s = select_solution(&e, 8).unwrap();
        assert_eq!(s.layout.d(), 2);
        assert!(s.layout.is_aligned());
        assert!(s.flops < crate::ttd::cost::dense_flops(1000, 2048));
        assert_eq!(s.layout.n_shape().iter().product::<u64>(), 2048);
        assert_eq!(s.layout.m_shape().iter().product::<u64>(), 1000);
    }

    #[test]
    fn alternates_sorted_by_selection_score() {
        let e = explore(512, 512, &DseConfig::default());
        let alts = alternates(&e, 5);
        assert!(alts.len() >= 2);
        for w in alts.windows(2) {
            let a = (solution_imbalance(&w[0]), w[0].flops);
            let b = (solution_imbalance(&w[1]), w[1].flops);
            assert!(a <= b);
        }
    }

    #[test]
    fn empty_space_is_an_error() {
        let e = explore(13, 17, &DseConfig::default());
        assert!(select_solution(&e, 8).is_err());
        assert!(select_min_flops(&e, 8).is_err());
    }
}
