//! Solution selection: policies over the six-stage engine's output
//! ([`TimedExplored`]).
//!
//! Two policies ([`crate::config::SelectionPolicy`]):
//!
//! * **Balance** (default, paper §6.4). The paper's text says "minimum
//!   FLOPs and a configuration length of two", but every §6.4 selection it
//!   reports is a near-square factorization ([4096, 2048] -> [64x64,
//!   64x32]; [1024, 1000] -> [16x64, 40x25]; ...) which is far from the
//!   FLOPs minimum of Eq. 11 — degenerate shapes like n = [2, N/2]
//!   minimize FLOPs but destroy the TT-rank structure of real weight
//!   matrices, so they are useless for accuracy. Balance is therefore an
//!   *accuracy proxy*, orthogonal to the frontier's three objectives, and
//!   deliberately searches every stage-6-qualified survivor
//!   ([`TimedExplored::timed`]): restricting it to the frontier would hand
//!   back exactly the degenerate FLOPs-minimal shapes the policy exists to
//!   avoid, because near-square solutions are dominated on (time, params,
//!   FLOPs) by longer/skewed ones.
//! * **MinTime**: the fastest modeled solution; by construction a Pareto
//!   frontier member, selected directly from
//!   [`TimedExplored::frontier`].
//!
//! Every candidate either way carries a modeled time that beat the
//! configured speedup-vs-dense threshold (stage 6), so selection never
//! returns a solution the machine model considers a slowdown.
//! [`select_min_flops`] keeps the literal-text policy for comparison, and
//! [`rerank_measured`] re-orders a frontier head by *measured* chain time
//! (autotuned via [`crate::kernels::Executor::tune_chain`], timed by the
//! floored harness timer) for deployments that can afford to run
//! candidates.
//!
//! The engine keeps the whole qualified list, so callers can walk
//! [`alternates`] if an accuracy constraint fails downstream (paper §4;
//! "Tensorizing Neural Networks" motivates retaining fallbacks).

use crate::config::SelectionPolicy;
use crate::error::{Error, Result};
use crate::kernels::{Executor, PackedG};
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::cost;
use crate::ttd::decompose::{random_cores, TtCores};
use crate::util::prng::Rng;
use crate::util::timer::{self, MeasureFloor};

use super::ranksweep::{RankSweep, SweptSolution};
use super::space::Solution;
use super::timed::{TimedExplored, TimedSolution};

/// Total-order comparison on the balance-selection score `(imbalance,
/// FLOPs)`. `f64::total_cmp` instead of `partial_cmp().expect(..)`: a
/// degenerate cost producing NaN must order deterministically (after every
/// finite score), never panic the thread doing selection.
fn balance_score_cmp(a: &TimedSolution, b: &TimedSolution) -> std::cmp::Ordering {
    solution_imbalance(&a.solution)
        .total_cmp(&solution_imbalance(&b.solution))
        .then_with(|| a.solution.flops.cmp(&b.solution.flops))
}

/// Imbalance score of a shape: `max(factor) / min(factor)` (1.0 = square).
fn imbalance(shape: &[u64]) -> f64 {
    let max = *shape.iter().max().expect("non-empty") as f64;
    let min = *shape.iter().min().expect("non-empty") as f64;
    max / min
}

/// Combined imbalance of a solution's (m, n) shapes.
pub fn solution_imbalance(s: &Solution) -> f64 {
    imbalance(s.layout.m_shape()) * imbalance(s.layout.n_shape())
}

fn no_solution(e: &TimedExplored, rank: u64) -> Error {
    Error::NoSolution(format!(
        "no time-qualified TT solution for {}x{} at rank {rank}",
        e.explored.m_dim, e.explored.n_dim
    ))
}

/// Select a solution under the given policy. Balance walks the
/// `(d = 2, rank)` preference ladder over the time-qualified survivors;
/// MinTime takes the fastest frontier member.
pub fn select_solution(
    e: &TimedExplored,
    rank: u64,
    policy: SelectionPolicy,
) -> Result<TimedSolution> {
    match policy {
        SelectionPolicy::Balance => select_balance(e, rank),
        SelectionPolicy::MinTime => select_min_time(e, rank),
    }
}

/// [`select_solution`] under an int8 quantization-error budget: only
/// candidates whose modeled quantization error
/// ([`super::report::quant_error_estimate`], a function of chain depth)
/// fits `max_quant_error` are eligible; the policy then picks among them
/// as usual. `ttrv compress --quantize` routes selection through this so
/// an int8 deployment never selects a layout the error model already
/// rules out. A budget no candidate fits is a typed [`Error::NoSolution`]
/// naming the budget — never a silent fallback past it.
pub fn select_solution_within_error_budget(
    e: &TimedExplored,
    rank: u64,
    policy: SelectionPolicy,
    max_quant_error: f64,
) -> Result<TimedSolution> {
    let fits =
        |s: &TimedSolution| super::report::quant_error_estimate(s.layout().d()) <= max_quant_error;
    let mut filtered = e.clone();
    filtered.timed.retain(fits);
    filtered.frontier.retain(fits);
    // Emptiness is checked per policy *substrate*: Balance selects from
    // `timed`, MinTime from `frontier`, and the two can empty
    // independently (the frontier can be all-d>=3 while d=2 survivors
    // remain — `balance_pick_is_time_qualified_but_frontier_is_not_its_home`).
    // Requiring both to be empty used to let a frontier-emptying budget
    // fall through to the generic no-solution error that never named it.
    let substrate_empty = match policy {
        SelectionPolicy::Balance => filtered.timed.is_empty(),
        SelectionPolicy::MinTime => filtered.frontier.is_empty(),
    };
    if substrate_empty {
        return Err(Error::NoSolution(format!(
            "no time-qualified TT solution for {}x{} at rank {rank} within quantization \
             error budget {max_quant_error}",
            e.explored.m_dim, e.explored.n_dim
        )));
    }
    select_solution(&filtered, rank, policy)
}

/// Accuracy-budget policy over a rank sweep: the fastest (modeled) swept
/// candidate whose measured TT-SVD relative reconstruction error fits
/// `budget` — the accuracy analogue of
/// [`select_solution_within_error_budget`], with the rank chosen by the
/// sweep rather than taken from the config. Ties on modeled time resolve
/// canonically. Like the quantization budget, a budget no candidate fits
/// is a typed [`Error::NoSolution`] naming the budget — the swept set is
/// the policy's only substrate, so the guard can never route through an
/// error that omits it.
pub fn select_within_accuracy_budget(sweep: &RankSweep, budget: f64) -> Result<SweptSolution> {
    sweep
        .swept
        .iter()
        .filter(|s| s.rel_error <= budget)
        .min_by(|a, b| {
            a.timed
                .time_s
                .total_cmp(&b.timed.time_s)
                .then_with(|| a.timed.solution.canonical_cmp(&b.timed.solution))
        })
        .cloned()
        .ok_or_else(|| {
            Error::NoSolution(format!(
                "no time-qualified TT solution for {}x{} within accuracy budget {budget}",
                sweep.m_dim, sweep.n_dim
            ))
        })
}

/// §6.4 policy: the most balanced time-qualified d=2 solution at the
/// requested rank (FLOPs tie-break); falls back to any-d / any-rank.
fn select_balance(e: &TimedExplored, rank: u64) -> Result<TimedSolution> {
    let candidates = |d2_only: bool, rank_only: bool| {
        e.timed
            .iter()
            .filter(move |s| !d2_only || s.layout().d() == 2)
            .filter(move |s| !rank_only || s.solution.rank == rank)
    };
    for (d2, rk) in [(true, true), (true, false), (false, true), (false, false)] {
        let best = candidates(d2, rk).min_by(|a, b| balance_score_cmp(a, b));
        if let Some(s) = best {
            return Ok(s.clone());
        }
    }
    Err(no_solution(e, rank))
}

/// Min-time policy: the fastest frontier member at the requested rank,
/// falling back to the fastest at any rank when the frontier has no member
/// at that rank (same preference-ladder shape as the balance policy; ties
/// resolve to the canonically-first member).
fn select_min_time(e: &TimedExplored, rank: u64) -> Result<TimedSolution> {
    for rank_only in [true, false] {
        let best = e
            .frontier
            .iter()
            .filter(|s| !rank_only || s.solution.rank == rank)
            .min_by(|a, b| a.time_s.total_cmp(&b.time_s));
        if let Some(s) = best {
            return Ok(s.clone());
        }
    }
    Err(no_solution(e, rank))
}

/// The literal §6.4 text policy: minimum FLOPs among time-qualified d=2 at
/// the rank; any qualified solution as fallback. Kept for comparison with
/// the balance policy.
pub fn select_min_flops(e: &TimedExplored, rank: u64) -> Result<TimedSolution> {
    e.timed
        .iter()
        .filter(|s| s.layout().d() == 2 && s.solution.rank == rank)
        .min_by_key(|s| s.solution.flops)
        .or_else(|| e.timed.iter().min_by_key(|s| s.solution.flops))
        .cloned()
        .ok_or_else(|| no_solution(e, rank))
}

/// The ranked alternates list for accuracy-driven fallback: every
/// time-qualified survivor ordered by the balance-selection score.
pub fn alternates(e: &TimedExplored, limit: usize) -> Vec<TimedSolution> {
    let mut sols = e.timed.clone();
    sols.sort_by(balance_score_cmp);
    sols.truncate(limit);
    sols
}

/// Deterministic per-candidate measurement seed: an FNV-1a hash of the
/// candidate's canonical layout (factor shapes and achieved ranks) and
/// requested rank, mixed with the historical re-rank seed constant.
fn candidate_seed(cand: &TimedSolution) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    let layout = cand.layout();
    mix(layout.d() as u64);
    for &v in layout.m_shape() {
        mix(v);
    }
    for &v in layout.n_shape() {
        mix(v);
    }
    for &v in layout.ranks() {
        mix(v);
    }
    mix(cand.solution.rank);
    h ^ 0x5e1ec7
}

/// The deterministic measurement inputs for one candidate: representative
/// random cores and a calibration batch, drawn from a fresh Rng seeded by
/// [`candidate_seed`]. A function of the candidate (and batch) alone —
/// re-ranking `[a, b]`, `[b, a]`, or `[b]` by itself measures
/// byte-identical tensors for `b`. This is the only source of randomness
/// in [`rerank_measured`]; threading one Rng across the candidate list
/// used to make a candidate's cores depend on its list position.
fn measurement_inputs(cand: &TimedSolution, batch: usize) -> (TtCores, Tensor) {
    let layout = cand.layout();
    let mut rng = Rng::new(candidate_seed(cand));
    let tt = random_cores(layout, &mut rng);
    let x = Tensor::randn(vec![batch, layout.n_total() as usize], 1.0, &mut rng);
    (tt, x)
}

/// Re-rank candidate solutions by **measured** end-to-end chain time on
/// this host: each candidate gets representative random cores, a
/// chain-autotuned executor ([`Executor::tune_chain`] measures RB × thread
/// candidates for every einsum in the chain), one warmup pass, then a
/// floored min-of-samples timing ([`timer::min_secs`] under `floor` — the
/// same harness timer `ttrv bench` uses, so the old zero-ns best-of-3 on
/// coarse clocks cannot happen here either). Returns
/// `(solution, measured seconds)` sorted fastest-first via `total_cmp`
/// (modeled `time_s` is left untouched; ties keep the input order). A
/// non-finite measurement is a typed [`Error::Numeric`], never a NaN that
/// poisons downstream sorts.
///
/// Intended for the frontier head (a handful of candidates) — measurement
/// costs real kernel executions per candidate. Each candidate's random
/// cores and calibration input come from a seed derived from the
/// candidate itself ([`measurement_inputs`]), so its measurement does not
/// depend on where it sits in the list or on which other candidates are
/// measured alongside it.
pub fn rerank_measured(
    candidates: &[TimedSolution],
    machine: &MachineSpec,
    batch: usize,
    floor: &MeasureFloor,
) -> Result<Vec<(TimedSolution, f64)>> {
    let mut measured = Vec::with_capacity(candidates.len());
    for cand in candidates {
        let layout = cand.layout().clone();
        let (tt, x) = measurement_inputs(cand, batch);
        let mut ex = Executor::new(machine);
        let chain = cost::einsum_chain(&layout, batch);
        let packed: Vec<PackedG> = chain
            .iter()
            .enumerate()
            .map(|(step, dims)| ex.pack(&tt.cores[layout.d() - 1 - step], dims))
            .collect::<Result<_>>()?;
        ex.tune_chain(&layout, batch, &packed, floor)?;
        // try_min_secs warms once (validating), then takes the floored min
        let secs = timer::try_min_secs(
            "measured re-rank chain",
            || ex.run_tt_chain(&layout, batch, &packed, x.data()).map(|_| ()),
            floor,
        )?;
        measured.push((cand.clone(), secs));
    }
    measured.sort_by(|a, b| a.1.total_cmp(&b.1));
    Ok(measured)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DseConfig;
    use crate::dse::timed::explore_timed;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    fn timed(m: u64, n: u64) -> TimedExplored {
        explore_timed(m, n, &k1(), &DseConfig::default())
    }

    #[test]
    fn selects_balanced_d2_at_rank8() {
        let e = timed(300, 784);
        let s = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert_eq!(s.layout().d(), 2);
        assert_eq!(s.solution.rank, 8);
        // the balanced pick for 784 is [28, 28]; for 300 it is [20, 15] —
        // exactly the layout the AOT artifacts use
        assert_eq!(s.layout().n_shape(), &[28, 28]);
        assert_eq!(s.layout().m_shape(), &[20, 15]);
        // stage 6 guarantees a modeled win over dense
        assert!(s.speedup >= 1.0);
        assert!(s.time_s > 0.0);
    }

    #[test]
    fn paper_fig15_alexnet_selection() {
        // paper §6.4: [4096, 2048] factorized into [64x64, 64x32]
        let e = timed(2048, 4096);
        let s = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert_eq!(s.layout().n_shape(), &[64, 64]);
        assert_eq!(s.layout().m_shape(), &[64, 32]);
    }

    #[test]
    fn min_time_policy_picks_the_fastest_frontier_member() {
        let e = timed(300, 784);
        let s = select_solution(&e, 8, SelectionPolicy::MinTime).unwrap();
        assert!(e.frontier.contains(&s));
        for f in &e.frontier {
            assert!(s.time_s <= f.time_s);
        }
        for t in &e.timed {
            assert!(s.time_s <= t.time_s, "{} faster", t.layout().describe());
        }
        // the modeled-fastest solution is much faster than the balanced one
        let bal = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert!(s.time_s <= bal.time_s);
    }

    #[test]
    fn min_time_falls_back_when_the_frontier_lacks_the_rank() {
        let e = timed(300, 784);
        // rank 8 dominates higher ranks of the same shapes on every axis,
        // so this frontier is rank-8-only...
        assert!(e.frontier.iter().all(|s| s.solution.rank == 8));
        // ...and a rank-16 request walks the ladder down to the global
        // fastest instead of failing
        let s16 = select_solution(&e, 16, SelectionPolicy::MinTime).unwrap();
        let s8 = select_solution(&e, 8, SelectionPolicy::MinTime).unwrap();
        assert_eq!(s16, s8);
    }

    #[test]
    fn balance_pick_is_time_qualified_but_frontier_is_not_its_home() {
        // the near-square paper selection is dominated on (time, params,
        // FLOPs) by skewed shapes — the very reason Balance searches the
        // qualified set rather than the frontier (module docs)
        let e = timed(300, 784);
        let bal = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert!(e.timed.contains(&bal));
        assert!(!e.frontier.contains(&bal));
    }

    #[test]
    fn error_budget_filters_depth_and_rejects_impossible_budgets() {
        let e = timed(300, 784);
        // a generous budget reproduces the unbudgeted selection exactly
        let plain = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        let budgeted =
            select_solution_within_error_budget(&e, 8, SelectionPolicy::Balance, 0.5).unwrap();
        assert_eq!(plain, budgeted);
        // every admitted candidate's modeled error fits the budget
        let tight = 3.0 / 254.0; // admits d <= 3
        let s =
            select_solution_within_error_budget(&e, 8, SelectionPolicy::MinTime, tight).unwrap();
        assert!(crate::dse::report::quant_error_estimate(s.layout().d()) <= tight);
        // a budget below the d = 2 floor is a typed NoSolution
        let err = select_solution_within_error_budget(&e, 8, SelectionPolicy::Balance, 1e-9)
            .unwrap_err();
        assert!(matches!(err, Error::NoSolution(_)), "{err}");
        assert!(err.to_string().contains("budget"));
    }

    #[test]
    fn min_flops_policy_is_cheaper_but_less_balanced() {
        let e = timed(300, 784);
        let bal = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        let min = select_min_flops(&e, 8).unwrap();
        assert!(min.solution.flops <= bal.solution.flops);
        assert!(solution_imbalance(&min.solution) >= solution_imbalance(&bal.solution));
    }

    #[test]
    fn fig15_selection_is_aligned_and_compressive() {
        let e = timed(1000, 2048);
        let s = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert_eq!(s.layout().d(), 2);
        assert!(s.layout().is_aligned());
        assert!(s.solution.flops < crate::ttd::cost::dense_flops(1000, 2048));
        assert_eq!(s.layout().n_shape().iter().product::<u64>(), 2048);
        assert_eq!(s.layout().m_shape().iter().product::<u64>(), 1000);
    }

    #[test]
    fn alternates_sorted_by_selection_score() {
        let e = timed(512, 512);
        let alts = alternates(&e, 5);
        assert!(alts.len() >= 2);
        for w in alts.windows(2) {
            let a = (solution_imbalance(&w[0].solution), w[0].solution.flops);
            let b = (solution_imbalance(&w[1].solution), w[1].solution.flops);
            assert!(a <= b);
        }
    }

    #[test]
    fn empty_space_is_an_error() {
        let e = timed(13, 17);
        assert!(select_solution(&e, 8, SelectionPolicy::Balance).is_err());
        assert!(select_solution(&e, 8, SelectionPolicy::MinTime).is_err());
        assert!(select_min_flops(&e, 8).is_err());
    }

    #[test]
    fn nan_times_cannot_panic_selection() {
        // a degenerate upstream measurement (0/0 speedup, poisoned cost)
        // used to kill the selecting thread via partial_cmp().expect();
        // total_cmp orders NaN after every finite time instead
        let mut e = timed(300, 784);
        e.timed[0].time_s = f64::NAN;
        if let Some(f) = e.frontier.first_mut() {
            f.time_s = f64::NAN;
        }
        let _ = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        let s = select_solution(&e, 8, SelectionPolicy::MinTime).unwrap();
        if e.frontier.len() > 1 {
            assert!(!s.time_s.is_nan(), "NaN must order after every finite time");
        }
        let _ = alternates(&e, 3);
    }

    #[test]
    fn frontier_emptying_budget_names_the_budget_for_min_time() {
        // regression: with the frontier emptied by the budget but d=2
        // survivors still time-qualified, MinTime used to fall through to
        // select_min_time's generic no-solution error that never
        // mentioned the budget
        let mut e = timed(300, 784);
        assert!(e.timed.iter().any(|s| s.layout().d() == 2));
        let deep = crate::ttd::TtLayout::with_uniform_rank(vec![5, 5, 12], vec![16, 7, 7], 8)
            .expect("valid d=3 layout");
        e.frontier = vec![TimedSolution {
            solution: Solution::new(deep, 8),
            time_s: 1e-6,
            speedup: 2.0,
        }];
        let tight = 2.0 / 254.0; // admits only d = 2, so the frontier empties
        let err = select_solution_within_error_budget(&e, 8, SelectionPolicy::MinTime, tight)
            .unwrap_err();
        assert!(matches!(err, Error::NoSolution(_)), "{err}");
        assert!(err.to_string().contains("budget"), "{err}");
        // the Balance substrate keeps its d=2 survivors, so it succeeds
        let s =
            select_solution_within_error_budget(&e, 8, SelectionPolicy::Balance, tight).unwrap();
        assert_eq!(s.layout().d(), 2);
    }

    #[test]
    fn rerank_measurement_tensors_do_not_depend_on_list_composition() {
        // regression: one Rng threaded across the candidate list made a
        // candidate's random cores (and so its measured time) depend on
        // its position and on which other candidates were measured;
        // measurement inputs are now a function of the candidate alone
        let e = timed(300, 784);
        assert!(e.timed.len() >= 2);
        let a = e.timed[0].clone();
        let b = e.timed[1].clone();
        let bits = |t: &Tensor| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        let (cores_b1, x_b1) = measurement_inputs(&b, 2);
        // drawing `a`'s inputs in between must not perturb `b`'s
        let _ = measurement_inputs(&a, 2);
        let (cores_b2, x_b2) = measurement_inputs(&b, 2);
        assert_eq!(cores_b1.cores.len(), cores_b2.cores.len());
        for (c1, c2) in cores_b1.cores.iter().zip(&cores_b2.cores) {
            assert_eq!(bits(c1), bits(c2));
        }
        assert_eq!(bits(&x_b1), bits(&x_b2));
        // distinct candidates draw from distinct streams
        assert_ne!(candidate_seed(&a), candidate_seed(&b));
        let (cores_a, _) = measurement_inputs(&a, 2);
        assert_ne!(bits(&cores_a.cores[0]), bits(&cores_b1.cores[0]));
    }

    #[test]
    fn accuracy_budget_picks_fastest_within_budget_and_is_typed_below_floor() {
        let mk = |rank: u64, time_s: f64, rel_error: f64| {
            let layout =
                crate::ttd::TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], rank).unwrap();
            SweptSolution {
                timed: TimedSolution {
                    solution: Solution::new(layout, rank),
                    time_s,
                    speedup: 1.0 / time_s,
                },
                rel_error,
            }
        };
        let sweep = RankSweep {
            m_dim: 300,
            n_dim: 784,
            swept: vec![mk(2, 1e-6, 0.4), mk(4, 2e-6, 0.2), mk(8, 3e-6, 0.05)],
            frontier: vec![],
            shapes_swept: 1,
            shapes_total: 1,
        };
        // the fastest candidate within the budget — not the most accurate
        let pick = select_within_accuracy_budget(&sweep, 0.25).unwrap();
        assert_eq!(pick.timed.solution.rank, 4);
        // a loose budget admits everything, so the globally fastest wins
        let loose = select_within_accuracy_budget(&sweep, 1.0).unwrap();
        assert_eq!(loose.timed.solution.rank, 2);
        // below the accuracy floor: a typed NoSolution naming the budget
        let err = select_within_accuracy_budget(&sweep, 0.01).unwrap_err();
        assert!(matches!(err, Error::NoSolution(_)), "{err}");
        assert!(err.to_string().contains("accuracy budget"), "{err}");
    }

    #[test]
    fn rerank_measured_orders_the_frontier_head() {
        let host = MachineSpec::host();
        let e = explore_timed(120, 400, &host, &DseConfig::default());
        let head: Vec<TimedSolution> = e.frontier.iter().take(3).cloned().collect();
        let ranked = rerank_measured(&head, &host, 1, &MeasureFloor::quick()).unwrap();
        assert_eq!(ranked.len(), head.len());
        // sorted by measured seconds, and it is a permutation of the head
        for w in ranked.windows(2) {
            assert!(w[0].1 <= w[1].1);
        }
        for (sol, secs) in &ranked {
            assert!(*secs > 0.0);
            assert!(head.contains(sol));
        }
    }
}
