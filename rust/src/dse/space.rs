//! Aligned-solution enumeration (the *vectorization* stage of the
//! [`super::pipeline`]) and its parallel work-unit decomposition.

use std::cmp::Ordering;
use std::sync::Arc;

use crate::config::DseConfig;
use crate::factor::{self, factor_multisets, partitions::omega};
use crate::ttd::{cost, TtLayout};

/// One candidate factorization of an FC layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    /// The factorized layout.
    pub layout: TtLayout,
    /// Uniform rank value R of the layout.
    pub rank: u64,
    /// Stored parameter count of the layout.
    pub params: u64,
    /// FLOPs per batch-1 inference.
    pub flops: u64,
}

impl Solution {
    /// Price a layout (params + FLOPs) at the given uniform rank.
    pub fn new(layout: TtLayout, rank: u64) -> Self {
        let params = cost::params(&layout);
        let flops = cost::flops(&layout);
        Solution { layout, rank, params, flops }
    }

    /// The canonical total order over solutions:
    /// `(flops, params, rank, m-shape lexicographic, n-shape lexicographic)`.
    ///
    /// Every survivor/frontier list in the DSE engine is sorted by this key,
    /// which (a) makes tie ordering deterministic (plain FLOPs sorting left
    /// equal-FLOPs solutions in enumeration order) and (b) makes parallel
    /// exploration results byte-identical to serial ones after the merge.
    pub fn canonical_cmp(&self, other: &Self) -> Ordering {
        (self.flops, self.params, self.rank)
            .cmp(&(other.flops, other.params, other.rank))
            .then_with(|| self.layout.m_shape().cmp(other.layout.m_shape()))
            .then_with(|| self.layout.n_shape().cmp(other.layout.n_shape()))
    }
}

/// One independent slice of the enumeration space: a configuration length
/// `d` and one aligned output-shape multiset. Work units are the grain of
/// the parallel exploration engine ([`super::timed::explore_timed`]): each
/// unit enumerates and prices its `(n-shape, rank)` sweep in isolation, so
/// units can run on any worker in any order and still merge
/// deterministically. The input-shape multisets for the unit's `d` are
/// computed once per `d` and `Arc`-shared by every unit of that length.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkUnit {
    /// Configuration length of this slice.
    pub d: usize,
    /// Aligned (descending) output-shape multiset.
    pub m_aligned: Vec<u64>,
    /// Aligned (ascending) input-shape multisets of length `d`, shared
    /// across the units of this `d`.
    pub n_aligned_sets: Arc<Vec<Vec<u64>>>,
}

/// The deterministic work-unit list for one FC layer: every `(d, m-shape)`
/// pair in enumeration order (`d` ascending, multisets in
/// [`factor_multisets`] order).
pub fn work_units(m_dim: u64, n_dim: u64, cfg: &DseConfig) -> Vec<WorkUnit> {
    let d_cap = cfg.d_max.min(omega(m_dim)).min(omega(n_dim)).max(2);
    let mut out = Vec::new();
    for d in 2..=d_cap {
        let n_aligned_sets: Arc<Vec<Vec<u64>>> = Arc::new(
            factor_multisets(n_dim, d).into_iter().map(factor::align_n).collect(),
        );
        for ms in factor_multisets(m_dim, d) {
            out.push(WorkUnit {
                d,
                m_aligned: factor::align_m(ms),
                n_aligned_sets: Arc::clone(&n_aligned_sets),
            });
        }
    }
    out
}

/// Enumerate one work unit: every aligned solution with this unit's
/// `(d, m-shape)`, uniform rank drawn from `cfg.ranks`, restricted to ranks
/// that are multiples of `cfg.vl` (the vectorization constraint) and
/// feasible w.r.t. the TT rank bound.
pub fn enumerate_unit(unit: &WorkUnit, cfg: &DseConfig) -> Vec<Solution> {
    let mut out = Vec::new();
    for n_aligned in unit.n_aligned_sets.iter() {
        // tightest rank bound across boundaries caps the sweep
        let bound = (1..unit.d)
            .map(|t| factor::max_rank_at(&unit.m_aligned, n_aligned, t))
            .min()
            .unwrap_or(1);
        for &r in &cfg.ranks {
            if r % cfg.vl != 0 || r > bound {
                continue;
            }
            let layout =
                TtLayout::with_uniform_rank(unit.m_aligned.clone(), n_aligned.clone(), r)
                    .expect("validated by construction");
            out.push(Solution::new(layout, r));
        }
    }
    out
}

/// Enumerate every *aligned* solution of the layer: the concatenation of
/// [`enumerate_unit`] over [`work_units`] in order.
///
/// `m_dim` = output width M, `n_dim` = input width N.
pub fn enumerate_aligned(m_dim: u64, n_dim: u64, cfg: &DseConfig) -> Vec<Solution> {
    work_units(m_dim, n_dim, cfg)
        .iter()
        .flat_map(|u| enumerate_unit(u, cfg))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DseConfig {
        DseConfig::default()
    }

    #[test]
    fn all_solutions_are_aligned_and_vectorizable() {
        for s in enumerate_aligned(300, 784, &cfg()) {
            assert!(s.layout.is_aligned(), "{}", s.layout.describe());
            assert_eq!(s.rank % 8, 0);
            assert!(s.layout.ranks_feasible());
            assert_eq!(s.layout.m_total(), 300);
            assert_eq!(s.layout.n_total(), 784);
        }
    }

    #[test]
    fn includes_the_paper_selected_d2_solution() {
        // Sec. 6.4 style: [784 -> 300] at rank 8 with d = 2 must exist
        let sols = enumerate_aligned(300, 784, &cfg());
        assert!(sols.iter().any(|s| {
            s.layout.d() == 2 && s.rank == 8 && s.layout.m_shape() == [20, 15]
                && s.layout.n_shape() == [28, 28]
        }));
    }

    #[test]
    fn no_duplicate_layouts() {
        let sols = enumerate_aligned(120, 400, &cfg());
        let mut seen = std::collections::HashSet::new();
        for s in &sols {
            let key = format!("{}-{}", s.layout.describe(), s.rank);
            assert!(seen.insert(key), "dup {}", s.layout.describe());
        }
        assert!(!sols.is_empty());
    }

    #[test]
    fn rank_bound_respected() {
        // tiny layer: 4 x 4 = [2,2]x[2,2], bound at t=1 is 4 < 8 -> empty
        let sols = enumerate_aligned(4, 4, &cfg());
        assert!(sols.is_empty());
    }

    #[test]
    fn prime_dims_empty() {
        assert!(enumerate_aligned(13, 784, &cfg()).is_empty());
    }

    #[test]
    fn units_partition_the_enumeration() {
        // flattening the units must reproduce enumerate_aligned exactly and
        // each unit must only contain its own (d, m-shape)
        let c = cfg();
        let units = work_units(300, 784, &c);
        assert!(!units.is_empty());
        let mut flat = Vec::new();
        for u in &units {
            for s in enumerate_unit(u, &c) {
                assert_eq!(s.layout.d(), u.d);
                assert_eq!(s.layout.m_shape(), &u.m_aligned[..]);
                flat.push(s);
            }
        }
        assert_eq!(flat, enumerate_aligned(300, 784, &c));
    }

    #[test]
    fn canonical_order_is_total_and_ties_break_on_shape() {
        let a = Solution::new(
            TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 8).unwrap(),
            8,
        );
        let b = Solution::new(
            TtLayout::with_uniform_rank(vec![25, 12], vec![28, 28], 8).unwrap(),
            8,
        );
        assert_eq!(a.canonical_cmp(&a), Ordering::Equal);
        // antisymmetry on distinct solutions
        assert_ne!(a.canonical_cmp(&b), Ordering::Equal);
        assert_eq!(a.canonical_cmp(&b), b.canonical_cmp(&a).reverse());
        // equal numeric keys fall through to the lexicographic shape compare
        let mut forged = b.clone();
        forged.flops = a.flops;
        forged.params = a.params;
        assert_eq!(
            a.canonical_cmp(&forged),
            a.layout.m_shape().cmp(forged.layout.m_shape())
        );
    }
}
