//! Aligned-solution enumeration (stage 3 of the pipeline).

use crate::config::DseConfig;
use crate::factor::{self, factor_multisets, partitions::omega};
use crate::ttd::{cost, TtLayout};

/// One candidate factorization of an FC layer.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    /// The factorized layout.
    pub layout: TtLayout,
    /// Uniform rank value R of the layout.
    pub rank: u64,
    /// Stored parameter count of the layout.
    pub params: u64,
    /// FLOPs per batch-1 inference.
    pub flops: u64,
}

impl Solution {
    /// Price a layout (params + FLOPs) at the given uniform rank.
    pub fn new(layout: TtLayout, rank: u64) -> Self {
        let params = cost::params(&layout);
        let flops = cost::flops(&layout);
        Solution { layout, rank, params, flops }
    }
}

/// Enumerate every *aligned* solution with uniform rank drawn from
/// `cfg.ranks`, restricted to ranks that are multiples of `cfg.vl` (the
/// vectorization constraint) and feasible w.r.t. the TT rank bound.
///
/// `m_dim` = output width M, `n_dim` = input width N.
pub fn enumerate_aligned(m_dim: u64, n_dim: u64, cfg: &DseConfig) -> Vec<Solution> {
    let mut out = Vec::new();
    let d_cap = cfg.d_max.min(omega(m_dim)).min(omega(n_dim)).max(2);
    for d in 2..=d_cap {
        let m_sets = factor_multisets(m_dim, d);
        let n_sets = factor_multisets(n_dim, d);
        for ms in &m_sets {
            let m_aligned = factor::align_m(ms.clone());
            for ns in &n_sets {
                let n_aligned = factor::align_n(ns.clone());
                // tightest rank bound across boundaries caps the sweep
                let bound = (1..d)
                    .map(|t| factor::max_rank_at(&m_aligned, &n_aligned, t))
                    .min()
                    .unwrap_or(1);
                for &r in &cfg.ranks {
                    if r % cfg.vl != 0 || r > bound {
                        continue;
                    }
                    let layout = TtLayout::with_uniform_rank(
                        m_aligned.clone(),
                        n_aligned.clone(),
                        r,
                    )
                    .expect("validated by construction");
                    out.push(Solution::new(layout, r));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DseConfig {
        DseConfig::default()
    }

    #[test]
    fn all_solutions_are_aligned_and_vectorizable() {
        for s in enumerate_aligned(300, 784, &cfg()) {
            assert!(s.layout.is_aligned(), "{}", s.layout.describe());
            assert_eq!(s.rank % 8, 0);
            assert!(s.layout.ranks_feasible());
            assert_eq!(s.layout.m_total(), 300);
            assert_eq!(s.layout.n_total(), 784);
        }
    }

    #[test]
    fn includes_the_paper_selected_d2_solution() {
        // Sec. 6.4 style: [784 -> 300] at rank 8 with d = 2 must exist
        let sols = enumerate_aligned(300, 784, &cfg());
        assert!(sols.iter().any(|s| {
            s.layout.d() == 2 && s.rank == 8 && s.layout.m_shape() == [20, 15]
                && s.layout.n_shape() == [28, 28]
        }));
    }

    #[test]
    fn no_duplicate_layouts() {
        let sols = enumerate_aligned(120, 400, &cfg());
        let mut seen = std::collections::HashSet::new();
        for s in &sols {
            let key = format!("{}-{}", s.layout.describe(), s.rank);
            assert!(seen.insert(key), "dup {}", s.layout.describe());
        }
        assert!(!sols.is_empty());
    }

    #[test]
    fn rank_bound_respected() {
        // tiny layer: 4 x 4 = [2,2]x[2,2], bound at t=1 is 4 < 8 -> empty
        let sols = enumerate_aligned(4, 4, &cfg());
        assert!(sols.is_empty());
    }

    #[test]
    fn prime_dims_empty() {
        assert!(enumerate_aligned(13, 784, &cfg()).is_empty());
    }
}
