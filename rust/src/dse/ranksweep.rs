//! The rank sweep: accuracy as a measured DSE axis (stage 7, after the
//! modeled-time cut).
//!
//! Stages 1-6 prune by shape efficiency and modeled performance but take
//! the TT rank as a config input; this stage makes rank a *searched*
//! dimension with a measurable accuracy cost, following "Data-Driven
//! Low-Rank Neural Network Compression" (rank from reconstruction error)
//! and "Comprehensive Design Space Exploration for Tensorized Neural
//! Network Hardware Accelerators" (accuracy as an explicit DSE objective)
//! — see PAPERS.md. For each distinct stage-6 survivor shape (most
//! balanced first — the accuracy-relevant ordering — capped at
//! [`DseConfig::sweep_shapes`]), the layer's weight matrix is
//! TT-SVD-decomposed at every rank in [`DseConfig::rank_candidates`], and
//! each priced, time-qualified result is annotated with its relative
//! Frobenius reconstruction error
//! ([`crate::ttd::decompose::TtCores::rel_error`]).
//!
//! Candidate ranks are deliberately *not* restricted to the enumerated
//! space's `rank % vl == 0` vectorization constraint: low ranks like 2 or
//! 4 trade vector-lane utilization for accuracy headroom (the compiler
//! falls back to K-loop vectorization), and the same modeled-time
//! qualification as stage 6 (`time_speedup_min`) decides what survives —
//! never the vectorization heuristic alone.
//!
//! The annotated frontier composes the reconstruction axis with the
//! modeled int8 quantization axis
//! ([`super::pareto::pareto_frontier_with_errors`] over
//! `[rel_error, quant_error]`) instead of forking a second single-error
//! frontier. Selection under an accuracy budget is
//! [`super::select::select_within_accuracy_budget`]. Everything here is a
//! pure function of `(explored, w, machine, cfg)`, so worker-parallel
//! enumeration upstream stays bit-identical to serial.

use crate::config::DseConfig;
use crate::error::Result;
use crate::machine::MachineSpec;
use crate::tensor::Tensor;
use crate::ttd::decompose::tt_svd;
use crate::ttd::TtLayout;

use super::pareto::pareto_frontier_with_errors;
use super::report::quant_error_estimate;
use super::space::Solution;
use super::timed::{price_solution, TimedExplored, TimedSolution};

/// One swept candidate: a priced, time-qualified solution at a sweep
/// rank, annotated with its measured TT-SVD reconstruction error.
#[derive(Debug, Clone, PartialEq)]
pub struct SweptSolution {
    /// The priced solution. `timed.solution.rank` is the *requested*
    /// sweep rank; the layout carries the achieved (possibly clipped)
    /// TT-SVD ranks that pricing used.
    pub timed: TimedSolution,
    /// Relative Frobenius reconstruction error of the TT-SVD cores
    /// against the layer's weight matrix.
    pub rel_error: f64,
}

/// Result of sweeping one layer's stage-6 survivor shapes over the rank
/// ladder.
#[derive(Debug, Clone, PartialEq)]
pub struct RankSweep {
    /// Output dimension M of the swept layer.
    pub m_dim: u64,
    /// Input dimension N of the swept layer.
    pub n_dim: u64,
    /// Every time-qualified swept candidate, in canonical order,
    /// deduplicated by achieved layout (two requested ranks clipping to
    /// the same achieved cores keep the smaller request).
    pub swept: Vec<SweptSolution>,
    /// The non-dominated subset of `swept` under the composed relation
    /// (modeled time, params, FLOPs, reconstruction error, modeled
    /// quantization error), input (canonical) order preserved.
    pub frontier: Vec<SweptSolution>,
    /// Distinct survivor shapes actually swept.
    pub shapes_swept: usize,
    /// Distinct survivor shapes available; greater than `shapes_swept`
    /// when the [`DseConfig::sweep_shapes`] cap truncated the sweep.
    pub shapes_total: usize,
}

/// Imbalance of one shape pair, matching the balance-selection score
/// ([`super::select::solution_imbalance`]): `max/min` per factor list,
/// multiplied across the m- and n-shapes (1.0 = perfectly square).
fn shape_imbalance(m_shape: &[u64], n_shape: &[u64]) -> f64 {
    let one = |shape: &[u64]| {
        let max = *shape.iter().max().expect("non-empty shape") as f64;
        let min = *shape.iter().min().expect("non-empty shape") as f64;
        max / min
    };
    one(m_shape) * one(n_shape)
}

/// Sweep the stage-6 survivor shapes of one explored layer over
/// `cfg.rank_candidates` against the layer's weight matrix `w` (`(M, N)`,
/// matching `e.explored`). Per shape x rank: TT-SVD (ranks clip to the
/// achieved unfolding ranks), reconstruction error, pricing at the
/// achieved layout, and the same speedup-vs-dense cut as stage 6.
/// Candidates whose rank is infeasible for a shape, whose chain has no
/// feasible schedule, or whose modeled speedup misses
/// `cfg.time_speedup_min` are skipped, like their stage-6 counterparts.
pub fn sweep_ranks(
    e: &TimedExplored,
    w: &Tensor,
    machine: &MachineSpec,
    cfg: &DseConfig,
) -> Result<RankSweep> {
    // distinct (m-shape, n-shape) pairs of the stage-6 survivors, most
    // balanced first (ties break lexicographically) so the sweep_shapes
    // cap keeps the accuracy-relevant near-square shapes, not the
    // cheap skewed ones canonical order leads with
    let mut shapes: Vec<(Vec<u64>, Vec<u64>)> = Vec::new();
    for t in &e.timed {
        let key = (t.layout().m_shape().to_vec(), t.layout().n_shape().to_vec());
        if !shapes.contains(&key) {
            shapes.push(key);
        }
    }
    shapes.sort_by(|a, b| {
        shape_imbalance(&a.0, &a.1)
            .total_cmp(&shape_imbalance(&b.0, &b.1))
            .then_with(|| a.cmp(b))
    });
    let shapes_total = shapes.len();
    shapes.truncate(cfg.sweep_shapes);
    let shapes_swept = shapes.len();

    let mut swept: Vec<SweptSolution> = Vec::new();
    for (m_shape, n_shape) in &shapes {
        for &r in &cfg.rank_candidates {
            let Ok(target) = TtLayout::with_uniform_rank(m_shape.clone(), n_shape.clone(), r)
            else {
                continue; // rank infeasible for this shape pair
            };
            let tt = tt_svd(w, &target)?;
            let rel_error = tt.rel_error(w)? as f64;
            // price at the achieved layout; the requested rank stays as
            // the solution's rank label
            let sol = Solution::new(tt.layout, r);
            let Some(time_s) = price_solution(&sol, machine, cfg.batch) else {
                continue; // unschedulable chain, discarded like stage 6
            };
            let speedup = e.dense_time_s / time_s;
            if speedup < cfg.time_speedup_min {
                continue; // same cut as stage 6
            }
            swept.push(SweptSolution {
                timed: TimedSolution { solution: sol, time_s, speedup },
                rel_error,
            });
        }
    }

    // two requested ranks can clip to the same achieved layout (e.g. 8
    // and 16 on a shape whose unfolding rank is 5): identical cores,
    // price, and error — keep the smaller request
    let mut unique: Vec<SweptSolution> = Vec::new();
    for s in swept {
        match unique.iter_mut().find(|u| u.timed.layout() == s.timed.layout()) {
            Some(u) => {
                if s.timed.solution.rank < u.timed.solution.rank {
                    *u = s;
                }
            }
            None => unique.push(s),
        }
    }
    let mut swept = unique;
    swept.sort_by(|a, b| a.timed.solution.canonical_cmp(&b.timed.solution));

    let annotated: Vec<(TimedSolution, Vec<f64>)> = swept
        .iter()
        .map(|s| {
            let errs = vec![s.rel_error, quant_error_estimate(s.timed.layout().d())];
            (s.timed.clone(), errs)
        })
        .collect();
    let frontier = pareto_frontier_with_errors(&annotated)
        .into_iter()
        .map(|(timed, errs)| SweptSolution { timed, rel_error: errs[0] })
        .collect();

    Ok(RankSweep {
        m_dim: e.explored.m_dim,
        n_dim: e.explored.n_dim,
        swept,
        frontier,
        shapes_swept,
        shapes_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SelectionPolicy;
    use crate::dse::select::{select_solution, select_within_accuracy_budget};
    use crate::dse::timed::explore_timed;
    use crate::error::Error;
    use crate::ttd::decompose::random_cores;
    use crate::util::prng::Rng;

    fn k1() -> MachineSpec {
        MachineSpec::spacemit_k1()
    }

    /// A small ladder and shape cap keep the per-test TT-SVD count at
    /// e2e-suite scale (Jacobi SVDs of 300x784 unfoldings dominate).
    fn sweep_cfg(shapes: usize, ranks: Vec<u64>) -> DseConfig {
        DseConfig { sweep_shapes: shapes, rank_candidates: ranks, ..Default::default() }
    }

    #[test]
    fn rel_error_is_monotone_nonincreasing_in_rank_per_shape() {
        let cfg = sweep_cfg(1, vec![2, 4, 8]);
        let e = explore_timed(300, 784, &k1(), &cfg);
        let w = Tensor::randn(vec![300, 784], 0.1, &mut Rng::new(5));
        let sweep = sweep_ranks(&e, &w, &k1(), &cfg).unwrap();
        assert_eq!(sweep.m_dim, 300);
        assert_eq!(sweep.n_dim, 784);
        assert_eq!(sweep.shapes_swept, 1);
        assert!(sweep.shapes_total > 1);
        // on a full-rank random W no ranks clip, so all three survive if
        // any does; more rank never reconstructs worse
        assert!(sweep.swept.len() >= 2, "swept: {}", sweep.swept.len());
        let mut by_rank = sweep.swept.clone();
        by_rank.sort_by_key(|s| s.timed.solution.rank);
        for pair in by_rank.windows(2) {
            assert!(
                pair[1].rel_error <= pair[0].rel_error + 1e-5,
                "rank {} err {} > rank {} err {}",
                pair[1].timed.solution.rank,
                pair[1].rel_error,
                pair[0].timed.solution.rank,
                pair[0].rel_error
            );
        }
        // every candidate carries a meaningful error and a stage-6-grade
        // time qualification
        for s in &sweep.swept {
            assert!(s.rel_error.is_finite() && s.rel_error >= 0.0);
            assert!(s.timed.speedup >= cfg.time_speedup_min);
            assert!(s.timed.time_s > 0.0);
        }
    }

    #[test]
    fn frontier_is_nondominated_subset_under_composed_errors() {
        let cfg = sweep_cfg(2, vec![2, 8]);
        let e = explore_timed(300, 784, &k1(), &cfg);
        let w = Tensor::randn(vec![300, 784], 0.1, &mut Rng::new(6));
        let sweep = sweep_ranks(&e, &w, &k1(), &cfg).unwrap();
        assert!(!sweep.frontier.is_empty());
        assert!(sweep.frontier.len() <= sweep.swept.len());
        let errs = |s: &SweptSolution| {
            vec![s.rel_error, quant_error_estimate(s.timed.layout().d())]
        };
        for f in &sweep.frontier {
            assert!(sweep.swept.contains(f));
            for o in &sweep.swept {
                assert!(!crate::dse::pareto::dominates_with_errors(
                    &o.timed,
                    &errs(o),
                    &f.timed,
                    &errs(f)
                ));
            }
        }
    }

    #[test]
    fn budget_forces_a_rank_the_fixed_rank_path_would_not_select() {
        // plant a TT-rank-2 weight matrix on the balance pick's shape
        // ([20, 15] x [28, 28], `selects_balanced_d2_at_rank8`): the
        // fixed-rank path keeps the configured rank 8, but the sweep sees
        // that rank 2 already reconstructs W exactly and a tight budget
        // selects it — a rank outside the enumerated space entirely
        // (2 % vl != 0)
        let cfg = sweep_cfg(2, vec![2, 4, 8]);
        let e = explore_timed(300, 784, &k1(), &cfg);
        let planted = TtLayout::with_uniform_rank(vec![20, 15], vec![28, 28], 2).unwrap();
        let w = random_cores(&planted, &mut Rng::new(7)).reconstruct().unwrap();
        let sweep = sweep_ranks(&e, &w, &k1(), &cfg).unwrap();
        let pick = select_within_accuracy_budget(&sweep, 1e-3).unwrap();
        // only the planted shape reconstructs under the budget, and there
        // the sweep prefers a cheap low rank over the configured 8
        assert_eq!(pick.timed.layout().m_shape(), &[20, 15]);
        assert_eq!(pick.timed.layout().n_shape(), &[28, 28]);
        assert!(pick.timed.solution.rank < 8, "picked rank {}", pick.timed.solution.rank);
        assert!(pick.rel_error <= 1e-3, "err {}", pick.rel_error);
        assert_ne!(pick.timed.solution.rank % cfg.vl, 0);
        // the old fixed-rank path cannot produce this rank
        let fixed = select_solution(&e, 8, SelectionPolicy::Balance).unwrap();
        assert_eq!(fixed.solution.rank, 8);
        assert_ne!(pick.timed.solution.rank, fixed.solution.rank);
        // an impossible budget on the same sweep is a typed, budget-naming
        // error (the accuracy floor of a planted rank-2 W is ~0, so go
        // below float noise)
        let err = select_within_accuracy_budget(&sweep, 1e-12).unwrap_err();
        assert!(matches!(err, Error::NoSolution(_)), "{err}");
        assert!(err.to_string().contains("accuracy budget"), "{err}");
    }

    #[test]
    fn sweep_is_identical_for_parallel_exploration() {
        // the sweep is a pure function of the explored result, and the
        // explored result is byte-identical for every worker count — so
        // the new stage preserves the engine's parallel determinism
        let mut cfg = sweep_cfg(1, vec![2, 8]);
        let w = Tensor::randn(vec![300, 784], 0.1, &mut Rng::new(8));
        let serial = {
            let e = explore_timed(300, 784, &k1(), &cfg);
            sweep_ranks(&e, &w, &k1(), &cfg).unwrap()
        };
        cfg.dse_workers = 4;
        let e = explore_timed(300, 784, &k1(), &cfg);
        let parallel = sweep_ranks(&e, &w, &k1(), &cfg).unwrap();
        assert_eq!(parallel, serial);
    }

    #[test]
    fn empty_exploration_sweeps_nothing() {
        let cfg = sweep_cfg(8, vec![2, 8]);
        let e = explore_timed(13, 17, &k1(), &cfg); // prime layer: no survivors
        let w = Tensor::randn(vec![13, 17], 0.1, &mut Rng::new(9));
        let sweep = sweep_ranks(&e, &w, &k1(), &cfg).unwrap();
        assert!(sweep.swept.is_empty());
        assert!(sweep.frontier.is_empty());
        assert_eq!(sweep.shapes_total, 0);
        let err = select_within_accuracy_budget(&sweep, 0.5).unwrap_err();
        assert!(matches!(err, Error::NoSolution(_)), "{err}");
    }
}
