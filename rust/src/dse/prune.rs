//! The staged pruning pipeline and its stage-size accounting
//! (paper Tables 1-2).

use crate::config::DseConfig;
use crate::factor::count::{space_sizes, CountCfg};
use crate::ttd::cost;

use super::space::{enumerate_aligned, Solution};

/// Design-space size after each pipeline stage (one Tables-1/2 row).
///
/// Stages 1-2 are counted combinatorially (f64 magnitudes; the raw space
/// reaches ~1e33). Stages 3-5 are exact enumeration counts.
#[derive(Debug, Clone, PartialEq)]
pub struct StageCounts {
    /// Stage 1: every (shape, permutation, rank) combination.
    pub all: f64,
    /// Stage 2: after shape alignment.
    pub aligned: f64,
    /// Stage 3: after the vectorization (rank multiple of vl) cut.
    pub vectorized: usize,
    /// Stage 4: after the initial-configuration cut.
    pub initial: usize,
    /// Stage 5: after the scalability cut.
    pub scalability: usize,
}

/// Result of exploring one FC layer.
#[derive(Debug, Clone)]
pub struct Explored {
    /// Output dimension M of the explored layer.
    pub m_dim: u64,
    /// Input dimension N of the explored layer.
    pub n_dim: u64,
    /// Per-stage design-space sizes.
    pub counts: StageCounts,
    /// Solutions surviving all five stages, sorted by ascending FLOPs.
    pub survivors: Vec<Solution>,
}

/// Stage 4: the initial-layer constraint (§4.2.2) — keep solutions whose
/// FLOPs *and* parameters beat the unfactorized layer.
pub fn initial_layer_ok(s: &Solution, m_dim: u64, n_dim: u64) -> bool {
    s.flops < cost::dense_flops(m_dim, n_dim) && s.params < cost::dense_params(m_dim, n_dim)
}

/// Stage 5: the scalability constraint (§4.2.3) — discard configuration
/// lengths over `cfg.d_scal_limit` whose heaviest Einsum has fewer than
/// `cfg.scal_flops` FLOPs (poor workload per thread).
pub fn scalability_ok(s: &Solution, cfg: &DseConfig) -> bool {
    if s.layout.d() <= cfg.d_scal_limit {
        return true;
    }
    let max_flops = cost::einsum_chain(&s.layout, cfg.batch)
        .iter()
        .map(|e| e.flops())
        .max()
        .unwrap_or(0);
    max_flops >= cfg.scal_flops
}

/// Run the full pipeline for one FC layer (M outputs, N inputs).
pub fn explore(m_dim: u64, n_dim: u64, cfg: &DseConfig) -> Explored {
    let ccfg = CountCfg { vl: cfg.vl, d_max: cfg.d_max, ..CountCfg::default() };
    let sizes = space_sizes(m_dim, n_dim, &ccfg);

    let vectorized = enumerate_aligned(m_dim, n_dim, cfg);
    let n_vec = vectorized.len();

    let mut initial: Vec<Solution> = vectorized
        .into_iter()
        .filter(|s| initial_layer_ok(s, m_dim, n_dim))
        .collect();
    let n_init = initial.len();

    initial.retain(|s| scalability_ok(s, cfg));
    let n_scal = initial.len();

    initial.sort_by_key(|s| (s.flops, s.params));
    Explored {
        m_dim,
        n_dim,
        counts: StageCounts {
            all: sizes.all,
            aligned: sizes.aligned,
            vectorized: n_vec,
            initial: n_init,
            scalability: n_scal,
        },
        survivors: initial,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn cfg() -> DseConfig {
        DseConfig::default()
    }

    #[test]
    fn stage_counts_monotone_nonincreasing() {
        for (m, n) in [(120u64, 400u64), (300, 784), (512, 512), (2048, 2048)] {
            let e = explore(m, n, &cfg());
            let c = &e.counts;
            assert!(c.all >= c.aligned, "{m}x{n}");
            assert!(c.aligned >= c.vectorized as f64, "{m}x{n}");
            assert!(c.vectorized >= c.initial, "{m}x{n}");
            assert!(c.initial >= c.scalability, "{m}x{n}");
            assert_eq!(e.survivors.len(), c.scalability);
        }
    }

    #[test]
    fn survivors_sorted_and_all_beat_dense() {
        let e = explore(300, 784, &cfg());
        assert!(!e.survivors.is_empty());
        for w in e.survivors.windows(2) {
            assert!(w[0].flops <= w[1].flops);
        }
        for s in &e.survivors {
            assert!(s.flops < cost::dense_flops(300, 784));
            assert!(s.params < cost::dense_params(300, 784));
        }
    }

    #[test]
    fn initial_layer_constraint_bites_at_high_rank() {
        // with a huge uniform rank the factorized layer is more expensive
        let mut c = cfg();
        c.ranks = vec![512];
        let e = explore(512, 512, &c);
        // everything enumerable at rank 512 must fail the initial constraint
        assert_eq!(e.counts.initial, 0);
    }

    #[test]
    fn scalability_prunes_only_long_light_configs() {
        let e = explore(4096, 4096, &cfg());
        // pruned = initial - scalability; every pruned solution must have
        // d > 4, i.e. every survivor with d > 4 is heavy
        for s in &e.survivors {
            if s.layout.d() > 4 {
                let max_f = cost::einsum_chain(&s.layout, 1)
                    .iter()
                    .map(|x| x.flops())
                    .max()
                    .unwrap();
                assert!(max_f >= cfg().scal_flops);
            }
        }
        assert!(e.counts.initial > e.counts.scalability, "constraint should bite");
    }

    #[test]
    fn property_survivors_always_satisfy_all_constraints() {
        testkit::check("dse invariants", 12, |d| {
            // random composite dims
            let m = 8 * d.usize_in(2, 64) as u64;
            let n = 8 * d.usize_in(2, 64) as u64;
            let e = explore(m, n, &cfg());
            for s in &e.survivors {
                if !s.layout.is_aligned() {
                    return Err(format!("misaligned survivor {}", s.layout.describe()));
                }
                if s.rank % 8 != 0 {
                    return Err("non-vectorizable rank".into());
                }
                if !initial_layer_ok(s, m, n) {
                    return Err("initial-layer violation".into());
                }
                if !scalability_ok(s, &cfg()) {
                    return Err("scalability violation".into());
                }
            }
            Ok(())
        });
    }
}
