//! Crate-wide error type.
//!
//! `thiserror` is unavailable offline, so the derive is spelled out by hand —
//! same shape: one variant per subsystem, `Display` + `std::error::Error` +
//! `From` conversions.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// All error conditions surfaced by the public API.
#[derive(Debug)]
pub enum Error {
    /// Tensor shape mismatch or invalid reshape/transpose request.
    Shape(String),
    /// Invalid TT layout (factor products, rank bounds, alignment).
    Layout(String),
    /// Numerical failure (SVD non-convergence, NaN poisoning).
    Numeric(String),
    /// Design-space exploration produced no feasible solution.
    NoSolution(String),
    /// Compiler pass could not produce a plan (e.g. Eq. 28 infeasible).
    Plan(String),
    /// Config file / CLI parse error.
    Config(String),
    /// JSON parse error (artifact manifest).
    Json(String),
    /// PJRT runtime failure (wraps the `xla` crate error as text).
    Runtime(String),
    /// Serving coordinator failure (queue closed, engine missing, ...).
    Serve(String),
    /// Compressed-model artifact failure: malformed or corrupted `.ttrv`
    /// bundle (bad magic/version, CRC mismatch, truncated section, invalid
    /// layer encoding). A typed variant so the decoder surface can promise
    /// "typed error, never panic" on arbitrary input bytes.
    Artifact(String),
    /// Microkernel dispatch failure: a kernel was requested (forced via
    /// config, restored from a tuned artifact, or enumerated by autotune)
    /// whose `supported()` probe is false on this host. A typed variant so
    /// `tune_chain` and `Executor` construction can refuse cleanly instead
    /// of panicking or executing illegal instructions.
    Kernel(String),
    /// Admission control refused a request: the serving queue is at
    /// capacity. A typed variant so callers can distinguish backpressure
    /// (retry / shed load) from hard serving failures without string
    /// matching.
    QueueFull,
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Layout(m) => write!(f, "tt-layout error: {m}"),
            Error::Numeric(m) => write!(f, "numeric error: {m}"),
            Error::NoSolution(m) => write!(f, "no feasible solution: {m}"),
            Error::Plan(m) => write!(f, "compiler plan error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Serve(m) => write!(f, "serve error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Kernel(m) => write!(f, "kernel error: {m}"),
            Error::QueueFull => write!(f, "serve error: queue full (admission control)"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

// Shorthand constructors used across the crate.
impl Error {
    /// An [`Error::Shape`] with the given message.
    pub fn shape(msg: impl Into<String>) -> Self {
        Error::Shape(msg.into())
    }
    /// An [`Error::Layout`] with the given message.
    pub fn layout(msg: impl Into<String>) -> Self {
        Error::Layout(msg.into())
    }
    /// An [`Error::Numeric`] with the given message.
    pub fn numeric(msg: impl Into<String>) -> Self {
        Error::Numeric(msg.into())
    }
    /// An [`Error::Plan`] with the given message.
    pub fn plan(msg: impl Into<String>) -> Self {
        Error::Plan(msg.into())
    }
    /// An [`Error::Config`] with the given message.
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    /// An [`Error::Json`] with the given message.
    pub fn json(msg: impl Into<String>) -> Self {
        Error::Json(msg.into())
    }
    /// An [`Error::Runtime`] with the given message.
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }
    /// An [`Error::Serve`] with the given message.
    pub fn serve(msg: impl Into<String>) -> Self {
        Error::Serve(msg.into())
    }
    /// An [`Error::Artifact`] with the given message.
    pub fn artifact(msg: impl Into<String>) -> Self {
        Error::Artifact(msg.into())
    }
    /// An [`Error::Kernel`] with the given message.
    pub fn kernel(msg: impl Into<String>) -> Self {
        Error::Kernel(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_prefixed() {
        assert!(Error::shape("bad").to_string().starts_with("shape error"));
        assert!(Error::runtime("x").to_string().contains("runtime"));
        assert!(Error::artifact("crc").to_string().starts_with("artifact error"));
    }

    #[test]
    fn io_error_converts_and_sources() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::Other, "boom").into();
        assert!(std::error::Error::source(&e).is_some());
    }
}
