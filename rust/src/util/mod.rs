//! Small self-contained utilities (offline image: no external crates).

pub mod prng;
pub mod stats;
pub mod timer;
pub mod json;
pub mod log;

/// Whether `TTRV_BENCH_QUICK=1` (or `true`) is set — the shared opt-in for
/// fast measurement presets ([`crate::bench::BenchCfg::from_env`] and
/// [`timer::MeasureFloor::from_env`] both honor it, so one env var flips
/// every measurement path to its quick preset at once).
pub fn bench_quick_env() -> bool {
    match std::env::var("TTRV_BENCH_QUICK") {
        Ok(v) => v == "1" || v.eq_ignore_ascii_case("true"),
        Err(_) => false,
    }
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `m`.
#[inline]
pub fn round_up(a: usize, m: usize) -> usize {
    ceil_div(a, m) * m
}

/// Human-readable engineering notation, e.g. `9.5e8 -> "9.5E+08"` (the
/// format used in the paper's Tables 1-2).
pub fn sci(v: f64) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let exp = v.abs().log10().floor() as i32;
    let mant = v / 10f64.powi(exp);
    format!("{mant:.1}E{exp:+03}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_and_round_up() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(round_up(10, 8), 16);
        assert_eq!(round_up(16, 8), 16);
    }

    #[test]
    fn sci_matches_paper_format() {
        assert_eq!(sci(9.5e8), "9.5E+08");
        assert_eq!(sci(4.9e33), "4.9E+33");
        assert_eq!(sci(0.0), "0");
    }
}
