//! Wall-clock timing helpers for the bench harness and coordinator metrics.

use std::time::{Duration, Instant};

/// Measure one invocation of `f`, returning (result, elapsed seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Run `f` repeatedly until at least `min_time` has elapsed *and* at least
/// `min_iters` iterations have run; returns per-iteration seconds samples.
pub fn time_iters(
    mut f: impl FnMut(),
    min_iters: usize,
    min_time: Duration,
) -> Vec<f64> {
    let mut samples = Vec::with_capacity(min_iters.max(8));
    let deadline = Instant::now() + min_time;
    loop {
        let start = Instant::now();
        f();
        samples.push(start.elapsed().as_secs_f64());
        if samples.len() >= min_iters && Instant::now() >= deadline {
            break;
        }
        // hard cap so accidental O(huge) workloads terminate
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples
}

/// A stopwatch accumulating named phase durations (coordinator metrics).
#[derive(Debug, Default)]
pub struct Stopwatch {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    /// An empty stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or switch to) the named phase, closing any open phase.
    pub fn phase(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Close the open phase, if any.
    pub fn stop(&mut self) {
        if let Some((name, start)) = self.current.take() {
            self.phases.push((name, start.elapsed()));
        }
    }

    /// Accumulated (name, seconds) pairs, merged by name.
    pub fn totals(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        for (name, dur) in &self.phases {
            match out.iter_mut().find(|(n, _)| n == name) {
                Some((_, acc)) => *acc += dur.as_secs_f64(),
                None => out.push((name.clone(), dur.as_secs_f64())),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value_and_positive_time() {
        let (v, t) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn time_iters_respects_min_iters() {
        let samples = time_iters(|| {}, 5, Duration::from_millis(0));
        assert!(samples.len() >= 5);
    }

    #[test]
    fn stopwatch_merges_phases() {
        let mut sw = Stopwatch::new();
        sw.phase("a");
        sw.phase("b");
        sw.phase("a");
        sw.stop();
        let totals = sw.totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0].0, "a");
    }
}
