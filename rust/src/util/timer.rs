//! Wall-clock timing helpers for the bench harness and coordinator metrics.

use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// Measure one invocation of `f`, returning (result, elapsed seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    (out, start.elapsed().as_secs_f64())
}

/// Run `f` repeatedly until at least `min_time` has elapsed *and* at least
/// `min_iters` iterations have run; returns per-iteration seconds samples.
pub fn time_iters(
    mut f: impl FnMut(),
    min_iters: usize,
    min_time: Duration,
) -> Vec<f64> {
    let mut samples = Vec::with_capacity(min_iters.max(8));
    let deadline = Instant::now() + min_time;
    loop {
        let start = Instant::now();
        f();
        samples.push(start.elapsed().as_secs_f64());
        if samples.len() >= min_iters && Instant::now() >= deadline {
            break;
        }
        // hard cap so accidental O(huge) workloads terminate
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    samples
}

/// The per-candidate measurement floor shared by every min-of-N timing
/// path (the RB autotuner [`crate::kernels::tune_plan`], the chain tuner,
/// the measured DSE re-rank and the `ttrv bench` harness).
///
/// A candidate is timed until **both** bounds are met. Without the floor,
/// a best-of-3 on a coarse-clock host reads 0 ns for several candidates
/// and the "winner" is arbitrary — the bug this type exists to prevent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MeasureFloor {
    /// Minimum iterations of the measured closure.
    pub min_iters: usize,
    /// Minimum total wall-clock spent measuring.
    pub min_elapsed: Duration,
}

impl Default for MeasureFloor {
    fn default() -> Self {
        MeasureFloor { min_iters: 16, min_elapsed: Duration::from_millis(2) }
    }
}

impl MeasureFloor {
    /// Fast preset for CI smoke runs and tests.
    pub fn quick() -> Self {
        MeasureFloor { min_iters: 4, min_elapsed: Duration::from_micros(200) }
    }

    /// Honor `TTRV_BENCH_QUICK=1` (same switch as
    /// [`crate::bench::BenchCfg::from_env`]).
    pub fn from_env() -> Self {
        if crate::util::bench_quick_env() {
            MeasureFloor::quick()
        } else {
            MeasureFloor::default()
        }
    }
}

/// Minimum per-iteration seconds of `f` under a [`MeasureFloor`] — the
/// estimator every tuning comparison uses (min is right for short
/// deterministic kernels: noise only ever adds time).
///
/// Iterations run in **batches** whose size doubles until a single batch
/// is clock-resolvable (spans at least a quarter of the elapsed floor), so
/// per-iteration estimates (`batch elapsed / batch iters`) stay nonzero
/// even when one call is far below the host clock granularity. Returns
/// `f64::INFINITY` only if no batch ever observed a nonzero elapsed time
/// within the runaway cap — callers treat a non-finite result as a typed
/// measurement error.
pub fn min_secs(mut f: impl FnMut(), floor: &MeasureFloor) -> f64 {
    let start = Instant::now();
    let mut iters_total = 0usize;
    let mut batch = 1usize;
    let mut best = f64::INFINITY;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        iters_total += batch;
        if dt > Duration::ZERO {
            best = best.min(dt.as_secs_f64() / batch as f64);
        }
        if start.elapsed() >= floor.min_elapsed
            && iters_total >= floor.min_iters.max(1)
            && best.is_finite()
        {
            break;
        }
        if dt == Duration::ZERO || dt < floor.min_elapsed / 4 {
            batch = batch.saturating_mul(2).min(1 << 20);
        }
        // hard cap so accidental O(huge) workloads / broken clocks terminate
        if iters_total >= 10_000_000 {
            break;
        }
    }
    best
}

/// Per-iteration samples under a floor, batched for coarse clocks — the
/// bench harness's sampler ([`crate::bench::measure`]). The batch size
/// doubles until a single batch is clock-resolvable (nonzero elapsed and
/// at least a per-sample slice of `min_time`); every sample is then
/// `batch elapsed / batch iterations`, so trimmed-mean/MAD estimators
/// stay meaningful on hosts where one call is below the clock
/// granularity. On fine-grained clocks the batch stays at 1 and this
/// degrades to [`time_iters`]. Zero-elapsed batches contribute no sample,
/// so a coarse clock can never poison the sample set with zeros (the same
/// zero-ns class of bug [`min_secs`] fixes for tuning comparisons).
pub fn time_iters_batched(
    mut f: impl FnMut(),
    min_samples: usize,
    min_time: Duration,
) -> Vec<f64> {
    // saturating divisor: a huge configured sample count must degrade to
    // "any nonzero batch is resolvable", never overflow/zero-divide
    let div = 4u64
        .saturating_mul(min_samples.max(1) as u64)
        .min(u32::MAX as u64) as u32;
    let slice = min_time / div;
    let start = Instant::now();
    let mut samples = Vec::with_capacity(min_samples.max(8));
    let mut iters_total = 0usize;
    let mut batch = 1usize;
    loop {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed();
        iters_total += batch;
        if dt > Duration::ZERO {
            samples.push(dt.as_secs_f64() / batch as f64);
        }
        if samples.len() >= min_samples.max(1) && start.elapsed() >= min_time {
            break;
        }
        if dt == Duration::ZERO || dt < slice {
            batch = batch.saturating_mul(2).min(1 << 20);
        }
        // hard cap so accidental O(huge) workloads / broken clocks terminate
        if iters_total >= 10_000_000 {
            break;
        }
    }
    samples
}

/// [`min_secs`] for a fallible measured closure — the shared shape of
/// every tuning/re-rank timing path. The first call runs untimed to warm
/// caches and surface any plan/shape error; the timed loop then only
/// repeats a call that already succeeded, so an error inside it is
/// captured and returned instead of panicking a serving thread. A result
/// that is still non-finite after the floor is a typed numeric error
/// naming `what`.
pub fn try_min_secs(
    what: &str,
    mut f: impl FnMut() -> Result<()>,
    floor: &MeasureFloor,
) -> Result<f64> {
    f()?;
    let mut err = None;
    let secs = min_secs(
        || {
            if err.is_none() {
                if let Err(e) = f() {
                    err = Some(e);
                }
            }
        },
        floor,
    );
    if let Some(e) = err {
        return Err(e);
    }
    if !secs.is_finite() {
        return Err(Error::numeric(format!(
            "{what}: floored measurement produced a non-finite time"
        )));
    }
    Ok(secs)
}

/// A stopwatch accumulating named phase durations (coordinator metrics).
#[derive(Debug, Default)]
pub struct Stopwatch {
    phases: Vec<(String, Duration)>,
    current: Option<(String, Instant)>,
}

impl Stopwatch {
    /// An empty stopwatch.
    pub fn new() -> Self {
        Self::default()
    }

    /// Start (or switch to) the named phase, closing any open phase.
    pub fn phase(&mut self, name: &str) {
        self.stop();
        self.current = Some((name.to_string(), Instant::now()));
    }

    /// Close the open phase, if any.
    pub fn stop(&mut self) {
        if let Some((name, start)) = self.current.take() {
            self.phases.push((name, start.elapsed()));
        }
    }

    /// Accumulated (name, seconds) pairs, merged by name.
    pub fn totals(&self) -> Vec<(String, f64)> {
        let mut out: Vec<(String, f64)> = Vec::new();
        for (name, dur) in &self.phases {
            match out.iter_mut().find(|(n, _)| n == name) {
                Some((_, acc)) => *acc += dur.as_secs_f64(),
                None => out.push((name.clone(), dur.as_secs_f64())),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_once_returns_value_and_positive_time() {
        let (v, t) = time_once(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }

    #[test]
    fn time_iters_respects_min_iters() {
        let samples = time_iters(|| {}, 5, Duration::from_millis(0));
        assert!(samples.len() >= 5);
    }

    #[test]
    fn min_secs_meets_the_floor_and_is_finite() {
        let floor = MeasureFloor { min_iters: 32, min_elapsed: Duration::from_millis(1) };
        let mut n = 0u64;
        let t0 = Instant::now();
        let secs = min_secs(|| n += 1, &floor);
        // both bounds respected, estimate resolvable even for a ~ns closure
        assert!(n >= 32, "only {n} iterations ran");
        assert!(t0.elapsed() >= floor.min_elapsed);
        assert!(secs.is_finite() && secs > 0.0, "min_secs = {secs}");
    }

    #[test]
    fn min_secs_zero_floor_still_runs_once() {
        let floor = MeasureFloor { min_iters: 0, min_elapsed: Duration::ZERO };
        let mut ran = false;
        let secs = min_secs(|| ran = true, &floor);
        assert!(ran);
        assert!(secs.is_finite() || secs.is_infinite()); // never NaN
    }

    #[test]
    fn time_iters_batched_meets_floor_with_resolvable_samples() {
        let mut n = 0u64;
        let samples = time_iters_batched(|| n += 1, 6, Duration::from_millis(1));
        assert!(samples.len() >= 6, "only {} samples", samples.len());
        // zero-elapsed batches are excluded, so every sample is positive
        assert!(samples.iter().all(|&s| s > 0.0 && s.is_finite()));
    }

    #[test]
    fn time_iters_batched_survives_absurd_sample_counts() {
        // 2^30 samples * 4 would overflow a u32 divisor; must not panic
        // (the floor is unreachable, the runaway cap terminates the loop)
        // not panicking IS the assertion; the samples themselves are
        // whatever the runaway cap produced
        drop(time_iters_batched(|| {}, 1 << 30, Duration::from_nanos(1)));
        drop(time_iters_batched(|| {}, usize::MAX, Duration::ZERO));
    }

    #[test]
    fn try_min_secs_propagates_the_first_error() {
        let floor = MeasureFloor::quick();
        let err = try_min_secs("t", || Err(Error::numeric("boom")), &floor).unwrap_err();
        assert!(err.to_string().contains("boom"));
        let ok = try_min_secs("t", || Ok(()), &floor).unwrap();
        assert!(ok.is_finite() && ok > 0.0);
    }

    #[test]
    fn measure_floor_presets() {
        let d = MeasureFloor::default();
        let q = MeasureFloor::quick();
        assert!(q.min_elapsed < d.min_elapsed);
        assert!(q.min_iters <= d.min_iters);
    }

    #[test]
    fn stopwatch_merges_phases() {
        let mut sw = Stopwatch::new();
        sw.phase("a");
        sw.phase("b");
        sw.phase("a");
        sw.stop();
        let totals = sw.totals();
        assert_eq!(totals.len(), 2);
        assert_eq!(totals[0].0, "a");
    }
}
