//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Used to read `artifacts/manifest.json` (runtime) and to emit structured
//! bench reports. Supports the full JSON grammar except `\u` surrogate pairs
//! beyond the BMP (sufficient for our ASCII manifests; non-BMP escapes fail
//! loudly rather than silently).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// JSON `null`.
    Null,
    /// A boolean.
    Bool(bool),
    /// A number (all JSON numbers are f64 here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// The numeric value as usize, if integral and in range.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// The numeric value as u64, if it is a non-negative integer exactly
    /// representable in an f64 (<= 2^53 — artifact metadata fields).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|n| n.fract() == 0.0 && (0.0..=9_007_199_254_740_992.0).contains(n))
            .map(|n| n as u64)
    }
    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
    /// Builder helper: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy the remaining continuation bytes
                    let extra = match c {
                        0xC0..=0xDF => 1,
                        0xE0..=0xEF => 2,
                        0xF0..=0xF7 => 3,
                        _ => return Err(self.err("invalid utf-8 lead byte")),
                    };
                    let start = self.pos - 1;
                    for _ in 0..extra {
                        self.bump().ok_or_else(|| self.err("truncated utf-8"))?;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8 sequence"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number bytes"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number '{s}'")))
    }
}

/// Parse a JSON document (trailing whitespace allowed, trailing junk is an error).
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

fn escape(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_value(v: &Json, out: &mut String, indent: usize, pretty: bool) {
    let pad = |out: &mut String, n: usize| {
        if pretty {
            out.push('\n');
            out.push_str(&"  ".repeat(n));
        }
    };
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(n) => {
            if !n.is_finite() {
                // JSON has no inf/NaN tokens; null is the conventional
                // encoding (what our own parser round-trips)
                out.push_str("null");
            } else if n.fract() == 0.0 && n.abs() < 1e15 {
                let _ = write!(out, "{}", *n as i64);
            } else {
                let _ = write!(out, "{n}");
            }
        }
        Json::Str(s) => escape(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                write_value(item, out, indent + 1, pretty);
            }
            if !items.is_empty() {
                pad(out, indent);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                pad(out, indent + 1);
                escape(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, out, indent + 1, pretty);
            }
            if !map.is_empty() {
                pad(out, indent);
            }
            out.push('}');
        }
    }
}

/// Serialize compactly.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, false);
    out
}

/// Serialize with 2-space indentation.
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, &mut out, 0, true);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like_doc() {
        let text = r#"{
            "format": "hlo-text", "return_tuple": true,
            "artifacts": [
                {"name": "mlp_tt_b16", "file": "mlp_tt_b16.hlo.txt",
                 "args": [{"shape": [16, 784], "dtype": "float32"}]}
            ]
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("format").unwrap().as_str().unwrap(), "hlo-text");
        assert_eq!(v.get("return_tuple").unwrap().as_bool(), Some(true));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        let shape = arts[0].get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(16));
        // reparse of our own output must be identical
        let again = parse(&to_string(&v)).unwrap();
        assert_eq!(v, again);
        let again2 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, again2);
    }

    #[test]
    fn parses_scalars_and_numbers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn u64_accessor_accepts_integers_only() {
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(1e300).as_u64(), None);
        assert_eq!(Json::Str("42".into()).as_u64(), None);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_strings_roundtrip() {
        let v = parse("\"caf\\u00e9 — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "café — ok");
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(to_string(&Json::Num(16.0)), "16");
        assert_eq!(to_string(&Json::Num(1.5)), "1.5");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // f64::INFINITY reaches the writer via modeled-time reports (an
        // unschedulable dense baseline); bare `inf` would not be JSON
        for v in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            let doc = Json::obj(vec![("t", Json::Num(v))]);
            let text = to_string(&doc);
            assert_eq!(text, "{\"t\":null}");
            assert!(parse(&text).is_ok());
        }
    }
}
