//! Summary statistics for the measurement harness and reports.

/// Arithmetic mean. Returns 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in stats input"));
    v
}

/// Interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let v = sorted(xs);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean after trimming `frac` of samples from each tail — the measurement
/// harness's primary estimator (robust to scheduler noise spikes).
pub fn trimmed_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let v = sorted(xs);
    let cut = ((v.len() as f64) * frac).floor() as usize;
    let kept = &v[cut..v.len() - cut.min(v.len() - 1 - cut)];
    if kept.is_empty() {
        median(&v)
    } else {
        mean(kept)
    }
}

/// Median absolute deviation (robust spread estimate).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Min/max of a slice (0 for empty).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn trimmed_mean_rejects_outlier() {
        let xs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1000.0];
        assert!((trimmed_mean(&xs, 0.1) - 1.0).abs() < 1e-9);
        // untrimmed mean is poisoned
        assert!(mean(&xs) > 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn mad_of_constant_is_zero() {
        assert_eq!(mad(&[5.0, 5.0, 5.0]), 0.0);
        assert!(mad(&[1.0, 2.0, 9.0]) > 0.0);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(trimmed_mean(&[], 0.1), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }
}
