//! Summary statistics for the measurement harness and reports.

/// Arithmetic mean. Returns 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    // total_cmp: NaN sorts to the tails instead of panicking mid-sort — a
    // single poisoned sample must never kill a server/measurement thread.
    // Callers that cannot tolerate NaN filter first via [`finite_samples`].
    v.sort_by(f64::total_cmp);
    v
}

/// Split a sample set into its finite part, returning how many non-finite
/// samples were dropped. The measurement harness calls this before any
/// estimator, so a poisoned sample can never leak NaN into a report: what
/// remains of a fully non-finite set is an empty sample set (`iters: 0`,
/// zero estimates), which the BENCH schema gate rejects loudly — while
/// the comparison-grade timing paths get their typed error from
/// [`crate::util::timer::try_min_secs`] instead.
pub fn finite_samples(xs: &[f64]) -> (Vec<f64>, usize) {
    let finite: Vec<f64> = xs.iter().copied().filter(|x| x.is_finite()).collect();
    let dropped = xs.len() - finite.len();
    (finite, dropped)
}

/// Interpolated percentile, `p` in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let v = sorted(xs);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean after trimming `frac` of samples from each tail — the measurement
/// harness's primary estimator (robust to scheduler noise spikes).
///
/// The cut is clamped so at least one sample always survives: for small
/// `n` (or `frac >= 0.5`) the naive `n * frac` cut could trim everything —
/// slicing out of bounds or silently yielding NaN, which would poison the
/// BENCH json. With the clamp, `n <= 2` keeps every sample and odd small
/// `n` degrades to the median.
pub fn trimmed_mean(xs: &[f64], frac: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let v = sorted(xs);
    let max_cut = (v.len() - 1) / 2;
    let cut = (((v.len() as f64) * frac.max(0.0)).floor() as usize).min(max_cut);
    mean(&v[cut..v.len() - cut])
}

/// Median absolute deviation (robust spread estimate).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let med = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    median(&devs)
}

/// Min/max of a slice (0 for empty).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn median_even_odd() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn trimmed_mean_rejects_outlier() {
        let xs = [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1000.0];
        assert!((trimmed_mean(&xs, 0.1) - 1.0).abs() < 1e-9);
        // untrimmed mean is poisoned
        assert!(mean(&xs) > 100.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
    }

    #[test]
    fn mad_of_constant_is_zero() {
        assert_eq!(mad(&[5.0, 5.0, 5.0]), 0.0);
        assert!(mad(&[1.0, 2.0, 9.0]) > 0.0);
    }

    #[test]
    fn empty_inputs_do_not_panic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(trimmed_mean(&[], 0.1), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }

    #[test]
    fn trimmed_mean_small_n_never_trims_to_empty() {
        // the old cut arithmetic could slice out of bounds / return NaN for
        // small n; every result here must be finite for every frac
        for frac in [0.0, 0.1, 0.2, 0.4, 0.5, 0.9, 1.0] {
            assert_eq!(trimmed_mean(&[], frac), 0.0, "n=0 frac={frac}");
            assert_eq!(trimmed_mean(&[3.0], frac), 3.0, "n=1 frac={frac}");
            let t2 = trimmed_mean(&[1.0, 3.0], frac);
            assert!(t2.is_finite() && t2 == 2.0, "n=2 frac={frac}: {t2}");
            let t3 = trimmed_mean(&[1.0, 2.0, 300.0], frac);
            assert!(t3.is_finite(), "n=3 frac={frac}: {t3}");
        }
        // n=3 with any real trim keeps (at least) the median
        assert_eq!(trimmed_mean(&[1.0, 2.0, 300.0], 0.4), 2.0);
        // negative frac clamps to no trimming
        assert_eq!(trimmed_mean(&[1.0, 3.0], -1.0), 2.0);
    }

    #[test]
    fn nan_samples_do_not_panic_the_sort() {
        // total_cmp: NaN sorts to a tail; estimators stay panic-free
        let xs = [1.0, f64::NAN, 2.0, 3.0];
        let _ = median(&xs);
        let _ = percentile(&xs, 99.0);
        let _ = trimmed_mean(&xs, 0.25);
        // ...and the finite filter reports exactly what was dropped
        let (finite, dropped) = finite_samples(&xs);
        assert_eq!(finite, vec![1.0, 2.0, 3.0]);
        assert_eq!(dropped, 1);
        let (none, dropped) = finite_samples(&[f64::NAN, f64::INFINITY]);
        assert!(none.is_empty());
        assert_eq!(dropped, 2);
    }
}
