//! Tiny leveled stderr logger (the `log` facade is not wired offline).
//!
//! Level is taken from `TTRV_LOG` (error|warn|info|debug|trace), default
//! `info`. Usage: `log::info!(...)`-style via the exported macros `tinfo!`,
//! `twarn!`, `tdebug!`, `terror!`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;

/// Severity levels, ascending verbosity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static INIT: Once = Once::new();

fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("TTRV_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                "trace" => Level::Trace,
                _ => Level::Info,
            };
            LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

/// Override the level programmatically (tests, CLI `-v`).
pub fn set_level(level: Level) {
    init_from_env();
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is the given level enabled?
pub fn enabled(level: Level) -> bool {
    init_from_env();
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Internal sink for the macros.
pub fn emit(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[ttrv {tag}] {args}");
    }
}

#[macro_export]
macro_rules! terror {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Error, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! twarn {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! tinfo {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! tdebug {
    ($($arg:tt)*) => { $crate::util::log::emit($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering_gates_output() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }
}
