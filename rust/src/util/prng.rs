//! Deterministic PRNG: SplitMix64 seeding a xoshiro256** core.
//!
//! The `rand` crate is unavailable offline; every stochastic component in the
//! crate (weight init, workload generators, the property-testing kit) routes
//! through this generator so runs are reproducible from a single seed.

/// xoshiro256** generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box-Muller.
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[lo, hi)` (unbiased via rejection).
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range {lo}..{hi}");
        let span = (hi - lo) as u64;
        // Lemire-style rejection to stay unbiased.
        let zone = u64::MAX - u64::MAX % span;
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as usize;
            }
        }
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a slice with N(0, sigma^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Vector of N(0, sigma^2) f32 values.
    pub fn normal_vec(&mut self, len: usize, sigma: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.fill_normal(&mut v, sigma);
        v
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(5, 15);
            assert!((5..15).contains(&v));
            seen[v - 5] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut c1 = base.fork();
        let mut c2 = base.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
