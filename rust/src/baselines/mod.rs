//! Comparator implementations (paper §5/§6): the IREE-like and Pluto-like
//! strategies the paper benchmarks against, plus the dense uncompressed FC.
//!
//! Neither tool runs in this environment (no RISC-V board, no cross
//! toolchain), so each baseline reimplements the *code shape* the paper
//! attributes to the tool — reproducing its overhead structure on the same
//! substrate our kernels run on (DESIGN.md §3):
//!
//! * **IREE-like** ([`iree_like`]): the `iree-stablehlo-to-stablehlo-
//!   preprocessing` rewrite from the paper's Appendix — einsum becomes
//!   transpose/reshape -> MMM -> reshape/transpose, with the `G` transpose
//!   const-folded away (`iree-consteval-jit-globals`) but the input/output
//!   transposes and pack/unpack paid at runtime.
//! * **Pluto-like** ([`pluto_like`]): polyhedral tiling + interchange of the
//!   Listing-2 nest on the canonical layout, *without* vectorization (the
//!   paper observed gcc fails to vectorize Pluto's output).
//! * **Dense** ([`dense`]): the unfactorized FC as an MMM kernel (the
//!   paper's Fig. 15 uncompressed-IREE baseline).

pub mod iree_like;
pub mod pluto_like;
pub mod dense;
