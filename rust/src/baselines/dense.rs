//! Dense (uncompressed) FC baseline: the MMM kernel the paper's Fig. 15
//! uses for the non-factorized layers ("non-factorized FC layers were
//! executed using the MMM kernel").

use crate::error::Result;
use crate::linalg::matmul;
use crate::tensor::Tensor;

/// A dense FC layer prepared for repeated inference: `W^T` materialized once
/// (compile-time) so the hot path is a single row-major MMM.
#[derive(Debug, Clone)]
pub struct DenseFc {
    /// `(N, M)` — transposed weights.
    wt: Tensor,
    bias: Option<Vec<f32>>,
    /// Output width.
    pub m: usize,
    /// Input width.
    pub n: usize,
}

impl DenseFc {
    /// Build from `W (M, N)`.
    pub fn new(w: &Tensor, bias: Option<Vec<f32>>) -> Result<Self> {
        let d = w.dims();
        let (m, n) = (d[0], d[1]);
        Ok(DenseFc { wt: w.transpose(&[1, 0])?, bias, m, n })
    }

    /// `Y = X W^T + b`, X `(B, N)`.
    pub fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let mut y = matmul(x, &self.wt)?;
        if let Some(b) = &self.bias {
            let m = self.m;
            for row in y.data_mut().chunks_mut(m) {
                for (v, &bv) in row.iter_mut().zip(b) {
                    *v += bv;
                }
            }
        }
        Ok(y)
    }

    /// FLOPs per forward at batch `b`.
    pub fn flops(&self, b: usize) -> u64 {
        (2 * self.m * self.n * b + if self.bias.is_some() { self.m * b } else { 0 }) as u64
    }

    /// Resident bytes of the layer's parameters (transposed weights plus
    /// bias), the quantity the serving registry's memory budget accounts.
    pub fn weight_bytes(&self) -> u64 {
        ((self.m * self.n + self.bias.as_ref().map_or(0, Vec::len)) * 4) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::fc_batched_ref;
    use crate::util::prng::Rng;

    #[test]
    fn matches_reference_fc() {
        let mut rng = Rng::new(95);
        let w = Tensor::randn(vec![30, 20], 1.0, &mut rng);
        let bias: Vec<f32> = (0..30).map(|i| i as f32 / 10.0).collect();
        let fc = DenseFc::new(&w, Some(bias.clone())).unwrap();
        let x = Tensor::randn(vec![7, 20], 1.0, &mut rng);
        let got = fc.forward(&x).unwrap();
        let want = fc_batched_ref(&w, &x, Some(&bias)).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
        assert_eq!(fc.flops(7), 2 * 30 * 20 * 7 + 30 * 7);
    }

    #[test]
    fn no_bias_path() {
        let mut rng = Rng::new(96);
        let w = Tensor::randn(vec![4, 6], 1.0, &mut rng);
        let fc = DenseFc::new(&w, None).unwrap();
        let x = Tensor::randn(vec![2, 6], 1.0, &mut rng);
        let got = fc.forward(&x).unwrap();
        let want = fc_batched_ref(&w, &x, None).unwrap();
        assert!(got.allclose(&want, 1e-5, 1e-5));
    }
}
