//! IREE-like baseline: einsum as transpose/pack -> MMM -> unpack/transpose
//! (the paper's Appendix, Listing 8).

use crate::error::Result;
use crate::linalg::matmul;
use crate::tensor::einsum::{core_dims, slab_dims};
use crate::tensor::Tensor;

/// The compile-time half: `G (r, n, m, k) -> (r*m, n*k)` matrix, i.e. the
/// `stablehlo.transpose dims=[0,2,1,3]` + reshape that
/// `iree-consteval-jit-globals` folds into the constant.
pub fn prepare_g(g: &Tensor) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let t = g.transpose(&[0, 2, 1, 3])?; // (r, m, n, k)
    t.reshape(vec![r * m, n * k])
}

/// The runtime half, mirroring Listing 8 exactly:
/// 1. transpose input `(b, n, k) -> (n, k, b)`, reshape `(n*k, b)` (packing);
/// 2. `stablehlo.dot`: `(r*m, n*k) x (n*k, b)`;
/// 3. reshape `(r, m, b)`, transpose `-> (m, b, r)` (unpacking).
pub fn run(g_mat: &Tensor, x: &Tensor, r: usize) -> Result<Tensor> {
    let d = x.dims();
    let (b, n, k) = (d[0], d[1], d[2]);
    let rm = g_mat.dims()[0];
    let m = rm / r;
    // step 1: input transpose + pack
    let xt = x.transpose(&[1, 2, 0])?.reshape(vec![n * k, b])?;
    // step 2: MMM
    let prod = matmul(g_mat, &xt)?; // (r*m, b)
    // step 3: output unpack + transpose
    prod.reshape(vec![r, m, b])?.transpose(&[1, 2, 0])
}

/// Convenience: full einsum through the IREE-like path.
pub fn einsum(g: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (r, n, k) = {
        let (r, n, _m, k) = core_dims(g)?;
        (r, n, k)
    };
    slab_dims(x, n, k)?;
    let g_mat = prepare_g(g)?;
    run(&g_mat, x, r)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::util::prng::Rng;

    #[test]
    fn matches_reference_on_cb5() {
        // the Appendix's own example: G (8,7,32,8), x (9,7,8) -> (32,9,8)
        let mut rng = Rng::new(80);
        let g = Tensor::randn(vec![8, 7, 32, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![9, 7, 8], 1.0, &mut rng);
        let got = einsum(&g, &x).unwrap();
        assert_eq!(got.dims(), &[32, 9, 8]);
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }

    #[test]
    fn matches_reference_on_degenerate_ranks() {
        let mut rng = Rng::new(81);
        for (r, n, m, k, b) in [(8, 5, 16, 1, 7), (1, 6, 12, 8, 9), (1, 2, 3, 1, 4)] {
            let g = Tensor::randn(vec![r, n, m, k], 1.0, &mut rng);
            let x = Tensor::randn(vec![b, n, k], 1.0, &mut rng);
            let got = einsum(&g, &x).unwrap();
            let want = tt_einsum_ref(&g, &x).unwrap();
            assert!(got.allclose(&want, 1e-4, 1e-4), "r={r} k={k}");
        }
    }

    #[test]
    fn prepared_g_is_reusable_across_inputs() {
        let mut rng = Rng::new(82);
        let g = Tensor::randn(vec![8, 4, 8, 8], 1.0, &mut rng);
        let gm = prepare_g(&g).unwrap();
        for _ in 0..3 {
            let x = Tensor::randn(vec![5, 4, 8], 1.0, &mut rng);
            let got = run(&gm, &x, 8).unwrap();
            let want = tt_einsum_ref(&g, &x).unwrap();
            assert!(got.allclose(&want, 1e-4, 1e-4));
        }
    }
}
