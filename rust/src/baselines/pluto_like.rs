//! Pluto-like baseline: polyhedral loop tiling + interchange of the paper's
//! Listing-2 nest, on the canonical (untouched) data layout, with **no
//! vectorization** — reproducing the paper's observation that "Pluto depends
//! on gcc to apply vectorization, which in this case was not effectively
//! applied".

use crate::error::Result;
use crate::tensor::einsum::{core_dims, slab_dims};
use crate::tensor::Tensor;

/// Tile sizes a polyhedral scheduler would emit for an L2-sized footprint.
#[derive(Debug, Clone, Copy)]
pub struct PlutoTiles {
    /// Tile extent over `m`.
    pub tm: usize,
    /// Tile extent over `b`.
    pub tb: usize,
}

impl PlutoTiles {
    /// Pick tiles so the per-tile G/In/Out slices fit the given cache size
    /// (the paper passes the L2 size to Pluto via its flag).
    pub fn for_cache(m: usize, b: usize, n: usize, r: usize, k: usize, cache_bytes: usize) -> Self {
        let l = n * k;
        let mut tm = m.min(64).max(1);
        let mut tb = b.min(64).max(1);
        // shrink until (G tile + In tile + Out tile) * 4B fits half the cache
        while tm * tb > 1 {
            let bytes = 4 * (r * l * tm + tb * l + tm * tb * r);
            if bytes <= cache_bytes / 2 {
                break;
            }
            if tm >= tb && tm > 1 {
                tm /= 2;
            } else if tb > 1 {
                tb /= 2;
            } else {
                break;
            }
        }
        PlutoTiles { tm, tb }
    }
}

/// Tiled, interchanged, *scalar* einsum over canonical layouts.
///
/// The strided canonical `G[r][n][m][k]` access (stride `m*k` along `n`,
/// stride `n*m*k` along `r`) is exactly what defeats the host compiler's
/// auto-vectorizer, as it did gcc's in the paper.
pub fn einsum(g: &Tensor, x: &Tensor, tiles: PlutoTiles) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let b = slab_dims(x, n, k)?;
    let (gd, xd) = (g.data(), x.data());
    let mut out = Tensor::zeros(vec![m, b, r]);
    let od = out.data_mut();
    for m0 in (0..m).step_by(tiles.tm.max(1)) {
        let m1 = (m0 + tiles.tm).min(m);
        for b0 in (0..b).step_by(tiles.tb.max(1)) {
            let b1 = (b0 + tiles.tb).min(b);
            for mi in m0..m1 {
                for bi in b0..b1 {
                    for ri in 0..r {
                        let mut acc = 0.0f32;
                        for ni in 0..n {
                            let gbase = ((ri * n + ni) * m + mi) * k;
                            let xbase = (bi * n + ni) * k;
                            for ki in 0..k {
                                acc += gd[gbase + ki] * xd[xbase + ki];
                            }
                        }
                        od[(mi * b + bi) * r + ri] = acc;
                    }
                }
            }
        }
    }
    Ok(out)
}

/// Convenience with K1-sized L2 tiles.
pub fn einsum_default(g: &Tensor, x: &Tensor) -> Result<Tensor> {
    let (r, n, m, k) = core_dims(g)?;
    let b = x.dims()[0];
    let tiles = PlutoTiles::for_cache(m, b, n, r, k, 1024 * 1024);
    einsum(g, x, tiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::einsum::tt_einsum_ref;
    use crate::util::prng::Rng;

    #[test]
    fn matches_reference_across_tile_choices() {
        let mut rng = Rng::new(90);
        let g = Tensor::randn(vec![8, 5, 30, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![23, 5, 8], 1.0, &mut rng);
        let want = tt_einsum_ref(&g, &x).unwrap();
        for (tm, tb) in [(1, 1), (4, 4), (7, 5), (64, 64)] {
            let got = einsum(&g, &x, PlutoTiles { tm, tb }).unwrap();
            assert!(got.allclose(&want, 1e-4, 1e-4), "tiles {tm}x{tb}");
        }
    }

    #[test]
    fn tile_selection_fits_cache() {
        let t = PlutoTiles::for_cache(512, 896, 28, 8, 8, 1024 * 1024);
        let bytes = 4 * (8 * 224 * t.tm + t.tb * 224 + t.tm * t.tb * 8);
        assert!(bytes <= 512 * 1024, "{t:?} -> {bytes}");
        assert!(t.tm >= 1 && t.tb >= 1);
    }

    #[test]
    fn default_matches_reference() {
        let mut rng = Rng::new(91);
        let g = Tensor::randn(vec![1, 6, 12, 8], 1.0, &mut rng);
        let x = Tensor::randn(vec![9, 6, 8], 1.0, &mut rng);
        let got = einsum_default(&g, &x).unwrap();
        let want = tt_einsum_ref(&g, &x).unwrap();
        assert!(got.allclose(&want, 1e-4, 1e-4));
    }
}
