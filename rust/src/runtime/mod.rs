//! PJRT runtime: load the AOT artifacts produced by `python/compile/aot.py`
//! (HLO **text** — see the AOT recipe note in aot.py) and execute them from
//! Rust. Python never runs on this path.
//!
//! The real backend lives in [`pjrt`] and needs the `xla` crate, which is not
//! available in the offline build image. It is therefore gated behind the
//! `pjrt` cargo feature: vendor the crate, add it to `rust/Cargo.toml`, and
//! build with `--features pjrt`. The default build compiles an API-identical
//! stub whose `Runtime::open` fails loudly, so everything that *can* work
//! offline (manifest parsing, the artifact-presence skips in the integration
//! tests) still does.

mod manifest;

pub use manifest::{ArgSpec, ArtifactManifest, ArtifactMeta, MANIFEST_FORMAT};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::{Executable, Runtime};

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{Executable, Runtime};

// NOTE: integration tests for the runtime live in rust/tests/runtime_pjrt.rs
// (they need the artifacts directory built by the AOT pipeline). Manifest
// parsing is unit-tested in `manifest`.
