//! API-identical stand-in for the PJRT backend used when the `pjrt` feature
//! (and its vendored `xla` crate) is absent. `Runtime::open` always fails
//! loudly — with or without an artifacts directory present — so callers can
//! never silently run without the real executor; both types are
//! uninhabited, making the rest of the surface provably unreachable while
//! keeping call sites (tests, CLI, examples) compiling.

use std::convert::Infallible;
use std::path::Path;

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::manifest::{ArtifactManifest, ArtifactMeta};

fn unavailable() -> Error {
    Error::runtime(
        "PJRT backend not built: this binary was compiled without the `pjrt` \
         feature (vendor the `xla` crate and build with `--features pjrt`)",
    )
}

/// Stub runtime: never constructible; `open` always errs.
pub struct Runtime {
    never: Infallible,
}

impl Runtime {
    /// Always fails: the PJRT executor is not compiled in. The manifest path
    /// is still validated first so a missing artifact directory gives the
    /// more actionable of the two errors.
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        ArtifactManifest::load(&dir.as_ref().join("manifest.json"))?;
        Err(unavailable())
    }

    /// Unreachable (no stub `Runtime` can exist).
    pub fn manifest(&self) -> &ArtifactManifest {
        match self.never {}
    }

    /// Unreachable (no stub `Runtime` can exist).
    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Unreachable (no stub `Runtime` can exist).
    pub fn compile(&self, _name: &str) -> Result<Executable> {
        match self.never {}
    }
}

/// Stub executable: uninhabited (no stub `Runtime` exists to create one).
pub enum Executable {}

impl Executable {
    /// Unreachable (no stub `Executable` can exist).
    pub fn meta(&self) -> &ArtifactMeta {
        match *self {}
    }

    /// Unreachable (no stub `Executable` can exist).
    pub fn run(&self, _inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        match *self {}
    }
}
