//! `artifacts/manifest.json` parsing (written by python/compile/aot.py).
//!
//! This is the **AOT** side of the repo's two artifact stories, and the two
//! are deliberately split along the paper's deployment boundary:
//!
//! * this manifest + its HLO-text files describe *runtime-compilable
//!   programs* for the PJRT backend (`make artifacts`; JSON because the
//!   Python AOT pipeline writes it, format tag [`MANIFEST_FORMAT`]);
//! * [`crate::artifact`] `.ttrv` bundles carry the *already-compressed
//!   serving model* — packed TT cores, compiled plans, checksums — in a
//!   versioned binary container, written and read by Rust only.
//!
//! Both are validated load-time artifacts looked up by name; a PJRT bundle
//! section could later embed this manifest verbatim, which is why the
//! format tag lives in one place.

use std::path::Path;

use crate::error::{Error, Result};
use crate::util::json::{self, Json};

/// The only artifact encoding the AOT manifest declares today (HLO text;
/// see the AOT recipe note in `python/compile/aot.py`).
pub const MANIFEST_FORMAT: &str = "hlo-text";

/// Shape + dtype of one executable argument.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArgSpec {
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. `f32`).
    pub dtype: String,
}

/// One AOT artifact entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    /// Artifact name (compile key).
    pub name: String,
    /// HLO text file relative to the artifact dir.
    pub file: String,
    /// Expected argument shapes, in call order.
    pub args: Vec<ArgSpec>,
    /// Free-form provenance note.
    pub note: String,
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    /// Every artifact listed in the manifest.
    pub artifacts: Vec<ArtifactMeta>,
}

impl ArtifactManifest {
    /// Read and parse `manifest.json` from disk.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text)
    }

    /// Parse a manifest document.
    pub fn parse(text: &str) -> Result<Self> {
        let doc = json::parse(text)?;
        if doc.get("format").and_then(Json::as_str) != Some(MANIFEST_FORMAT) {
            return Err(Error::runtime(format!(
                "manifest format must be '{MANIFEST_FORMAT}'"
            )));
        }
        if doc.get("return_tuple").and_then(Json::as_bool) != Some(true) {
            return Err(Error::runtime("manifest must declare return_tuple=true"));
        }
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::runtime("manifest missing 'artifacts'"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::runtime("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| Error::runtime(format!("artifact {name} missing file")))?
                .to_string();
            let note = a.get("note").and_then(Json::as_str).unwrap_or("").to_string();
            let args = a
                .get("args")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::runtime(format!("artifact {name} missing args")))?
                .iter()
                .map(|arg| {
                    let shape = arg
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| Error::runtime("arg missing shape"))?
                        .iter()
                        .map(|v| v.as_usize().ok_or_else(|| Error::runtime("bad dim")))
                        .collect::<Result<Vec<_>>>()?;
                    let dtype = arg
                        .get("dtype")
                        .and_then(Json::as_str)
                        .unwrap_or("float32")
                        .to_string();
                    Ok(ArgSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactMeta { name, file, args, note });
        }
        Ok(ArtifactManifest { artifacts })
    }

    /// Look up an artifact by name.
    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifact names, manifest order.
    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "format": "hlo-text", "return_tuple": true,
        "artifacts": [
            {"name": "mlp_tt_b16", "file": "mlp_tt_b16.hlo.txt", "note": "x",
             "args": [{"shape": [16, 784], "dtype": "float32"},
                      {"shape": [1, 28, 20, 8], "dtype": "float32"}]}
        ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("mlp_tt_b16").unwrap();
        assert_eq!(a.file, "mlp_tt_b16.hlo.txt");
        assert_eq!(a.args[0].shape, vec![16, 784]);
        assert_eq!(a.args[1].shape, vec![1, 28, 20, 8]);
        assert_eq!(m.names(), vec!["mlp_tt_b16"]);
        assert!(m.find("nope").is_none());
    }

    #[test]
    fn rejects_wrong_format_or_tuple() {
        assert!(ArtifactManifest::parse(
            r#"{"format": "proto", "return_tuple": true, "artifacts": []}"#
        )
        .is_err());
        assert!(ArtifactManifest::parse(
            r#"{"format": "hlo-text", "return_tuple": false, "artifacts": []}"#
        )
        .is_err());
        assert!(ArtifactManifest::parse(r#"{"format": "hlo-text", "return_tuple": true}"#).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = ArtifactManifest::load(&path).unwrap();
            assert!(m.find("mlp_tt_b16").is_some());
            assert!(m.find("dense_fc_784x300_b16").is_some());
        }
    }
}
