//! The xla-rs-backed PJRT runtime (requires the `pjrt` feature and a
//! vendored `xla` crate).
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `client.compile` -> `execute`.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::tensor::Tensor;

use super::manifest::{ArtifactManifest, ArtifactMeta};

fn xe(e: xla::Error) -> Error {
    Error::runtime(e.to_string())
}

/// A PJRT CPU client plus the artifact directory's manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: ArtifactManifest,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(xe)?;
        Ok(Runtime { client, dir, manifest })
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// PJRT platform string (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact by manifest name into an executable.
    pub fn compile(&self, name: &str) -> Result<Executable> {
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::runtime(format!("artifact '{name}' not in manifest")))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::runtime("non-utf8 path"))?,
        )
        .map_err(xe)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xe)?;
        Ok(Executable { exe, meta })
    }
}

/// A compiled artifact ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

impl Executable {
    /// Manifest entry this executable was compiled from.
    pub fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    /// Execute with f32 tensors, validating shapes against the manifest.
    /// Returns the tuple elements as tensors (aot.py lowers with
    /// `return_tuple=True`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.args.len() {
            return Err(Error::runtime(format!(
                "artifact '{}' expects {} args, got {}",
                self.meta.name,
                self.meta.args.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&self.meta.args) {
            if t.dims() != spec.shape.as_slice() {
                return Err(Error::runtime(format!(
                    "artifact '{}': arg shape {:?} != manifest {:?}",
                    self.meta.name,
                    t.dims(),
                    spec.shape
                )));
            }
            let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(t.data()).reshape(&dims).map_err(xe)?;
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals).map_err(xe)?;
        let out = result
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::runtime("empty execution result"))?
            .to_literal_sync()
            .map_err(xe)?;
        let mut tensors = Vec::new();
        for lit in out.to_tuple().map_err(xe)? {
            let shape = lit.array_shape().map_err(xe)?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data = lit.to_vec::<f32>().map_err(xe)?;
            tensors.push(Tensor::from_vec(dims, data)?);
        }
        Ok(tensors)
    }
}
